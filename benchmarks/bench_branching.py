"""Table 6: MCTS branching factor B=2 vs B=4.

B=2 (the paper's default, following Coulom/Auer) is more sample-efficient:
wider branching spreads the same sample budget thinner per subtree.
"""
from __future__ import annotations

from repro.core.search import repeat_search

from .common import ABLATION_PLATFORM, BUDGET, REPEATS, emit, grid_upto

WORKLOADS = [
    "llama3_8b_attention", "deepseek_r1_moe", "flux_attention", "flux_conv",
]


def run(budget: int = None, repeats: int = None) -> dict:
    budget = budget or BUDGET
    repeats = repeats or REPEATS
    grid = grid_upto(budget)
    out = {}
    for wname in WORKLOADS:
        for b in (2, 4):
            curve, results = repeat_search(
                wname, ABLATION_PLATFORM, "llm-mcts", budget,
                repeats=repeats, grid=grid, branching=b,
            )
            out[(wname, b)] = curve
            best_t = min(r.best_latency_s for r in results)
            derived = ";".join(f"@{s}={v:.2f}x" for s, v in curve)
            emit(f"table6/{wname}/B{b}", best_t * 1e6, derived)
    return out


if __name__ == "__main__":
    run()
