"""Table 2: end-to-end Llama-3-8B across 5 platforms.

One decoder layer = attention + QKV/O projections + SwiGLU MLP; each
constituent kernel is tuned separately (budget split by its runtime share)
and the end-to-end speedup composes by Amdahl over the pre-optimization
runtime shares — the paper's end-to-end protocol at layer granularity.
"""
from __future__ import annotations

from repro.compiler import CompilerSession
from repro.core.workloads import end_to_end_llama3_workloads

from .common import BUDGET, PAPER_PLATFORMS, REPEATS, emit, geomean


def _e2e(platform: str, method: str, budget: int, repeats: int):
    """Returns (samples_used, end_to_end_speedup) meaned over repeats."""
    parts = end_to_end_llama3_workloads()
    total_s, total_n = [], []
    for seed in range(repeats):
        inv = 0.0
        samples = 0
        for w, share in parts:
            b = max(20, int(budget * share))
            # one-shot session per kernel: the historical run_search
            # semantics (fresh LLM/oracle, no shared context)
            session = CompilerSession(target=platform, method=method,
                                      shared_context=False)
            r = session.search(w, budget=b, seed=seed)
            inv += share / max(r.best_speedup, 1e-9)
            samples += r.samples
        total_s.append(1.0 / inv)
        total_n.append(samples)
    return (sum(total_n) / len(total_n), sum(total_s) / len(total_s))


def run(budget: int = None, repeats: int = None) -> list:
    budget = budget or BUDGET
    repeats = max(1, (repeats or REPEATS) - 1)
    rows = []
    for plat in PAPER_PLATFORMS:
        bn, bs = _e2e(plat, "evolutionary", budget * 4, repeats)
        on, os_ = _e2e(plat, "llm-mcts", budget, repeats)
        red = bn / max(1, on)
        eff = (os_ / on) / (bs / bn)
        rows.append((plat, bn, bs, on, os_, red, eff))
        emit(
            f"table2/{plat}", 0.0,
            f"tvm {bn:.0f}@{bs:.1f}x;ours {on:.0f}@{os_:.1f}x;"
            f"reduction={red:.1f}x;effgain={eff:.1f}x",
        )
    emit(
        "table2/geomean", 0.0,
        f"ours_speedup={geomean([r[4] for r in rows]):.2f}x;"
        f"sample_reduction={geomean([r[5] for r in rows]):.2f}x;"
        f"efficiency_gain={geomean([r[6] for r in rows]):.2f}x",
    )
    return rows


if __name__ == "__main__":
    run()
