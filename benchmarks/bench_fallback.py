"""Table 8: LLM proposal validity and fallback rates by model tier.

A fallback = an expansion in which ALL proposals failed validation, so the
search reverted to the default (random) policy — Appendix G semantics.
Strong models show ~0%; small open models show the high rates the paper
reports (10.5% / 17.2% invalid-proposal probability per mention).
"""
from __future__ import annotations

from repro.compiler import CompilerSession

from .common import ABLATION_PLATFORM, BUDGET, REPEATS, emit

TIERS = [
    "gpt-4o-mini", "o1-mini", "llama3.3-70b", "deepseek-r1-distill-32b",
    "llama3.1-8b", "deepseek-r1-distill-7b",
]


def run(budget: int = None, repeats: int = None) -> dict:
    budget = budget or BUDGET
    repeats = repeats or REPEATS
    out = {}
    for tier in TIERS:
        exp = fb = prop = inv = 0
        for seed in range(repeats):
            # one-shot session per repeat: fresh LLM, fresh oracle, no
            # shared context (the historical run_search semantics)
            session = CompilerSession(
                target=ABLATION_PLATFORM, proposer=tier, method="llm-mcts",
                shared_context=False,
            )
            r = session.search("llama3_8b_attention", budget=budget,
                               seed=seed)
            exp += r.fallback.expansions
            fb += r.fallback.fallbacks
            prop += r.fallback.proposed
            inv += r.fallback.invalid
        rate = fb / max(1, exp)
        inv_rate = inv / max(1, prop)
        out[tier] = rate
        emit(
            f"table8/{tier}", 0.0,
            f"fallback={rate:.2%};invalid_mentions={inv_rate:.2%};"
            f"expansions={exp}",
        )
    return out


if __name__ == "__main__":
    run()
