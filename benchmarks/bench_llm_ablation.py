"""Fig. 4(a) / Table 4: impact of the proposal model's capability tier.

Stronger / instruction-tuned models converge with fewer samples; small open
models still beat uninformed search; a `random` proposal engine collapses to
plain MCTS — confirming the reasoning, not the plumbing, drives the gap.
"""
from __future__ import annotations

from repro.core.search import repeat_search

from .common import ABLATION_PLATFORM, BUDGET, REPEATS, emit, grid_upto

TIERS = [
    "gpt-4o-mini", "o1-mini", "llama3.3-70b", "deepseek-r1-distill-32b",
    "llama3.1-8b", "deepseek-r1-distill-7b", "random",
]
WORKLOADS = [
    "llama3_8b_attention", "deepseek_r1_moe", "flux_attention", "flux_conv",
]


def run(budget: int = None, repeats: int = None) -> dict:
    budget = budget or BUDGET
    repeats = repeats or REPEATS
    grid = grid_upto(budget)
    out = {}
    for wname in WORKLOADS:
        for tier in TIERS:
            curve, results = repeat_search(
                wname, ABLATION_PLATFORM, "llm-mcts", budget,
                repeats=repeats, grid=grid, llm=tier,
            )
            out[(wname, tier)] = curve
            best_t = min(r.best_latency_s for r in results)
            derived = ";".join(f"@{s}={v:.2f}x" for s, v in curve)
            emit(f"table4/{wname}/{tier}", best_t * 1e6, derived)
    return out


if __name__ == "__main__":
    run()
