"""Fig. 4(a) / Table 4: impact of the proposal model's capability tier.

Stronger / instruction-tuned models converge with fewer samples; small open
models still beat uninformed search; a `random` proposal engine collapses to
plain MCTS — confirming the reasoning, not the plumbing, drives the gap.

Runs through the session API (``repro.compiler.CompilerSession``) via the
``sweep_proposer`` harness, which accepts any proposer spec — a tier name
from ``MODEL_TIERS`` *or* a ``pool:`` spec — so the proposer-pool ablation
(``bench_sample_efficiency.run_proposers``) shares the exact same
measurement path as the single-tier sweep here.
"""
from __future__ import annotations

import os

from repro.compiler import CompilerSession
from repro.core.search import mean_curve

from .common import ABLATION_PLATFORM, BUDGET, REPEATS, emit, grid_upto

TIERS = [
    "gpt-4o-mini", "o1-mini", "llama3.3-70b", "deepseek-r1-distill-32b",
    "llama3.1-8b", "deepseek-r1-distill-7b", "random",
]
WORKLOADS = [
    "llama3_8b_attention", "deepseek_r1_moe", "flux_attention", "flux_conv",
]
ORACLE = os.environ.get("REPRO_BENCH_ORACLE", "analytical")


def sweep_proposer(
    spec: str,
    workloads,
    budget: int,
    repeats: int,
    grid,
    summaries: list = None,
) -> dict:
    """One proposer spec (tier name or ``pool:...``) over a workload set.

    One session per repeat owns the proposer (and, for pools, the routing
    + hit-rate state) across all workloads — the deployment shape.  Returns
    ``{workload: (mean_curve, results)}``; each session's end-of-sweep
    ``proposer_summary()`` rows are appended to ``summaries`` when given.
    """
    sessions = [
        CompilerSession(
            target=ABLATION_PLATFORM, oracle=ORACLE, method="llm-mcts",
            proposer=spec, shared_context=False,
        )
        for _ in range(repeats)
    ]
    out = {}
    for wname in workloads:
        results = [
            s.search(wname, budget=budget, seed=seed)
            for seed, s in enumerate(sessions)
        ]
        out[wname] = (mean_curve([r.curve for r in results], grid), results)
    if summaries is not None:
        summaries.extend(s.proposer_summary() for s in sessions)
    return out


def run(budget: int = None, repeats: int = None) -> dict:
    budget = budget or BUDGET
    repeats = repeats or REPEATS
    grid = grid_upto(budget)
    out = {}
    for tier in TIERS:
        swept = sweep_proposer(tier, WORKLOADS, budget, repeats, grid)
        for wname, (curve, results) in swept.items():
            out[(wname, tier)] = curve
            best_t = min(r.best_latency_s for r in results)
            derived = ";".join(f"@{s}={v:.2f}x" for s, v in curve)
            emit(f"table4/{wname}/{tier}", best_t * 1e6, derived)
    return out


if __name__ == "__main__":
    run()
