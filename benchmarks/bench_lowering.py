"""Lowering fidelity: analytical-vs-measured rank correlation.

The analytical oracle is only useful if it *ranks* schedules the way real
execution does (the paper's premise: the search needs faithful feedback,
not absolute microseconds).  This benchmark draws a pool of distinct
schedules per workload, lowers each to its executable kernel
(``core/lowering.py``), verifies numerics against ``kernels/ref.py``, and
reports the Spearman rank correlation between analytical predictions and
measured (interpret-mode on CPU) wall clocks.

A numerics mismatch is a hard failure — a fast wrong kernel must never
enter a rank comparison.  Shapes are CI-sized; ``REPRO_BENCH_LOWERING_N``
scales the schedule pool (>= 16 by default, the EXPERIMENTS.md §Measured
protocol floor).

Runs through the session API: one ``CompilerSession`` owns the measured
oracle (and its schedule/launch-config caches) for the whole pool, so the
timed-kernel count reported at the end reflects the dedup a deployment
would see.
"""
from __future__ import annotations

import os
import random

from repro.compiler import CompilerSession
from repro.core.cost_model import HardwareOracle, get_platform
from repro.core.lowering import LoweringError
from repro.core.schedule import ScheduleError, initial_schedule, random_schedule
from repro.core.surrogate import crossval_rank_predictions
from repro.core.workloads import attention_workload, matmul_workload

from .common import emit, emit_json, spearman

PLATFORM = "tpu-v5e"


def _workloads():
    return [
        matmul_workload("lowering_gemm", m=64, n=256, k=256, dtype_bytes=4,
                        epilogue="swiglu"),
        attention_workload("lowering_attn", heads=2, seq_q=128, seq_kv=128,
                           head_dim=64, dtype_bytes=4),
    ]


def run(n_schedules: int = None) -> dict:
    n = n_schedules or int(os.environ.get("REPRO_BENCH_LOWERING_N", "16"))
    platform = get_platform(PLATFORM)
    analytical = HardwareOracle(platform, noise=False)
    session = CompilerSession(target=PLATFORM, oracle="measured",
                              method="mcts", shared_context=False)
    measured = session.oracle
    out: dict = {}
    spearman_by_backend: dict[str, dict] = {"analytical": {}, "surrogate": {}}
    advantage: dict[str, float] = {}
    for w in _workloads():
        rng = random.Random(0)
        s0 = initial_schedule(w)
        pool = {s0.key(): s0}
        guard = 0
        while len(pool) < n and guard < n * 50:
            guard += 1
            try:
                s = random_schedule(rng, s0, rng.randint(1, 6))
            except ScheduleError:
                continue
            pool.setdefault(s.key(), s)
        xs, ys = [], []
        kinds: dict[str, int] = {}
        scheds = list(pool.values())
        for s in scheds:
            try:
                t = measured.measure(s)  # verifies vs kernels/ref.py first
            except LoweringError as e:  # numerics mismatch = hard failure
                raise AssertionError(f"lowering failed on {w.name}: {e}")
            xs.append(analytical.measure(s))
            ys.append(t)
            k = measured.lower(s).kind
            kinds[k] = kinds.get(k, 0) + 1
        rho = spearman(xs, ys)
        # surrogate rank fidelity on the SAME measured pool, leave-one-out:
        # each schedule is scored by a model trained on the others, so the
        # correlation measures generalization, not memorization
        sur = crossval_rank_predictions(scheds, ys, platform)
        rho_sur = spearman(sur, ys)
        out[w.name] = rho
        spearman_by_backend["analytical"][w.name] = round(rho, 4)
        spearman_by_backend["surrogate"][w.name] = round(rho_sur, 4)
        advantage[w.name] = round(rho_sur - rho, 4)
        emit(
            f"lowering/{w.name}/spearman", min(ys) * 1e6,
            f"rho={rho:.3f};rho_surrogate={rho_sur:.3f};n={len(xs)};"
            f"timed={measured.timed_kernels};"
            f"kinds={'+'.join(f'{k}:{v}' for k, v in sorted(kinds.items()))}",
        )
    emit("lowering/numerics", 0.0,
         f"0 mismatches over {measured.measurements} measurements")
    emit_json("lowering", {
        "pool_size": n,
        "numerics_ok": True,            # a mismatch raised above
        "measurements": measured.measurements,
        "spearman": {k: round(v, 4) for k, v in out.items()},
        "spearman_by_backend": spearman_by_backend,
        # the headline the CI band gates: record-trained surrogate must
        # out-rank the analytical model on every workload (strictly > 0)
        "surrogate_advantage": advantage,
    })
    return out


if __name__ == "__main__":
    run()
