"""Lowering fidelity: analytical-vs-measured rank correlation.

The analytical oracle is only useful if it *ranks* schedules the way real
execution does (the paper's premise: the search needs faithful feedback,
not absolute microseconds).  This benchmark draws a pool of distinct
schedules per workload, lowers each to its executable kernel
(``core/lowering.py``), verifies numerics against ``kernels/ref.py``, and
reports the Spearman rank correlation between analytical predictions and
measured (interpret-mode on CPU) wall clocks.

A numerics mismatch is a hard failure — a fast wrong kernel must never
enter a rank comparison.  Shapes are CI-sized; ``REPRO_BENCH_LOWERING_N``
scales the schedule pool (>= 16 by default, the EXPERIMENTS.md §Measured
protocol floor).

Runs through the session API: one ``CompilerSession`` owns the measured
oracle (and its schedule/launch-config caches) for the whole pool, so the
timed-kernel count reported at the end reflects the dedup a deployment
would see.
"""
from __future__ import annotations

import os
import random

from repro.compiler import CompilerSession
from repro.core.cost_model import HardwareOracle, get_platform
from repro.core.lowering import LoweringError
from repro.core.schedule import ScheduleError, initial_schedule, random_schedule
from repro.core.workloads import attention_workload, matmul_workload

from .common import emit, emit_json

PLATFORM = "tpu-v5e"


def _workloads():
    return [
        matmul_workload("lowering_gemm", m=64, n=256, k=256, dtype_bytes=4,
                        epilogue="swiglu"),
        attention_workload("lowering_attn", heads=2, seq_q=128, seq_kv=128,
                           head_dim=64, dtype_bytes=4),
    ]


def _ranks(xs):
    """Average ranks (ties share their mean rank)."""
    order = sorted(range(len(xs)), key=lambda i: xs[i])
    ranks = [0.0] * len(xs)
    i = 0
    while i < len(order):
        j = i
        while j + 1 < len(order) and xs[order[j + 1]] == xs[order[i]]:
            j += 1
        mean_rank = (i + j) / 2.0
        for k in range(i, j + 1):
            ranks[order[k]] = mean_rank
        i = j + 1
    return ranks


def spearman(xs, ys) -> float:
    n = len(xs)
    if n < 2:
        return 0.0
    rx, ry = _ranks(xs), _ranks(ys)
    mx = sum(rx) / n
    my = sum(ry) / n
    cov = sum((a - mx) * (b - my) for a, b in zip(rx, ry))
    vx = sum((a - mx) ** 2 for a in rx) ** 0.5
    vy = sum((b - my) ** 2 for b in ry) ** 0.5
    if vx == 0 or vy == 0:
        return 0.0
    return cov / (vx * vy)


def run(n_schedules: int = None) -> dict:
    n = n_schedules or int(os.environ.get("REPRO_BENCH_LOWERING_N", "16"))
    analytical = HardwareOracle(get_platform(PLATFORM), noise=False)
    session = CompilerSession(target=PLATFORM, oracle="measured",
                              method="mcts", shared_context=False)
    measured = session.oracle
    out: dict = {}
    for w in _workloads():
        rng = random.Random(0)
        s0 = initial_schedule(w)
        pool = {s0.key(): s0}
        guard = 0
        while len(pool) < n and guard < n * 50:
            guard += 1
            try:
                s = random_schedule(rng, s0, rng.randint(1, 6))
            except ScheduleError:
                continue
            pool.setdefault(s.key(), s)
        xs, ys = [], []
        kinds: dict[str, int] = {}
        for s in pool.values():
            try:
                t = measured.measure(s)  # verifies vs kernels/ref.py first
            except LoweringError as e:  # numerics mismatch = hard failure
                raise AssertionError(f"lowering failed on {w.name}: {e}")
            xs.append(analytical.measure(s))
            ys.append(t)
            k = measured.lower(s).kind
            kinds[k] = kinds.get(k, 0) + 1
        rho = spearman(xs, ys)
        out[w.name] = rho
        emit(
            f"lowering/{w.name}/spearman", min(ys) * 1e6,
            f"rho={rho:.3f};n={len(xs)};timed={measured.timed_kernels};"
            f"kinds={'+'.join(f'{k}:{v}' for k, v in sorted(kinds.items()))}",
        )
    emit("lowering/numerics", 0.0,
         f"0 mismatches over {measured.measurements} measurements")
    emit_json("lowering", {
        "pool_size": n,
        "numerics_ok": True,            # a mismatch raised above
        "measurements": measured.measurements,
        "spearman": {k: round(v, 4) for k, v in out.items()},
    })
    return out


if __name__ == "__main__":
    run()
