"""Table 1: sample efficiency across 5 hardware platforms x 5 kernels.

Per (platform, kernel): ES baseline vs REASONING COMPILER — samples to
converge, speedup, sample reduction, and the speedup/#samples efficiency
gain, with geomeans over all 25 cells.
"""
from __future__ import annotations

from repro.core.search import compare_efficiency, repeat_search
from repro.core.mcts import SearchCurve

from .common import (
    BUDGET,
    PAPER_PLATFORMS,
    PAPER_WORKLOADS,
    REPEATS,
    emit,
    geomean,
    grid_upto,
)


def run(budget: int = None, repeats: int = None) -> list:
    budget = budget or BUDGET
    repeats = repeats or REPEATS
    grid = grid_upto(budget)
    rows = []
    for plat in PAPER_PLATFORMS:
        for wname in PAPER_WORKLOADS:
            base, _ = repeat_search(
                wname, plat, "evolutionary", budget, repeats=repeats,
                grid=grid,
            )
            ours, ours_res = repeat_search(
                wname, plat, "llm-mcts", budget, repeats=repeats, grid=grid,
            )
            cmpr = compare_efficiency(
                SearchCurve(base), SearchCurve(ours), budget
            )
            rows.append((plat, wname, cmpr))
            best_t = min(r.best_latency_s for r in ours_res)
            emit(
                f"table1/{plat}/{wname}", best_t * 1e6,
                f"tvm {cmpr.baseline_samples}@{cmpr.baseline_speedup:.1f}x;"
                f"ours {cmpr.ours_samples}@{cmpr.ours_speedup:.1f}x;"
                f"reduction={cmpr.sample_reduction:.1f}x;"
                f"effgain={cmpr.efficiency_gain:.1f}x",
            )
    emit(
        "table1/geomean", 0.0,
        f"ours_speedup={geomean([c.ours_speedup for _, _, c in rows]):.2f}x;"
        f"sample_reduction="
        f"{geomean([c.sample_reduction for _, _, c in rows]):.2f}x;"
        f"efficiency_gain="
        f"{geomean([c.efficiency_gain for _, _, c in rows]):.2f}x",
    )
    return rows


if __name__ == "__main__":
    run()
