"""Retune ablation: restart-free pickup of retuned kernels (§Retune).

The serve→compile loop end to end, CI-sized: a ``PagedServeEngine`` bound
to an isolated ``ArtifactRegistry`` serves a seeded greedy stream, the
``BackgroundRetuner`` runs one synchronous cycle over the observed shape
distribution (fresh ``TuningRecords`` — every hot shape compiles for
real), and the engine hot-swaps to the published epoch at its next step
boundary.  A control engine with no registry serves the identical stream
for the exactness check.

Gated in ``BENCH_retune.json`` (deterministic counters only — wall-clock
is never gated directly):

  * ``swap_count >= 1``        — the engine adopted a retuned epoch live;
  * ``token_mismatches == 0``  — greedy outputs bit-identical across the
    swap (vs the no-swap control, both phases);
  * ``hot_shape_tuned``        — the hottest observed attention shape has
    a record in the registry's store after the cycle;
  * ``post_latency_ok``        — the post-swap steady-state latency floor
    (min step wall, excluding the first 2 re-trace steps) is within an
    internal 1.25x tolerance of the pre-swap floor.  The min is the
    stable estimator here — medians over ~9 steps of 2-4ms jitter too
    much to gate on; both are emitted, only the floor is rated.

Env knobs (CI defaults in parens): REPRO_RETUNE_ARCH (tinyllama-1.1b),
REPRO_RETUNE_SLOTS (2), REPRO_RETUNE_MAX_NEW (12), REPRO_RETUNE_MAX_LEN
(64), REPRO_RETUNE_BUDGET (8: search samples per retuned task).
"""
from __future__ import annotations

import os
import statistics
import time

import numpy as np

from .common import emit, emit_json

WARMUP_STEPS = 2       # steps dropped from each phase's median (jit trace)
POST_TOL = 1.25        # internal tolerance for post_latency_ok


def _env(name: str, default: int) -> int:
    return int(os.environ.get(name, str(default)))


def _drive(engine, prompts, uid0, max_new):
    """Submit one slot-filling batch and step to drain, timing each
    step; returns (outputs-by-offset-uid, per-step walls)."""
    from repro.serve import Request

    for i, p in enumerate(prompts):
        engine.submit(Request(uid0 + i, p, max_new_tokens=max_new))
    walls, done = [], []
    while engine.queue or engine.active or engine.prefilling:
        t0 = time.perf_counter()
        done.extend(engine.step())
        walls.append(time.perf_counter() - t0)
    return {r.uid - uid0: list(r.output) for r in done}, walls


def _steady(walls):
    """(median, floor) over the post-warmup steps."""
    steady = walls[WARMUP_STEPS:]
    if not steady:
        return float("nan"), float("nan")
    return statistics.median(steady), min(steady)


def run():
    import jax

    from repro.compiler import ArtifactRegistry, local_attention_dims
    from repro.compiler.records import TuningRecords, record_key
    from repro.compiler.tasks import attention_tuning_workload
    from repro.configs import get_config
    from repro.models import model as M
    from repro.obs import Tracer
    from repro.serve import BackgroundRetuner, PagedServeEngine

    arch = os.environ.get("REPRO_RETUNE_ARCH", "tinyllama-1.1b")
    slots = _env("REPRO_RETUNE_SLOTS", 2)
    max_new = _env("REPRO_RETUNE_MAX_NEW", 12)
    max_len = _env("REPRO_RETUNE_MAX_LEN", 64)
    budget = _env("REPRO_RETUNE_BUDGET", 8)

    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    records = TuningRecords(None)                # isolated: all shapes fresh
    registry = ArtifactRegistry(records, platform="core-i9")
    tracer = Tracer()
    engine = PagedServeEngine(cfg, params, slots=slots, max_len=max_len,
                              backend="jax", registry=registry,
                              tracer=tracer)
    control = PagedServeEngine(cfg, params, slots=slots, max_len=max_len,
                               backend="jax")

    rng = np.random.RandomState(11)
    prompts = [rng.randint(4, cfg.vocab, size=int(rng.randint(5, 9)))
               .astype(np.int32) for _ in range(slots)]

    # phase 1: pre-swap serving (epoch 0, default blocks)
    out_pre, walls_pre = _drive(engine, prompts, uid0=0, max_new=max_new)
    ctl_pre, _ = _drive(control, prompts, uid0=0, max_new=max_new)

    # one synchronous retune cycle over the observed distribution
    (hot_attn, _w), = engine.metrics.shapes.top_k("attention", 1)
    t0 = time.perf_counter()
    retuner = BackgroundRetuner(engine, top_k=4, budget=budget)
    cycle = retuner.run_once()
    cycle_s = time.perf_counter() - t0
    hq, hkv = local_attention_dims(cfg, 1)
    hot_key = record_key("core-i9", attention_tuning_workload(
        hq, hot_attn[0], hot_attn[1], cfg.hd, kv_heads=hkv))
    hot_shape_tuned = records.get(hot_key) is not None

    # phase 2: identical stream; the first step adopts the new epoch
    out_post, walls_post = _drive(engine, prompts, uid0=100,
                                  max_new=max_new)
    ctl_post, _ = _drive(control, prompts, uid0=100, max_new=max_new)

    mismatches = sum(out_pre[u] != ctl_pre[u] for u in ctl_pre) \
        + sum(out_post[u] != ctl_post[u] for u in ctl_post) \
        + sum(out_pre[u] != out_post[u] for u in out_pre)
    pre_med, pre_min = _steady(walls_pre)
    post_med, post_min = _steady(walls_post)
    swap_count = engine.metrics.artifact_swaps
    metrics = {
        "swap_count": swap_count,
        "published_epoch": cycle["epoch"] or 0,
        "fresh_records": cycle["fresh"],
        "retuned_tasks": cycle["tasks"],
        "token_mismatches": int(mismatches),
        "hot_shape_tuned": bool(hot_shape_tuned),
        "post_latency_ok": bool(post_min <= pre_min * POST_TOL),
        "pre_swap_decode_ms": round(pre_med * 1e3, 3),
        "post_swap_decode_ms": round(post_med * 1e3, 3),
        "pre_swap_floor_ms": round(pre_min * 1e3, 3),
        "post_swap_floor_ms": round(post_min * 1e3, 3),
        "retune_cycle_s": round(cycle_s, 3),
        "steady_steps": {"pre": len(walls_pre) - WARMUP_STEPS,
                         "post": len(walls_post) - WARMUP_STEPS},
        "workload": {"arch": arch, "slots": slots, "max_new": max_new,
                     "max_len": max_len, "budget": budget},
    }
    emit("retune/pre_swap", pre_med * 1e6,
         f"epoch0 decode median ({metrics['steady_steps']['pre']} steps)")
    emit("retune/post_swap", post_med * 1e6,
         f"epoch{metrics['published_epoch']} decode median "
         f"(swaps={swap_count} fresh={cycle['fresh']} "
         f"mismatches={mismatches})")
    emit("retune/cycle", cycle_s * 1e6,
         f"1 cycle: {cycle['tasks']} tasks, {cycle['fresh']} fresh, "
         f"hot_shape_tuned={hot_shape_tuned}")
    out_dir = os.environ.get("REPRO_BENCH_JSON", "")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tracer.write(os.path.join(out_dir, "retune.trace.json"))
    emit_json("retune", metrics)
    assert mismatches == 0, "greedy outputs diverged across the swap"
    assert swap_count >= 1, "engine never adopted the published epoch"
    return metrics


if __name__ == "__main__":
    run()
