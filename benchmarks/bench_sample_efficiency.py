"""Fig. 3 / Table 3: speedup vs. evaluated samples, 3 methods x 5 kernels.

Reproduces the paper's central result on the ablation platform: the
REASONING COMPILER (llm-mcts) reaches high speedups with far fewer samples
than MCTS and Evolutionary Search, especially in low-budget regimes.

Runs through the session API (``repro.compiler.CompilerSession``): one
session per (method, repeat) owns the LLM + oracle for all five kernels,
so oracle caches persist the way a deployment's would.

``REPRO_BENCH_ORACLE=measured|hybrid`` swaps the reward backend for real
timed kernel executions (core/oracle.py) — paper-protocol runs only: the
paper workload shapes exceed the interpret-mode grid guard on CPU, so the
measured variants need TPU hardware (EXPERIMENTS.md §Measured).

``REPRO_BENCH_SHARED=0|1`` (default both) is the shared-context ablation:
compile a family of sibling attention shapes isolated vs. through one
session's shared context (cross-task trace seeding), and report the
samples each takes to reach the isolated search's best speedup — the
LiteCoOp-style claim that related workloads amortize reasoning.
"""
from __future__ import annotations

import os
import tempfile

from repro.compiler import (
    BudgetPolicy,
    CompilerSession,
    attention_task,
    gemm_task,
)
from repro.core.search import mean_curve

from .common import (
    ABLATION_PLATFORM,
    BUDGET,
    PAPER_WORKLOADS,
    REPEATS,
    emit,
    emit_json,
    geomean,
    grid_upto,
)

METHODS = ["evolutionary", "mcts", "llm-mcts"]
ORACLE = os.environ.get("REPRO_BENCH_ORACLE", "analytical")
SHARED = os.environ.get("REPRO_BENCH_SHARED", "")  # "" = run both arms


def run(budget: int = None, repeats: int = None) -> dict:
    budget = budget or BUDGET
    repeats = repeats or REPEATS
    grid = grid_upto(budget)
    table: dict = {}
    for method in METHODS:
        # one session per (method, repeat): the session owns the LLM and
        # the oracle (with its caches) across all five kernels
        sessions = [
            CompilerSession(
                target=ABLATION_PLATFORM, oracle=ORACLE, method=method,
                shared_context=False,
            )
            for _ in range(repeats)
        ]
        for wname in PAPER_WORKLOADS:
            results = [
                s.search(wname, budget=budget, seed=seed)
                for seed, s in enumerate(sessions)
            ]
            curve = mean_curve([r.curve for r in results], grid)
            table[(wname, method)] = curve
            best_t = min(r.best_latency_s for r in results)
            derived = ";".join(f"@{s}={v:.2f}x" for s, v in curve)
            emit(f"table3/{wname}/{method}", best_t * 1e6, derived)
    # headline check: llm-mcts >= others at the lowest budget point
    wins = sum(
        1 for w in PAPER_WORKLOADS
        if table[(w, "llm-mcts")][0][1]
        >= max(table[(w, "mcts")][0][1],
               table[(w, "evolutionary")][0][1])
    )
    emit("table3/low_budget_wins", 0.0,
         f"llm-mcts best at {grid[0]} samples on {wins}/5 kernels")
    shared_context_curve(budget)
    return table


def shared_context_curve(budget: int) -> dict:
    """Shared-context ablation: sibling shapes isolated vs. one session.

    Family: the llama3-style attention operator at three context lengths.
    The isolated arm searches each shape from scratch; the shared arm
    compiles them through one session, so the longest context's winning
    trace seeds the siblings.  Reported: samples for the sibling to reach
    the isolated search's best speedup (lower = shared context pays).
    """
    arms = ("0", "1") if SHARED not in ("0", "1") else (SHARED,)
    family = [
        attention_task(8, 1024, 1024, 128, kv_heads=2, priority=100),
        attention_task(8, 512, 512, 128, kv_heads=2, priority=50),
        attention_task(8, 256, 256, 128, kv_heads=2, priority=10),
    ]
    out: dict = {}
    iso_best: dict[str, float] = {}
    for arm in sorted(arms):  # isolated first: its bests set the targets
        shared = arm == "1"
        session = CompilerSession(
            target=ABLATION_PLATFORM, oracle=ORACLE, method="llm-mcts",
            shared_context=shared,
            budget_policy=BudgetPolicy(per_task=budget, early_stop=False,
                                       reallocate=shared),
        )
        arts = session.compile(family, force=True)
        for art in arts:
            r = art.result
            name = art.task.workload.name
            dims = f"seq{art.task.workload.loop_map['i'].extent}"
            if not shared:
                iso_best[dims] = r.best_speedup
                reach = r.curve.samples_to_reach(r.best_speedup * 0.999)
            else:
                target = iso_best.get(dims, r.best_speedup)
                reach = r.curve.samples_to_reach(target)
            out[(arm, dims)] = (r.best_speedup, reach)
            emit(
                f"table3/shared_context/{dims}/"
                f"{'shared' if shared else 'isolated'}",
                0.0,
                f"best={r.best_speedup:.2f}x;"
                f"samples_to_isolated_best={reach};"
                f"seeded={bool(art.record.provenance.get('seeded_from'))}",
            )
    return out


def _escalation_backend(spec: str) -> str:
    """Map REPRO_BENCH_ORACLE to the backend the screened arm escalates to.

    ``surrogate:X`` names the escalation explicitly; bare ``surrogate``
    means measured (the ``make_oracle`` default); a plain backend name is
    used as-is.  The unscreened arm always runs that same backend alone,
    so the two arms optimize the identical objective.
    """
    if spec.startswith("surrogate"):
        _, _, esc = spec.partition(":")
        return esc or "measured"
    return spec


def _surrogate_tasks():
    # lowering-bench-sized shapes: small enough that even a measured
    # escalation backend stays inside the interpret-mode grid guard
    return [
        gemm_task(64, 256, 256, epilogue="swiglu", priority=10,
                  label="surrogate smoke gemm"),
        attention_task(2, 128, 128, 64, priority=5,
                       label="surrogate smoke attn"),
    ]


def run_surrogate(budget: int = None) -> dict:
    """Surrogate pre-screening ablation: escalations vs. plain samples.

    Two arms over the same two CI-sized kernels and the same sample
    budget: *plain* runs MCTS where every expansion pays one oracle
    evaluation; *screened* wraps the same backend in the record-trained
    ``SurrogateOracle`` (``surrogate:<backend>``), which ranks a
    ``screen_width`` candidate pool per expansion and escalates only the
    top-1.  Reported (and band-gated by ``BENCH_surrogate.json``): the
    fraction of screened proposals that ever reach compile-and-time
    (``escalation_frac`` — the paper-motivating claim is << 1) and the
    best-speedup ratio screened/plain (must not regress).
    """
    budget = budget or int(os.environ.get("REPRO_BENCH_SURROGATE_BUDGET",
                                          "16"))
    escalate = _escalation_backend(ORACLE)
    arms: dict[str, dict] = {}
    arts_by_arm: dict[str, list] = {}
    for arm, spec in (("plain", escalate),
                      ("screened", f"surrogate:{escalate}")):
        with tempfile.TemporaryDirectory() as tmp:
            session = CompilerSession(
                target="tpu-v5e", oracle=spec, method="mcts",
                records=os.path.join(tmp, "records.jsonl"),
                shared_context=False,
                budget_policy=BudgetPolicy(per_task=budget,
                                           early_stop=False),
                escalate_topk=1, screen_width=8,
            )
            arts = session.compile(_surrogate_tasks(), force=True)
            arts_by_arm[arm] = arts
            info: dict = {
                "best": {a.task.kind: round(a.record.speedup, 4)
                         for a in arts},
                "samples": session.samples_spent,
            }
            if hasattr(session.oracle, "surrogate_provenance"):
                info["surrogate"] = session.oracle.surrogate_provenance()
            arms[arm] = info
    # escalations the screened arm spent to match the plain arm's best
    # (the sample-efficiency headline: screening reaches the unscreened
    # search's quality with fewer compile-and-time calls)
    reach: dict[str, object] = {}
    for plain_art, scr_art in zip(arts_by_arm["plain"],
                                  arts_by_arm["screened"]):
        reach[plain_art.task.kind] = scr_art.result.curve.samples_to_reach(
            plain_art.record.speedup)
    sp = arms["screened"]["surrogate"]
    proposals = max(sp["proposals"], 1)
    frac = sp["escalations"] / proposals
    ratios = [
        arms["screened"]["best"][k] / max(arms["plain"]["best"][k], 1e-9)
        for k in arms["plain"]["best"]
    ]
    best_ratio = geomean(ratios)
    reached = sum(1 for r in ratios if r >= 0.999)
    reach_str = ",".join(f"{k}:{v}" for k, v in sorted(reach.items()))
    emit(
        "surrogate/escalation", 0.0,
        f"backend={escalate};proposals={sp['proposals']};"
        f"escalations={sp['escalations']};frac={frac:.3f};"
        f"plain_samples={arms['plain']['samples']};"
        f"best_ratio={best_ratio:.3f};reached={reached}/{len(ratios)};"
        f"samples_to_plain_best={reach_str};model={sp['version']}",
    )
    emit_json("surrogate", {
        "escalate_backend": escalate,
        "budget": budget,
        "proposals": sp["proposals"],
        "escalations": sp["escalations"],
        "escalation_frac": round(frac, 4),
        "plain_samples": arms["plain"]["samples"],
        "samples_to_plain_best": reach,
        "best_speedup": {
            "plain": arms["plain"]["best"],
            "screened": arms["screened"]["best"],
        },
        "best_ratio": round(best_ratio, 4),
        "reached_plain_best": reached,
        "surrogate_version": sp["version"],
        "train_rows": sp["train_rows"],
    })
    return arms


POOL_SPEC = os.environ.get(
    "REPRO_BENCH_POOL",
    "pool:gpt-4o-mini+llama3.1-8b:reviewer=o1-mini:route=bandit")
POOL_WORKLOADS = ["llama3_8b_attention", "flux_attention"]


def run_proposers(budget: int = None, repeats: int = None) -> dict:
    """Proposer-pool ablation: pool vs. best/worst single member.

    Three-way comparison over the same workloads, budget, and measurement
    harness (``bench_llm_ablation.sweep_proposer``): the routed pool
    (``REPRO_BENCH_POOL``) against each of its members running alone.
    Reported — and band-gated by ``BENCH_proposers.json`` — are the
    ``ge``-gated sample-efficiency claim (the pool reaches the single-best
    member's speedup in no more samples), the per-proposer hit-rate
    counters, the reviewer's veto rate, and the record-provenance gate: a
    pool compile persists ``TuningRecords`` rows whose ``proposer`` field
    names >= 2 distinct drafters.
    """
    from repro.compiler import parse_pool_spec

    from .bench_llm_ablation import sweep_proposer

    budget = budget or int(os.environ.get("REPRO_BENCH_PROPOSERS_BUDGET",
                                          "48"))
    repeats = repeats or int(os.environ.get("REPRO_BENCH_PROPOSERS_REPEATS",
                                            "2"))
    # the full budget is always a grid point: the reach comparison targets
    # each arm's speedup at the END of the sample budget
    grid = sorted(set(grid_upto(budget) + [budget]))
    ps = parse_pool_spec(POOL_SPEC)
    arms = {"pool": POOL_SPEC, **{m: m for m in ps.members}}

    curves: dict[str, dict] = {}
    summaries: dict[str, list] = {}
    for arm, spec in arms.items():
        rows: list = []
        curves[arm] = sweep_proposer(spec, POOL_WORKLOADS, budget, repeats,
                                     grid, summaries=rows)
        summaries[arm] = rows

    # per-workload: the strongest/weakest single member's final speedup,
    # and the samples each arm takes to reach the single-best level
    singles = list(ps.members)
    reach: dict[str, dict] = {}
    final: dict[str, dict] = {}
    pool_le_best = 0
    for wname in POOL_WORKLOADS:
        finals = {arm: curves[arm][wname][0][-1][1]
                  for arm in arms}
        best_single = max(singles, key=lambda m: finals[m])
        worst_single = min(singles, key=lambda m: finals[m])
        target = finals[best_single]
        arm_reach = {}
        for arm in ("pool", best_single, worst_single):
            _, results = curves[arm][wname]
            rs = [r.curve.samples_to_reach(target * 0.999) for r in results]
            got = [s for s in rs if s is not None]
            arm_reach[arm] = round(sum(got) / len(got), 1) if got else None
        ok = arm_reach["pool"] is not None and (
            arm_reach[best_single] is None
            or arm_reach["pool"] <= arm_reach[best_single])
        pool_le_best += bool(ok)
        reach[wname] = {
            "target_speedup": round(target, 4),
            "best_single": best_single,
            "worst_single": worst_single,
            "pool_samples": arm_reach["pool"],
            "best_single_samples": arm_reach[best_single],
            "worst_single_final": round(finals[worst_single], 4),
            "pool_final": round(finals["pool"], 4),
            "pool_reaches_in_no_more_samples": bool(ok),
        }
        final[wname] = {a: round(v, 4) for a, v in finals.items()}
        emit(
            f"proposers/{wname}", 0.0,
            f"pool={finals['pool']:.2f}x@{arm_reach['pool']};"
            f"best_single={best_single}={target:.2f}x"
            f"@{arm_reach[best_single]};"
            f"worst_single={worst_single}={finals[worst_single]:.2f}x;"
            f"pool_le_best={ok}",
        )

    # per-proposer routing/hit-rate counters (summed over the pool arm's
    # sessions) + the reviewer's outcome mix
    proposers: dict[str, dict] = {}
    reviewer: dict = {}
    for rows in summaries["pool"]:
        for row in rows:
            if "reviewer" in row:
                for k in ("reviews", "accepted", "refined", "replaced",
                          "vetoed"):
                    reviewer[k] = reviewer.get(k, 0) + row[k]
                reviewer["name"] = row["reviewer"]
            else:
                agg = proposers.setdefault(
                    row["proposer"],
                    {"cost": row["cost"], "drafted": 0, "measured": 0,
                     "hits": 0},
                )
                for k in ("drafted", "measured", "hits"):
                    agg[k] += row[k]
    for name, agg in proposers.items():
        agg["hit_rate"] = round(agg["hits"] / max(agg["drafted"], 1), 4)
        emit(f"proposers/hit_rate/{name}", 0.0,
             f"drafted={agg['drafted']};hits={agg['hits']};"
             f"hit_rate={agg['hit_rate']}")
    if reviewer:
        reviewer["veto_rate"] = round(
            reviewer["vetoed"] / max(reviewer["reviews"], 1), 4)
        emit("proposers/reviewer", 0.0,
             f"name={reviewer['name']};reviews={reviewer['reviews']};"
             f"veto_rate={reviewer['veto_rate']}")

    # record-provenance gate: a pool compile persists rows whose
    # ``proposer`` field names the drafter (>= 2 distinct across tasks)
    # round-robin drafting here regardless of the ablation's route policy:
    # the gate checks the provenance *plumbing* (every member's drafts can
    # win records), not the routing preference
    rr_spec = ("pool:" + "+".join(ps.members)
               + (f":reviewer={ps.reviewer}" if ps.reviewer else ""))
    with tempfile.TemporaryDirectory() as tmp:
        session = CompilerSession(
            target=ABLATION_PLATFORM, oracle=ORACLE, proposer=rr_spec,
            records=os.path.join(tmp, "records.jsonl"),
            budget_policy=BudgetPolicy(per_task=budget, early_stop=False),
        )
        session.compile([
            attention_task(8, 512, 512, 128, kv_heads=2, priority=10),
            attention_task(8, 256, 256, 128, kv_heads=2, priority=5),
            gemm_task(512, 1024, 1024, epilogue="swiglu", priority=1),
        ], force=True)
        names = {r.proposer for r in session.records.all() if r.proposer}
        schema2 = sum(1 for r in session.records.all() if r.schema >= 2)
    emit("proposers/provenance", 0.0,
         f"distinct_proposers={len(names)};names={sorted(names)};"
         f"schema2_rows={schema2}")

    payload = {
        "pool_spec": POOL_SPEC,
        "budget": budget,
        "repeats": repeats,
        "final_speedup": final,
        "reach": reach,
        "pool_le_best_workloads": pool_le_best,
        "proposers": proposers,
        # flat aggregates for the regression rules (member names contain
        # dots, so per-member dotted rule paths would not resolve)
        "min_hit_rate": min(
            (a["hit_rate"] for a in proposers.values()), default=0.0),
        "total_drafted": sum(a["drafted"] for a in proposers.values()),
        "reviewer": reviewer,
        "distinct_proposers_in_records": len(names),
        "schema2_rows": schema2,
    }
    emit_json("proposers", payload)
    return payload


if __name__ == "__main__":
    run()
