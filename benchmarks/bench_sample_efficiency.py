"""Fig. 3 / Table 3: speedup vs. evaluated samples, 3 methods x 5 kernels.

Reproduces the paper's central result on the ablation platform: the
REASONING COMPILER (llm-mcts) reaches high speedups with far fewer samples
than MCTS and Evolutionary Search, especially in low-budget regimes.

Runs through the session API (``repro.compiler.CompilerSession``): one
session per (method, repeat) owns the LLM + oracle for all five kernels,
so oracle caches persist the way a deployment's would.

``REPRO_BENCH_ORACLE=measured|hybrid`` swaps the reward backend for real
timed kernel executions (core/oracle.py) — paper-protocol runs only: the
paper workload shapes exceed the interpret-mode grid guard on CPU, so the
measured variants need TPU hardware (EXPERIMENTS.md §Measured).

``REPRO_BENCH_SHARED=0|1`` (default both) is the shared-context ablation:
compile a family of sibling attention shapes isolated vs. through one
session's shared context (cross-task trace seeding), and report the
samples each takes to reach the isolated search's best speedup — the
LiteCoOp-style claim that related workloads amortize reasoning.
"""
from __future__ import annotations

import os
import tempfile

from repro.compiler import (
    BudgetPolicy,
    CompilerSession,
    attention_task,
    gemm_task,
)
from repro.core.search import mean_curve

from .common import (
    ABLATION_PLATFORM,
    BUDGET,
    PAPER_WORKLOADS,
    REPEATS,
    emit,
    emit_json,
    geomean,
    grid_upto,
)

METHODS = ["evolutionary", "mcts", "llm-mcts"]
ORACLE = os.environ.get("REPRO_BENCH_ORACLE", "analytical")
SHARED = os.environ.get("REPRO_BENCH_SHARED", "")  # "" = run both arms


def run(budget: int = None, repeats: int = None) -> dict:
    budget = budget or BUDGET
    repeats = repeats or REPEATS
    grid = grid_upto(budget)
    table: dict = {}
    for method in METHODS:
        # one session per (method, repeat): the session owns the LLM and
        # the oracle (with its caches) across all five kernels
        sessions = [
            CompilerSession(
                target=ABLATION_PLATFORM, oracle=ORACLE, method=method,
                shared_context=False,
            )
            for _ in range(repeats)
        ]
        for wname in PAPER_WORKLOADS:
            results = [
                s.search(wname, budget=budget, seed=seed)
                for seed, s in enumerate(sessions)
            ]
            curve = mean_curve([r.curve for r in results], grid)
            table[(wname, method)] = curve
            best_t = min(r.best_latency_s for r in results)
            derived = ";".join(f"@{s}={v:.2f}x" for s, v in curve)
            emit(f"table3/{wname}/{method}", best_t * 1e6, derived)
    # headline check: llm-mcts >= others at the lowest budget point
    wins = sum(
        1 for w in PAPER_WORKLOADS
        if table[(w, "llm-mcts")][0][1]
        >= max(table[(w, "mcts")][0][1],
               table[(w, "evolutionary")][0][1])
    )
    emit("table3/low_budget_wins", 0.0,
         f"llm-mcts best at {grid[0]} samples on {wins}/5 kernels")
    shared_context_curve(budget)
    return table


def shared_context_curve(budget: int) -> dict:
    """Shared-context ablation: sibling shapes isolated vs. one session.

    Family: the llama3-style attention operator at three context lengths.
    The isolated arm searches each shape from scratch; the shared arm
    compiles them through one session, so the longest context's winning
    trace seeds the siblings.  Reported: samples for the sibling to reach
    the isolated search's best speedup (lower = shared context pays).
    """
    arms = ("0", "1") if SHARED not in ("0", "1") else (SHARED,)
    family = [
        attention_task(8, 1024, 1024, 128, kv_heads=2, priority=100),
        attention_task(8, 512, 512, 128, kv_heads=2, priority=50),
        attention_task(8, 256, 256, 128, kv_heads=2, priority=10),
    ]
    out: dict = {}
    iso_best: dict[str, float] = {}
    for arm in sorted(arms):  # isolated first: its bests set the targets
        shared = arm == "1"
        session = CompilerSession(
            target=ABLATION_PLATFORM, oracle=ORACLE, method="llm-mcts",
            shared_context=shared,
            budget_policy=BudgetPolicy(per_task=budget, early_stop=False,
                                       reallocate=shared),
        )
        arts = session.compile(family, force=True)
        for art in arts:
            r = art.result
            name = art.task.workload.name
            dims = f"seq{art.task.workload.loop_map['i'].extent}"
            if not shared:
                iso_best[dims] = r.best_speedup
                reach = r.curve.samples_to_reach(r.best_speedup * 0.999)
            else:
                target = iso_best.get(dims, r.best_speedup)
                reach = r.curve.samples_to_reach(target)
            out[(arm, dims)] = (r.best_speedup, reach)
            emit(
                f"table3/shared_context/{dims}/"
                f"{'shared' if shared else 'isolated'}",
                0.0,
                f"best={r.best_speedup:.2f}x;"
                f"samples_to_isolated_best={reach};"
                f"seeded={bool(art.record.provenance.get('seeded_from'))}",
            )
    return out


def _escalation_backend(spec: str) -> str:
    """Map REPRO_BENCH_ORACLE to the backend the screened arm escalates to.

    ``surrogate:X`` names the escalation explicitly; bare ``surrogate``
    means measured (the ``make_oracle`` default); a plain backend name is
    used as-is.  The unscreened arm always runs that same backend alone,
    so the two arms optimize the identical objective.
    """
    if spec.startswith("surrogate"):
        _, _, esc = spec.partition(":")
        return esc or "measured"
    return spec


def _surrogate_tasks():
    # lowering-bench-sized shapes: small enough that even a measured
    # escalation backend stays inside the interpret-mode grid guard
    return [
        gemm_task(64, 256, 256, epilogue="swiglu", priority=10,
                  label="surrogate smoke gemm"),
        attention_task(2, 128, 128, 64, priority=5,
                       label="surrogate smoke attn"),
    ]


def run_surrogate(budget: int = None) -> dict:
    """Surrogate pre-screening ablation: escalations vs. plain samples.

    Two arms over the same two CI-sized kernels and the same sample
    budget: *plain* runs MCTS where every expansion pays one oracle
    evaluation; *screened* wraps the same backend in the record-trained
    ``SurrogateOracle`` (``surrogate:<backend>``), which ranks a
    ``screen_width`` candidate pool per expansion and escalates only the
    top-1.  Reported (and band-gated by ``BENCH_surrogate.json``): the
    fraction of screened proposals that ever reach compile-and-time
    (``escalation_frac`` — the paper-motivating claim is << 1) and the
    best-speedup ratio screened/plain (must not regress).
    """
    budget = budget or int(os.environ.get("REPRO_BENCH_SURROGATE_BUDGET",
                                          "16"))
    escalate = _escalation_backend(ORACLE)
    arms: dict[str, dict] = {}
    arts_by_arm: dict[str, list] = {}
    for arm, spec in (("plain", escalate),
                      ("screened", f"surrogate:{escalate}")):
        with tempfile.TemporaryDirectory() as tmp:
            session = CompilerSession(
                target="tpu-v5e", oracle=spec, method="mcts",
                records=os.path.join(tmp, "records.jsonl"),
                shared_context=False,
                budget_policy=BudgetPolicy(per_task=budget,
                                           early_stop=False),
                escalate_topk=1, screen_width=8,
            )
            arts = session.compile(_surrogate_tasks(), force=True)
            arts_by_arm[arm] = arts
            info: dict = {
                "best": {a.task.kind: round(a.record.speedup, 4)
                         for a in arts},
                "samples": session.samples_spent,
            }
            if hasattr(session.oracle, "surrogate_provenance"):
                info["surrogate"] = session.oracle.surrogate_provenance()
            arms[arm] = info
    # escalations the screened arm spent to match the plain arm's best
    # (the sample-efficiency headline: screening reaches the unscreened
    # search's quality with fewer compile-and-time calls)
    reach: dict[str, object] = {}
    for plain_art, scr_art in zip(arts_by_arm["plain"],
                                  arts_by_arm["screened"]):
        reach[plain_art.task.kind] = scr_art.result.curve.samples_to_reach(
            plain_art.record.speedup)
    sp = arms["screened"]["surrogate"]
    proposals = max(sp["proposals"], 1)
    frac = sp["escalations"] / proposals
    ratios = [
        arms["screened"]["best"][k] / max(arms["plain"]["best"][k], 1e-9)
        for k in arms["plain"]["best"]
    ]
    best_ratio = geomean(ratios)
    reached = sum(1 for r in ratios if r >= 0.999)
    reach_str = ",".join(f"{k}:{v}" for k, v in sorted(reach.items()))
    emit(
        "surrogate/escalation", 0.0,
        f"backend={escalate};proposals={sp['proposals']};"
        f"escalations={sp['escalations']};frac={frac:.3f};"
        f"plain_samples={arms['plain']['samples']};"
        f"best_ratio={best_ratio:.3f};reached={reached}/{len(ratios)};"
        f"samples_to_plain_best={reach_str};model={sp['version']}",
    )
    emit_json("surrogate", {
        "escalate_backend": escalate,
        "budget": budget,
        "proposals": sp["proposals"],
        "escalations": sp["escalations"],
        "escalation_frac": round(frac, 4),
        "plain_samples": arms["plain"]["samples"],
        "samples_to_plain_best": reach,
        "best_speedup": {
            "plain": arms["plain"]["best"],
            "screened": arms["screened"]["best"],
        },
        "best_ratio": round(best_ratio, 4),
        "reached_plain_best": reached,
        "surrogate_version": sp["version"],
        "train_rows": sp["train_rows"],
    })
    return arms


if __name__ == "__main__":
    run()
