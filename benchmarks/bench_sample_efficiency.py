"""Fig. 3 / Table 3: speedup vs. evaluated samples, 3 methods x 5 kernels.

Reproduces the paper's central result on the ablation platform: the
REASONING COMPILER (llm-mcts) reaches high speedups with far fewer samples
than MCTS and Evolutionary Search, especially in low-budget regimes.

``REPRO_BENCH_ORACLE=measured|hybrid`` swaps the reward backend for real
timed kernel executions (core/oracle.py) — paper-protocol runs only: the
paper workload shapes exceed the interpret-mode grid guard on CPU, so the
measured variants need TPU hardware (EXPERIMENTS.md §Measured).
"""
from __future__ import annotations

import os

from repro.core.search import repeat_search

from .common import (
    ABLATION_PLATFORM,
    BUDGET,
    PAPER_WORKLOADS,
    REPEATS,
    emit,
    grid_upto,
)

METHODS = ["evolutionary", "mcts", "llm-mcts"]
ORACLE = os.environ.get("REPRO_BENCH_ORACLE", "analytical")


def run(budget: int = None, repeats: int = None) -> dict:
    budget = budget or BUDGET
    repeats = repeats or REPEATS
    grid = grid_upto(budget)
    table: dict = {}
    for wname in PAPER_WORKLOADS:
        for method in METHODS:
            curve, results = repeat_search(
                wname, ABLATION_PLATFORM, method, budget,
                repeats=repeats, grid=grid, oracle=ORACLE,
            )
            table[(wname, method)] = curve
            best_t = min(r.best_latency_s for r in results)
            derived = ";".join(f"@{s}={v:.2f}x" for s, v in curve)
            emit(f"table3/{wname}/{method}", best_t * 1e6, derived)
    # headline check: llm-mcts >= others at the lowest budget point
    wins = sum(
        1 for w in PAPER_WORKLOADS
        if table[(w, "llm-mcts")][0][1]
        >= max(table[(w, "mcts")][0][1],
               table[(w, "evolutionary")][0][1])
    )
    emit("table3/low_budget_wins", 0.0,
         f"llm-mcts best at {grid[0]} samples on {wins}/5 kernels")
    return table


if __name__ == "__main__":
    run()
