"""Serving benchmark: dense vs paged engine on one ragged workload.

The serving-side perf number EXPERIMENTS.md §Serve defines: identical
request streams (seeded ragged prompt lengths, greedy decode) are pushed
through the dense ``ServeEngine`` baseline, the ``PagedServeEngine``
(batched bucketed prefill), and the paged engine with chunked prefill;
each emits one CSV row of its ``EngineMetrics`` summary.  The batching win
is directly visible as prefill_calls (jitted admission calls) dropping at
equal-or-better tokens/sec, and paging shows up as mean page occupancy
below the dense cache's 100% slot provisioning.

CI runs a tiny smoke (env knobs below); paper-scale runs raise them:

  REPRO_SERVE_ARCH      (tinyllama-1.1b)  REPRO_SERVE_REQUESTS (8)
  REPRO_SERVE_SLOTS     (4)               REPRO_SERVE_MAX_NEW  (8)
  REPRO_SERVE_MAX_LEN   (128)             REPRO_SERVE_PAGE     (16)
"""
from __future__ import annotations

import os

import numpy as np

from .common import emit


def _env(name: str, default: int) -> int:
    return int(os.environ.get(name, str(default)))


def _requests(cfg, n, max_new):
    from repro.serve import Request

    rng = np.random.RandomState(0)
    out = []
    for uid in range(n):
        plen = int(rng.randint(4, 48))
        out.append(Request(
            uid, rng.randint(0, cfg.vocab, size=plen).astype(np.int32),
            max_new_tokens=max_new,
        ))
    return out


def run() -> None:
    import jax

    from repro.configs import get_config
    from repro.models import model as M
    from repro.serve import PagedServeEngine, ServeEngine

    cfg = get_config(os.environ.get("REPRO_SERVE_ARCH", "tinyllama-1.1b"),
                     smoke=True)
    n_req = _env("REPRO_SERVE_REQUESTS", 8)
    slots = _env("REPRO_SERVE_SLOTS", 4)
    max_new = _env("REPRO_SERVE_MAX_NEW", 8)
    max_len = _env("REPRO_SERVE_MAX_LEN", 128)
    page = _env("REPRO_SERVE_PAGE", 16)
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    engines = {
        "dense": lambda: ServeEngine(
            cfg, params, slots=slots, max_len=max_len),
        "paged": lambda: PagedServeEngine(
            cfg, params, slots=slots, max_len=max_len, page_size=page),
        "paged_chunked": lambda: PagedServeEngine(
            cfg, params, slots=slots, max_len=max_len, page_size=page,
            prefill_chunk=32),
    }
    outputs = {}
    summaries = {}
    for name, build in engines.items():
        eng = build()
        for req in _requests(cfg, n_req, max_new):
            eng.submit(req)
        done = eng.run()
        outputs[name] = {r.uid: r.output for r in done}
        s = summaries[name] = eng.metrics.summary()
        emit(
            f"serving/{name}",
            s["tpot_mean_s"] * 1e6,
            f"tok_s={s['throughput_tok_s']:.2f}"
            f";ttft_ms={s['ttft_mean_s'] * 1e3:.1f}"
            f";requests={s['requests']}"
            f";prefill_calls={s['prefill_calls']}"
            f";chunk_calls={s['prefill_chunk_calls']}"
            f";decode_steps={s['decode_steps']}"
            f";occ={s['kv_occupancy_mean']:.2f}",
        )
    # equivalence + batching-win guardrails: the benchmark doubles as an
    # end-to-end check that every engine variant is exact and the paged
    # path admits the same stream in fewer jitted prefill calls
    for name in ("paged", "paged_chunked"):
        assert outputs[name] == outputs["dense"], f"{name} != dense tokens"
    d, p = summaries["dense"], summaries["paged"]
    assert p["prefill_calls"] <= d["prefill_calls"]
    emit(
        "serving/batching_win",
        0.0,
        f"prefill_calls {d['prefill_calls']}->{p['prefill_calls']}"
        f";tok_s {d['throughput_tok_s']:.2f}->{p['throughput_tok_s']:.2f}",
    )


if __name__ == "__main__":
    run()
