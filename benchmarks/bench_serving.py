"""Serving benchmark: dense vs paged vs prefix-cached engines on one
shared-prompt workload.

The serving-side perf number EXPERIMENTS.md §Serve defines: identical
request streams (seeded, a configurable fraction sharing one long system
prompt, greedy decode) are pushed through

  * the dense ``ServeEngine`` baseline,
  * the ``PagedServeEngine`` (batched bucketed prefill),
  * the paged engine with chunked prefill (batched lanes),
  * the paged engine with the prompt-prefix cache on, and
  * prefix + TTFT-SLO-aware admission,

and each emits one CSV row of its ``EngineMetrics`` summary.  The batching
win is directly visible as prefill_calls (jitted admission calls) dropping
at equal-or-better tokens/sec; prefix caching as *strictly fewer prefill
tokens computed* at a nonzero hit rate; paging as mean page occupancy
below the dense cache's 100% slot provisioning.  Every variant is required
to decode token-identically to dense (asserted below — the benchmark
doubles as an end-to-end exactness check).

CI runs a tiny smoke (env knobs below); paper-scale runs raise them:

  REPRO_SERVE_ARCH      (tinyllama-1.1b)  REPRO_SERVE_REQUESTS (8)
  REPRO_SERVE_SLOTS     (4)               REPRO_SERVE_MAX_NEW  (8)
  REPRO_SERVE_MAX_LEN   (128)             REPRO_SERVE_PAGE     (16)
  REPRO_SERVE_SHARED_LEN (37: shared-prefix tokens, deliberately NOT
  page-aligned so boundary pages exercise copy-on-write)
  REPRO_SERVE_SHARED_FRAC (0.75)          REPRO_SERVE_TTFT_SLO (2.0 s)

With REPRO_BENCH_JSON set, the deterministic counters land in
``BENCH_serving.json`` for the CI regression gate
(benchmarks/check_regression.py).
"""
from __future__ import annotations

import os

import numpy as np

from .common import emit, emit_json


def _env(name: str, default: int) -> int:
    return int(os.environ.get(name, str(default)))


def _requests(cfg, n, max_new, shared_len, shared_frac, page):
    """Seeded stream: ``shared_frac`` of requests start with one common
    ``shared_len``-token system prompt followed by a unique ragged tail;
    the first request's tail spans one extra page so its last
    shared-boundary page is full (later matches hit it partially → the
    copy-on-write path runs)."""
    from repro.serve import Request

    rng = np.random.RandomState(0)
    shared = rng.randint(0, cfg.vocab, size=shared_len).astype(np.int32)
    out, n_shared = [], 0
    for uid in range(n):
        tail_len = int(rng.randint(4, 16)) if uid else page
        tail = rng.randint(0, cfg.vocab, size=tail_len).astype(np.int32)
        if rng.rand() < shared_frac or uid == 0:
            prompt = np.concatenate([shared, tail])
            n_shared += 1
        else:
            prompt = tail
        out.append(Request(uid, prompt, max_new_tokens=max_new))
    return out, n_shared


def run() -> None:
    import jax

    from repro.configs import get_config
    from repro.models import model as M
    from repro.serve import PagedServeEngine, ServeEngine

    cfg = get_config(os.environ.get("REPRO_SERVE_ARCH", "tinyllama-1.1b"),
                     smoke=True)
    n_req = _env("REPRO_SERVE_REQUESTS", 8)
    slots = _env("REPRO_SERVE_SLOTS", 4)
    max_new = _env("REPRO_SERVE_MAX_NEW", 8)
    max_len = _env("REPRO_SERVE_MAX_LEN", 128)
    page = _env("REPRO_SERVE_PAGE", 16)
    shared_len = _env("REPRO_SERVE_SHARED_LEN", 37)
    shared_frac = float(os.environ.get("REPRO_SERVE_SHARED_FRAC", "0.75"))
    ttft_slo = float(os.environ.get("REPRO_SERVE_TTFT_SLO", "2.0"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    engines = {
        "dense": lambda: ServeEngine(
            cfg, params, slots=slots, max_len=max_len),
        "paged": lambda: PagedServeEngine(
            cfg, params, slots=slots, max_len=max_len, page_size=page),
        "paged_chunked": lambda: PagedServeEngine(
            cfg, params, slots=slots, max_len=max_len, page_size=page,
            prefill_chunk=32),
        "paged_prefix": lambda: PagedServeEngine(
            cfg, params, slots=slots, max_len=max_len, page_size=page,
            prefix_cache=True),
        "paged_prefix_slo": lambda: PagedServeEngine(
            cfg, params, slots=slots, max_len=max_len, page_size=page,
            prefix_cache=True, admission="slo", ttft_slo_s=ttft_slo),
    }
    outputs = {}
    summaries = {}
    cow = {}
    for name, build in engines.items():
        eng = build()
        reqs, n_shared = _requests(cfg, n_req, max_new, shared_len,
                                   shared_frac, page)
        for req in reqs:
            eng.submit(req)
        done = eng.run()
        outputs[name] = {r.uid: r.output for r in done}
        s = summaries[name] = eng.metrics.summary()
        cow[name] = getattr(getattr(eng, "kv", None), "cow_copies", 0)
        emit(
            f"serving/{name}",
            s["tpot_mean_s"] * 1e6,
            f"tok_s={s['throughput_tok_s']:.2f}"
            f";ttft_ms={s['ttft_mean_s'] * 1e3:.1f}"
            f";ttft_p99_ms={s['ttft_p99_s'] * 1e3:.1f}"
            f";under_slo={s['ttft_under_slo']:.2f}"
            f";requests={s['requests']}"
            f";prefill_calls={s['prefill_calls']}"
            f";chunk_calls={s['prefill_chunk_calls']}"
            f";prefill_tokens={s['prefill_tokens']}"
            f";hit_rate={s['prefix_hit_rate']:.2f}"
            f";cached_tokens={s['prefix_cached_tokens']}"
            f";decode_steps={s['decode_steps']}"
            f";occ={s['kv_occupancy_mean']:.2f}",
        )
    # equivalence + batching + prefix guardrails: the benchmark doubles as
    # an end-to-end check that every engine variant is exact, the paged
    # path admits the same stream in fewer jitted prefill calls, and the
    # prefix cache computes strictly fewer prefill tokens at a real hit
    # rate — admission order (SLO policy) must never change tokens either
    for name in engines:
        if name == "dense":
            continue
        assert outputs[name] == outputs["dense"], f"{name} != dense tokens"
    d, p = summaries["dense"], summaries["paged"]
    px = summaries["paged_prefix"]
    assert p["prefill_calls"] <= d["prefill_calls"]
    # a hit requires a donor indexed in an EARLIER admission round: with
    # more shared requests than slots, at least one shared prompt admits
    # after its donor finished prefilling (a lone shared prompt, or slots
    # covering the whole stream in round one, legitimately never hits)
    if n_shared > slots:
        assert px["prefill_tokens"] < p["prefill_tokens"], \
            "prefix cache did not skip any prefill compute"
        assert px["prefix_hit_rate"] > 0 and px["prefix_cached_tokens"] > 0
    emit(
        "serving/batching_win",
        0.0,
        f"prefill_calls {d['prefill_calls']}->{p['prefill_calls']}"
        f";tok_s {d['throughput_tok_s']:.2f}->{p['throughput_tok_s']:.2f}",
    )
    emit(
        "serving/prefix_win",
        0.0,
        f"prefill_tokens {p['prefill_tokens']}->{px['prefill_tokens']}"
        f";hit_rate={px['prefix_hit_rate']:.2f}"
        f";cached={px['prefix_cached_tokens']}"
        f";cow_copies={cow['paged_prefix']}",
    )
    emit_json("serving", {
        "workload": {
            "requests": n_req, "slots": slots, "max_new": max_new,
            "max_len": max_len, "page_size": page,
            "shared_len": shared_len, "shared_frac": shared_frac,
        },
        "token_equivalent": True,   # a mismatch asserted above (no emit)
        "engines": {
            name: {
                "requests": s["requests"],
                "prefill_calls": s["prefill_calls"],
                "prefill_chunk_calls": s["prefill_chunk_calls"],
                "prefill_tokens": s["prefill_tokens"],
                "prefix_hit_rate": round(s["prefix_hit_rate"], 4),
                "prefix_cached_tokens": s["prefix_cached_tokens"],
                "decode_steps": s["decode_steps"],
                "cow_copies": cow[name],
                # timing columns ride along for humans; the regression
                # gate only pins the deterministic counters above
                "throughput_tok_s": round(s["throughput_tok_s"], 3),
                "ttft_p50_s": round(s["ttft_p50_s"], 4),
                "ttft_p99_s": round(s["ttft_p99_s"], 4),
                "ttft_under_slo": round(s["ttft_under_slo"], 4),
            }
            for name, s in summaries.items()
        },
    })


if __name__ == "__main__":
    run()
