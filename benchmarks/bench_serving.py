"""Serving benchmark: dense vs paged vs prefix-cached engines on one
shared-prompt workload.

The serving-side perf number EXPERIMENTS.md §Serve defines: identical
request streams (seeded, a configurable fraction sharing one long system
prompt, greedy decode) are pushed through

  * the dense ``ServeEngine`` baseline,
  * the ``PagedServeEngine`` (batched bucketed prefill),
  * the paged engine with chunked prefill (batched lanes),
  * the paged engine with the prompt-prefix cache on, and
  * prefix + TTFT-SLO-aware admission,

and each emits one CSV row of its ``EngineMetrics`` summary.  The batching
win is directly visible as prefill_calls (jitted admission calls) dropping
at equal-or-better tokens/sec; prefix caching as *strictly fewer prefill
tokens computed* at a nonzero hit rate; paging as mean page occupancy
below the dense cache's 100% slot provisioning.  Every variant is required
to decode token-identically to dense (asserted below — the benchmark
doubles as an end-to-end exactness check).

A second, *reasoning-shaped* workload (short prompts off one shared
system prompt, long decodes, bursty Poisson arrivals in engine-step
units) then runs through the paged engine with and without the
speculative draft/verify lane: speculation's win is
``tokens_per_target_call > 1.0`` at a high self-speculative acceptance
rate, at bit-identical tokens (EXPERIMENTS.md §Speculative).

CI runs a tiny smoke (env knobs below); paper-scale runs raise them:

  REPRO_SERVE_ARCH      (tinyllama-1.1b)  REPRO_SERVE_REQUESTS (8)
  REPRO_SERVE_SLOTS     (4)               REPRO_SERVE_MAX_NEW  (8)
  REPRO_SERVE_MAX_LEN   (128)             REPRO_SERVE_PAGE     (16)
  REPRO_SERVE_SHARED_LEN (37: shared-prefix tokens, deliberately NOT
  page-aligned so boundary pages exercise copy-on-write)
  REPRO_SERVE_SHARED_FRAC (0.75)          REPRO_SERVE_TTFT_SLO (2.0 s)
  REPRO_SERVE_REASONING_REQUESTS (6)  REPRO_SERVE_REASONING_SLOTS (2)
  REPRO_SERVE_REASONING_MAX_NEW (24)  REPRO_SERVE_REASONING_MAX_LEN (96)
  REPRO_SERVE_DRAFT_LEN (4: draft tokens per speculative round)
  REPRO_SERVE_TRACE_REPEATS (3: min-of-k walls for the tracing-overhead
  measurement)

A final traced-vs-untraced A/B (warmed engines, identical streams,
min-of-k walls) measures the ``repro.obs`` instrumentation overhead and
gates it in ``BENCH_serving.json``; the traced run's Chrome timeline is
written to ``$REPRO_BENCH_JSON/serving.trace.json`` (CI uploads it).

With REPRO_BENCH_JSON set, the deterministic counters land in
``BENCH_serving.json`` for the CI regression gate
(benchmarks/check_regression.py).
"""
from __future__ import annotations

import os
import time

import numpy as np

from .common import emit, emit_json


def _env(name: str, default: int) -> int:
    return int(os.environ.get(name, str(default)))


def _requests(cfg, n, max_new, shared_len, shared_frac, page):
    """Seeded stream: ``shared_frac`` of requests start with one common
    ``shared_len``-token system prompt followed by a unique ragged tail;
    the first request's tail spans one extra page so its last
    shared-boundary page is full (later matches hit it partially → the
    copy-on-write path runs)."""
    from repro.serve import Request

    rng = np.random.RandomState(0)
    shared = rng.randint(0, cfg.vocab, size=shared_len).astype(np.int32)
    out, n_shared = [], 0
    for uid in range(n):
        tail_len = int(rng.randint(4, 16)) if uid else page
        tail = rng.randint(0, cfg.vocab, size=tail_len).astype(np.int32)
        if rng.rand() < shared_frac or uid == 0:
            prompt = np.concatenate([shared, tail])
            n_shared += 1
        else:
            prompt = tail
        out.append(Request(uid, prompt, max_new_tokens=max_new))
    return out, n_shared


def _reasoning_requests(cfg, n, shared_len, max_new):
    """Reasoning-trace workload shape: every request is one short user
    turn appended to the SAME system prompt (agents re-enter with the
    system prompt cached), tails drawn from a small set of lengths so
    jitted prefill traces stay bounded, and decode runs long — the
    regime where draft/verify speculation pays."""
    from repro.serve import Request

    rng = np.random.RandomState(17)
    shared = rng.randint(0, cfg.vocab, size=shared_len).astype(np.int32)
    reqs = []
    for uid in range(n):
        tail_len = int(rng.choice([4, 8, 12]))
        tail = rng.randint(0, cfg.vocab, size=tail_len).astype(np.int32)
        reqs.append(Request(
            uid, np.concatenate([shared, tail]),
            max_new_tokens=int(rng.choice([max_new, max_new + 8,
                                           max_new + 16])),
        ))
    return reqs


def _bursty_arrivals(n, mean_gap=4.0):
    """Bursty Poisson arrival times in ENGINE-STEP units (deterministic —
    no wall clock): burst starts are exponential gaps apart, each burst
    lands 1 + Poisson(1) requests on the same step."""
    rng = np.random.RandomState(23)
    steps, t = [], 0
    while len(steps) < n:
        t += 1 + int(rng.exponential(mean_gap))
        for _ in range(1 + int(rng.poisson(1.0))):
            if len(steps) < n:
                steps.append(t)
    return steps


def _drive(eng, reqs, arrivals):
    """Arrival-driven serving: request i is submitted once the engine
    has run ``arrivals[i]`` iterations, so bursts queue up behind busy
    slots exactly as a live frontend would deliver them."""
    pending = sorted(zip(arrivals, reqs), key=lambda p: p[0])
    finished, t = [], 0
    while pending or eng.queue or eng.active \
            or getattr(eng, "prefilling", None):
        while pending and pending[0][0] <= t:
            eng.submit(pending.pop(0)[1])
        finished.extend(eng.step())
        t += 1
        assert t < 10_000, "arrival-driven serve did not drain"
    return finished


def _trace_overhead(build, make_reqs, repeats=3):
    """Traced-vs-untraced wall overhead on identical request streams.

    Both engines warm up on TWO full streams first (the second stream
    still compiles fresh chunk-lane shapes once prefix-cache state from
    the first kicks in), then the timed streams run INTERLEAVED —
    off/on/off/on — so slow drift on a CI-shared box (frequency scaling,
    cache warmth) cancels instead of charging whichever variant ran
    last.  The best (min) wall per variant is compared; min-of-k is the
    standard way to strip scheduler noise.  Returns
    ``(overhead_frac, untraced_s, traced_s, tracer)``."""
    from repro.obs import Tracer

    tracer = Tracer()
    eng_off, eng_on = build(None), build(tracer)
    uid = 0

    def serve(eng):
        nonlocal uid
        reqs = make_reqs(uid)
        uid += len(reqs)
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run()
        return time.perf_counter() - t0

    for _ in range(2):                     # warmup (compiles, both streams)
        serve(eng_off), serve(eng_on)
    walls = [(serve(eng_off), serve(eng_on)) for _ in range(repeats)]
    off = min(w for w, _ in walls)
    on = min(w for _, w in walls)
    return on / max(off, 1e-9) - 1.0, off, on, tracer


def run() -> None:
    import jax

    from repro.configs import get_config
    from repro.models import model as M
    from repro.serve import PagedServeEngine, ServeEngine

    cfg = get_config(os.environ.get("REPRO_SERVE_ARCH", "tinyllama-1.1b"),
                     smoke=True)
    n_req = _env("REPRO_SERVE_REQUESTS", 8)
    slots = _env("REPRO_SERVE_SLOTS", 4)
    max_new = _env("REPRO_SERVE_MAX_NEW", 8)
    max_len = _env("REPRO_SERVE_MAX_LEN", 128)
    page = _env("REPRO_SERVE_PAGE", 16)
    shared_len = _env("REPRO_SERVE_SHARED_LEN", 37)
    shared_frac = float(os.environ.get("REPRO_SERVE_SHARED_FRAC", "0.75"))
    ttft_slo = float(os.environ.get("REPRO_SERVE_TTFT_SLO", "2.0"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    engines = {
        "dense": lambda: ServeEngine(
            cfg, params, slots=slots, max_len=max_len),
        "paged": lambda: PagedServeEngine(
            cfg, params, slots=slots, max_len=max_len, page_size=page),
        "paged_chunked": lambda: PagedServeEngine(
            cfg, params, slots=slots, max_len=max_len, page_size=page,
            prefill_chunk=32),
        "paged_prefix": lambda: PagedServeEngine(
            cfg, params, slots=slots, max_len=max_len, page_size=page,
            prefix_cache=True),
        "paged_prefix_slo": lambda: PagedServeEngine(
            cfg, params, slots=slots, max_len=max_len, page_size=page,
            prefix_cache=True, admission="slo", ttft_slo_s=ttft_slo),
    }
    outputs = {}
    summaries = {}
    cow = {}
    for name, build in engines.items():
        eng = build()
        reqs, n_shared = _requests(cfg, n_req, max_new, shared_len,
                                   shared_frac, page)
        for req in reqs:
            eng.submit(req)
        done = eng.run()
        outputs[name] = {r.uid: r.output for r in done}
        s = summaries[name] = eng.metrics.summary()
        cow[name] = getattr(getattr(eng, "kv", None), "cow_copies", 0)
        emit(
            f"serving/{name}",
            s["tpot_mean_s"] * 1e6,
            f"tok_s={s['throughput_tok_s']:.2f}"
            f";ttft_ms={s['ttft_mean_s'] * 1e3:.1f}"
            f";ttft_p99_ms={s['ttft_p99_s'] * 1e3:.1f}"
            f";under_slo={s['ttft_under_slo']:.2f}"
            f";requests={s['requests']}"
            f";prefill_calls={s['prefill_calls']}"
            f";chunk_calls={s['prefill_chunk_calls']}"
            f";prefill_tokens={s['prefill_tokens']}"
            f";hit_rate={s['prefix_hit_rate']:.2f}"
            f";cached_tokens={s['prefix_cached_tokens']}"
            f";decode_steps={s['decode_steps']}"
            f";occ={s['kv_occupancy_mean']:.2f}",
        )
    # equivalence + batching + prefix guardrails: the benchmark doubles as
    # an end-to-end check that every engine variant is exact, the paged
    # path admits the same stream in fewer jitted prefill calls, and the
    # prefix cache computes strictly fewer prefill tokens at a real hit
    # rate — admission order (SLO policy) must never change tokens either
    for name in engines:
        if name == "dense":
            continue
        assert outputs[name] == outputs["dense"], f"{name} != dense tokens"
    d, p = summaries["dense"], summaries["paged"]
    px = summaries["paged_prefix"]
    assert p["prefill_calls"] <= d["prefill_calls"]
    # a hit requires a donor indexed in an EARLIER admission round: with
    # more shared requests than slots, at least one shared prompt admits
    # after its donor finished prefilling (a lone shared prompt, or slots
    # covering the whole stream in round one, legitimately never hits)
    if n_shared > slots:
        assert px["prefill_tokens"] < p["prefill_tokens"], \
            "prefix cache did not skip any prefill compute"
        assert px["prefix_hit_rate"] > 0 and px["prefix_cached_tokens"] > 0
    emit(
        "serving/batching_win",
        0.0,
        f"prefill_calls {d['prefill_calls']}->{p['prefill_calls']}"
        f";tok_s {d['throughput_tok_s']:.2f}->{p['throughput_tok_s']:.2f}",
    )
    emit(
        "serving/prefix_win",
        0.0,
        f"prefill_tokens {p['prefill_tokens']}->{px['prefill_tokens']}"
        f";hit_rate={px['prefix_hit_rate']:.2f}"
        f";cached={px['prefix_cached_tokens']}"
        f";cow_copies={cow['paged_prefix']}",
    )
    # -- reasoning workload: long decodes off one shared system prompt,
    # bursty step-unit arrivals, paged vs +prefix vs +prefix+speculative
    r_req = _env("REPRO_SERVE_REASONING_REQUESTS", 6)
    r_slots = _env("REPRO_SERVE_REASONING_SLOTS", 2)
    r_max_new = _env("REPRO_SERVE_REASONING_MAX_NEW", 24)
    r_max_len = _env("REPRO_SERVE_REASONING_MAX_LEN", 96)
    draft_len = _env("REPRO_SERVE_DRAFT_LEN", 4)
    r_engines = {
        "reasoning_paged": lambda: PagedServeEngine(
            cfg, params, slots=r_slots, max_len=r_max_len,
            page_size=page),
        "reasoning_prefix": lambda: PagedServeEngine(
            cfg, params, slots=r_slots, max_len=r_max_len,
            page_size=page, prefix_cache=True),
        "reasoning_spec": lambda: PagedServeEngine(
            cfg, params, slots=r_slots, max_len=r_max_len,
            page_size=page, prefix_cache=True, speculative=True,
            draft_len=draft_len),
    }
    arrivals = _bursty_arrivals(r_req)
    r_outputs, r_summaries = {}, {}
    for name, build in r_engines.items():
        eng = build()
        reqs = _reasoning_requests(cfg, r_req, shared_len, r_max_new)
        done = _drive(eng, reqs, arrivals)
        r_outputs[name] = {r.uid: r.output for r in done}
        s = r_summaries[name] = eng.metrics.summary()
        emit(
            f"serving/{name}",
            s["tpot_mean_s"] * 1e6,
            f"tok_s={s['throughput_tok_s']:.2f}"
            f";requests={s['requests']}"
            f";decode_tokens={s['decode_tokens']}"
            f";cached_tokens={s['prefix_cached_tokens']}"
            f";acceptance={s['spec_acceptance_rate']:.3f}"
            f";tok_per_target_call={s['tokens_per_target_call']:.3f}"
            f";verify_steps={s['spec_steps']}"
            f";draft_calls={s['draft_calls']}",
        )
    # speculation guardrails: bit-identical tokens, and each per-slot
    # target call must emit MORE than the sequential engine's 1.0 —
    # self-speculative greedy acceptance should be ~perfect
    for name in r_engines:
        assert r_outputs[name] == r_outputs["reasoning_paged"], \
            f"{name} != reasoning_paged tokens"
    sp = r_summaries["reasoning_spec"]
    assert sp["spec_acceptance_rate"] >= 0.9, sp["spec_acceptance_rate"]
    assert sp["tokens_per_target_call"] > 1.0, sp["tokens_per_target_call"]
    if r_req > r_slots:
        assert r_summaries["reasoning_prefix"]["prefix_cached_tokens"] > 0
    emit(
        "serving/speculation_win",
        0.0,
        f"decode_dispatches "
        f"{r_summaries['reasoning_paged']['decode_steps']}"
        f"->{sp['spec_steps']}"
        f";tok_per_target_call={sp['tokens_per_target_call']:.3f}"
        f";acceptance={sp['spec_acceptance_rate']:.3f}",
    )
    # -- tracing overhead: the repro.obs instrumentation must be cheap
    # enough to leave on in perf runs (EXPERIMENTS.md §Observability gates
    # it at <= 5% of wall; the baseline rule adds a noise tolerance)
    def build_traced(tracer):
        return PagedServeEngine(
            cfg, params, slots=slots, max_len=max_len, page_size=page,
            prefix_cache=True, tracer=tracer,
        )

    def make_reqs(uid0):
        reqs, _ = _requests(cfg, n_req, max_new, shared_len,
                            shared_frac, page)
        for r in reqs:
            r.uid += uid0
        return reqs

    overhead, wall_off, wall_on, tracer = _trace_overhead(
        build_traced, make_reqs,
        repeats=_env("REPRO_SERVE_TRACE_REPEATS", 3),
    )
    emit(
        "serving/tracing_overhead",
        wall_on * 1e6,
        f"overhead_frac={overhead:.4f}"
        f";untraced_s={wall_off:.4f};traced_s={wall_on:.4f}"
        f";events={len(tracer.events())}",
    )
    out_dir = os.environ.get("REPRO_BENCH_JSON", "")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tracer.export_chrome(os.path.join(out_dir, "serving.trace.json"))
    emit_json("serving", {
        "tracing": {
            "overhead_frac": round(overhead, 4),
            "untraced_wall_s": round(wall_off, 5),
            "traced_wall_s": round(wall_on, 5),
            "events": len(tracer.events()),
        },
        "reasoning": {
            "workload": {
                "requests": r_req, "slots": r_slots,
                "max_new": r_max_new, "max_len": r_max_len,
                "shared_len": shared_len, "draft_len": draft_len,
                "arrival_steps": arrivals,
            },
            "token_equivalent": True,   # asserted above
            "engines": {
                name: {
                    "requests": s["requests"],
                    "decode_tokens": s["decode_tokens"],
                    "prefill_tokens": s["prefill_tokens"],
                    "prefix_cached_tokens": s["prefix_cached_tokens"],
                    "spec_steps": s["spec_steps"],
                    "spec_acceptance_rate":
                        round(s["spec_acceptance_rate"], 4),
                    "tokens_per_target_call":
                        round(s["tokens_per_target_call"], 4),
                    "draft_calls": s["draft_calls"],
                    "throughput_tok_s": round(s["throughput_tok_s"], 3),
                    "tpot_mean_s": round(s["tpot_mean_s"], 5),
                }
                for name, s in r_summaries.items()
            },
        },
        "workload": {
            "requests": n_req, "slots": slots, "max_new": max_new,
            "max_len": max_len, "page_size": page,
            "shared_len": shared_len, "shared_frac": shared_frac,
        },
        "token_equivalent": True,   # a mismatch asserted above (no emit)
        "engines": {
            name: {
                "requests": s["requests"],
                "prefill_calls": s["prefill_calls"],
                "prefill_chunk_calls": s["prefill_chunk_calls"],
                "prefill_tokens": s["prefill_tokens"],
                "prefix_hit_rate": round(s["prefix_hit_rate"], 4),
                "prefix_cached_tokens": s["prefix_cached_tokens"],
                "decode_steps": s["decode_steps"],
                "cow_copies": cow[name],
                # timing columns ride along for humans; the regression
                # gate only pins the deterministic counters above
                "throughput_tok_s": round(s["throughput_tok_s"], 3),
                "ttft_p50_s": round(s["ttft_p50_s"], 4),
                "ttft_p99_s": round(s["ttft_p99_s"], 4),
                "ttft_under_slo": round(s["ttft_under_slo"], 4),
            }
            for name, s in summaries.items()
        },
    })


if __name__ == "__main__":
    run()
