"""Session-API smoke: the compiler front door, end to end, CI-sized.

Compiles two sibling attention shapes for a real arch through one
``CompilerSession`` with shared context on the deterministic heuristic
LLM, then asserts the deploy-side contract:

* >= 1 record persisted in the JSONL store (with schema + provenance),
* the sibling search was seeded from the donor's winning trace,
* an ``ArtifactSet`` (what engines bind onto ``cfg``) resolves the SAME
  attention blocks the record persisted — i.e. tune-time keys and
  deploy-time keys agree by construction.
"""
from __future__ import annotations

import os
import tempfile

from repro.compiler import (
    ArtifactSet,
    BudgetPolicy,
    CompilerSession,
    TuningRecords,
    attention_task,
    local_attention_dims,
)
from repro.configs import get_config

from .common import emit, emit_json

ARCH = os.environ.get("REPRO_SESSION_ARCH", "tinyllama-1.1b")
BUDGET = int(os.environ.get("REPRO_SESSION_BUDGET", "12"))
TP = int(os.environ.get("REPRO_SESSION_TP", "1"))


def run() -> dict:
    cfg = get_config(ARCH)
    hq, hkv = local_attention_dims(cfg, TP)
    tasks = [
        attention_task(hq, 256, 256, cfg.hd, kv_heads=hkv, priority=10,
                       label=f"{cfg.name} seq=256"),
        attention_task(hq, 128, 128, cfg.hd, kv_heads=hkv,
                       label=f"{cfg.name} seq=128"),
    ]
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "records.jsonl")
        session = CompilerSession(
            target="tpu-v5e", oracle="analytical", proposer="gpt-4o-mini",
            budget_policy=BudgetPolicy(per_task=BUDGET),
            records=path, shared_context=True,
        )
        arts = session.compile(tasks)

        store = TuningRecords(path)  # fresh load: what another process sees
        assert len(store) >= 1, "no records persisted"
        for art in arts:
            rec = store.get(art.record.key)
            assert rec is not None, f"record missing for {art.record.key}"
            assert rec.schema >= 1 and rec.provenance, "provenance missing"
        sib = arts[1].record
        assert sib.provenance.get("seeded_from"), \
            "sibling search was not seeded from the donor trace"

        # deploy-side resolution: the ArtifactSet an engine binds onto cfg
        # must return exactly the blocks the records persisted
        artset = ArtifactSet(store, tp=TP)
        for art, seq in zip(arts, (256, 128)):
            bq, bk = artset.attention_blocks(cfg, seq, seq)
            assert (bq, bk) == (art.blocks.block_q, art.blocks.block_k), \
                f"artifact-resolved blocks {(bq, bk)} != record " \
                f"{(art.blocks.block_q, art.blocks.block_k)} at seq={seq}"

        emit(
            "session/smoke", 0.0,
            f"records={len(store)};samples={session.samples_spent};"
            f"seeds={session.seeds_played};"
            f"blocks@256={arts[0].blocks.block_q}x{arts[0].blocks.block_k}",
        )
        emit_json("session", {
            "records": len(store),
            "samples": session.samples_spent,
            "seeds_played": session.seeds_played,
            "artifacts_resolve": True,   # a mismatch asserted above
        })
        return {"records": len(store), "samples": session.samples_spent}


if __name__ == "__main__":
    run()
