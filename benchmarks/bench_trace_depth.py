"""Fig. 4(b) / Table 5: historical trace depth in the prompt.

Deeper context (parent + grandparent + great-grandparent) sharpens the
model's credit assignment over the visible trajectory -> faster convergence.
"""
from __future__ import annotations

from repro.core.search import repeat_search

from .common import ABLATION_PLATFORM, BUDGET, REPEATS, emit, grid_upto

DEPTHS = {2: "parent+grandparent", 3: "parent+grandparent+great-grandparent"}
WORKLOADS = [
    "llama3_8b_attention", "deepseek_r1_moe", "flux_attention", "flux_conv",
]


def run(budget: int = None, repeats: int = None) -> dict:
    budget = budget or BUDGET
    repeats = repeats or REPEATS
    grid = grid_upto(budget)
    out = {}
    for wname in WORKLOADS:
        for depth, label in DEPTHS.items():
            curve, results = repeat_search(
                wname, ABLATION_PLATFORM, "llm-mcts", budget,
                repeats=repeats, grid=grid, trace_depth=depth,
            )
            out[(wname, depth)] = curve
            best_t = min(r.best_latency_s for r in results)
            derived = ";".join(f"@{s}={v:.2f}x" for s, v in curve)
            emit(f"table5/{wname}/depth{depth}", best_t * 1e6, derived)
    return out


if __name__ == "__main__":
    run()
