"""Bench-regression gate: diff smoke-emitted BENCH_*.json against
checked-in baselines.

CI's bench-smoke job runs the lowering/serving/session smokes with
``REPRO_BENCH_JSON=<dir>`` so each drops a machine-readable
``BENCH_<table>.json``; this gate then compares every baseline under
``benchmarks/baselines/`` against the freshly emitted file and fails the
job on regression.

Baselines are self-describing: each holds the expected ``metrics`` tree
plus a ``rules`` map from dotted metric path to a tolerance-banded
comparison —

  * ``eq`` — exact equality (token-equivalence flags, request counts),
  * ``le`` — current must be <= expected (+tol): lower-is-better counters
    like jitted prefill calls and computed prefill tokens may improve but
    never regress,
  * ``ge`` — current must be >= expected (−tol): higher-is-better numbers
    like the prefix hit rate.

Only deterministic counters carry rules; wall-clock columns ride along in
the artifacts for humans but are never gated (CI machines are noisy).

Usage:

  python -m benchmarks.check_regression --out bench-out
  python -m benchmarks.check_regression --out bench-out --update

``--update`` rewrites each baseline's ``metrics`` from the current run
(rules are preserved) — commit the result when a change legitimately
moves a gated number.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baselines")


def _lookup(tree: dict, path: str):
    cur = tree
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def _check(path: str, rule: dict, got, want) -> str | None:
    """None = pass; otherwise a human-readable failure line."""
    if got is None:
        return f"{path}: missing from current run"
    if want is None:
        return f"{path}: missing from baseline metrics"
    cmp_ = rule.get("cmp", "eq")
    tol = float(rule.get("tol", 0.0))
    if cmp_ == "eq":
        ok = got == want
        detail = f"expected exactly {want!r}"
    elif cmp_ == "le":
        ok = got <= want + tol
        detail = f"must be <= {want}{f' (+{tol})' if tol else ''}"
    elif cmp_ == "ge":
        ok = got >= want - tol
        detail = f"must be >= {want}{f' (-{tol})' if tol else ''}"
    else:
        return f"{path}: unknown cmp {cmp_!r} in baseline rule"
    return None if ok else f"{path}: got {got!r}, {detail}"


def check(out_dir: str, baseline_dir: str = BASELINE_DIR,
          update: bool = False) -> list[str]:
    """Returns the list of failures (empty = green)."""
    failures: list[str] = []
    names = sorted(
        f for f in os.listdir(baseline_dir)
        if f.startswith("BENCH_") and f.endswith(".json")
    )
    if not names:
        return [f"no baselines found under {baseline_dir}"]
    for name in names:
        base_path = os.path.join(baseline_dir, name)
        cur_path = os.path.join(out_dir, name)
        with open(base_path) as f:
            base = json.load(f)
        if not os.path.exists(cur_path):
            failures.append(f"{name}: not emitted by the smoke run "
                            f"(expected {cur_path})")
            continue
        with open(cur_path) as f:
            cur = json.load(f)
        if update:
            base["metrics"] = cur
            with open(base_path, "w") as f:
                json.dump(base, f, indent=2, sort_keys=True)
                f.write("\n")
            print(f"updated {base_path}")
            continue
        table_fail = []
        for path, rule in sorted(base.get("rules", {}).items()):
            err = _check(path, rule, _lookup(cur, path),
                         _lookup(base.get("metrics", {}), path))
            if err:
                table_fail.append(f"  {name}: {err}")
        if table_fail:
            failures.extend(table_fail)
        else:
            print(f"{name}: {len(base.get('rules', {}))} gated metrics OK")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True,
                    help="directory the smokes emitted BENCH_*.json into "
                         "(REPRO_BENCH_JSON)")
    ap.add_argument("--baselines", default=BASELINE_DIR)
    ap.add_argument("--update", action="store_true",
                    help="rewrite baseline metrics from the current run")
    args = ap.parse_args()
    failures = check(args.out, args.baselines, update=args.update)
    if failures:
        print("BENCH REGRESSION:", file=sys.stderr)
        for line in failures:
            print(line, file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
