"""Shared benchmark protocol (paper §4.1).

Every benchmark emits CSV rows ``name,us_per_call,derived`` where
``us_per_call`` is the best-found schedule latency (microseconds, oracle)
and ``derived`` packs the table's headline metrics.  Repeats/budget default
low enough for CI; set REPRO_BENCH_REPEATS / REPRO_BENCH_BUDGET to approach
the paper's 20-repeat protocol.

When ``REPRO_BENCH_JSON`` names a directory, benchmarks additionally drop
one machine-readable ``BENCH_<table>.json`` there via ``emit_json`` —
that is what CI uploads as artifacts and what
``benchmarks/check_regression.py`` diffs against the checked-in baselines
under ``benchmarks/baselines/``.
"""
from __future__ import annotations

import json
import os
import platform
import statistics
import subprocess
import sys

REPEATS = int(os.environ.get("REPRO_BENCH_REPEATS", "3"))
BUDGET = int(os.environ.get("REPRO_BENCH_BUDGET", "600"))

BENCH_SCHEMA_VERSION = 1

PAPER_WORKLOADS = [
    "llama3_8b_attention",
    "deepseek_r1_moe",
    "flux_attention",
    "flux_conv",
    "llama4_scout_mlp",
]
PAPER_PLATFORMS = ["graviton2", "epyc-7r13", "m2-pro", "core-i9", "xeon-e3"]
ABLATION_PLATFORM = "core-i9"  # the paper's dedicated ablation workstation
SAMPLE_GRID = [18, 36, 72, 150, 200, 600, 900, 1632, 3000]


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
    sys.stdout.flush()


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def run_meta() -> dict:
    """Provenance stamped into every BENCH_*.json: schema version plus
    the commit and interpreter that produced the numbers — so an archived
    artifact is attributable without its CI run."""
    return {
        "schema_version": BENCH_SCHEMA_VERSION,
        "git_sha": _git_sha(),
        "python": platform.python_version(),
    }


def emit_json(table: str, payload: dict) -> str | None:
    """Write ``BENCH_<table>.json`` into $REPRO_BENCH_JSON (no-op when the
    env knob is unset).  Returns the written path.  A top-level ``meta``
    key (``run_meta()``) is stamped in unless the payload already carries
    one; metric keys stay top-level so baseline rules' dotted paths keep
    resolving."""
    out_dir = os.environ.get("REPRO_BENCH_JSON", "")
    if not out_dir:
        return None
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{table}.json")
    payload = dict(payload)
    payload.setdefault("meta", run_meta())
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def _ranks(xs):
    """Average ranks (ties share their mean rank)."""
    order = sorted(range(len(xs)), key=lambda i: xs[i])
    ranks = [0.0] * len(xs)
    i = 0
    while i < len(order):
        j = i
        while j + 1 < len(order) and xs[order[j + 1]] == xs[order[i]]:
            j += 1
        mean_rank = (i + j) / 2.0
        for k in range(i, j + 1):
            ranks[order[k]] = mean_rank
        i = j + 1
    return ranks


def spearman(xs, ys) -> float:
    """Spearman rank correlation (shared by the lowering-fidelity and
    surrogate rank-quality benchmarks)."""
    n = len(xs)
    if n < 2:
        return 0.0
    rx, ry = _ranks(xs), _ranks(ys)
    mx = sum(rx) / n
    my = sum(ry) / n
    cov = sum((a - mx) * (b - my) for a, b in zip(rx, ry))
    vx = sum((a - mx) ** 2 for a in rx) ** 0.5
    vy = sum((b - my) ** 2 for b in ry) ** 0.5
    if vx == 0 or vy == 0:
        return 0.0
    return cov / (vx * vy)


def geomean(xs) -> float:
    xs = [max(x, 1e-12) for x in xs]
    return statistics.geometric_mean(xs)


def grid_upto(budget: int):
    return [g for g in SAMPLE_GRID if g <= budget] or [budget]
