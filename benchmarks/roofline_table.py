"""Beyond-paper: the 40-cell roofline table from the dry-run artifact.

Reads artifacts/dryrun.json (produced by ``repro.launch.dryrun``) and emits
one CSV row per (arch x shape x mesh) cell with the three roofline terms,
the dominant bottleneck, and MODEL_FLOPS/HLO_FLOPs — plus a markdown table
for EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import json
import os

from .common import emit

ARTIFACT = os.environ.get(
    "REPRO_DRYRUN_JSON",
    os.path.join(os.path.dirname(__file__), "..", "artifacts",
                 "dryrun.json"),
)


def run(markdown_out: str = None) -> dict:
    if not os.path.exists(ARTIFACT):
        emit("roofline/missing", 0.0,
             f"no dry-run artifact at {ARTIFACT}; run "
             "PYTHONPATH=src python -m repro.launch.dryrun first")
        return {}
    with open(ARTIFACT) as f:
        results = json.load(f)
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s |"
        " dominant | 6ND/HLO | MFU | peak GiB | mb | status |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(results):
        r = results[key]
        if r["status"] == "ok":
            emit(
                f"roofline/{key}", r["step_time_s"] * 1e6
                if "step_time_s" in r
                else max(r["compute_s"], r["memory_s"],
                         r["collective_s"]) * 1e6,
                f"dom={r['dominant']};compute={r['compute_s']:.3g}s;"
                f"memory={r['memory_s']:.3g}s;"
                f"collective={r['collective_s']:.3g}s;"
                f"useful={r['useful_flops_ratio']:.3f};mfu={r['mfu']:.4f}",
            )
            peak = r["bytes_per_device"]["peak_bytes"] / 2**30
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                f"| {r['compute_s']:.3g} | {r['memory_s']:.3g} "
                f"| {r['collective_s']:.3g} | **{r['dominant']}** "
                f"| {r['useful_flops_ratio']:.2f} | {r['mfu']:.3f} "
                f"| {peak:.1f} | {r.get('microbatches', 1)} | ok |"
            )
        elif r["status"] == "skip":
            emit(f"roofline/{key}", 0.0, f"skip:{r['reason'][:60]}")
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - "
                f"| - | - | - | - | - | skip: {r['reason'][:48]} |"
            )
        else:
            emit(f"roofline/{key}", 0.0, f"ERROR:{r.get('error', '')[:80]}")
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - "
                f"| - | - | - | - | - | ERROR |"
            )
    if markdown_out:
        with open(markdown_out, "w") as f:
            f.write("\n".join(lines) + "\n")
    return results


if __name__ == "__main__":
    run()
