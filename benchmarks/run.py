"""Benchmark entry point: one function per paper table.

``PYTHONPATH=src python -m benchmarks.run [table3 table1 ...]``

Emits ``name,us_per_call,derived`` CSV rows.  Default repeats/budget are
CI-sized; set REPRO_BENCH_REPEATS / REPRO_BENCH_BUDGET for paper-scale runs.
"""
from __future__ import annotations

import sys
import time

from . import (
    bench_branching,
    bench_end_to_end,
    bench_fallback,
    bench_llm_ablation,
    bench_lowering,
    bench_platforms,
    bench_retune,
    bench_sample_efficiency,
    bench_serving,
    bench_session,
    bench_trace_depth,
    roofline_table,
)
from .common import emit

TABLES = {
    "table3": bench_sample_efficiency.run,   # Fig 3 / Table 3
    "table1": bench_platforms.run,           # Table 1
    "table2": bench_end_to_end.run,          # Table 2
    "table4": bench_llm_ablation.run,        # Fig 4a / Table 4
    "table5": bench_trace_depth.run,         # Fig 4b / Table 5
    "table6": bench_branching.run,           # Table 6
    "table8": bench_fallback.run,            # Table 8
    "roofline": roofline_table.run,          # beyond-paper: dry-run roofline
    "serving": bench_serving.run,            # beyond-paper: engine TTFT/TPOT
    "lowering": bench_lowering.run,          # beyond-paper: measured-oracle
                                             # rank fidelity vs analytical
    "session": bench_session.run,            # beyond-paper: CompilerSession
                                             # shared-context + artifact smoke
    "surrogate": bench_sample_efficiency.run_surrogate,
                                             # beyond-paper: record-trained
                                             # surrogate pre-screening vs
                                             # plain compile-and-time
    "proposers": bench_sample_efficiency.run_proposers,
                                             # beyond-paper: routed proposer
                                             # pool vs best/worst single
                                             # member (compiler/proposers)
    "retune": bench_retune.run,              # beyond-paper: serve→compile
                                             # loop — live shape retune +
                                             # hot epoch swap (serve/retune)
}


def main() -> None:
    which = sys.argv[1:] or list(TABLES)
    t0 = time.time()
    for name in which:
        fn = TABLES[name]
        t = time.time()
        fn()
        emit(f"{name}/elapsed", (time.time() - t) * 1e6, "wall-time")
    emit("all/elapsed", (time.time() - t0) * 1e6, "wall-time")


if __name__ == "__main__":
    main()
