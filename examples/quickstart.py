"""Quickstart: optimize one kernel with the REASONING COMPILER.

Runs the paper's central comparison on the DeepSeek-R1 MoE GEMM (the exact
workload from the paper's Appendix A prompt) and prints the speedup-vs-
samples curves for Evolutionary Search, plain MCTS, and LLM-guided MCTS.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.search import run_search  # noqa: E402

BUDGET = 150
GRID = [18, 36, 72, 150]


def main():
    print("workload: deepseek_r1_moe  platform: core-i9  "
          f"budget: {BUDGET} samples\n")
    header = f"{'method':14s}" + "".join(f"  @{g:<5d}" for g in GRID)
    print(header)
    print("-" * len(header))
    for method in ("evolutionary", "mcts", "llm-mcts"):
        r = run_search("deepseek_r1_moe", "core-i9", method,
                       budget=BUDGET, seed=0)
        row = f"{method:14s}" + "".join(
            f"  {r.curve.at(g):5.1f}x" for g in GRID
        )
        print(row)
    print("\nbest schedule found by llm-mcts:")
    r = run_search("deepseek_r1_moe", "core-i9", "llm-mcts",
                   budget=BUDGET, seed=0)
    print(r.best_schedule.render())
    print(f"\n{r.best_speedup:.1f}x over the unoptimized program "
          f"in {r.samples} samples")


if __name__ == "__main__":
    main()
