"""Quickstart: optimize one kernel with the REASONING COMPILER.

Runs the paper's central comparison on the DeepSeek-R1 MoE GEMM (the exact
workload from the paper's Appendix A prompt) and prints the speedup-vs-
samples curves for Evolutionary Search, plain MCTS, and LLM-guided MCTS.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.compiler import CompilerSession  # noqa: E402

BUDGET = 150
GRID = [18, 36, 72, 150]


def main():
    print("workload: deepseek_r1_moe  platform: core-i9  "
          f"budget: {BUDGET} samples\n")
    header = f"{'method':14s}" + "".join(f"  @{g:<5d}" for g in GRID)
    print(header)
    print("-" * len(header))
    best = None
    for method in ("evolutionary", "mcts", "llm-mcts"):
        # one session per method: the session owns the LLM and oracle
        session = CompilerSession(target="core-i9", method=method,
                                  shared_context=False)
        r = session.search("deepseek_r1_moe", budget=BUDGET, seed=0)
        row = f"{method:14s}" + "".join(
            f"  {r.curve.at(g):5.1f}x" for g in GRID
        )
        print(row)
        best = r
    print("\nbest schedule found by llm-mcts:")
    print(best.best_schedule.render())
    print(f"\n{best.best_speedup:.1f}x over the unoptimized program "
          f"in {best.samples} samples")


if __name__ == "__main__":
    main()
