"""Serve a small model with batched requests (continuous batching).

    PYTHONPATH=src python examples/serve_llm.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.serve.engine import Request, ServeEngine  # noqa: E402


def main():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, slots=4, max_len=128)

    rng = np.random.RandomState(0)
    n_requests = 12
    for uid in range(n_requests):
        plen = int(rng.randint(8, 24))
        engine.submit(Request(
            uid, rng.randint(0, cfg.vocab, size=plen).astype(np.int32),
            max_new_tokens=12,
        ))
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s on {jax.devices()[0].platform})")
    for r in sorted(done, key=lambda r: r.uid)[:3]:
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.output}")


if __name__ == "__main__":
    main()
