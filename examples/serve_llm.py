"""Serve a small model with the paged-KV batching scheduler.

Requests with ragged prompts are admitted through bucketed *batched*
prefill into a paged KV cache (fixed-size pages + per-slot page tables),
then decoded with continuous batching; the dense baseline engine runs the
identical stream for comparison and must produce identical tokens.

    PYTHONPATH=src python examples/serve_llm.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.serve import (  # noqa: E402
    PagedServeEngine,
    Request,
    ServeEngine,
)


def _submit_all(engine, cfg, n_requests=12, seed=0):
    rng = np.random.RandomState(seed)
    for uid in range(n_requests):
        plen = int(rng.randint(8, 24))
        engine.submit(Request(
            uid, rng.randint(0, cfg.vocab, size=plen).astype(np.int32),
            max_new_tokens=12,
        ))


def main():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    paged = PagedServeEngine(cfg, params, slots=4, max_len=128,
                             page_size=16)
    _submit_all(paged, cfg)
    done = paged.run()
    s = paged.metrics.summary()
    print(f"paged: {s['requests']} requests / {s['generated_tokens']} "
          f"tokens in {s['wall_s']:.2f}s ({s['throughput_tok_s']:.1f} "
          f"tok/s on {jax.devices()[0].platform})")
    print(f"  ttft {s['ttft_mean_s'] * 1e3:.0f}ms  "
          f"tpot {s['tpot_mean_s'] * 1e3:.1f}ms  "
          f"prefill calls {s['prefill_calls']}  "
          f"kv occupancy {s['kv_occupancy_mean']:.2f}")

    dense = ServeEngine(cfg, params, slots=4, max_len=128)
    _submit_all(dense, cfg)
    dense_done = dense.run()
    d = dense.metrics.summary()
    print(f"dense: {d['throughput_tok_s']:.1f} tok/s, "
          f"prefill calls {d['prefill_calls']}")

    same = {r.uid: r.output for r in done} == \
        {r.uid: r.output for r in dense_done}
    print(f"token-identical across engines: {same}")
    for r in sorted(done, key=lambda r: r.uid)[:3]:
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.output}")


if __name__ == "__main__":
    main()
