"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the stablelm-1.6b architecture at reduced width (~100M params), the
deterministic synthetic pipeline, AdamW with cosine schedule, per-layer
remat, checkpointing, and the straggler watchdog — the full training
substrate on whatever devices this host has.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import sys
import tempfile

sys.path.insert(0, "src")

from repro.configs import get_config  # noqa: E402
from repro.configs.base import ShapeSpec  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402
from repro.train.trainer import Trainer, TrainerConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M-param reduction of the stablelm architecture
    cfg = dataclasses.replace(
        get_config("stablelm-1.6b"),
        layers=8, d_model=512, heads=8, kv_heads=8, d_ff=1408,
        vocab=32000, dtype="float32",
    )
    import jax

    n = M.param_count(M.init_params(cfg, jax.random.PRNGKey(0)))
    print(f"model: {cfg.name}-reduced, {n / 1e6:.1f}M params")

    shape = ShapeSpec("local_train", args.seq, args.batch, "train")
    with tempfile.TemporaryDirectory() as ckdir:
        trainer = Trainer(
            cfg, shape,
            TrainerConfig(
                total_steps=args.steps, checkpoint_every=100,
                checkpoint_dir=ckdir, log_every=20, remat="full",
            ),
            opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=30,
                                total_steps=args.steps),
        )
        hist = trainer.run()
    first = sum(h["loss"] for h in hist[:10]) / 10
    last = sum(h["loss"] for h in hist[-10:]) / 10
    print(f"\nloss: {first:.3f} -> {last:.3f} over {len(hist)} steps")
    if trainer.watchdog.events:
        print(f"stragglers flagged: {len(trainer.watchdog.events)}")


if __name__ == "__main__":
    main()
