"""Tune the Pallas flash-attention block shapes for a real architecture.

The Reasoning Compiler searches the TPU-v5e schedule space for
tinyllama-1.1b's attention at 4k context, maps the winning schedule onto
Pallas BlockSpec parameters, validates the tuned kernel against the jnp
oracle in interpret mode, and persists the result in the tuning cache.

    PYTHONPATH=src python examples/tune_attention.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.compiler import (  # noqa: E402
    BudgetPolicy,
    CompilerSession,
    attention_task,
)
from repro.configs import get_config  # noqa: E402
from repro.kernels.flash_attention import flash_attention  # noqa: E402
from repro.kernels.ref import attention_ref  # noqa: E402


def main():
    cfg = get_config("tinyllama-1.1b")
    session = CompilerSession(
        target="tpu-v5e", budget_policy=BudgetPolicy(per_task=48),
        shared_context=False,
    )
    art = session.compile([
        attention_task(cfg.heads, 4096, 4096, cfg.hd,
                       label=f"{cfg.name} attention @4k"),
    ])[0]
    blocks = art.blocks
    print(f"tuned blocks for {cfg.name} attention @4k: "
          f"block_q={blocks.block_q} block_k={blocks.block_k}")

    # validate the tuned kernel on a reduced shape (interpret mode = the
    # Pallas kernel body executed on CPU)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 4, 256, cfg.hd), jnp.float32)
    k = jax.random.normal(ks[1], (1, 1, 256, cfg.hd), jnp.float32)
    v = jax.random.normal(ks[2], (1, 1, 256, cfg.hd), jnp.float32)
    out = flash_attention(
        q, k, v, causal=True,
        block_q=min(blocks.block_q, 64), block_k=min(blocks.block_k, 64),
        interpret=True,
    )
    ref = attention_ref(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"interpret-mode validation vs jnp oracle: max err = {err:.2e}")
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)
    print("OK")


if __name__ == "__main__":
    main()
