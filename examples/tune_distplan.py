"""Beyond-paper: tune a distribution plan with the Reasoning-Compiler-style
hypothesis engine against REAL compiled cells.

Each sample re-lowers a (reduced) train cell on an 8-device mesh and reads
its roofline terms + peak memory from the compiled artifact; the tuner's
reasoned proposals drive the dominant term down (core/distplan.py).
Takes ~2-4 minutes (every sample is an XLA compile — which is exactly why
sample efficiency matters at this level too).

    PYTHONPATH=src python examples/tune_distplan.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys  # noqa: E402

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.distplan import DistPlan, DistPlanTuner, PlanEval  # noqa: E402
from repro.launch import dryrun  # noqa: E402
from repro.roofline.analysis import TPU_V5E, parse_collectives  # noqa: E402

MESH = jax.make_mesh((2, 4), ("data", "model"))
CFG = get_config("tinyllama-1.1b", smoke=True)
SHAPE = "train_4k"
CHIPS = 8
HBM = 6 * 2**30  # scaled-down budget so the toy cell has real pressure


def evaluate(plan: DistPlan) -> PlanEval:
    fn, args, _ = dryrun.build_cell(
        CFG, SHAPE, MESH, microbatches=plan.microbatches,
        remat="full" if plan.remat else "none",
    )
    with MESH:
        compiled = fn.lower(*args).compile()
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    coll = parse_collectives(compiled.as_text(), chips_per_pod=CHIPS)
    peak = mem.temp_size_in_bytes + mem.argument_size_in_bytes
    return PlanEval(
        plan,
        compute_s=float(cost.get("flops", 0)) / TPU_V5E["peak_flops_bf16"],
        memory_s=float(cost.get("bytes accessed", 0)) / TPU_V5E["hbm_bw"],
        collective_s=coll.total_bytes / (3 * TPU_V5E["ici_bw_per_link"]),
        peak_bytes=float(peak),
        fits=peak <= HBM,
    )


def main():
    tuner = DistPlanTuner(evaluate, hbm_bytes=HBM)
    start = DistPlan(microbatches=1, remat=False)
    print(f"tuning {CFG.name} x {SHAPE} on a 2x4 mesh "
          f"(budget: 9 compiles)\n")
    best = tuner.tune(start, budget=9)
    print(tuner.report())
    print(f"\nbest plan: {best.plan}")
    print(f"step roofline {best.step_s:.4g}s "
          f"(dominant: {best.dominant}), "
          f"peak {best.peak_bytes / 2**30:.2f}GiB, fits={best.fits}")


if __name__ == "__main__":
    main()
