"""``repro.compiler`` — the Reasoning Compiler's public session API.

One front door for search, tuning records, and deploy-time artifacts:

    from repro.compiler import CompilerSession, tasks_for_config

    session = CompilerSession(target="tpu-v5e", oracle="analytical",
                              proposer="gpt-4o-mini", budget_policy=64)
    artifacts = session.compile(tasks_for_config(cfg, seq=4096, tp=8))

The session owns one LLM, one oracle (with its caches), and one
``TuningRecords`` database for its lifetime, and compiles related shapes
through a shared search context (cross-task trace seeding + budget
reallocation).  Deploy-time consumers bind an immutable ``ArtifactSet``
epoch through ``ArtifactRegistry.bind(cfg, mesh=...)`` — the single
binding entry point — and engines hot-swap to newly ``publish()``-ed
epochs at step boundaries (``serve/retune.py`` closes that loop).

``artifacts_for_config`` / ``bind_artifacts`` /
``ArchConfig.with_artifacts`` are thin one-release deprecation aliases
over the registry.
"""
from .artifacts import (
    ArtifactRegistry,
    ArtifactSet,
    AttentionBlocks,
    CompiledArtifact,
    GemmBlocks,
    artifacts_for_config,
    bind_artifacts,
    blocks_from_record,
    default_records,
    default_registry,
)
from .context import SeededProposer, SharedContext, TaskOutcome, adapt_history
from .proposers import (
    PooledProposer,
    PoolProposer,
    ProposerPool,
    ReviewTier,
    build_pool,
    is_pool_spec,
    parse_pool_spec,
)
from .records import (
    DEFAULT_RECORDS_PATH,
    LEGACY_JSON_PATH,
    SCHEMA_VERSION,
    TuningRecord,
    TuningRecords,
    migrate_json_cache,
    record_key,
)
from .session import BudgetPolicy, CompilerSession
from .tasks import (
    Task,
    attention_task,
    attention_tuning_workload,
    gemm_task,
    gemm_tuning_workload,
    local_attention_dims,
    tasks_for_config,
    tasks_for_shapes,
)

__all__ = [
    "ArtifactRegistry",
    "ArtifactSet",
    "AttentionBlocks",
    "BudgetPolicy",
    "CompiledArtifact",
    "CompilerSession",
    "DEFAULT_RECORDS_PATH",
    "GemmBlocks",
    "LEGACY_JSON_PATH",
    "PoolProposer",
    "PooledProposer",
    "ProposerPool",
    "ReviewTier",
    "SCHEMA_VERSION",
    "SeededProposer",
    "SharedContext",
    "Task",
    "TaskOutcome",
    "TuningRecord",
    "TuningRecords",
    "adapt_history",
    "artifacts_for_config",
    "attention_task",
    "bind_artifacts",
    "attention_tuning_workload",
    "blocks_from_record",
    "build_pool",
    "default_records",
    "default_registry",
    "is_pool_spec",
    "parse_pool_spec",
    "gemm_task",
    "gemm_tuning_workload",
    "local_attention_dims",
    "migrate_json_cache",
    "record_key",
    "tasks_for_config",
    "tasks_for_shapes",
]
