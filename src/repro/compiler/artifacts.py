"""Deploy-time artifacts: what the rest of the stack consumes.

``CompiledArtifact`` is what ``CompilerSession.compile`` returns per task:
the extracted kernel block parameters, the persisted provenance record,
and (on request) the lowered Pallas kernel itself.

``ArtifactSet`` is the *resolution* object that replaces the old
module-global plumbing (``models.layers.set_active_tp`` + a raw JSON
dict): an engine resolves one at construction against its mesh's TP
degree and threads it through ``cfg`` (``ArchConfig.with_artifacts``), so
every traced attention launch reads its tuned blocks from an explicit,
engine-owned object instead of whatever another engine last wrote into a
global.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..core.lowering import _band_extent, _quantize_block
from ..core.schedule import Schedule, initial_schedule
from .context import adapt_history
from .records import (
    DEFAULT_RECORDS_PATH,
    LEGACY_JSON_PATH,
    TuningRecord,
    TuningRecords,
    record_key,
)
from .tasks import (
    Task,
    attention_tuning_workload,
    gemm_tuning_workload,
    local_attention_dims,
)

# ---------------------------------------------------------------------------
# block parameter extraction (DESIGN.md §3 mapping; moved from
# core/autotuner.py, which re-exports for compatibility)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AttentionBlocks:
    block_q: int = 128
    block_k: int = 128

    @classmethod
    def from_schedule(cls, s: Schedule) -> "AttentionBlocks":
        w = s.workload
        sq = w.loop_map["i"].extent
        skv = w.loop_map["j"].extent
        bq = _quantize_block(_band_extent(s, "i"), sq, lo=8, hi=512)
        bk = _quantize_block(_band_extent(s, "j"), skv, lo=128, hi=1024)
        return cls(block_q=bq, block_k=bk)

    @classmethod
    def from_params(cls, params: dict) -> "AttentionBlocks":
        return cls(params["block_q"], params["block_k"])


@dataclasses.dataclass
class GemmBlocks:
    bm: int = 128
    bn: int = 128
    bk: int = 512

    @classmethod
    def from_schedule(cls, s: Schedule) -> "GemmBlocks":
        w = s.workload
        m = w.loop_map["i"].extent
        n = w.loop_map["j"].extent
        k = w.loop_map["k"].extent
        return cls(
            bm=_quantize_block(_band_extent(s, "i"), m, lo=8, hi=512),
            bn=_quantize_block(_band_extent(s, "j"), n, lo=128, hi=1024),
            bk=_quantize_block(_band_extent(s, "k"), k, lo=128, hi=2048),
        )

    @classmethod
    def from_params(cls, params: dict) -> "GemmBlocks":
        return cls(params["bm"], params["bn"], params["bk"])


def blocks_from_record(rec: TuningRecord):
    if rec.kind == "attention":
        return AttentionBlocks.from_params(rec.params)
    return GemmBlocks.from_params(rec.params)


# ---------------------------------------------------------------------------
# CompiledArtifact
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CompiledArtifact:
    """One compiled task: blocks + provenance (+ optional lowered kernel)."""

    task: Task
    record: TuningRecord
    blocks: object                    # AttentionBlocks | GemmBlocks
    lowered: Optional[object] = None  # core.lowering.Lowered, on request
    cache_hit: bool = False           # True: resolved from records, 0 samples
    # The in-session SearchResult (curve, fallback stats); None on cache
    # hits.  Not persisted — benchmarks/tests read convergence from here.
    result: Optional[object] = None

    @property
    def key(self) -> str:
        return self.record.key

    @property
    def provenance(self) -> dict:
        return self.record.provenance

    def schedule(self) -> Schedule:
        """Reconstruct the winning schedule by replaying the record's
        transform trace on the task's initial program."""
        s = initial_schedule(self.task.workload)
        for t in adapt_history(self.record.history, self.task.workload):
            s = t.apply(s)
        return s

    def lower(self, *, interpret: Optional[bool] = None):
        """Lower the winning schedule to its executable Pallas realization
        (cached on the artifact)."""
        if self.lowered is None:
            from ..core.lowering import lower_schedule

            self.lowered = lower_schedule(
                self.schedule(), interpret=interpret, hardware_floors=True,
            )
        return self.lowered


# ---------------------------------------------------------------------------
# deploy-time resolution
# ---------------------------------------------------------------------------

_DEFAULT_RECORDS: Optional[TuningRecords] = None


def default_records() -> TuningRecords:
    """Process-wide read/write handle on the default record store (the
    sessions' equivalent of the old singleton JSON cache), with the v0
    JSON cache folded in when present."""
    global _DEFAULT_RECORDS
    if _DEFAULT_RECORDS is None:
        _DEFAULT_RECORDS = TuningRecords(
            DEFAULT_RECORDS_PATH, legacy_json=LEGACY_JSON_PATH
        )
    return _DEFAULT_RECORDS


class ArtifactSet:
    """Tuned-block resolver bound to (record store, platform, tp degree).

    Read-only: a miss returns kernel defaults, never launches a search.
    Engines hold one per constructed model (``cfg.with_artifacts``), so
    two engines serving differently-sharded models in one process resolve
    against their *own* TP degree — the race the old ``set_active_tp``
    module global could not express.
    """

    def __init__(self, records: Optional[TuningRecords] = None, *,
                 tp: int = 1, platform: str = "tpu-v5e"):
        self.records = records if records is not None else default_records()
        self.tp = max(1, int(tp))
        self.platform = platform

    def __repr__(self):
        return (f"ArtifactSet(platform={self.platform!r}, tp={self.tp}, "
                f"records={len(self.records)})")

    # -- resolution ---------------------------------------------------------
    def attention_record(self, cfg, seq_q: int, seq_kv: int) \
            -> Optional[TuningRecord]:
        heads, kv_heads = local_attention_dims(cfg, self.tp)
        w = attention_tuning_workload(
            heads, seq_q, seq_kv, cfg.hd, kv_heads=kv_heads
        )
        return self.records.get(record_key(self.platform, w))

    def attention_blocks(self, cfg, seq_q: int, seq_kv: int) \
            -> tuple[int, int]:
        """(block_q, block_k) for an ``ArchConfig`` attention launch under
        this set's TP degree; kernel defaults on a miss."""
        rec = self.attention_record(cfg, seq_q, seq_kv)
        b = AttentionBlocks.from_params(rec.params) if rec \
            else AttentionBlocks()
        return b.block_q, b.block_k

    def gemm_blocks(self, m: int, n: int, k: int,
                    epilogue: str = "none") -> tuple[int, int, int]:
        w = gemm_tuning_workload(m, n, k, epilogue=epilogue)
        rec = self.records.get(record_key(self.platform, w))
        b = GemmBlocks.from_params(rec.params) if rec else GemmBlocks()
        return b.bm, b.bn, b.bk


def artifacts_for_config(
    cfg, *, tp: int = 1, records: Optional[TuningRecords] = None,
    platform: str = "tpu-v5e",
) -> ArtifactSet:
    """The engine-construction front door: resolve the artifact set an
    engine threads through ``cfg`` (``cfg.with_artifacts(...)``)."""
    return ArtifactSet(records, tp=tp, platform=platform)


def bind_artifacts(
    cfg, *, mesh=None, tp: int = 1,
    records: Optional[TuningRecords] = None, platform: str = "tpu-v5e",
) -> tuple:
    """Engine-side binding: ``(bound_cfg, block_tp)``.

    The tp degree comes from the mesh when one is given (matching
    ``dist.sharding``'s axis contract), else from ``tp``; an already-bound
    cfg passes through untouched, so callers constructing engines with a
    pre-resolved artifact set keep it."""
    if mesh is not None:
        from ..dist import sharding as shd

        tp = shd.tp_degree(mesh)
    if getattr(cfg, "artifacts", None) is None:
        cfg = cfg.with_artifacts(
            artifacts_for_config(cfg, tp=tp, records=records,
                                 platform=platform)
        )
    return cfg, tp
