"""Deploy-time artifacts: what the rest of the stack consumes.

``CompiledArtifact`` is what ``CompilerSession.compile`` returns per task:
the extracted kernel block parameters, the persisted provenance record,
and (on request) the lowered Pallas kernel itself.

``ArtifactSet`` is the *resolution* object: an immutable epoch snapshot
of the record store at (platform, tp degree).  ``ArtifactRegistry``
versions those epochs — ``bind(cfg, mesh=...)`` is the one engine-binding
entry point, ``publish()``/``current()`` atomically swap in newly tuned
epochs — so every traced attention launch reads its blocks from an
explicit, engine-owned object, and a background retuner
(``serve/retune.py``) can hand a *running* engine fresh kernels between
decode steps without restart.
"""
from __future__ import annotations

import dataclasses
import threading
import warnings
from typing import Optional

from ..core.lowering import _band_extent, _quantize_block
from ..core.schedule import Schedule, initial_schedule
from .context import adapt_history
from .records import (
    DEFAULT_RECORDS_PATH,
    LEGACY_JSON_PATH,
    TuningRecord,
    TuningRecords,
    record_key,
)
from .tasks import (
    Task,
    attention_tuning_workload,
    gemm_tuning_workload,
    local_attention_dims,
)

# ---------------------------------------------------------------------------
# block parameter extraction (DESIGN.md §3 mapping; moved from
# core/autotuner.py, which re-exports for compatibility)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AttentionBlocks:
    block_q: int = 128
    block_k: int = 128

    @classmethod
    def from_schedule(cls, s: Schedule) -> "AttentionBlocks":
        w = s.workload
        sq = w.loop_map["i"].extent
        skv = w.loop_map["j"].extent
        bq = _quantize_block(_band_extent(s, "i"), sq, lo=8, hi=512)
        bk = _quantize_block(_band_extent(s, "j"), skv, lo=128, hi=1024)
        return cls(block_q=bq, block_k=bk)

    @classmethod
    def from_params(cls, params: dict) -> "AttentionBlocks":
        return cls(params["block_q"], params["block_k"])


@dataclasses.dataclass
class GemmBlocks:
    bm: int = 128
    bn: int = 128
    bk: int = 512

    @classmethod
    def from_schedule(cls, s: Schedule) -> "GemmBlocks":
        w = s.workload
        m = w.loop_map["i"].extent
        n = w.loop_map["j"].extent
        k = w.loop_map["k"].extent
        return cls(
            bm=_quantize_block(_band_extent(s, "i"), m, lo=8, hi=512),
            bn=_quantize_block(_band_extent(s, "j"), n, lo=128, hi=1024),
            bk=_quantize_block(_band_extent(s, "k"), k, lo=128, hi=2048),
        )

    @classmethod
    def from_params(cls, params: dict) -> "GemmBlocks":
        return cls(params["bm"], params["bn"], params["bk"])


def blocks_from_record(rec: TuningRecord):
    if rec.kind == "attention":
        return AttentionBlocks.from_params(rec.params)
    return GemmBlocks.from_params(rec.params)


# ---------------------------------------------------------------------------
# CompiledArtifact
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CompiledArtifact:
    """One compiled task: blocks + provenance (+ optional lowered kernel)."""

    task: Task
    record: TuningRecord
    blocks: object                    # AttentionBlocks | GemmBlocks
    lowered: Optional[object] = None  # core.lowering.Lowered, on request
    cache_hit: bool = False           # True: resolved from records, 0 samples
    # The in-session SearchResult (curve, fallback stats); None on cache
    # hits.  Not persisted — benchmarks/tests read convergence from here.
    result: Optional[object] = None

    @property
    def key(self) -> str:
        return self.record.key

    @property
    def provenance(self) -> dict:
        return self.record.provenance

    def schedule(self) -> Schedule:
        """Reconstruct the winning schedule by replaying the record's
        transform trace on the task's initial program."""
        s = initial_schedule(self.task.workload)
        for t in adapt_history(self.record.history, self.task.workload):
            s = t.apply(s)
        return s

    def lower(self, *, interpret: Optional[bool] = None):
        """Lower the winning schedule to its executable Pallas realization
        (cached on the artifact)."""
        if self.lowered is None:
            from ..core.lowering import lower_schedule

            self.lowered = lower_schedule(
                self.schedule(), interpret=interpret, hardware_floors=True,
            )
        return self.lowered


# ---------------------------------------------------------------------------
# deploy-time resolution: immutable epochs + the registry that swaps them
# ---------------------------------------------------------------------------

_DEFAULT_RECORDS: Optional[TuningRecords] = None


def default_records() -> TuningRecords:
    """Process-wide read/write handle on the default record store (the
    sessions' equivalent of the old singleton JSON cache), with the v0
    JSON cache folded in when present."""
    global _DEFAULT_RECORDS
    if _DEFAULT_RECORDS is None:
        _DEFAULT_RECORDS = TuningRecords(
            DEFAULT_RECORDS_PATH, legacy_json=LEGACY_JSON_PATH
        )
    return _DEFAULT_RECORDS


class ArtifactSet:
    """One immutable artifact *epoch*: a point-in-time tuned-block
    resolver for (records snapshot, platform, tp degree).

    Frozen at construction — the resolver captures the record store's
    contents when built, so a set threaded through an engine's ``cfg``
    can never change underneath a traced kernel launch.  Newly tuned
    records become visible only as a NEW epoch
    (``ArtifactRegistry.publish()``), which engines adopt atomically at a
    step boundary.  A miss resolves to kernel defaults, never a search.
    """

    __slots__ = ("records", "tp", "platform", "epoch", "_sealed")

    def __init__(self, records=None, *,
                 tp: int = 1, platform: str = "tpu-v5e", epoch: int = 0):
        store = records if records is not None else default_records()
        if isinstance(store, dict):
            snap = dict(store)
        else:
            snap = {k: store.get(k) for k in store.keys()}
        self.records = snap              # {record key: TuningRecord}
        self.tp = max(1, int(tp))
        self.platform = platform
        self.epoch = int(epoch)
        self._sealed = True

    def __setattr__(self, name, value):
        if getattr(self, "_sealed", False):
            raise AttributeError(
                f"ArtifactSet is an immutable epoch; cannot set {name!r} "
                f"(publish a new epoch through ArtifactRegistry instead)"
            )
        object.__setattr__(self, name, value)

    def __repr__(self):
        return (f"ArtifactSet(platform={self.platform!r}, tp={self.tp}, "
                f"epoch={self.epoch}, records={len(self.records)})")

    # -- resolution ---------------------------------------------------------
    def attention_record(self, cfg, seq_q: int, seq_kv: int) \
            -> Optional[TuningRecord]:
        heads, kv_heads = local_attention_dims(cfg, self.tp)
        w = attention_tuning_workload(
            heads, seq_q, seq_kv, cfg.hd, kv_heads=kv_heads
        )
        return self.records.get(record_key(self.platform, w))

    def attention_blocks(self, cfg, seq_q: int, seq_kv: int) \
            -> tuple[int, int]:
        """(block_q, block_k) for an ``ArchConfig`` attention launch under
        this set's TP degree; kernel defaults on a miss."""
        rec = self.attention_record(cfg, seq_q, seq_kv)
        b = AttentionBlocks.from_params(rec.params) if rec \
            else AttentionBlocks()
        return b.block_q, b.block_k

    def gemm_blocks(self, m: int, n: int, k: int,
                    epilogue: str = "none") -> tuple[int, int, int]:
        w = gemm_tuning_workload(m, n, k, epilogue=epilogue)
        rec = self.records.get(record_key(self.platform, w))
        b = GemmBlocks.from_params(rec.params) if rec else GemmBlocks()
        return b.bm, b.bn, b.bk


class ArtifactRegistry:
    """Versioned artifact epochs over one record store — THE engine
    binding surface, and the publication side of the serve→compile loop.

      * ``bind(cfg, mesh=..., tp=...)`` — the one documented engine entry
        point (replaces the deprecated ``bind_artifacts`` /
        ``artifacts_for_config`` free functions): resolves the current
        epoch at the caller's TP degree, pins it, and returns
        ``(bound_cfg, tp)``.
      * ``publish()`` — snapshot the record store into a new immutable
        ``ArtifactSet`` epoch and atomically make it ``current()``; a
        background retuner (``serve/retune.py``) calls this after a
        ``CompilerSession.compile`` cycle, and engines hot-swap to the
        new epoch between decode steps without restart.
      * ``pin``/``unpin`` — epoch refcounts: a pinned epoch stays
        resolvable (``get``) across later publishes, so an engine
        mid-step keeps its bound epoch alive until its own step boundary;
        at refcount zero a superseded epoch is dropped.

    All state transitions hold one lock, so ``publish`` vs
    ``current``/``acquire`` is atomic and no reader ever observes a
    half-swapped epoch.
    """

    def __init__(self, records: Optional[TuningRecords] = None, *,
                 platform: str = "tpu-v5e"):
        self.records = records if records is not None else default_records()
        self.platform = platform
        self._lock = threading.Lock()
        self._epoch = 0
        self._snapshots: dict[int, dict] = {0: self._snap()}
        self._pins: dict[int, int] = {0: 0}
        self._sets: dict[tuple[int, int], ArtifactSet] = {}

    def _snap(self) -> dict:
        return {k: self.records.get(k) for k in self.records.keys()}

    def __repr__(self):
        return (f"ArtifactRegistry(platform={self.platform!r}, "
                f"epoch={self._epoch}, live_epochs={len(self._snapshots)})")

    @property
    def epoch(self) -> int:
        """The current (latest-published) epoch number."""
        return self._epoch

    # -- epoch lifecycle ----------------------------------------------------
    def publish(self) -> int:
        """Snapshot the record store as the next epoch and atomically make
        it current.  Returns the new epoch number.  Superseded epochs
        survive exactly as long as someone holds a pin on them."""
        with self._lock:
            prev = self._epoch
            self._epoch += 1
            self._snapshots[self._epoch] = self._snap()
            self._pins.setdefault(self._epoch, 0)
            self._gc(prev)
            return self._epoch

    def current(self, *, tp: int = 1) -> ArtifactSet:
        """The latest published epoch's resolver at ``tp``."""
        with self._lock:
            return self._set(self._epoch, tp)

    def get(self, epoch: int, *, tp: int = 1) -> ArtifactSet:
        """A specific epoch's resolver; raises ``KeyError`` once the epoch
        has been superseded and fully unpinned."""
        with self._lock:
            if epoch not in self._snapshots:
                raise KeyError(
                    f"artifact epoch {epoch} has been released "
                    f"(current is {self._epoch})"
                )
            return self._set(epoch, tp)

    def acquire(self, *, tp: int = 1) -> ArtifactSet:
        """Atomically resolve AND pin the current epoch (the engine-swap
        primitive: pin-new-then-unpin-old can never lose the epoch to a
        concurrent publish)."""
        with self._lock:
            art = self._set(self._epoch, tp)
            self._pins[art.epoch] = self._pins.get(art.epoch, 0) + 1
            return art

    def pin(self, epoch: int) -> int:
        """Increment an epoch's refcount; returns the new count."""
        with self._lock:
            if epoch not in self._snapshots:
                raise KeyError(f"artifact epoch {epoch} has been released")
            self._pins[epoch] = self._pins.get(epoch, 0) + 1
            return self._pins[epoch]

    def unpin(self, epoch: int) -> int:
        """Decrement an epoch's refcount; at zero a superseded epoch (and
        its cached resolvers) is dropped.  Returns the new count."""
        with self._lock:
            n = self._pins.get(epoch, 0)
            if n <= 0:
                raise ValueError(f"artifact epoch {epoch} is not pinned")
            self._pins[epoch] = n - 1
            self._gc(epoch)
            return self._pins.get(epoch, 0)

    def pins(self, epoch: int) -> int:
        """Current refcount for an epoch (0 for unknown/released)."""
        with self._lock:
            return self._pins.get(epoch, 0)

    def _gc(self, epoch: int) -> None:
        # lock held: a superseded epoch with no pins is unreachable by
        # contract (engines re-resolve through current/acquire)
        if epoch != self._epoch and self._pins.get(epoch, 0) <= 0:
            self._snapshots.pop(epoch, None)
            self._pins.pop(epoch, None)
            for key in [k for k in self._sets if k[0] == epoch]:
                del self._sets[key]

    def _set(self, epoch: int, tp: int) -> ArtifactSet:
        # lock held
        tp = max(1, int(tp))
        key = (epoch, tp)
        art = self._sets.get(key)
        if art is None:
            art = self._sets[key] = ArtifactSet(
                self._snapshots[epoch], tp=tp, platform=self.platform,
                epoch=epoch,
            )
        return art

    # -- engine binding -----------------------------------------------------
    def bind(self, cfg, *, mesh=None, tp: int = 1) -> tuple:
        """Bind the current epoch onto ``cfg``: ``(bound_cfg, block_tp)``.

        The single engine-binding entry point.  The tp degree comes from
        the mesh when one is given (matching ``dist.sharding``'s axis
        contract), else from ``tp``.  An already-bound cfg passes through
        untouched, so callers constructing engines with a pre-resolved
        artifact set keep it.  The bound epoch is pinned: it stays
        resolvable for this engine until it unpins on its next swap.
        """
        if mesh is not None:
            from ..dist import sharding as shd

            tp = shd.tp_degree(mesh)
        if getattr(cfg, "artifacts", None) is None:
            cfg = dataclasses.replace(cfg, artifacts=self.acquire(tp=tp))
        return cfg, tp


_DEFAULT_REGISTRY: Optional[ArtifactRegistry] = None


def default_registry() -> ArtifactRegistry:
    """Process-wide registry over ``default_records()`` — what the
    deprecated free-function binding path resolves against."""
    global _DEFAULT_REGISTRY
    if _DEFAULT_REGISTRY is None:
        _DEFAULT_REGISTRY = ArtifactRegistry(default_records())
    return _DEFAULT_REGISTRY


def artifacts_for_config(
    cfg, *, tp: int = 1, records: Optional[TuningRecords] = None,
    platform: str = "tpu-v5e",
) -> ArtifactSet:
    """.. deprecated:: resolve through ``ArtifactRegistry`` instead
    (``registry.current(tp=...)`` or ``registry.bind(cfg, ...)``) so the
    set is a versioned epoch the engine can hot-swap."""
    warnings.warn(
        "artifacts_for_config is deprecated; use "
        "ArtifactRegistry.current(tp=...) / ArtifactRegistry.bind(cfg, ...)",
        DeprecationWarning, stacklevel=2,
    )
    return ArtifactSet(records, tp=tp, platform=platform)


def bind_artifacts(
    cfg, *, mesh=None, tp: int = 1,
    records: Optional[TuningRecords] = None, platform: str = "tpu-v5e",
) -> tuple:
    """.. deprecated:: thin alias over ``ArtifactRegistry.bind`` (one
    release): same ``(bound_cfg, block_tp)`` contract, but the bound set
    is a registry epoch — new callers should hold the registry so they
    can also ``publish()``/hot-swap."""
    warnings.warn(
        "bind_artifacts is deprecated; use ArtifactRegistry.bind(cfg, "
        "mesh=..., tp=...)",
        DeprecationWarning, stacklevel=2,
    )
    if records is None and platform == "tpu-v5e":
        reg = default_registry()
    else:
        reg = ArtifactRegistry(records, platform=platform)
    return reg.bind(cfg, mesh=mesh, tp=tp)
