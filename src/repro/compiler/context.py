"""Cross-task shared search context (LiteCoOp-style trace seeding).

The paper frames optimization as a sequential, context-aware decision
process; a serving stack compiles *families* of related shapes (the same
attention operator at several context lengths, the same GEMM at several
token tiles), and searching each from scratch throws the accumulated
context away.  This module keeps it:

* ``SharedContext`` records, per task family, the winning transform trace,
  the runner-up traces, and plateau statistics (which transform families
  helped / hurt) of every compiled task.
* ``adapt_history`` replays a donor trace onto a *sibling* workload,
  rescaling tile decisions to the sibling's loop extents and dropping
  whatever stays illegal — the schedule-space analog of transferring a
  reasoning tree between related workloads.
* ``SeededProposer`` wraps the session's ``LLMProposer``: the first
  expansions of a sibling search replay the adapted donor traces (so the
  tree starts from a known-good region instead of ``p_0``), and every
  later prompt carries a "Cross-task context" section plus a structured
  prefer/avoid bias distilled from the donor's plateau statistics.
"""
from __future__ import annotations

import dataclasses
import math
import random
from collections import deque
from typing import Optional, Sequence

from ..core.llm import (
    LLMProposer,
    Proposal,
    TraceEntry,
    _CALL_RE,
    _FAMILIES,
    _materialize,
    _parse_args,
)
from ..core.schedule import (
    Schedule,
    ScheduleError,
    Transform,
    initial_schedule,
)
from .tasks import Task

# ---------------------------------------------------------------------------
# donor records
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ContextHint:
    """Structured cross-task bias handed to the proposal engine."""

    prefer: frozenset = frozenset()    # transform families that improved
    avoid: frozenset = frozenset()     # transform families that regressed
    note: str = ""                     # prose for the prompt text

    def render(self) -> str:
        parts = ["Cross-task context (from an already-compiled sibling "
                 "workload):"]
        if self.note:
            parts.append(self.note)
        if self.prefer:
            parts.append(
                f"Transformation families that improved the sibling: "
                f"{', '.join(sorted(self.prefer))}."
            )
        if self.avoid:
            parts.append(
                f"Families that regressed it: {', '.join(sorted(self.avoid))}."
            )
        return "\n".join(parts) + "\n"


@dataclasses.dataclass
class TaskOutcome:
    """What one compiled task contributes to its family's shared context."""

    family: str
    workload_name: str
    dims: dict
    best_speedup: float
    samples: int
    samples_to_best: int
    history: tuple                      # winning transform trace
    top_histories: tuple = ()           # runner-up traces, best first
    prefer: frozenset = frozenset()
    avoid: frozenset = frozenset()

    def hint(self) -> ContextHint:
        """The prompt-ready distillation of this outcome (what a sibling
        search's proposer weaves into every prompt)."""
        dims = ",".join(f"{a}={v}" for a, v in self.dims.items())
        return ContextHint(
            prefer=self.prefer, avoid=self.avoid,
            note=(f"A sibling shape {self.workload_name}[{dims}] reached "
                  f"{self.best_speedup:.2f}x in {self.samples_to_best} "
                  f"samples via: "
                  f"{'; '.join(self.history) or 'the unoptimized program'}."),
        )


def _family_deltas(
    history: Sequence[str], family_stats: Optional[dict]
) -> tuple[set, set]:
    """Distill a finished task's plateau statistics into prefer/avoid.

    Prefer: families in the winning trace, plus any family whose summed
    per-edge improvement over the whole search tree was positive
    (``SearchResult.family_stats``).  Avoid: families that net-regressed
    across the tree and did not make the winner — the moves the sibling
    search should not waste samples re-discovering are bad here.
    """
    prefer = {desc.split("(")[0] for desc in history}
    avoid: set = set()
    for fam, delta in (family_stats or {}).items():
        if delta > 0:
            prefer.add(fam)
        elif delta < 0 and fam not in prefer:
            avoid.add(fam)
    return prefer, avoid


class SharedContext:
    """Per-family donor registry a session accumulates while compiling."""

    def __init__(self):
        self.outcomes: dict[str, TaskOutcome] = {}

    def observe(self, task: Task, result) -> None:
        """Record a finished task (``result`` is a ``SearchResult``)."""
        if result.best_schedule is None:
            return
        history = tuple(result.best_schedule.history)
        prefer, avoid = _family_deltas(
            history, getattr(result, "family_stats", None)
        )
        tops = tuple(
            tuple(s.history) for s in result.top_schedules[:3]
            if s.history and tuple(s.history) != history
        )
        samples_to_best = result.curve.samples_to_reach(
            result.best_speedup * 0.999
        ) or result.samples
        out = TaskOutcome(
            family=task.family_key,
            workload_name=task.workload.name,
            dims={l.name: l.extent for l in task.workload.loops},
            best_speedup=result.best_speedup,
            samples=result.samples,
            samples_to_best=samples_to_best,
            history=history,
            top_histories=tops,
            prefer=frozenset(prefer),
            avoid=frozenset(avoid),
        )
        cur = self.outcomes.get(out.family)
        # keep the strongest donor per family
        if cur is None or out.best_speedup > cur.best_speedup:
            self.outcomes[out.family] = out

    def observe_record(self, task: Task, rec) -> None:
        """Seed the context from a persisted record (a cache-hit task whose
        winning trace lives in the record store, possibly from an earlier
        session — the queryable-corpus payoff)."""
        prefer = frozenset(d.split("(")[0] for d in rec.history)
        out = TaskOutcome(
            family=task.family_key,
            workload_name=task.workload.name,
            dims={l.name: l.extent for l in task.workload.loops},
            best_speedup=rec.speedup,
            samples=rec.samples,
            samples_to_best=rec.samples,
            history=tuple(rec.history),
            prefer=prefer,
        )
        cur = self.outcomes.get(out.family)
        if cur is None or out.best_speedup > cur.best_speedup:
            self.outcomes[out.family] = out

    def donor(self, task: Task) -> Optional[TaskOutcome]:
        d = self.outcomes.get(task.family_key)
        if d is not None and d.workload_name == task.workload.name \
                and d.dims == {l.name: l.extent
                               for l in task.workload.loops}:
            return None  # same shape: a record-store hit, not a sibling
        return d


# ---------------------------------------------------------------------------
# trace adaptation
# ---------------------------------------------------------------------------


def _rescale_decision(decision: list, extent: int) -> Optional[list]:
    """Rescale a donor tile split to a sibling extent, preserving the inner
    (VMEM-band) levels — those are what the lowering bridge turns into
    block shapes — and absorbing the extent change at the outermost level.
    """
    if not decision or any(not isinstance(x, int) or x < 1
                           for x in decision):
        return None
    if math.prod(decision) == extent:
        return list(decision)
    inner = list(decision[1:])
    for drop in range(len(inner) + 1):
        keep = inner if drop == 0 else inner[:-drop] + [1] * drop
        rest = math.prod(keep)
        if rest <= extent and extent % rest == 0:
            return [extent // rest] + keep
    return None


def adapt_transform(
    desc: str, s: Schedule, rng: random.Random
) -> Optional[Transform]:
    """One donor-trace entry -> a legal Transform on schedule ``s``."""
    m = _CALL_RE.match(desc.strip())
    if not m:
        return None
    fam = _FAMILIES.get(m.group(1).strip().lower())
    if fam is None:
        return None
    args, kwargs = _parse_args(m.group(3) or "")
    if fam == "TileSize":
        axis = kwargs.get("axis", args[0] if args else None)
        decision = kwargs.get("decision",
                              args[1] if len(args) > 1 else None)
        if isinstance(axis, str) and axis in s.workload.loop_map \
                and isinstance(decision, list):
            scaled = _rescale_decision(
                decision, s.workload.loop_map[axis].extent
            )
            if scaled is None:
                return None
            args, kwargs = [], {"axis": axis, "decision": scaled}
    return _materialize(fam, args, kwargs, s, rng)


def adapt_history(
    history: Sequence[str], workload, rng: Optional[random.Random] = None,
) -> list[Transform]:
    """Replay a donor trace onto a sibling workload's initial schedule,
    returning the legal (possibly rescaled) transform list."""
    rng = rng or random.Random(0)
    s = initial_schedule(workload)
    out: list[Transform] = []
    for desc in history:
        t = adapt_transform(desc, s, rng)
        if t is None:
            continue
        try:
            s = t.apply(s)
        except ScheduleError:
            continue
        out.append(t)
    return out


# ---------------------------------------------------------------------------
# the seeded proposer
# ---------------------------------------------------------------------------


class SeededProposer(LLMProposer):
    """``LLMProposer`` primed by a sibling task's outcome.

    The first expansions replay the donor's winning (and runner-up) traces
    adapted to this workload, then control passes to the LLM with the
    cross-task hint woven into every prompt.  Fallback statistics only
    count genuine LLM expansions, so Table-8 numbers stay comparable.
    """

    def __init__(self, llm, platform, trace_depth: int = 2,
                 donor: Optional[TaskOutcome] = None,
                 workload=None, max_seeds: int = 3):
        super().__init__(llm, platform, trace_depth=trace_depth)
        self.hint: Optional[ContextHint] = None
        self._seeds: deque[tuple[list[Transform], str]] = deque()
        self.seeds_played = 0
        if donor is not None and workload is not None:
            self.hint = donor.hint()
            seen: set[tuple] = set()
            for hist in (donor.history, *donor.top_histories)[:max_seeds]:
                ts = adapt_history(hist, workload)
                key = tuple(t.describe() for t in ts)
                if ts and key not in seen:
                    seen.add(key)
                    self._seeds.append((
                        ts,
                        f"cross-task seed: replay the sibling "
                        f"{donor.workload_name} trace "
                        f"({donor.best_speedup:.2f}x) adapted to this shape",
                    ))

    def propose(
        self, trace: Sequence[TraceEntry], rng: random.Random
    ) -> Proposal:
        # seeds only make sense from the root (depth 0): they are full
        # traces from p_0, not continuations — off-root expansions leave
        # the queue intact so runner-up traces still play when selection
        # returns to the not-yet-fully-expanded root
        while self._seeds and not trace[0].schedule.history:
            transforms, why = self._seeds.popleft()
            s = trace[0].schedule
            try:
                for t in transforms:
                    s = t.apply(s)
            except ScheduleError:
                continue
            self.seeds_played += 1
            return Proposal(
                transforms=list(transforms), reasoning=why,
                raw_text=f"Reasoning: {why}.\nTransformations to apply: "
                         + ", ".join(t.describe() for t in transforms) + ".",
                n_proposed=len(transforms), n_invalid=0,
            )
        return super().propose(trace, rng)

    # weave the hint into the prompt (LLMProposer.propose builds prompts
    # through this seam; see core/llm.build_prompt)
    def _build_prompt(self, trace):
        from ..core.llm import build_prompt

        return build_prompt(trace, self.platform, self.trace_depth,
                            hint=self.hint)
