"""Routed multi-LLM proposer pools for the reasoning compiler.

The paper's search asks ONE LLM for transform proposals at every MCTS
expansion.  This package generalizes the proposal side to a *pool*:
several tier-tagged proposers share the search tree, a deterministic
routing policy (``routing.py``) decides who drafts each expansion, and an
optional review tier (``review.py``) escalates drafts at promising nodes
to a strong model that may refine, replace, or veto them before the
oracle spends a sample.

Select a pool anywhere a proposer spec is accepted::

    CompilerSession(proposer="pool:gpt-4o-mini+llama3.1-8b:reviewer=o1-mini")
    repro-tune --proposer pool:llama3.1-8b+deepseek-r1-distill-7b \
               --route bandit

A pool of size 1 with no reviewer is RNG-identical to the plain
single-proposer search path.
"""
from .pool import PooledProposer, PoolProposer, ProposerPool, tier_cost
from .review import ReviewTier
from .routing import ROUTE_POLICIES, Router, make_router
from .spec import PoolSpec, build_pool, is_pool_spec, parse_pool_spec

__all__ = [
    "PooledProposer",
    "PoolProposer",
    "ProposerPool",
    "ReviewTier",
    "Router",
    "ROUTE_POLICIES",
    "PoolSpec",
    "build_pool",
    "is_pool_spec",
    "make_router",
    "parse_pool_spec",
    "tier_cost",
]
