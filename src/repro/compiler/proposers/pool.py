"""The proposer pool: N tier-tagged LLMs sharing one search tree.

PAPERS.md's LiteCoOp observation: several lightweight proposer LLMs
sharing a single MCTS tree — with a routing policy deciding who drafts at
each expansion and a strong reviewer escalated at promising nodes — beat
any single proposer at equal cost.  ``ProposerPool`` holds the members
(each a ``PooledProposer``: one ``LLMBase`` plus per-proposer
``FallbackStats``, a cost weight derived from its ``TierSpec``, and a
rolling hit-rate), a ``Router`` policy, and an optional ``ReviewTier``.

``PoolProposer`` is the per-search adapter: it subclasses
``SeededProposer`` so cross-task donor traces (``SharedContext``) replay
exactly as they do for a single proposer, and overrides the completion
seam (``LLMProposer._query``) to route each draft through the pool.  The
pool object itself outlives individual searches — a ``CompilerSession``
builds it once, so routing statistics and hit-rates accumulate across
every task the session compiles.

RNG discipline: routing is deterministic (``routing.py``) and a pool of
size 1 with no reviewer performs exactly one ``complete`` + one
``parse_response`` per expansion — the same draws as a plain
``LLMProposer`` — so single-member pools are RNG-identical to the
pre-pool code path (asserted in tests).
"""
from __future__ import annotations

import dataclasses
import random
from collections import deque
from typing import Optional, Sequence

from ...core.llm import (
    ALL_DIAGNOSES,
    MODEL_TIERS,
    FallbackStats,
    LLMBase,
    Prompt,
    Proposal,
    TierSpec,
    TraceEntry,
    parse_response,
)
from ...obs import NULL_TRACER
from ..context import SeededProposer
from .review import ReviewTier
from .routing import Router, make_router

__all__ = ["PooledProposer", "PoolProposer", "ProposerPool", "tier_cost"]


def tier_cost(spec: Optional[TierSpec]) -> float:
    """Relative per-call cost of a proposal model, derived from its
    capability profile: context actually consumed, reasoning passes run,
    and plan length emitted.  Normalized so the strongest registered
    tier costs ~1.0 and the weakest ~0.3; unknown models (API adapters,
    custom ``LLMBase``) default to 1.0."""
    if spec is None:
        return 1.0
    return round(
        0.4 * (spec.context_depth + 1) / 5
        + 0.4 * len(spec.diagnoses) / len(ALL_DIAGNOSES)
        + 0.2 * spec.plan_len / 6,
        4,
    )


@dataclasses.dataclass
class PooledProposer:
    """One pool member: an LLM, its tier tag, and its attribution state."""

    llm: LLMBase
    tier: Optional[TierSpec] = None
    cost: float = 0.0
    stats: FallbackStats = None
    drafted: int = 0     # expansions routed to this member
    measured: int = 0    # drafts that survived screening -> oracle sample
    hits: int = 0        # measured drafts that improved on their parent
    window: deque = None  # rolling outcomes (1 = hit), drives the bandit

    def __post_init__(self):
        if self.tier is None:
            self.tier = MODEL_TIERS.get(self.llm.name)
        if not self.cost:
            self.cost = tier_cost(self.tier)
        if self.stats is None:
            self.stats = FallbackStats(name=self.llm.name)
        if self.window is None:
            self.window = deque(maxlen=64)

    @property
    def name(self) -> str:
        return self.llm.name

    @property
    def hit_rate(self) -> float:
        """Rolling fraction of drafts that survived oracle/surrogate
        screening AND improved their node's reward."""
        return sum(self.window) / len(self.window) if self.window else 0.0

    @property
    def lifetime_hit_rate(self) -> float:
        return self.hits / self.drafted if self.drafted else 0.0

    def summary(self) -> dict:
        return {
            "proposer": self.name,
            "cost": self.cost,
            "drafted": self.drafted,
            "measured": self.measured,
            "hits": self.hits,
            "hit_rate": round(self.lifetime_hit_rate, 4),
            "rolling_hit_rate": round(self.hit_rate, 4),
            "fallback_rate": round(self.stats.fallback_rate, 4),
            "invalid_rate": round(self.stats.invalid_rate, 4),
            "expansions": self.stats.expansions,
        }


class ProposerPool:
    """N tier-tagged proposers + a routing policy + an optional reviewer.

    Built once per ``CompilerSession`` (``proposer="pool:..."``); state —
    per-member draft counts, hit-rate windows, review outcomes — survives
    across the tasks the session compiles, so the bandit router keeps
    learning where cross-task seeding left off.
    """

    def __init__(self, members: Sequence[PooledProposer],
                 router: Router, reviewer: Optional[ReviewTier] = None,
                 tracer=None):
        if not members:
            raise ValueError("a proposer pool needs at least one member")
        names = [m.name for m in members]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate pool members: {names}")
        self.members = list(members)
        self.router = router
        self.reviewer = reviewer
        self.trace = tracer or NULL_TRACER

    @property
    def name(self) -> str:
        spec = "pool:" + "+".join(m.name for m in self.members)
        if self.reviewer is not None:
            spec += f":reviewer={self.reviewer.name}"
        if self.router.name != "round-robin":
            spec += f":route={self.router.name}"
        return spec

    def member(self, name: str) -> Optional[PooledProposer]:
        for m in self.members:
            if m.name == name:
                return m
        return None

    # -- the draft -> review pipeline ---------------------------------------
    def propose(
        self, prompt: Prompt, trace: Sequence[TraceEntry],
        rng: random.Random,
    ) -> Proposal:
        """Route one expansion: pick the drafter, complete + parse, then
        (at promising nodes, with a reviewer configured) escalate."""
        single = len(self.members) == 1 and self.reviewer is None
        m = self.members[self.router.pick(self.members)]
        if not single:
            self.trace.instant("route", cat="pool", proposer=m.name,
                               policy=self.router.name)
        m.drafted += 1
        with self.trace.span("draft", cat="pool", proposer=m.name):
            text = m.llm.complete(prompt, rng)
            prop = parse_response(text, trace[0].schedule, rng)
        prop.proposer = m.name
        m.stats.absorb(prop)
        if self.reviewer is not None:
            self.reviewer.observe(trace[0].speedup)
            if prop.fallback or self.reviewer.promising(trace[0].speedup):
                with self.trace.span(
                    "review", cat="pool", proposer=m.name,
                    reviewer=self.reviewer.name,
                ) as rsp:
                    prop = self.reviewer.review(prompt, trace, prop, rng)
                    rsp.set(action=prop.review_action)
                if prop.review_action == "veto":
                    self.trace.instant("veto", cat="pool",
                                       proposer=m.name,
                                       reviewer=self.reviewer.name)
        return prop

    # -- screening feedback (MCTS calls through PoolProposer) ---------------
    def feedback(self, proposal: Proposal, improved: bool) -> None:
        """One drafted proposal survived screening and was measured:
        credit (or debit) its drafter's rolling hit-rate."""
        m = self.member(proposal.proposer) if proposal.proposer else None
        if m is None:
            return
        m.measured += 1
        if improved:
            m.hits += 1
        m.window.append(1 if improved else 0)

    # -- reporting -----------------------------------------------------------
    def stats_by_proposer(self) -> dict[str, FallbackStats]:
        out = {m.name: m.stats for m in self.members}
        return out

    def summary(self) -> list[dict]:
        rows = [m.summary() for m in self.members]
        if self.reviewer is not None:
            rows.append(self.reviewer.summary())
        return rows


class PoolProposer(SeededProposer):
    """Per-search adapter: the ``LLMProposer`` interface over a shared
    ``ProposerPool``.  Donor seeding (cross-task ``SharedContext``) and
    prompt hints come from ``SeededProposer``; the completion seam routes
    through the pool.  Aggregate ``stats`` keep the legacy single-counter
    view (``SearchResult.fallback``) consistent."""

    def __init__(self, pool: ProposerPool, platform, trace_depth: int = 2,
                 donor=None, workload=None, max_seeds: int = 3):
        super().__init__(None, platform, trace_depth=trace_depth,
                         donor=donor, workload=workload,
                         max_seeds=max_seeds)
        self.pool = pool
        self.stats = FallbackStats(name=pool.name)

    def _query(self, prompt, trace, rng) -> Proposal:
        prop = self.pool.propose(prompt, trace, rng)
        self.stats.absorb(prop)
        return prop

    def feedback(self, proposal: Proposal, improved: bool) -> None:
        self.pool.feedback(proposal, improved)

    def stats_by_proposer(self) -> dict[str, FallbackStats]:
        return self.pool.stats_by_proposer()
