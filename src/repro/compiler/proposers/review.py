"""The review tier: a strong model audits drafts at promising nodes.

Two-tier draft-then-review routing (the govproposal bridge idiom, the
LiteCoOp escalation protocol): cheap pool members draft at every MCTS
expansion, and only at *promising* nodes — node value above a rolling
quantile of the values this search has surfaced — does the designated
strong reviewer spend a completion.  The reviewer may

  * ``accept``  — its own proposal agrees with (or has no opinion on)
    the draft; the draft proceeds unchanged,
  * ``refine``  — it proposes an overlapping but different sequence; the
    reviewer's transforms replace the draft's,
  * ``replace`` — the draft was invalid (fallback) or entirely off-axis;
    the reviewer's proposal substitutes wholesale,
  * ``veto``    — every drafted family sits in the trace's avoid set
    (ancestor evidence says those moves regressed here) and the reviewer
    has nothing better: the draft dies *before the oracle spends a
    sample* and the expansion falls back to the default policy.

Every outcome is counted (``veto_rate`` is CI-gated in
``BENCH_proposers.json``) and stamped into the proposal's provenance.
"""
from __future__ import annotations

import bisect
from collections import deque
from typing import Optional, Sequence

from ...core.llm import LLMBase, Prompt, Proposal, TraceEntry, parse_response

__all__ = ["ReviewTier"]


def _trace_avoid(trace: Sequence[TraceEntry]) -> set:
    """Transform families the visible ancestor trace says regressed:
    the same (transform, delta) credit assignment the reasoning tiers
    run internally, recomputed here so any reviewer LLM can veto."""
    avoid: set = set()
    prefer: set = set()
    for child, parent in zip(trace[:-1], trace[1:]):
        new = child.schedule.history[len(parent.schedule.history):]
        delta = parent.latency_s - child.latency_s  # >0 == improvement
        for desc in new:
            fam = desc.split("(")[0]
            if delta > 0.02 * parent.latency_s:
                prefer.add(fam)
            elif delta < -0.02 * parent.latency_s:
                avoid.add(fam)
    return avoid - prefer


class ReviewTier:
    """Escalation wrapper around one strong reviewer LLM.

    ``quantile``: a node is promising when its speedup is at or above
    this quantile of the node values the pool has observed so far in the
    current search context.  ``min_obs`` observations gate the quantile
    (an empty window reviews nothing, so short searches stay cheap).
    """

    def __init__(self, llm: LLMBase, quantile: float = 0.7,
                 min_obs: int = 8, window: int = 256):
        self.llm = llm
        self.name = llm.name
        self.quantile = quantile
        self.min_obs = min_obs
        self._values: deque[float] = deque(maxlen=window)
        # outcome counters (reported via summary(), gated in CI)
        self.reviews = 0
        self.accepted = 0
        self.refined = 0
        self.replaced = 0
        self.vetoed = 0

    # -- promising-node detection -----------------------------------------
    def observe(self, speedup: float) -> None:
        self._values.append(speedup)

    def promising(self, speedup: float) -> bool:
        if len(self._values) < self.min_obs:
            return False
        ordered = sorted(self._values)
        idx = bisect.bisect_left(ordered, speedup)
        return idx / len(ordered) >= self.quantile

    @property
    def veto_rate(self) -> float:
        return self.vetoed / self.reviews if self.reviews else 0.0

    # -- the review itself --------------------------------------------------
    def review(
        self, prompt: Prompt, trace: Sequence[TraceEntry],
        draft: Proposal, rng,
    ) -> Proposal:
        """Audit ``draft`` for the node ``trace[0]``; returns the proposal
        the expansion should actually spend its sample on."""
        self.reviews += 1
        schedule = trace[0].schedule
        own = parse_response(self.llm.complete(prompt, rng), schedule, rng)
        avoid = _trace_avoid(trace)

        draft_fams = {t.name for t in draft.transforms}
        if not draft.fallback and draft_fams and draft_fams <= avoid \
                and own.fallback:
            # ancestor evidence says every drafted family regresses here
            # and the reviewer offers nothing better: kill the draft so
            # no oracle sample is spent on it
            self.vetoed += 1
            return Proposal(
                [], f"review veto by {self.name}: drafted families "
                    f"{sorted(draft_fams)} all regressed in the visible "
                    f"trace", draft.raw_text, draft.n_proposed,
                draft.n_proposed, proposer=draft.proposer,
                reviewer=self.name, review_action="veto",
            )
        if own.fallback:
            # reviewer has no (valid) opinion: the draft stands
            self.accepted += 1
            return self._stamp(draft, "accept")
        if draft.fallback:
            # invalid draft, valid review: wholesale substitution
            self.replaced += 1
            return self._adopt(own, draft, "replace")
        own_descr = [t.describe() for t in own.transforms]
        if own_descr == [t.describe() for t in draft.transforms]:
            self.accepted += 1
            return self._stamp(draft, "accept")
        own_fams = {t.name for t in own.transforms}
        if own_fams & draft_fams:
            self.refined += 1
            return self._adopt(own, draft, "refine")
        self.replaced += 1
        return self._adopt(own, draft, "replace")

    def _stamp(self, draft: Proposal, action: str) -> Proposal:
        draft.reviewer = self.name
        draft.review_action = action
        return draft

    def _adopt(self, own: Proposal, draft: Proposal,
               action: str) -> Proposal:
        """The reviewer's transforms win; drafting credit stays with the
        drafter (its prompt bought the context) but the review outcome
        and reviewer name ride along in provenance."""
        own.proposer = draft.proposer
        own.reviewer = self.name
        own.review_action = action
        return own

    def summary(self) -> dict:
        return {
            "reviewer": self.name,
            "reviews": self.reviews,
            "accepted": self.accepted,
            "refined": self.refined,
            "replaced": self.replaced,
            "vetoed": self.vetoed,
            "veto_rate": round(self.veto_rate, 4),
        }
