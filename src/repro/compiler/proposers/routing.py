"""Routing policies: which pool member drafts at each MCTS expansion.

All policies are DETERMINISTIC — they never draw from the search rng —
so adding or swapping a router cannot perturb the random stream a search
consumes.  That invariant is what keeps a pool of size 1 RNG-identical
to the plain single-proposer path (asserted in tests/test_proposers.py).

  * ``round-robin``   — cycle through the members in declaration order.
  * ``cost-weighted`` — smooth weighted round-robin on 1/cost: cheaper
    tiers draft proportionally more often, every member still drafts.
  * ``bandit``        — UCB1 over observed hit-rate-per-unit-cost: the
    exploit term is each member's rolling screened-and-improved rate
    divided by its tier cost, the explore bonus decays with drafts.
"""
from __future__ import annotations

import math
from typing import Sequence

__all__ = ["ROUTE_POLICIES", "Router", "make_router"]


class Router:
    """Base: ``pick(members) -> index``; stateful across calls."""

    name = "router"

    def pick(self, members: Sequence) -> int:
        raise NotImplementedError


class RoundRobinRouter(Router):
    name = "round-robin"

    def __init__(self):
        self._next = 0

    def pick(self, members: Sequence) -> int:
        i = self._next % len(members)
        self._next = i + 1
        return i


class CostWeightedRouter(Router):
    """Smooth weighted round-robin (nginx-style): each pick adds every
    member's weight (1/cost) to its credit, the highest credit drafts and
    pays the total weight back.  Deterministic, no starvation, and the
    draft shares converge to the 1/cost proportions."""

    name = "cost-weighted"

    def __init__(self):
        self._credit: list[float] = []

    def pick(self, members: Sequence) -> int:
        if len(self._credit) != len(members):
            self._credit = [0.0] * len(members)
        weights = [1.0 / max(m.cost, 1e-6) for m in members]
        for i, w in enumerate(weights):
            self._credit[i] += w
        best = max(range(len(members)), key=lambda i: (self._credit[i], -i))
        self._credit[best] -= sum(weights)
        return best


class UCBRouter(Router):
    """UCB1 bandit over hit-rate-per-unit-cost.

    score_i = hit_rate_i / cost_i + c * sqrt(ln(T + 1) / (n_i + 1))

    ``hit_rate`` is the member's rolling rate of drafts that survived
    oracle/surrogate screening AND improved on their parent node
    (``PooledProposer.hit_rate``); ``n_i`` its draft count, ``T`` the
    pool total.  Ties break toward the earlier member, so the policy is
    deterministic.
    """

    name = "bandit"

    def __init__(self, c: float = 0.5):
        self.c = c

    def pick(self, members: Sequence) -> int:
        total = sum(m.drafted for m in members)
        scores = [
            m.hit_rate / max(m.cost, 1e-6)
            + self.c * math.sqrt(math.log(total + 1.0) / (m.drafted + 1.0))
            for m in members
        ]
        return max(range(len(members)), key=lambda i: (scores[i], -i))


ROUTE_POLICIES = ("round-robin", "cost-weighted", "bandit")


def make_router(name: str) -> Router:
    if name == "round-robin":
        return RoundRobinRouter()
    if name == "cost-weighted":
        return CostWeightedRouter()
    if name == "bandit":
        return UCBRouter()
    raise KeyError(
        f"unknown route policy {name!r}; known: {ROUTE_POLICIES}"
    )
