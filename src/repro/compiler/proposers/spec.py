"""Pool spec strings: ``"pool:<a>+<b>[:reviewer=<c>][:route=<policy>]"``.

One string selects the whole proposer configuration, so the same value
flows unmodified from ``launch/tune.py --proposer`` through
``CompilerSession(proposer=...)`` into benchmark configs and record
provenance:

    pool:gpt-4o-mini+llama3.1-8b
    pool:llama3.1-8b+deepseek-r1-distill-7b:reviewer=o1-mini
    pool:gpt-4o-mini+llama3.1-8b:reviewer=o1-mini:route=bandit

Members are any ``core/llm.make_llm`` spec — tier names, ``random``,
``api:<model>`` (the embedded colon is handled) — joined with ``+``.
Options may appear in either order; ``route`` defaults to round-robin.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ...core.llm import make_llm
from .pool import PooledProposer, ProposerPool
from .review import ReviewTier
from .routing import ROUTE_POLICIES, make_router

__all__ = ["PoolSpec", "build_pool", "is_pool_spec", "parse_pool_spec"]

_OPTION_KEYS = ("reviewer", "route")


def is_pool_spec(spec) -> bool:
    return isinstance(spec, str) and spec.startswith("pool:")


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    members: tuple[str, ...]
    reviewer: Optional[str] = None
    route: str = "round-robin"


def parse_pool_spec(spec: str) -> PoolSpec:
    if not is_pool_spec(spec):
        raise ValueError(f"not a pool spec: {spec!r}")
    body = spec[len("pool:"):]
    # ':'-separated segments; a segment that is not 'reviewer=' / 'route='
    # continues the preceding value (member and reviewer specs may embed
    # colons: 'api:<model>')
    segments = body.split(":")
    members_part = segments[0]
    opts: dict[str, str] = {}
    current: Optional[str] = None
    for seg in segments[1:]:
        key, _, value = seg.partition("=")
        if key in _OPTION_KEYS and "=" in seg:
            if key in opts:
                raise ValueError(f"duplicate {key!r} in pool spec {spec!r}")
            opts[key] = value
            current = key
        elif current is not None:
            opts[current] += ":" + seg
        else:
            members_part += ":" + seg
    members = tuple(n for n in members_part.split("+") if n)
    if not members:
        raise ValueError(f"pool spec {spec!r} names no members")
    if len(set(members)) != len(members):
        raise ValueError(f"duplicate members in pool spec {spec!r}")
    route = opts.get("route", "round-robin")
    if route not in ROUTE_POLICIES:
        raise ValueError(
            f"unknown route policy {route!r} in {spec!r}; "
            f"known: {ROUTE_POLICIES}"
        )
    return PoolSpec(members, opts.get("reviewer"), route)


def build_pool(spec: str | PoolSpec, tracer=None) -> ProposerPool:
    """Materialize a pool spec: one LLM per member (+ reviewer), the
    routing policy, fresh routing/hit-rate state."""
    ps = parse_pool_spec(spec) if isinstance(spec, str) else spec
    members = [PooledProposer(make_llm(name)) for name in ps.members]
    reviewer = ReviewTier(make_llm(ps.reviewer)) if ps.reviewer else None
    return ProposerPool(members, make_router(ps.route), reviewer=reviewer,
                        tracer=tracer)
