"""Schema-versioned tuning-record store: the compiler's persistent corpus.

The LLM-compiler line of work (Cummins et al., "Large Language Models for
Compiler Optimization") argues tuning results should live in a persistent,
queryable database rather than a write-only cache — every record here
carries full provenance (which oracle produced it, measured vs. analytical,
harness settings, a version stamp of the cost model) and the *winning
transform trace*, so later sessions can query, merge, and cross-seed from
it (``compiler/context.py``).

On disk the store is append-only JSONL (one record per line, each line a
self-describing ``schema``-versioned object).  Append-only is what makes
two processes writing the same db path safe: each ``add`` is a single
O_APPEND write, and ``reload`` merges whatever both processes wrote
(dedup-on-load, newest record per key wins).  The legacy v0 format — one
JSON dict mapping key -> block params (``core/autotuner.py`` before the
session API) — is migrated in place on first load and can be produced for
old readers via ``export_json``.

Corrupt input never crashes a session: unparseable JSONL lines and
truncated/corrupt legacy JSON files are quarantined next to the store
(``<path>.quarantined``) with a warning, and tuning proceeds fresh.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import subprocess
import time
import warnings
from typing import Iterable, Optional

# 1 -> 2: proposer/reviewer/review_action provenance (proposer pools,
# compiler/proposers).  v1 rows load unchanged — the fields default to
# None — and v2 rows are self-describing for old readers that filter
# unknown keys (``from_dict`` has always done so).
SCHEMA_VERSION = 2

# Default on-disk store, next to the arch configs like the v0 JSON cache.
DEFAULT_RECORDS_PATH = os.path.join(
    os.path.dirname(__file__), "..", "configs", "tuning_records.jsonl"
)
# The v0 cache the migration path (`--migrate-cache`) consumes.
LEGACY_JSON_PATH = os.path.join(
    os.path.dirname(__file__), "..", "configs", "tuning_cache.json"
)

_ATTENTION_PARAMS = ("block_q", "block_k")
_GEMM_PARAMS = ("bm", "bn", "bk")


def _cost_model_version() -> str:
    """Version stamp of the analytical cost model backing a record.

    ``git describe`` of the repo when available (records produced by a
    checkout are traceable to a commit), else a content hash of
    ``core/cost_model.py`` — either way two records disagree on this field
    iff they were produced by different cost models.
    """
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    try:
        out = subprocess.run(
            ["git", "describe", "--always", "--dirty"],
            cwd=root, capture_output=True, text=True, timeout=5,
        )
        if out.returncode == 0 and out.stdout.strip():
            return f"git:{out.stdout.strip()}"
    except (OSError, subprocess.SubprocessError):
        pass
    cm = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "core", "cost_model.py")
    try:
        with open(cm, "rb") as f:
            return f"sha:{hashlib.sha256(f.read()).hexdigest()[:12]}"
    except OSError:
        return "unknown"


_COST_MODEL_VERSION: Optional[str] = None


def cost_model_version() -> str:
    global _COST_MODEL_VERSION
    if _COST_MODEL_VERSION is None:
        _COST_MODEL_VERSION = _cost_model_version()
    return _COST_MODEL_VERSION


@dataclasses.dataclass
class TuningRecord:
    """One tuned (workload x platform) result with full provenance."""

    key: str                 # "platform:workload[axis=extent,...]" (v0-compat)
    kind: str                # "attention" | "gemm" | ...
    params: dict             # {block_q, block_k} | {bm, bn, bk}
    speedup: float
    samples: int
    method: str
    platform: str = "tpu-v5e"
    workload: str = ""
    dims: dict = dataclasses.field(default_factory=dict)
    llm: Optional[str] = None
    # pool provenance (schema 2): which pool member drafted the winning
    # node (nearest drafted ancestor), who reviewed it, what the review
    # did.  None for pre-pool records and non-LLM methods.
    proposer: Optional[str] = None
    reviewer: Optional[str] = None
    review_action: Optional[str] = None
    oracle: str = "analytical"        # search-time objective backend
    measured: bool = False            # True iff a real timed execution ranked it
    measured_latency_s: Optional[float] = None
    history: tuple = ()               # winning transform trace (cross-seeding)
    provenance: dict = dataclasses.field(default_factory=dict)
    created_at: float = 0.0
    schema: int = SCHEMA_VERSION

    def __post_init__(self):
        self.history = tuple(self.history)
        if not self.created_at:
            self.created_at = time.time()
        self.provenance.setdefault("cost_model", cost_model_version())

    # -- (de)serialization ---------------------------------------------------
    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["history"] = list(self.history)
        return json.dumps(d, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "TuningRecord":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    def legacy_entry(self) -> dict:
        """The v0 JSON-cache entry shape (retired raw-JSON tuner cache)."""
        entry = dict(self.params, speedup=round(self.speedup, 3),
                     samples=self.samples, method=self.method)
        if self.measured_latency_s is not None:
            entry["measured_latency_s"] = self.measured_latency_s
        if self.provenance.get("oracle"):
            entry["provenance"] = {
                k: v for k, v in self.provenance.items() if k != "cost_model"
            }
        return entry


def record_key(platform: str, workload) -> str:
    """The v0 cache-key format, kept so migration is identity on keys."""
    dims = ",".join(f"{l.name}={l.extent}" for l in workload.loops)
    return f"{platform}:{workload.name}[{dims}]"


def _kind_of(params: dict) -> str:
    if all(k in params for k in _ATTENTION_PARAMS):
        return "attention"
    if all(k in params for k in _GEMM_PARAMS):
        return "gemm"
    return "unknown"


def _split_key(key: str) -> tuple[str, str, dict]:
    """'plat:name[i=1,j=2]' -> (plat, name, {i:1, j:2}); best effort."""
    platform, _, rest = key.partition(":")
    name, _, dimstr = rest.partition("[")
    dims = {}
    for tok in dimstr.rstrip("]").split(","):
        if "=" in tok:
            a, _, v = tok.partition("=")
            try:
                dims[a] = int(v)
            except ValueError:
                pass
    return platform, name, dims


class TuningRecords:
    """Append-only, schema-versioned JSONL record database.

    ``path=None`` keeps the store in memory (unit tests, throwaway
    sessions).  With a path, every ``add`` appends one line; concurrent
    writers interleave lines instead of clobbering each other, and
    ``reload`` folds in records another process appended since we last
    read (newest per key wins).
    """

    def __init__(self, path: Optional[str] = None,
                 legacy_json: Optional[str] = None):
        self.path = path
        self.legacy_json = legacy_json
        self._records: dict[str, TuningRecord] = {}
        self.quarantined = 0
        self.load()

    # -- loading -------------------------------------------------------------
    def load(self) -> None:
        self._records = {}
        if self.legacy_json and os.path.exists(self.legacy_json):
            self._load_legacy(self.legacy_json)
        if self.path and os.path.exists(self.path):
            self._load_jsonl(self.path)

    def reload(self) -> None:
        """Re-merge the on-disk store (cross-process visibility)."""
        mine = dict(self._records)
        self.load()
        for key, rec in mine.items():
            cur = self._records.get(key)
            if cur is None or cur.created_at <= rec.created_at:
                self._records[key] = rec

    def _quarantine(self, path: str, why: str) -> None:
        qpath = path + ".quarantined"
        n = 1
        while os.path.exists(qpath):
            qpath = f"{path}.quarantined.{n}"
            n += 1
        try:
            os.replace(path, qpath)
        except OSError:
            qpath = "<unmovable>"
        self.quarantined += 1
        warnings.warn(
            f"tuning store {path!r} is corrupt ({why}); quarantined to "
            f"{qpath!r} and starting fresh", RuntimeWarning, stacklevel=3,
        )

    def _load_legacy(self, path: str) -> None:
        try:
            with open(path) as f:
                cache = json.load(f)
            if not isinstance(cache, dict):
                raise ValueError(f"expected a JSON object, got {type(cache)}")
        except (json.JSONDecodeError, ValueError, OSError) as e:
            self._quarantine(path, str(e))
            return
        try:
            stamp = os.path.getmtime(path)
        except OSError:
            stamp = time.time()
        for key, entry in cache.items():
            rec = legacy_entry_to_record(key, entry, created_at=stamp)
            if rec is not None:
                self._records[rec.key] = rec

    def _load_jsonl(self, path: str) -> None:
        bad: list[str] = []
        try:
            with open(path) as f:
                lines = f.readlines()
        except OSError as e:
            self._quarantine(path, str(e))
            return
        for line in lines:
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
                if not isinstance(d, dict) or "key" not in d:
                    raise ValueError("not a record object")
                rec = TuningRecord.from_dict(d)
            except (json.JSONDecodeError, ValueError, TypeError):
                bad.append(line)
                continue
            # dedup-on-load: later lines (newer appends) win
            self._records[rec.key] = rec
        if bad:
            # The store file is NEVER rewritten (append-only is the
            # cross-process safety contract: a "corrupt" tail line may be
            # another process's in-flight append).  Corrupt lines are
            # copied to the quarantine file and skipped; lines already
            # quarantined by an earlier load stay silent, so each unique
            # corrupt line warns exactly once.
            qpath = path + ".quarantined"
            known: set[str] = set()
            if os.path.exists(qpath):
                try:
                    with open(qpath) as f:
                        known = {l.strip() for l in f}
                except OSError:
                    pass
            new_bad = [l for l in bad if l not in known]
            self.quarantined += len(new_bad)
            if new_bad:
                with open(qpath, "a") as f:
                    f.write("\n".join(new_bad) + "\n")
                warnings.warn(
                    f"tuning store {path!r}: skipped {len(new_bad)} corrupt/"
                    f"truncated line(s), quarantined to {qpath!r}",
                    RuntimeWarning, stacklevel=3,
                )

    # -- mutation ------------------------------------------------------------
    def add(self, rec: TuningRecord) -> TuningRecord:
        self._records[rec.key] = rec
        if self.path:
            os.makedirs(os.path.dirname(os.path.abspath(self.path)),
                        exist_ok=True)
            with open(self.path, "a") as f:
                f.write(rec.to_json() + "\n")
        return rec

    def merge(self, other: "TuningRecords") -> int:
        """Adopt records from another store (newest per key wins);
        returns the number of records that changed."""
        changed = 0
        for key, rec in other._records.items():
            cur = self._records.get(key)
            if cur is None or cur.created_at < rec.created_at:
                self.add(rec)
                changed += 1
        return changed

    # -- queries -------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._records)

    def __contains__(self, key: str) -> bool:
        return key in self._records

    def get(self, key: str) -> Optional[TuningRecord]:
        return self._records.get(key)

    def keys(self) -> list[str]:
        return sorted(self._records)

    def all(self) -> list[TuningRecord]:
        return [self._records[k] for k in self.keys()]

    def query(
        self,
        *,
        platform: Optional[str] = None,
        kind: Optional[str] = None,
        workload: Optional[str] = None,
        measured: Optional[bool] = None,
    ) -> list[TuningRecord]:
        out = []
        for rec in self.all():
            if platform is not None and rec.platform != platform:
                continue
            if kind is not None and rec.kind != kind:
                continue
            if workload is not None and workload not in rec.workload:
                continue
            if measured is not None and rec.measured != measured:
                continue
            out.append(rec)
        return out

    # -- legacy interop ------------------------------------------------------
    def legacy_view(self) -> dict:
        """The whole store in the v0 ``{key: entry}`` JSON-cache shape."""
        return {k: r.legacy_entry() for k, r in sorted(self._records.items())}

    def export_json(self, path: str) -> None:
        """Write the v0 JSON-cache format for old readers."""
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.legacy_view(), f, indent=1, sort_keys=True)


def legacy_entry_to_record(
    key: str, entry: dict, created_at: float = 0.0
) -> Optional[TuningRecord]:
    """One v0 JSON-cache entry -> a versioned record (None if malformed).

    ``created_at`` should be the source file's mtime: migrated records
    then sort as old as the cache they came from, so re-migrating the
    same file is a no-op and freshly-searched records always win merges.
    """
    if not isinstance(entry, dict):
        return None
    kind = _kind_of(entry)
    if kind == "unknown":
        return None
    params = {k: entry[k] for k in
              (_ATTENTION_PARAMS if kind == "attention" else _GEMM_PARAMS)}
    platform, workload, dims = _split_key(key)
    prov = dict(entry.get("provenance") or {})
    prov.setdefault("migrated_from", "v0-json")
    return TuningRecord(
        created_at=created_at,
        key=key, kind=kind, params=params,
        speedup=float(entry.get("speedup", 1.0)),
        samples=int(entry.get("samples", 0)),
        method=str(entry.get("method", "unknown")),
        platform=platform, workload=workload, dims=dims,
        measured="measured_latency_s" in entry,
        measured_latency_s=entry.get("measured_latency_s"),
        provenance=prov,
    )


def migrate_json_cache(
    json_path: str, records: TuningRecords
) -> int:
    """One-shot v0 JSON cache -> versioned JSONL store migration; returns
    the number of migrated records (existing newer records are kept).

    Migration means *persisted in the JSONL file*: the comparison runs
    against what is actually on disk, not the target's in-memory view —
    a store that merely folded the same legacy JSON in at load time
    (``legacy_json=``) still gets its records written out.

    A v0 entry is a *lossy projection* (no winning trace, no llm/oracle
    provenance), so it never replaces an existing searched record for the
    same key — even when the JSON file is newer (it usually is: the
    legacy mirror ``export_json`` writes is derived FROM those records).
    It only beats an older record that is itself a legacy import.
    """
    if not os.path.exists(json_path):
        return 0
    src = TuningRecords(path=None, legacy_json=json_path)
    on_disk = TuningRecords(records.path) if records.path else records
    migrated = 0
    for rec in src.all():
        cur = on_disk.get(rec.key)
        if cur is None or (cur.created_at < rec.created_at
                           and cur.provenance.get("migrated_from")):
            records.add(rec)
            migrated += 1
    return migrated
