"""``CompilerSession``: the single front door of the Reasoning Compiler.

The paper frames optimization as a *sequential, context-aware decision
process*; this module gives that process a first-class owner.  One session
holds, for its lifetime:

* one LLM (``core/llm.make_llm`` — the expensive, stateful resource),
* one oracle with its schedule/launch-config caches (``core/oracle.py``),
* one ``TuningRecords`` database (``compiler/records.py``), and
* one ``SharedContext`` accumulating winning traces + plateau statistics
  across the tasks it compiles (``compiler/context.py``).

``session.compile(tasks)`` runs a list of ``Task``s through that shared
context: higher-priority tasks compile first and become seed donors for
their siblings (LiteCoOp-style), converged tasks donate their unused
sample budget to stragglers, and every result is persisted as a
provenance-carrying record plus returned as a ``CompiledArtifact`` the
deploy side consumes.

``session.search(workload, ...)`` is the single-search primitive
(``core.search._one_shot_search`` wraps it for one-off comparisons); the
retired legacy entry points (``run_search``, ``KernelTuner``) were thin
shims over these two methods and are gone.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

from ..core.cost_model import Platform, get_platform
from ..core.evolutionary import EvolutionarySearch
from ..core.llm import LLMBase, LLMProposer, make_llm
from ..core.lowering import LoweringError
from ..core.mcts import MCTS, SearchCurve
from ..core.oracle import MeasuredOracle, make_oracle
from ..core.workloads import Workload, get_workload
from ..obs import NULL_TRACER, Tracer
from .artifacts import (
    AttentionBlocks,
    CompiledArtifact,
    GemmBlocks,
    blocks_from_record,
)
from .context import SeededProposer, SharedContext, TaskOutcome
from .proposers import PoolProposer, ProposerPool, build_pool, is_pool_spec
from .records import TuningRecord, TuningRecords, record_key
from .tasks import Task

METHODS = ("evolutionary", "mcts", "llm-mcts")


def _family_stats(searcher: MCTS) -> dict:
    """Plateau statistics of one finished tree search: per transform
    family, the summed relative latency improvement over every evaluated
    (parent, child) edge.  Positive = the family net-helped on this
    workload; negative = it net-regressed.  Cross-task context distills
    these into the prefer/avoid hint for sibling searches."""
    stats: dict[str, float] = {}
    for node in searcher._seen.values():
        parent = node.parent
        if parent is None:
            continue
        new = node.schedule.history[len(parent.schedule.history):]
        delta = (parent.latency_s - node.latency_s) \
            / max(parent.latency_s, 1e-30)
        for desc in new:
            fam = desc.split("(")[0]
            stats[fam] = stats.get(fam, 0.0) + delta
    return stats


@dataclasses.dataclass
class BudgetPolicy:
    """How a session spreads its sample budget across tasks.

    ``total`` is a HARD ceiling on the whole ``compile`` call (a task's
    ``min_samples`` floor yields to it: once the pool is spent, remaining
    tasks get a 0-sample record of the unoptimized program rather than
    overrunning — with a measured oracle every sample is real hardware
    time).  When None, each task gets ``per_task``.  With ``early_stop``,
    a task that has not improved for ``patience`` consecutive samples is
    declared converged and stops; with ``reallocate``, whatever it did
    not spend flows to the remaining (straggler) tasks' grants.

    ``early_stop``/``patience`` (and seeding) apply to the tree searches
    (``mcts``/``llm-mcts``); ``evolutionary`` runs monolithically and
    always consumes its full grant.
    """

    total: Optional[int] = None
    per_task: int = 64
    patience: int = 12
    early_stop: bool = True
    reallocate: bool = True

    def pool(self, n_tasks: int) -> int:
        return self.total if self.total is not None \
            else self.per_task * n_tasks


class CompilerSession:
    """One LLM + one oracle + one record database, shared across tasks.

    Parameters
    ----------
    target:        platform name or ``Platform`` ("tpu-v5e", "core-i9", ...)
    oracle:        "analytical" | "measured" | "hybrid" | Oracle instance —
                   built once, caches live for the session
    proposer:      LLM name (``core/llm.MODEL_TIERS`` / "random" /
                   "api:<model>") or an ``LLMBase`` instance
    budget_policy: ``BudgetPolicy`` or an int (shorthand for
                   ``BudgetPolicy(per_task=...)``)
    records:       ``TuningRecords``, a path to a JSONL store, or None
                   (in-memory)
    shared_context: cross-task trace seeding + prompt hints (the ablation
                   knob ``REPRO_BENCH_SHARED`` flips in benchmarks)
    measure:       re-rank each task's winners by real timed execution
                   before persisting (deploy-time default in launch/tune)
    """

    def __init__(
        self,
        target: Union[str, Platform] = "tpu-v5e",
        *,
        oracle="analytical",
        proposer: Union[str, LLMBase] = "gpt-4o-mini",
        method: str = "llm-mcts",
        budget_policy: Union[BudgetPolicy, int, None] = None,
        records: Union[TuningRecords, str, None] = None,
        shared_context: bool = True,
        trace_depth: int = 2,
        branching: int = 2,
        measure: bool = False,
        rerank_top: int = 3,
        measure_repeats: int = 3,
        seed: int = 0,
        tracer: Optional[Tracer] = None,
        escalate_topk: int = 1,
        screen_width: int = 8,
        screen_factor: int = 4,
    ):
        self.platform = target if isinstance(target, Platform) \
            else get_platform(target)
        self.trace = tracer or NULL_TRACER
        # records before oracle: a surrogate-tier oracle trains on open
        # from whatever the session's database has already accumulated
        if isinstance(records, TuningRecords):
            self.records = records
        else:
            self.records = TuningRecords(records)
        self.oracle = make_oracle(oracle, self.platform)
        if hasattr(self.oracle, "trace"):
            self.oracle.trace = self.trace
        if hasattr(self.oracle, "train_from_records"):
            self.oracle.train_from_records(self.records)
        # screened-expansion knobs (only oracles exposing ``screen`` use
        # them): pool width per expansion, measurements escalated per pool
        self.escalate_topk = escalate_topk
        self.screen_width = screen_width
        self.screen_factor = screen_factor
        self._proposer_spec = proposer
        self.pool: Optional[ProposerPool] = None
        if isinstance(proposer, ProposerPool):
            self.pool = proposer
            self.pool.trace = self.trace
            self.llm: Optional[LLMBase] = None
        elif isinstance(proposer, LLMBase):
            self.llm = proposer
        elif is_pool_spec(proposer) and method == "llm-mcts":
            self.pool = build_pool(proposer, tracer=self.trace)
            self.llm = None
        elif isinstance(proposer, str) and not is_pool_spec(proposer) \
                and method == "llm-mcts":
            self.llm = make_llm(proposer)
        else:
            self.llm = None  # built on first llm-mcts search (_ensure_llm)
        self.llm_name = self.pool.name if self.pool is not None \
            else (self.llm.name if self.llm is not None else None)
        # session-lifetime per-proposer expansion statistics, merged from
        # every search this session runs (proposer_summary)
        self.proposer_stats: dict = {}
        if method not in METHODS:
            raise ValueError(f"unknown method {method!r}; known: {METHODS}")
        self.method = method
        if budget_policy is None:
            budget_policy = BudgetPolicy()
        elif isinstance(budget_policy, int):
            budget_policy = BudgetPolicy(per_task=budget_policy)
        self.budget_policy = budget_policy
        self.shared_context = shared_context
        self.context = SharedContext()
        self.trace_depth = trace_depth
        self.branching = branching
        self.measure = measure
        self.rerank_top = rerank_top
        self.measure_repeats = measure_repeats
        self.seed = seed
        self._measured_oracle: Optional[MeasuredOracle] = None
        # session telemetry
        self.samples_spent = 0
        self.tasks_compiled = 0
        self.cache_hits = 0
        self.seeds_played = 0

    # ------------------------------------------------------------------
    # the single-search primitive
    # ------------------------------------------------------------------
    def search(
        self,
        workload: Union[str, Workload],
        budget: int = 200,
        seed: int = 0,
        *,
        method: Optional[str] = None,
        trace_depth: Optional[int] = None,
        branching: Optional[int] = None,
        donor: Optional[TaskOutcome] = None,
        patience: Optional[int] = None,
        min_samples: int = 0,
        **mcts_kwargs,
    ):
        """Run one optimization strategy on one workload for ``budget``
        samples, through the session's LLM and oracle.

        Without ``donor``/``patience`` this is the one-shot search
        primitive (``core.search._one_shot_search`` delegates here); a
        donor seeds the first expansions with the sibling's adapted
        traces, and ``patience`` enables converged-early termination.
        """
        from ..core.search import SearchResult, _oracle_name

        if isinstance(workload, str):
            workload = get_workload(workload)
        method = method or self.method
        oracle_name = _oracle_name(self.oracle)

        if method == "evolutionary":
            es = EvolutionarySearch(workload, self.oracle, seed=seed,
                                    screen_factor=self.screen_factor)
            curve = es.search(budget)
            best_t, best_s = es.best
            return SearchResult(
                workload.name, self.platform.name, method, curve,
                es.baseline_latency / best_t, best_s, es.baseline_latency,
                best_t, es.samples,
                oracle=oracle_name, top_schedules=tuple(es.top_schedules()),
            )
        if method not in ("mcts", "llm-mcts"):
            raise ValueError(f"unknown method {method!r}; known: {METHODS}")

        proposer: Optional[LLMProposer] = None
        if method == "llm-mcts":
            td = self.trace_depth if trace_depth is None else trace_depth
            pool = self._ensure_pool()
            if pool is not None:
                # pool state (routing, hit-rates, review counters) lives on
                # the session and survives across tasks; the PoolProposer is
                # the per-search adapter carrying donor seeds + hints
                proposer = PoolProposer(
                    pool, self.platform, trace_depth=td,
                    donor=donor, workload=workload,
                )
            elif donor is not None:
                proposer = SeededProposer(
                    self._ensure_llm(), self.platform, trace_depth=td,
                    donor=donor, workload=workload,
                )
            else:
                proposer = LLMProposer(self._ensure_llm(), self.platform,
                                       trace_depth=td)

        mcts_kwargs.setdefault("screen_width", self.screen_width)
        mcts_kwargs.setdefault("escalate_topk", self.escalate_topk)
        searcher = MCTS(
            workload, self.oracle, proposer=proposer,
            branching=self.branching if branching is None else branching,
            seed=seed, tracer=self.trace, **mcts_kwargs,
        )
        curve = self._drive(searcher, budget, patience=patience,
                            min_samples=min_samples)
        if isinstance(proposer, SeededProposer):
            self.seeds_played += proposer.seeds_played
        by_proposer = None
        if proposer is not None:
            by_proposer = proposer.stats_by_proposer()
            self._merge_proposer_stats(by_proposer)
        # credit for the winner: the drafter of the best node (or of its
        # nearest LLM-drafted ancestor — the draft that steered the search
        # into the winning subtree)
        prov = next(
            (n for n in searcher.best.ancestors() if n.proposer is not None),
            None,
        ) if proposer is not None else None
        return SearchResult(
            workload.name, self.platform.name, method, curve,
            searcher.best.speedup, searcher.best.schedule,
            searcher.baseline_latency, searcher.best.latency_s,
            searcher.samples,
            fallback=proposer.stats if proposer else None,
            llm=self.llm_name if proposer else None,
            oracle=oracle_name,
            top_schedules=tuple(searcher.top_schedules()),
            family_stats=_family_stats(searcher),
            fallback_by_proposer=by_proposer,
            proposer=prov.proposer if prov else None,
            reviewer=prov.reviewer if prov else None,
            review_action=prov.review_action if prov else None,
            pool_stats=self.pool.summary() if self.pool is not None else None,
        )

    def _ensure_llm(self) -> LLMBase:
        """The session's single LLM, built lazily from the constructor's
        proposer spec when the session method itself is not llm-mcts but a
        per-call ``method="llm-mcts"`` override needs one."""
        if self.llm is None:
            spec = self._proposer_spec
            self.llm = spec if isinstance(spec, LLMBase) else make_llm(spec)
            self.llm_name = self.llm.name
        return self.llm

    def _ensure_pool(self) -> Optional[ProposerPool]:
        """The session's proposer pool (None for single-proposer specs),
        built lazily like ``_ensure_llm`` when the constructor deferred."""
        if self.pool is None and is_pool_spec(self._proposer_spec):
            self.pool = build_pool(self._proposer_spec, tracer=self.trace)
            self.llm_name = self.pool.name
        return self.pool

    def _merge_proposer_stats(self, by_proposer: dict) -> None:
        """Fold one search's per-proposer counters into session totals.
        Pool members share live ``FallbackStats`` objects across searches,
        so those replace rather than accumulate; per-search proposers
        (plain ``LLMProposer``) merge."""
        from ..core.llm import FallbackStats

        for name, stats in by_proposer.items():
            if self.pool is not None and self.pool.member(name) is not None:
                self.proposer_stats[name] = stats
                continue
            cur = self.proposer_stats.setdefault(
                name, FallbackStats(name=name))
            cur.merge(stats)

    def proposer_summary(self) -> list[dict]:
        """Per-proposer rows for the session so far: pool members carry
        routing + hit-rate columns (``ProposerPool.summary``), a plain
        single proposer reports its aggregate Appendix-G statistics."""
        if self.pool is not None:
            return self.pool.summary()
        return [
            dict(proposer=name, expansions=s.expansions,
                 fallback_rate=round(s.fallback_rate, 4),
                 invalid_rate=round(s.invalid_rate, 4),
                 proposed=s.proposed, invalid=s.invalid)
            for name, s in sorted(self.proposer_stats.items())
        ]

    @staticmethod
    def _drive(searcher: MCTS, budget: int, *,
               patience: Optional[int] = None,
               min_samples: int = 0) -> SearchCurve:
        """The ``MCTS.search`` loop, with optional convergence detection:
        stop once ``patience`` consecutive samples brought no improvement
        (the unspent budget flows back to the compile pool)."""
        guard = 0
        best = searcher.best.speedup
        last_improved_at = 0
        while searcher.samples < budget and guard < budget * 20:
            guard += 1
            searcher.step()
            if searcher.best.speedup > best * (1 + 1e-9):
                best = searcher.best.speedup
                last_improved_at = searcher.samples
            if patience is not None \
                    and searcher.samples >= max(min_samples, 1) \
                    and searcher.samples - last_improved_at >= patience:
                break
        return SearchCurve(list(searcher.curve))

    # ------------------------------------------------------------------
    # the multi-task front door
    # ------------------------------------------------------------------
    def compile(
        self,
        tasks: Sequence[Task],
        *,
        force: bool = False,
        lower: bool = False,
    ) -> list[CompiledArtifact]:
        """Compile tasks through the shared search context.

        Order of work is priority-descending (ties: declaration order);
        the returned list matches the *input* order.  A task whose record
        already exists in the session's database resolves as a
        ``cache_hit`` artifact without consuming budget (``force=True``
        re-searches); its persisted trace still primes siblings.
        """
        tasks = list(tasks)
        policy = self.budget_policy
        order = sorted(range(len(tasks)), key=lambda i: -tasks[i].priority)
        pool = policy.pool(len(tasks))
        even_share = pool // max(1, len(tasks))  # non-reallocating grant
        out: dict[int, CompiledArtifact] = {}
        pending = len(tasks)
        for idx in order:
            task = tasks[idx]
            key = record_key(self.platform.name, task.workload)
            rec = self.records.get(key)
            if rec is not None and not force:
                art = CompiledArtifact(
                    task, rec, blocks_from_record(rec), cache_hit=True
                )
                self.cache_hits += 1
                if self.shared_context and rec.history:
                    self.context.observe_record(task, rec)
                out[idx] = art
                pending -= 1
                continue
            if policy.reallocate:
                # converged predecessors spent less than their share, so
                # the remaining pool splits over fewer pending tasks
                grant = max(task.min_samples, pool // max(1, pending))
            else:
                grant = max(task.min_samples, even_share)
            if task.max_samples is not None:
                grant = min(grant, task.max_samples)
            if policy.total is not None:
                grant = min(grant, pool)  # the explicit total is HARD
            # trace seeding requires the LLM-guided expansion policy; for
            # mcts/evolutionary no donor is used (and none is recorded)
            donor = self.context.donor(task) \
                if self.shared_context and self.method == "llm-mcts" else None
            with self.trace.span(
                "compile-task", cat="compile",
                workload=task.workload.name, platform=self.platform.name,
                method=self.method, llm=self.llm_name,
                budget_granted=grant,
                seeded_from=donor.workload_name if donor else None,
            ) as tsp:
                res = self.search(
                    task.workload, budget=grant, seed=self.seed,
                    donor=donor,
                    patience=policy.patience if policy.early_stop else None,
                    min_samples=task.min_samples,
                )
                tsp.set(samples=res.samples,
                        speedup=round(res.best_speedup, 4))
            pool = max(0, pool - res.samples)
            self.samples_spent += res.samples
            self.tasks_compiled += 1
            pending -= 1
            if self.shared_context:
                self.context.observe(task, res)
            rec = self._store(task, res, grant, donor)
            art = CompiledArtifact(task, rec, blocks_from_record(rec),
                                   result=res)
            if lower:
                try:
                    art.lower()
                except LoweringError:
                    pass  # no Pallas realization; blocks remain usable
            out[idx] = art
        return [out[i] for i in range(len(tasks))]

    # ------------------------------------------------------------------
    # winner selection + persistence
    # ------------------------------------------------------------------
    def _measured(self) -> MeasuredOracle:
        if self._measured_oracle is None:
            # hardware floors even under the interpreter: the re-rank must
            # time the same launch configuration the record persists
            self._measured_oracle = MeasuredOracle(
                self.platform, repeats=self.measure_repeats,
                hardware_floors=True, tracer=self.trace,
            )
        return self._measured_oracle

    def _pick_winner(self, res):
        """Re-rank the search's top schedules by real timed execution.

        The analytical winner is a *prediction*; before a record is
        persisted for every model build to read, the top ``rerank_top``
        candidates are lowered and wall-clock timed, and the measured
        fastest wins.  Schedules with no measurable realization (or when
        ``measure=False``) fall back to the analytical ranking.
        """
        if not self.measure:
            return res.best_schedule, None
        cands = list(res.top_schedules[: self.rerank_top])
        if res.best_schedule is not None and res.best_schedule not in cands:
            cands.insert(0, res.best_schedule)
        mo = self._measured()
        timed = []
        for s in cands:
            try:
                timed.append((mo.measure(s), s))
            except LoweringError:
                continue
        if not timed:
            return res.best_schedule, None
        t, winner = min(timed, key=lambda x: x[0])
        measured = dict(
            measured_latency_s=t,
            provenance=dict(
                oracle="measured",
                interpret=mo.interpret,
                warmup=mo.warmup,
                repeats=mo.repeats,
                candidates=len(timed),
                search_oracle=res.oracle,
                method=self.method,
                llm=self.llm_name,
            ),
        )
        return winner, measured

    def _store(self, task: Task, res, grant: int,
               donor: Optional[TaskOutcome]) -> TuningRecord:
        winner, measured = self._pick_winner(res)
        if task.kind == "attention":
            blocks = AttentionBlocks.from_schedule(winner)
        else:
            blocks = GemmBlocks.from_schedule(winner)
        prov: dict = dict(
            oracle=res.oracle,
            budget_granted=grant,
            shared_context=self.shared_context,
            # replay fidelity for the surrogate's feature extraction:
            # dtype/epilogue are not recoverable from dims alone
            dtype_bytes=task.workload.output.dtype_bytes,
            epilogue=task.workload.epilogue_kind or "none",
        )
        if hasattr(self.oracle, "surrogate_provenance"):
            prov["surrogate"] = self.oracle.surrogate_provenance()
        if donor is not None:
            prov["seeded_from"] = donor.workload_name
            prov["donor_speedup"] = round(donor.best_speedup, 3)
        if measured:
            prov.update(measured["provenance"])
        rec = TuningRecord(
            key=record_key(self.platform.name, task.workload),
            kind=task.kind,
            params=dataclasses.asdict(blocks),
            speedup=res.best_speedup,
            samples=res.samples,
            method=res.method,
            platform=self.platform.name,
            workload=task.workload.name,
            dims={l.name: l.extent for l in task.workload.loops},
            llm=res.llm,
            proposer=res.proposer,
            reviewer=res.reviewer,
            review_action=res.review_action,
            oracle=res.oracle,
            measured=measured is not None,
            measured_latency_s=measured["measured_latency_s"]
            if measured else None,
            history=tuple(winner.history) if winner is not None else (),
            provenance=prov,
        )
        return self.records.add(rec)
