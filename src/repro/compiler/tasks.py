"""Compilation tasks: what a ``CompilerSession`` is asked to optimize.

A ``Task`` is one (workload, constraints, priority) unit of search work.
``tasks_for_config`` enumerates the hot attention/GEMM shapes of an
``ArchConfig`` at a serving context length and TP degree — the whole-arch
tuning set ``python -m repro.launch.tune --all-kernels`` compiles in one
shared-context session.

Tasks in the same ``family`` (same operator with sequence-dependent dims
varying) are the cross-seeding unit: the winning transform trace of an
already-compiled family member primes the search of its siblings
(``compiler/context.py``, LiteCoOp-style shared-tree reasoning).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..core.workloads import Workload, attention_workload, matmul_workload

# ---------------------------------------------------------------------------
# tuning workloads + tp-local shape helpers (moved here from core/autotuner,
# which re-exports them for compatibility)
# ---------------------------------------------------------------------------


def local_attention_dims(cfg, tp: int = 1) -> tuple[int, int]:
    """Post-SPMD per-device (query_heads, kv_heads) for an ArchConfig.

    Mirrors ``dist.rules`` exactly: an axis shards over "model" only when
    the padded head count divides the TP degree, otherwise it stays
    replicated (e.g. KV heads when ``kv_heads < tp``).  Tuning against
    these LOCAL extents is what makes the cached block specs legal for the
    per-device Pallas launch after GSPMD partitioning — the global shapes
    can suggest tiles larger than a device's actual slice.
    """
    def local(padded: int) -> int:
        return padded // tp if tp > 0 and padded % tp == 0 else padded

    return local(cfg.padded_heads(tp)), local(cfg.padded_kv_heads(tp))


def attention_tuning_workload(
    heads: int, seq_q: int, seq_kv: int, head_dim: int,
    kv_heads: Optional[int] = None, name: str = "attn",
) -> Workload:
    """Attention workload keyed by the GQA shape.

    ``kv_heads`` (default: MHA, == heads) is folded into the workload name
    — and therefore the tuning-record key — because the K/V streaming
    volume per query tile depends on the KV head count: a block_k tuned
    for 32 local KV heads is not the right tile for 1 replicated head.
    """
    kv_heads = heads if kv_heads is None else kv_heads
    if kv_heads != heads:
        name = f"{name}.kv{kv_heads}"
    return attention_workload(
        name, heads=heads, seq_q=seq_q, seq_kv=seq_kv, head_dim=head_dim,
        dtype_bytes=2,
    )


def gemm_tuning_workload(m: int, n: int, k: int, name: str = "gemm",
                         epilogue: str = "none") -> Workload:
    return matmul_workload(name, m=m, n=n, k=k, dtype_bytes=2,
                           epilogue=epilogue)


# ---------------------------------------------------------------------------
# Task
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Task:
    """One unit of compilation work for a session.

    ``priority``: higher compiles first (and therefore becomes the seed
    donor for lower-priority siblings).  ``min_samples``/``max_samples``
    are per-task constraints on the session's budget allocation; ``None``
    max means "whatever the budget policy grants".
    """

    workload: Workload
    kind: str                       # "attention" | "gemm"
    priority: int = 0
    min_samples: int = 4
    max_samples: Optional[int] = None
    family: str = ""                # cross-seeding group; "" -> derived
    label: str = ""                 # human-readable provenance tag

    @property
    def family_key(self) -> str:
        if self.family:
            return self.family
        # same operator, same non-sequence dims -> siblings.  Sequence axes
        # (attention i/j, GEMM m) are what varies across serving shapes.
        w = self.workload
        dims = {l.name: l.extent for l in w.loops}
        if self.kind == "attention":
            return f"attention/h{dims.get('h')}/d{dims.get('k')}/" \
                   f"{w.name.split('.')[-1] if '.kv' in w.name else 'mha'}"
        return f"gemm/{w.epilogue_kind or 'none'}/" \
               f"n{dims.get('j')}/k{dims.get('k')}"

    def describe(self) -> str:
        dims = ",".join(f"{l.name}={l.extent}" for l in self.workload.loops)
        return f"{self.kind}:{self.workload.name}[{dims}]" \
               + (f" ({self.label})" if self.label else "")


def attention_task(
    heads: int, seq_q: int, seq_kv: int, head_dim: int,
    kv_heads: Optional[int] = None, priority: int = 0, label: str = "",
    **kw,
) -> Task:
    w = attention_tuning_workload(heads, seq_q, seq_kv, head_dim,
                                  kv_heads=kv_heads)
    return Task(w, "attention", priority=priority, label=label, **kw)


def gemm_task(
    m: int, n: int, k: int, epilogue: str = "none", priority: int = 0,
    label: str = "", **kw,
) -> Task:
    w = gemm_tuning_workload(m, n, k, epilogue=epilogue)
    return Task(w, "gemm", priority=priority, label=label, **kw)


def tasks_for_config(cfg, seq: int, tp: int = 1) -> list[Task]:
    """All hot attention/GEMM shapes of one arch at (seq, tp).

    Priorities follow flop share (attention and the MLP gate-up dominate a
    decoder layer), so the budget policy spends first — and seeds from —
    where the serving time goes.
    """
    tasks: list[Task] = []
    if cfg.block not in ("xlstm",):
        hq, hkv = local_attention_dims(cfg, tp)
        tasks.append(attention_task(
            hq, seq, seq, cfg.hd, kv_heads=hkv, priority=100,
            label=f"{cfg.name} attention tp={tp}",
        ))
        qkv_n = (cfg.heads + 2 * cfg.kv_heads) * cfg.hd
        tasks.append(gemm_task(
            seq, qkv_n, cfg.d_model, priority=60,
            label=f"{cfg.name} qkv-proj",
        ))
        tasks.append(gemm_task(
            seq, cfg.d_model, cfg.heads * cfg.hd, priority=50,
            label=f"{cfg.name} o-proj",
        ))
    if cfg.d_ff:
        tasks.append(gemm_task(
            seq, cfg.d_ff, cfg.d_model, epilogue="swiglu", priority=90,
            label=f"{cfg.name} mlp gate-up",
        ))
        if cfg.block == "moe" and cfg.n_experts:
            # per-expert token tile under uniform routing
            m = max(8, (seq * max(1, cfg.top_k)) // cfg.n_experts)
            tasks.append(gemm_task(
                m, cfg.d_ff, cfg.d_model, priority=40,
                label=f"{cfg.name} moe expert",
            ))
    return tasks


def tasks_for_shapes(
    cfg, *, attention=(), gemm_m=(), tp: int = 1,
) -> list[Task]:
    """Tasks for OBSERVED hot shapes (the serve→compile loop's input).

    ``attention`` is an iterable of ``((seq_q, seq_kv), weight)`` pairs
    and ``gemm_m`` of ``(m, weight)`` pairs — plain data, exactly what
    ``serve.metrics.ShapeStats.top_k`` returns, so the serving layer
    never imports the compiler (and vice versa).  Head counts / model
    dims come from ``cfg`` at the given TP degree.  Priorities are
    rank-ordered by weight: the hottest observed shape compiles first
    and seeds its family's colder siblings.
    """
    hq, hkv = local_attention_dims(cfg, tp)
    ranked = sorted(
        [("attention", tuple(int(x) for x in s), float(w))
         for s, w in attention]
        + [("gemm", (int(m),), float(w)) for m, w in gemm_m],
        key=lambda t: (-t[2], t[0], t[1]),
    )
    tasks: list[Task] = []
    for rank, (kind, shape, weight) in enumerate(ranked):
        prio = 100 - rank
        if kind == "attention":
            sq, skv = shape if len(shape) == 2 else (shape[0], shape[0])
            tasks.append(attention_task(
                hq, sq, skv, cfg.hd, kv_heads=hkv, priority=prio,
                label=f"{cfg.name} hot attention {sq}x{skv} "
                      f"(w={weight:.3g})",
            ))
        else:
            tasks.append(gemm_task(
                shape[0], cfg.d_ff, cfg.d_model, epilogue="swiglu",
                priority=prio,
                label=f"{cfg.name} hot mlp m={shape[0]} (w={weight:.3g})",
            ))
    return tasks
