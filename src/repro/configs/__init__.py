from .base import ArchConfig, ShapeSpec, SHAPES, get_config, input_specs, list_archs  # noqa: F401
