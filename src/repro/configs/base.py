"""Architecture config schema + the four assigned input shapes.

Every assigned architecture gets one ``configs/<id>.py`` defining an exact
``ArchConfig`` per the public spec, plus a reduced ``smoke()`` variant for
CPU tests.  ``input_specs()`` produces ShapeDtypeStruct stand-ins for the
dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# shapes (assigned): seq_len x global_batch; decode_*/long_* lower serve_step
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture (exact public config)."""

    name: str
    family: str          # dense | audio | ssm | vlm | hybrid | moe
    layers: int
    d_model: int
    heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // heads
    # block structure
    block: str = "dense"         # dense | moe | xlstm | hybrid | encoder
    causal: bool = True
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    window: int = 0              # sliding-window size (0 = full attention)
    global_layer_every: int = 0  # hybrid: every k-th layer is full-attention
    # modality frontend stubs
    frontend: Optional[str] = None   # None | "audio" | "vision"
    frontend_dim: int = 0            # stub feature dim
    vision_patches: int = 0          # VLM: patch tokens prepended
    # training
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    # provenance
    source: str = ""
    # Deploy-time tuned-kernel resolution (repro.compiler.ArtifactSet
    # epoch), bound by the engine that owns the mesh via
    # ``repro.compiler.ArtifactRegistry.bind`` and read by traced
    # attention launches (models/layers.attention_block).  Excluded from
    # eq/hash: two configs describe the same architecture regardless of
    # which tuning artifacts are bound.
    artifacts: Optional[object] = dataclasses.field(
        default=None, compare=False, repr=False,
    )

    def with_artifacts(self, artifacts) -> "ArchConfig":
        """.. deprecated:: bind through
        ``repro.compiler.ArtifactRegistry.bind(cfg, mesh=...)`` — the one
        engine-binding entry point, whose epochs engines can hot-swap.
        Kept one release as a thin alias over ``dataclasses.replace``."""
        warnings.warn(
            "ArchConfig.with_artifacts is deprecated; bind through "
            "repro.compiler.ArtifactRegistry.bind(cfg, mesh=...)",
            DeprecationWarning, stacklevel=2,
        )
        return dataclasses.replace(self, artifacts=artifacts)

    # -- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.heads

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k-token contexts? (SSM / windowed hybrid)"""
        return self.block in ("xlstm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return self.block != "encoder"

    def padded_heads(self, tp: int) -> int:
        """Megatron-style head padding to the TP degree (DESIGN.md §6).

        Padding is PER KV GROUP so the GQA mapping ``q_head // group`` still
        lands on the right kv head (function-preserving: padded slots carry
        zero weights).  If consistent padding would cost > 1.5x extra query
        heads (e.g. hymba's 25q/5kv on tp=16 would need 80), the arch keeps
        its true head count and attention is replicated on the model axis
        instead (dist.sharding checks divisibility).
        """
        if self.heads % tp == 0:
            return self.heads
        group = self.heads // self.kv_heads
        g = group
        while (self.kv_heads * g) % tp != 0:
            g += 1
        padded = self.kv_heads * g
        if padded > 1.5 * self.heads:
            return self.heads
        return padded

    def head_group_sizes(self, tp: int) -> tuple[int, int]:
        """(real_group, padded_group) of query heads per kv head."""
        group = self.heads // self.kv_heads
        return group, self.padded_heads(tp) // self.kv_heads

    def padded_kv_heads(self, tp: int) -> int:
        if self.kv_heads >= tp:
            return math.ceil(self.kv_heads / tp) * tp
        return self.kv_heads  # replicated when kv < tp

    def padded_vocab(self, tp: int) -> int:
        q = tp * 128
        return math.ceil(self.vocab / q) * q

    def supports(self, shape: str) -> tuple[bool, str]:
        """Whether an assigned shape cell applies to this arch (and why not)."""
        sp = SHAPES[shape]
        if sp.kind == "decode" and not self.has_decode:
            return False, "encoder-only architecture has no decode step"
        if shape == "long_500k" and not self.sub_quadratic:
            return False, ("full quadratic attention at 524k context is not "
                           "servable; shape assigned to SSM/hybrid archs only")
        return True, ""

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        d, f, L = self.d_model, self.d_ff, self.layers
        hq, hkv, hd = self.heads, self.kv_heads, self.hd
        attn = d * (hq * hd) + 2 * d * (hkv * hd) + (hq * hd) * d
        if self.block == "moe":
            mlp = 3 * d * f * self.n_experts + d * self.n_experts
        elif self.block == "xlstm":
            attn = 0
            inner = 2 * d
            mlp = 2 * d * inner + inner * d + 3 * inner * (inner // 4)
        else:
            mlp = 3 * d * f
        if self.block == "hybrid":
            inner = 2 * d
            mlp += 2 * d * inner + inner * self.ssm_state * 2
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return L * (attn + mlp) + emb

    def flops_per_token(self, training: bool = True) -> float:
        """MODEL_FLOPS/token: 6ND train (2ND forward), N = active params."""
        n = self.active_param_count()
        return (6.0 if training else 2.0) * n

    def active_param_count(self) -> int:
        if self.block != "moe":
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.layers
        hq, hkv, hd = self.heads, self.kv_heads, self.hd
        attn = d * (hq * hd) + 2 * d * (hkv * hd) + (hq * hd) * d
        mlp = 3 * d * f * self.top_k + d * self.n_experts
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return L * (attn + mlp) + emb


def input_specs(cfg: ArchConfig, shape: str, *, per_host: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of a step function.

    Train: {tokens, labels}; prefill: {tokens}; decode: {tokens(1-step)} plus
    the KV/state cache created by ``serve.cache_specs``.  Frontend stubs add
    precomputed frame/patch embeddings per the assignment note.
    """
    sp = SHAPES[shape]
    b, s = sp.global_batch, sp.seq_len
    i32 = jnp.int32
    if sp.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
    elif sp.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    else:  # decode: one new token against a seq_len-deep cache
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.frontend == "audio":
        # encoder stub: precomputed frame embeddings replace tokens
        specs = {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim),
                                           jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        } if sp.kind == "train" else {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim),
                                           jnp.bfloat16),
        }
    elif cfg.frontend == "vision" and sp.kind == "train":
        specs["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_patches, cfg.d_model), jnp.bfloat16
        )
    return specs


_REGISTRY: dict[str, ArchConfig] = {}
_SMOKE: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig, smoke: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    _SMOKE[cfg.name] = smoke
    return cfg


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    _load_all()
    return (_SMOKE if smoke else _REGISTRY)[name]


def list_archs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


_LOADED = False


def _load_all() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from . import (  # noqa: F401
        stablelm_1_6b, tinyllama_1_1b, stablelm_12b, phi4_mini_3_8b,
        hubert_xlarge, xlstm_125m, llava_next_34b, hymba_1_5b,
        qwen3_moe_30b_a3b, llama4_scout_17b_a16e,
    )
