"""HuBERT-XLarge encoder [arXiv:2106.07447; unverified].

Encoder-only (bidirectional attention, LayerNorm, no decode shapes); the
conv waveform frontend is a STUB per the assignment: input_specs() provides
precomputed 512-dim frame embeddings.
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="hubert-xlarge", family="audio", layers=48, d_model=1280,
    heads=16, kv_heads=16, d_ff=5120, vocab=504, block="encoder",
    causal=False, norm="layernorm", frontend="audio", frontend_dim=512,
    source="arXiv:2106.07447",
)
SMOKE = ArchConfig(
    name="hubert-xlarge", family="audio", layers=2, d_model=128,
    heads=4, kv_heads=4, d_ff=256, vocab=64, block="encoder",
    causal=False, norm="layernorm", frontend="audio", frontend_dim=32,
    dtype="float32", source="smoke",
)
register(FULL, SMOKE)
