"""Hymba-1.5B hybrid: parallel attn + mamba heads [arXiv:2411.13676; hf].

Each block runs a sliding-window attention path (window 1024) and an SSM
path in parallel and mean-combines them; every 16th layer uses global
attention (the paper keeps 3 global layers). ssm_state=16. 25 query heads
pad to 32 on the 16-way model axis. Sub-quadratic: runs long_500k.
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="hymba-1.5b", family="hybrid", layers=32, d_model=1600,
    heads=25, kv_heads=5, d_ff=5504, vocab=32001, head_dim=64,
    block="hybrid", ssm_state=16, window=1024, global_layer_every=16,
    source="arXiv:2411.13676",
)
SMOKE = ArchConfig(
    name="hymba-1.5b", family="hybrid", layers=2, d_model=64,
    heads=4, kv_heads=2, d_ff=128, vocab=256, head_dim=16,
    block="hybrid", ssm_state=4, window=32, global_layer_every=2,
    dtype="float32", source="smoke",
)
register(FULL, SMOKE)
