"""Llama-4-Scout-17B-16E: MoE 16 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

40 query heads pad to 48 on the 16-way model axis; 16 experts shard
1-per-device (EP).
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe", layers=48, d_model=5120,
    heads=40, kv_heads=8, d_ff=8192, vocab=202048, head_dim=128,
    block="moe", n_experts=16, top_k=1,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
SMOKE = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe", layers=2, d_model=64,
    heads=4, kv_heads=2, d_ff=128, vocab=256, head_dim=32,
    block="moe", n_experts=4, top_k=1, dtype="float32", source="smoke",
)
register(FULL, SMOKE)
