"""LLaVA-NeXT-34B backbone [hf:llava-hf/llava-v1.6; unverified].

The vision tower + anyres tiling frontend is a STUB per the assignment:
input_specs() provides precomputed patch embeddings (2880 tokens ~ 5 tiles
x 576 patches) prepended to the text sequence. 56 query heads pad to 64 on
the 16-way model axis (DESIGN.md §6).
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="llava-next-34b", family="vlm", layers=60, d_model=7168,
    heads=56, kv_heads=8, d_ff=20480, vocab=64000,
    frontend="vision", vision_patches=2880,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
SMOKE = ArchConfig(
    name="llava-next-34b", family="vlm", layers=2, d_model=128,
    heads=8, kv_heads=2, d_ff=256, vocab=512,
    frontend="vision", vision_patches=16, dtype="float32", source="smoke",
)
register(FULL, SMOKE)
