"""Phi-4-mini 3.8B: RoPE SwiGLU GQA [arXiv:2412.08905; hf].

24 heads with head_dim 128; on the 16-way model axis the query heads are
padded 24 -> 32 (Megatron-style; DESIGN.md §6), kv heads (8) replicated.
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="phi4-mini-3.8b", family="dense", layers=32, d_model=3072,
    heads=24, kv_heads=8, d_ff=8192, vocab=200064, head_dim=128,
    source="arXiv:2412.08905",
)
SMOKE = ArchConfig(
    name="phi4-mini-3.8b", family="dense", layers=2, d_model=96,
    heads=3, kv_heads=1, d_ff=256, vocab=512, head_dim=32,
    dtype="float32", source="smoke",
)
register(FULL, SMOKE)
