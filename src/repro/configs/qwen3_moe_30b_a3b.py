"""Qwen3-30B-A3B MoE: 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

d_ff=768 is per-expert; head_dim=128 per the HF config (not d_model/heads).
Experts shard 8-per-device over the 16-way model axis (EP).
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe", layers=48, d_model=2048,
    heads=32, kv_heads=4, d_ff=768, vocab=151936, head_dim=128,
    block="moe", n_experts=128, top_k=8,
    source="hf:Qwen/Qwen3-30B-A3B",
)
SMOKE = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe", layers=2, d_model=64,
    heads=4, kv_heads=2, d_ff=64, vocab=256, head_dim=32,
    block="moe", n_experts=8, top_k=2, dtype="float32", source="smoke",
)
register(FULL, SMOKE)
