"""StableLM-2-12B [hf:stabilityai/stablelm-2-12b; hf]."""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="stablelm-12b", family="dense", layers=40, d_model=5120,
    heads=32, kv_heads=8, d_ff=13824, vocab=100352,
    source="hf:stabilityai/stablelm-2-12b",
)
SMOKE = ArchConfig(
    name="stablelm-12b", family="dense", layers=2, d_model=128,
    heads=8, kv_heads=2, d_ff=384, vocab=512, dtype="float32",
    source="smoke",
)
register(FULL, SMOKE)
