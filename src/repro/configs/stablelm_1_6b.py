"""StableLM-2-1.6B [hf:stabilityai/stablelm-2-1_6b; unverified]."""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="stablelm-1.6b", family="dense", layers=24, d_model=2048,
    heads=32, kv_heads=32, d_ff=5632, vocab=100352,
    source="hf:stabilityai/stablelm-2-1_6b",
)
SMOKE = ArchConfig(
    name="stablelm-1.6b", family="dense", layers=2, d_model=128,
    heads=4, kv_heads=4, d_ff=256, vocab=512, dtype="float32",
    source="smoke",
)
register(FULL, SMOKE)
