"""TinyLlama-1.1B (llama2-arch small) [arXiv:2401.02385; hf]."""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="tinyllama-1.1b", family="dense", layers=22, d_model=2048,
    heads=32, kv_heads=4, d_ff=5632, vocab=32000,
    source="arXiv:2401.02385",
)
SMOKE = ArchConfig(
    name="tinyllama-1.1b", family="dense", layers=2, d_model=128,
    heads=8, kv_heads=2, d_ff=256, vocab=512, dtype="float32",
    source="smoke",
)
register(FULL, SMOKE)
