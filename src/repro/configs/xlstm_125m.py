"""xLSTM-125M: sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

d_ff=0 per spec: xLSTM blocks carry their own up/down projections
(proj_factor 2). Every 4th block is sLSTM, the rest mLSTM (xLSTM[3:1]).
Sub-quadratic: runs the long_500k cell.
"""
from .base import ArchConfig, register

FULL = ArchConfig(
    name="xlstm-125m", family="ssm", layers=12, d_model=768,
    heads=4, kv_heads=4, d_ff=0, vocab=50304, block="xlstm",
    ssm_state=0, tie_embeddings=True,
    source="arXiv:2405.04517",
)
SMOKE = ArchConfig(
    name="xlstm-125m", family="ssm", layers=2, d_model=64,
    heads=2, kv_heads=2, d_ff=0, vocab=256, block="xlstm",
    tie_embeddings=True, dtype="float32", source="smoke",
)
register(FULL, SMOKE)
