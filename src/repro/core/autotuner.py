"""Compatibility re-exports for the retired kernel-tuning entry point.

This module used to own the whole deploy-time tuning flow (LLM-guided MCTS
per workload + a raw JSON cache).  That flow lives behind the session API
now: ``repro.compiler.CompilerSession`` owns the LLM/oracle/record-store
for its lifetime, compiles related shapes through a shared search context,
and persists schema-versioned, provenance-carrying records
(``repro/compiler/records.py``); serving engines resolve the results
through ``repro.compiler.ArtifactRegistry`` epochs.

The class that lived here (``KernelTuner``) and its free-function sibling
(``core.search.run_search``) spent one release as deprecation shims and
are gone.  What remains importable from here are the block/workload
helpers old tests and tools reference:

* ``AttentionBlocks`` / ``GemmBlocks`` — block-parameter bundles
  (``compiler/artifacts.py``);
* ``local_attention_dims`` / ``attention_tuning_workload`` /
  ``gemm_tuning_workload`` — tp-local shape + workload builders
  (``compiler/tasks.py``);
* ``_quantize_block`` / ``_band_extent`` — lowering block extraction
  (``core/lowering.py``).
"""
from __future__ import annotations

# Block extraction lives with the artifact layer (compiler/artifacts.py);
# the lowering helpers stay importable here for old tests.
from ..compiler.artifacts import AttentionBlocks, GemmBlocks
from ..compiler.records import (  # noqa: F401 (compat)
    LEGACY_JSON_PATH,
    TuningRecords,
    record_key,
)
from ..compiler.session import BudgetPolicy, CompilerSession  # noqa: F401
from ..compiler.tasks import (  # noqa: F401 (compat)
    attention_task,
    attention_tuning_workload,
    gemm_task,
    gemm_tuning_workload,
    local_attention_dims,
)
from .cost_model import HardwareOracle, get_platform  # noqa: F401 (compat)
from .lowering import LoweringError, _band_extent, _quantize_block  # noqa: F401
from .schedule import Schedule  # noqa: F401 (compat)
from .search import SearchResult  # noqa: F401 (compat)
from .workloads import (  # noqa: F401 (compat)
    Workload,
    attention_workload,
    matmul_workload,
)

DEFAULT_CACHE_PATH = LEGACY_JSON_PATH

__all__ = [
    "AttentionBlocks",
    "BudgetPolicy",
    "CompilerSession",
    "DEFAULT_CACHE_PATH",
    "GemmBlocks",
    "attention_tuning_workload",
    "gemm_tuning_workload",
    "local_attention_dims",
]
