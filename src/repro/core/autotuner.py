"""Ties the Reasoning Compiler's schedule search to runnable kernel configs.

This is what makes the paper's technique a *first-class feature* of the
serving/training framework rather than a side experiment: per (workload x
target) the tuner runs LLM-guided MCTS on the TPU platform profile, extracts
the Pallas block parameters from the winning schedule, and persists them in
a JSON tuning cache that ``repro.kernels.ops`` consumers look up at model
build time.

Mapping (DESIGN.md §3): the VMEM-band tile extents (spatial levels 2..3) of
a tuned schedule are the Pallas BlockSpec block shape; the reduction inner
tile is ``bk``; a fused epilogue (ComputeLocation >= 0) selects the fused
kernel variant (flash attention / fused gate-up).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Optional

from .cost_model import HardwareOracle, get_platform
from .schedule import SPATIAL_LEVELS, Schedule
from .search import SearchResult, run_search
from .workloads import (
    Workload,
    attention_workload,
    matmul_workload,
)

DEFAULT_CACHE_PATH = os.path.join(
    os.path.dirname(__file__), "..", "configs", "tuning_cache.json"
)


def _quantize_block(x: int, extent: int, lo: int = 8, hi: int = 1024) -> int:
    """Clamp a tile extent to a power of two that divides the extent."""
    x = max(lo, min(hi, x))
    p = 1 << int(math.log2(max(1, x)))
    while p > lo and extent % p != 0:
        p //= 2
    return max(lo, min(p, extent)) if extent % max(lo, min(p, extent)) == 0 \
        else min(lo, extent)


def _band_extent(s: Schedule, axis: str) -> int:
    """Product of the VMEM-band tile levels (spatial 2..3 / reduction 1)."""
    tm = s.tile_map[axis]
    if len(tm) == SPATIAL_LEVELS:
        return tm[2] * tm[3]
    return tm[-1]


@dataclasses.dataclass
class AttentionBlocks:
    block_q: int = 128
    block_k: int = 128

    @classmethod
    def from_schedule(cls, s: Schedule) -> "AttentionBlocks":
        w = s.workload
        sq = w.loop_map["i"].extent
        skv = w.loop_map["j"].extent
        bq = _quantize_block(_band_extent(s, "i"), sq, lo=8, hi=512)
        bk = _quantize_block(_band_extent(s, "j"), skv, lo=128, hi=1024)
        return cls(block_q=bq, block_k=bk)


@dataclasses.dataclass
class GemmBlocks:
    bm: int = 128
    bn: int = 128
    bk: int = 512

    @classmethod
    def from_schedule(cls, s: Schedule) -> "GemmBlocks":
        w = s.workload
        m = w.loop_map["i"].extent
        n = w.loop_map["j"].extent
        k = w.loop_map["k"].extent
        return cls(
            bm=_quantize_block(_band_extent(s, "i"), m, lo=8, hi=512),
            bn=_quantize_block(_band_extent(s, "j"), n, lo=128, hi=1024),
            bk=_quantize_block(_band_extent(s, "k"), k, lo=128, hi=2048),
        )


def local_attention_dims(cfg, tp: int = 1) -> tuple[int, int]:
    """Post-SPMD per-device (query_heads, kv_heads) for an ArchConfig.

    Mirrors ``dist.rules`` exactly: an axis shards over "model" only when
    the padded head count divides the TP degree, otherwise it stays
    replicated (e.g. KV heads when ``kv_heads < tp``).  Tuning against
    these LOCAL extents is what makes the cached block specs legal for the
    per-device Pallas launch after GSPMD partitioning — the global shapes
    can suggest tiles larger than a device's actual slice.
    """
    def local(padded: int) -> int:
        return padded // tp if tp > 0 and padded % tp == 0 else padded

    return local(cfg.padded_heads(tp)), local(cfg.padded_kv_heads(tp))


def attention_tuning_workload(
    heads: int, seq_q: int, seq_kv: int, head_dim: int,
    kv_heads: Optional[int] = None, name: str = "attn",
) -> Workload:
    """Attention workload keyed by the GQA shape.

    ``kv_heads`` (default: MHA, == heads) is folded into the workload name
    — and therefore the tuning-cache key — because the K/V streaming
    volume per query tile depends on the KV head count: a block_k tuned
    for 32 local KV heads is not the right tile for 1 replicated head.
    """
    kv_heads = heads if kv_heads is None else kv_heads
    if kv_heads != heads:
        name = f"{name}.kv{kv_heads}"
    return attention_workload(
        name, heads=heads, seq_q=seq_q, seq_kv=seq_kv, head_dim=head_dim,
        dtype_bytes=2,
    )


def gemm_tuning_workload(m: int, n: int, k: int, name: str = "gemm",
                         epilogue: str = "none") -> Workload:
    return matmul_workload(name, m=m, n=n, k=k, dtype_bytes=2,
                           epilogue=epilogue)


class KernelTuner:
    """LLM-guided-MCTS kernel autotuner with a persistent JSON cache."""

    def __init__(
        self,
        platform: str = "tpu-v5e",
        method: str = "llm-mcts",
        budget: int = 64,
        cache_path: Optional[str] = DEFAULT_CACHE_PATH,
        llm: str = "gpt-4o-mini",
    ):
        self.platform = platform
        self.method = method
        self.budget = budget
        self.llm = llm
        self.cache_path = cache_path
        self._cache: dict = {}
        if cache_path and os.path.exists(cache_path):
            with open(cache_path) as f:
                self._cache = json.load(f)

    def _key(self, w: Workload) -> str:
        dims = ",".join(f"{l.name}={l.extent}" for l in w.loops)
        return f"{self.platform}:{w.name}[{dims}]"

    def tune_attention(
        self, heads, seq_q, seq_kv, head_dim, kv_heads=None
    ) -> AttentionBlocks:
        w = attention_tuning_workload(
            heads, seq_q, seq_kv, head_dim, kv_heads=kv_heads
        )
        key = self._key(w)
        if key in self._cache:
            e = self._cache[key]
            return AttentionBlocks(e["block_q"], e["block_k"])
        res = self._search(w)
        blocks = AttentionBlocks.from_schedule(res.best_schedule)
        self._store(key, dataclasses.asdict(blocks), res)
        return blocks

    def lookup_attention(
        self, heads, seq_q, seq_kv, head_dim, kv_heads=None
    ) -> Optional[AttentionBlocks]:
        """Read-only cache probe (no search on miss) — the model-build-time
        path ``kernels.ops.tuned_attention_blocks`` uses."""
        w = attention_tuning_workload(
            heads, seq_q, seq_kv, head_dim, kv_heads=kv_heads
        )
        e = self._cache.get(self._key(w))
        return AttentionBlocks(e["block_q"], e["block_k"]) if e else None

    def tune_gemm(self, m, n, k, epilogue="none") -> GemmBlocks:
        w = gemm_tuning_workload(m, n, k, epilogue=epilogue)
        key = self._key(w)
        if key in self._cache:
            e = self._cache[key]
            return GemmBlocks(e["bm"], e["bn"], e["bk"])
        res = self._search(w)
        blocks = GemmBlocks.from_schedule(res.best_schedule)
        self._store(key, dataclasses.asdict(blocks), res)
        return blocks

    def _search(self, w: Workload) -> SearchResult:
        return run_search(
            w, self.platform, self.method, budget=self.budget, seed=0,
            llm=self.llm,
        )

    def _store(self, key: str, params: dict, res: SearchResult) -> None:
        self._cache[key] = dict(
            params, speedup=round(res.best_speedup, 3),
            samples=res.samples, method=self.method,
        )
        if self.cache_path:
            os.makedirs(os.path.dirname(self.cache_path), exist_ok=True)
            with open(self.cache_path, "w") as f:
                json.dump(self._cache, f, indent=1, sort_keys=True)
