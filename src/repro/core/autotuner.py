"""Deprecated kernel-tuning entry point — shim over ``repro.compiler``.

This module used to own the whole deploy-time tuning flow (LLM-guided MCTS
per workload + a raw JSON cache).  That flow now lives behind the session
API: ``repro.compiler.CompilerSession`` owns the LLM/oracle/record-store
for its lifetime, compiles related shapes through a shared search context,
and persists schema-versioned, provenance-carrying records
(``repro/compiler/records.py``).

Everything importable from here keeps working:

* ``AttentionBlocks`` / ``GemmBlocks`` / ``local_attention_dims`` /
  ``attention_tuning_workload`` / ``gemm_tuning_workload`` are re-exported
  from ``repro.compiler``.
* ``KernelTuner`` is a thin wrapper that builds a single-task
  ``CompilerSession`` per call, configured to reproduce the historical
  behavior exactly (no shared context, no early stop, seed 0).  Its
  ``cache_path`` JSON file is maintained as a *mirror* of the JSONL record
  store for old readers; a corrupt/truncated cache file is quarantined
  with a warning instead of crashing the constructor.

New code should use ``CompilerSession`` directly.
"""
from __future__ import annotations

import os
import warnings
from typing import Optional

# Block extraction lives with the artifact layer now (compiler/artifacts
# .py); the lowering helpers stay importable here for old tests.
from ..compiler.artifacts import AttentionBlocks, GemmBlocks
from ..compiler.records import (
    LEGACY_JSON_PATH,
    TuningRecords,
    record_key,
)
from ..compiler.session import BudgetPolicy, CompilerSession
from ..compiler.tasks import (
    attention_task,
    attention_tuning_workload,
    gemm_task,
    gemm_tuning_workload,
    local_attention_dims,
)
from .cost_model import HardwareOracle, get_platform  # noqa: F401 (compat)
from .lowering import LoweringError, _band_extent, _quantize_block  # noqa: F401
from .schedule import Schedule  # noqa: F401 (compat)
from .search import SearchResult, run_search  # noqa: F401 (compat)
from .workloads import (  # noqa: F401 (compat)
    Workload,
    attention_workload,
    matmul_workload,
)

DEFAULT_CACHE_PATH = LEGACY_JSON_PATH


def _records_for(cache_path: Optional[str]) -> TuningRecords:
    """Map a legacy ``cache_path`` onto a JSONL record store.

    ``<stem>.json`` stores records in ``<stem>.jsonl`` next to it and
    treats the JSON file as the v0 input to migrate (quarantining it with
    a warning when corrupt).  The module-default path resolves to the
    process-wide default store so engines and ``kernels.ops`` lookups see
    what a default-constructed tuner persists.
    """
    if cache_path is None:
        return TuningRecords(None)
    if os.path.abspath(cache_path) == os.path.abspath(DEFAULT_CACHE_PATH):
        from ..compiler.artifacts import default_records

        return default_records()
    if cache_path.endswith(".json"):
        return TuningRecords(cache_path[:-5] + ".jsonl",
                             legacy_json=cache_path)
    return TuningRecords(cache_path)


class KernelTuner:
    """Deprecated: thin shim over ``repro.compiler.CompilerSession``.

    One tuner = one session with the historical single-task semantics
    (per-task ``budget``, no shared context, no budget reallocation).
    ``measure=True`` still re-ranks winners by real timed execution before
    persisting; the persisted entries now carry schema-versioned
    provenance in the JSONL store, with ``cache_path`` maintained as a
    legacy JSON mirror.
    """

    def __init__(
        self,
        platform: str = "tpu-v5e",
        method: str = "llm-mcts",
        budget: int = 64,
        cache_path: Optional[str] = DEFAULT_CACHE_PATH,
        llm: str = "gpt-4o-mini",
        oracle: str = "analytical",
        measure: bool = False,
        rerank_top: int = 3,
        measure_repeats: int = 3,
    ):
        warnings.warn(
            "KernelTuner is deprecated; hold a repro.compiler."
            "CompilerSession and call session.compile instead",
            DeprecationWarning, stacklevel=2,
        )
        self.platform = platform
        self.method = method
        self.budget = budget
        self.llm = llm
        self.cache_path = cache_path
        self.oracle = oracle
        self.measure = measure
        self.rerank_top = rerank_top
        self.measure_repeats = measure_repeats
        self.session = CompilerSession(
            target=platform,
            oracle=oracle,
            proposer=llm,
            method=method,
            budget_policy=BudgetPolicy(
                per_task=budget, early_stop=False, reallocate=False,
            ),
            records=_records_for(cache_path),
            shared_context=False,
            measure=measure,
            rerank_top=rerank_top,
            measure_repeats=measure_repeats,
            seed=0,
        )

    @property
    def _cache(self) -> dict:
        """Legacy ``{key: entry}`` view of the record store."""
        return self.session.records.legacy_view()

    def _key(self, w: Workload) -> str:
        return record_key(self.platform, w)

    def _mirror(self) -> None:
        if self.cache_path and self.cache_path.endswith(".json"):
            self.session.records.export_json(self.cache_path)

    def tune_attention(
        self, heads, seq_q, seq_kv, head_dim, kv_heads=None
    ) -> AttentionBlocks:
        (art,) = self.session.compile([
            attention_task(heads, seq_q, seq_kv, head_dim, kv_heads=kv_heads)
        ])
        if not art.cache_hit:
            self._mirror()
        return art.blocks

    def lookup_attention(
        self, heads, seq_q, seq_kv, head_dim, kv_heads=None
    ) -> Optional[AttentionBlocks]:
        """Read-only cache probe (no search on miss) — the model-build-time
        path ``kernels.ops.tuned_attention_blocks`` uses."""
        w = attention_tuning_workload(
            heads, seq_q, seq_kv, head_dim, kv_heads=kv_heads
        )
        rec = self.session.records.get(self._key(w))
        return AttentionBlocks.from_params(rec.params) if rec else None

    def tune_gemm(self, m, n, k, epilogue="none") -> GemmBlocks:
        (art,) = self.session.compile([
            gemm_task(m, n, k, epilogue=epilogue)
        ])
        if not art.cache_hit:
            self._mirror()
        return art.blocks
