"""Ties the Reasoning Compiler's schedule search to runnable kernel configs.

This is what makes the paper's technique a *first-class feature* of the
serving/training framework rather than a side experiment: per (workload x
target) the tuner runs LLM-guided MCTS on the TPU platform profile, extracts
the Pallas block parameters from the winning schedule, and persists them in
a JSON tuning cache that ``repro.kernels.ops`` consumers look up at model
build time.

Mapping (DESIGN.md §3): the VMEM-band tile extents (spatial levels 2..3) of
a tuned schedule are the Pallas BlockSpec block shape; the reduction inner
tile is ``bk``; a fused epilogue (ComputeLocation >= 0) selects the fused
kernel variant (flash attention / fused gate-up).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

from .cost_model import HardwareOracle, get_platform
# Block extraction lives with the lowering bridge now (core/lowering.py):
# the same _band_extent/_quantize_block mapping that fills this cache also
# instantiates the kernels the MeasuredOracle times, so the persisted
# blocks are the measured blocks by construction.
from .lowering import LoweringError, _band_extent, _quantize_block
from .oracle import MeasuredOracle
from .schedule import Schedule
from .search import SearchResult, run_search
from .workloads import (
    Workload,
    attention_workload,
    matmul_workload,
)

DEFAULT_CACHE_PATH = os.path.join(
    os.path.dirname(__file__), "..", "configs", "tuning_cache.json"
)


@dataclasses.dataclass
class AttentionBlocks:
    block_q: int = 128
    block_k: int = 128

    @classmethod
    def from_schedule(cls, s: Schedule) -> "AttentionBlocks":
        w = s.workload
        sq = w.loop_map["i"].extent
        skv = w.loop_map["j"].extent
        bq = _quantize_block(_band_extent(s, "i"), sq, lo=8, hi=512)
        bk = _quantize_block(_band_extent(s, "j"), skv, lo=128, hi=1024)
        return cls(block_q=bq, block_k=bk)


@dataclasses.dataclass
class GemmBlocks:
    bm: int = 128
    bn: int = 128
    bk: int = 512

    @classmethod
    def from_schedule(cls, s: Schedule) -> "GemmBlocks":
        w = s.workload
        m = w.loop_map["i"].extent
        n = w.loop_map["j"].extent
        k = w.loop_map["k"].extent
        return cls(
            bm=_quantize_block(_band_extent(s, "i"), m, lo=8, hi=512),
            bn=_quantize_block(_band_extent(s, "j"), n, lo=128, hi=1024),
            bk=_quantize_block(_band_extent(s, "k"), k, lo=128, hi=2048),
        )


def local_attention_dims(cfg, tp: int = 1) -> tuple[int, int]:
    """Post-SPMD per-device (query_heads, kv_heads) for an ArchConfig.

    Mirrors ``dist.rules`` exactly: an axis shards over "model" only when
    the padded head count divides the TP degree, otherwise it stays
    replicated (e.g. KV heads when ``kv_heads < tp``).  Tuning against
    these LOCAL extents is what makes the cached block specs legal for the
    per-device Pallas launch after GSPMD partitioning — the global shapes
    can suggest tiles larger than a device's actual slice.
    """
    def local(padded: int) -> int:
        return padded // tp if tp > 0 and padded % tp == 0 else padded

    return local(cfg.padded_heads(tp)), local(cfg.padded_kv_heads(tp))


def attention_tuning_workload(
    heads: int, seq_q: int, seq_kv: int, head_dim: int,
    kv_heads: Optional[int] = None, name: str = "attn",
) -> Workload:
    """Attention workload keyed by the GQA shape.

    ``kv_heads`` (default: MHA, == heads) is folded into the workload name
    — and therefore the tuning-cache key — because the K/V streaming
    volume per query tile depends on the KV head count: a block_k tuned
    for 32 local KV heads is not the right tile for 1 replicated head.
    """
    kv_heads = heads if kv_heads is None else kv_heads
    if kv_heads != heads:
        name = f"{name}.kv{kv_heads}"
    return attention_workload(
        name, heads=heads, seq_q=seq_q, seq_kv=seq_kv, head_dim=head_dim,
        dtype_bytes=2,
    )


def gemm_tuning_workload(m: int, n: int, k: int, name: str = "gemm",
                         epilogue: str = "none") -> Workload:
    return matmul_workload(name, m=m, n=n, k=k, dtype_bytes=2,
                           epilogue=epilogue)


class KernelTuner:
    """LLM-guided-MCTS kernel autotuner with a persistent JSON cache.

    ``oracle`` picks the search-time objective (``"analytical"`` default,
    ``"measured"``/``"hybrid"`` per core/oracle.py).  ``measure=True``
    additionally re-ranks the search's top ``rerank_top`` schedules by a
    *real* timed kernel execution before persisting — the analytical
    winner is a prediction; the persisted entry then carries
    ``measured_latency_s`` plus provenance (oracle backend, interpret vs.
    compiled, harness settings).  The deploy-time launcher
    (``launch/tune.py``) turns measurement on by default; unit-scale
    callers leave it off to keep CI cheap.
    """

    def __init__(
        self,
        platform: str = "tpu-v5e",
        method: str = "llm-mcts",
        budget: int = 64,
        cache_path: Optional[str] = DEFAULT_CACHE_PATH,
        llm: str = "gpt-4o-mini",
        oracle: str = "analytical",
        measure: bool = False,
        rerank_top: int = 3,
        measure_repeats: int = 3,
    ):
        self.platform = platform
        self.method = method
        self.budget = budget
        self.llm = llm
        self.cache_path = cache_path
        self.oracle = oracle
        self.measure = measure
        self.rerank_top = rerank_top
        self.measure_repeats = measure_repeats
        self._measured_oracle: Optional[MeasuredOracle] = None
        self._cache: dict = {}
        if cache_path and os.path.exists(cache_path):
            with open(cache_path) as f:
                self._cache = json.load(f)

    def _key(self, w: Workload) -> str:
        dims = ",".join(f"{l.name}={l.extent}" for l in w.loops)
        return f"{self.platform}:{w.name}[{dims}]"

    def tune_attention(
        self, heads, seq_q, seq_kv, head_dim, kv_heads=None
    ) -> AttentionBlocks:
        w = attention_tuning_workload(
            heads, seq_q, seq_kv, head_dim, kv_heads=kv_heads
        )
        key = self._key(w)
        if key in self._cache:
            e = self._cache[key]
            return AttentionBlocks(e["block_q"], e["block_k"])
        res = self._search(w)
        winner, measured = self._pick_winner(res)
        blocks = AttentionBlocks.from_schedule(winner)
        self._store(key, dataclasses.asdict(blocks), res, measured)
        return blocks

    def lookup_attention(
        self, heads, seq_q, seq_kv, head_dim, kv_heads=None
    ) -> Optional[AttentionBlocks]:
        """Read-only cache probe (no search on miss) — the model-build-time
        path ``kernels.ops.tuned_attention_blocks`` uses."""
        w = attention_tuning_workload(
            heads, seq_q, seq_kv, head_dim, kv_heads=kv_heads
        )
        e = self._cache.get(self._key(w))
        return AttentionBlocks(e["block_q"], e["block_k"]) if e else None

    def tune_gemm(self, m, n, k, epilogue="none") -> GemmBlocks:
        w = gemm_tuning_workload(m, n, k, epilogue=epilogue)
        key = self._key(w)
        if key in self._cache:
            e = self._cache[key]
            return GemmBlocks(e["bm"], e["bn"], e["bk"])
        res = self._search(w)
        winner, measured = self._pick_winner(res)
        blocks = GemmBlocks.from_schedule(winner)
        self._store(key, dataclasses.asdict(blocks), res, measured)
        return blocks

    def _search(self, w: Workload) -> SearchResult:
        return run_search(
            w, self.platform, self.method, budget=self.budget, seed=0,
            llm=self.llm, oracle=self.oracle,
        )

    def _measured(self) -> MeasuredOracle:
        if self._measured_oracle is None:
            # hardware floors even under the interpreter: the re-rank must
            # time the same launch configuration from_schedule persists
            self._measured_oracle = MeasuredOracle(
                self.platform, repeats=self.measure_repeats,
                hardware_floors=True,
            )
        return self._measured_oracle

    def _pick_winner(self, res: SearchResult):
        """Re-rank the search's top schedules by real timed execution.

        The analytical winner is a *prediction*; before an entry is
        persisted for every model build to read, the top ``rerank_top``
        candidates are lowered and wall-clock timed, and the measured
        fastest wins.  Schedules with no measurable realization (or when
        ``measure=False``) fall back to the analytical ranking.
        """
        if not self.measure:
            return res.best_schedule, None
        cands = list(res.top_schedules[: self.rerank_top])
        if res.best_schedule is not None and res.best_schedule not in cands:
            cands.insert(0, res.best_schedule)
        mo = self._measured()
        timed = []
        for s in cands:
            try:
                timed.append((mo.measure(s), s))
            except LoweringError:
                continue
        if not timed:
            return res.best_schedule, None
        t, winner = min(timed, key=lambda x: x[0])
        measured = dict(
            measured_latency_s=t,
            provenance=dict(
                oracle="measured",
                interpret=mo.interpret,
                warmup=mo.warmup,
                repeats=mo.repeats,
                candidates=len(timed),
                search_oracle=res.oracle,
                method=self.method,
                llm=self.llm,
            ),
        )
        return winner, measured

    def _store(self, key: str, params: dict, res: SearchResult,
               measured: Optional[dict] = None) -> None:
        entry = dict(
            params, speedup=round(res.best_speedup, 3),
            samples=res.samples, method=self.method,
        )
        if measured:
            entry.update(measured)
        self._cache[key] = entry
        if self.cache_path:
            os.makedirs(os.path.dirname(self.cache_path), exist_ok=True)
            with open(self.cache_path, "w") as f:
                json.dump(self._cache, f, indent=1, sort_keys=True)
