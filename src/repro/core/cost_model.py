"""Hardware cost models: the objective `f` and the learned surrogate `f̂`.

The paper (§3.2) distinguishes two evaluators:

* the *objective* ``f`` — real-hardware measurement of a compiled schedule.
  This container has one CPU core and no target hardware, so ``f`` is an
  analytical machine model (`HardwareOracle`) with per-platform profiles for
  the paper's five CPUs plus a TPU-v5e profile (DESIGN.md §3/§4).  The search
  treats it as a black box; its fidelity against *real* wall-clock timing of
  blocked matmuls on this container's CPU is asserted in
  ``tests/test_cost_model.py`` (Spearman rank correlation).

* the *surrogate* ``f̂`` — a learned, cheap stand-in used inside MCTS rollouts
  (the paper uses MetaSchedule's XGBoost model; we use online ridge regression
  on structural schedule features, which is retrained as oracle samples
  accumulate during search).

Oracle model structure (per platform):
  time = max(compute_time, memory_time) + loop_overhead + parallel_overhead
with
  compute_time  = flops / (cores_used * eff_flops_per_core)
  memory_time   = Σ_operand traffic(o) * derate(o) / mem_bw
  traffic(o)    = bytes(o) * reloads(o)   (per-operand LRU residency model)
plus epilogue-fusion traffic, cache_write accumulation, cache_read staging,
MXU alignment quantization (TPU), SIMD vector width, unroll ILP against FMA
latency, register-spill penalties, and load imbalance.  Deterministic
hash-seeded measurement noise (~2%, averaged over `NOISE_REPEATS` draws)
mirrors the paper's 20-repeat protocol.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import struct
from typing import Iterable, Mapping, Optional, Sequence

import numpy as np

from .schedule import (
    REDUCTION_LEVELS,
    SPATIAL_LEVELS,
    Schedule,
    initial_schedule,
)
from .workloads import REDUCTION, SPATIAL, Loop, Workload

NOISE_REPEATS = 20
NOISE_SIGMA = 0.02


# ---------------------------------------------------------------------------
# Platform profiles
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Platform:
    """An execution target for the analytical oracle."""

    name: str
    kind: str  # "cpu" | "tpu"
    cores: int
    freq_ghz: float
    simd_bytes: int           # vector register width (CPU) / lane bytes (TPU)
    fma_pipes: int            # FMA issue ports per core
    fma_latency: int          # cycles; ILP needed to saturate pipes
    cache_bytes: int          # reuse-level cache per core (L2 / VMEM)
    scratch_bytes: int        # software-managed staging (L1 / VMEM slice)
    mem_bw_gbs: float         # DRAM/HBM bandwidth (chip-wide)
    cacheline_bytes: int = 64
    loop_overhead_cycles: float = 2.0
    spawn_overhead_us: float = 0.2     # per parallel task
    region_overhead_us: float = 5.0    # per parallel region
    mxu: bool = False                  # systolic matmul unit (128x128)
    description: str = ""

    @property
    def peak_flops(self) -> float:
        """Peak f32 FLOP/s with full vectorization on all cores."""
        lanes = self.simd_bytes // 4
        return self.cores * self.freq_ghz * 1e9 * 2 * self.fma_pipes * lanes


# Published micro-architecture parameters (approximate, see DESIGN.md §4 —
# the oracle needs *relative* structure, not cycle accuracy).
PLATFORMS: dict[str, Platform] = {
    "graviton2": Platform(
        name="graviton2", kind="cpu", cores=64, freq_ghz=2.5,
        simd_bytes=16, fma_pipes=2, fma_latency=4,
        cache_bytes=1 << 20, scratch_bytes=64 << 10, mem_bw_gbs=160.0,
        description="Amazon Graviton2: 64x Neoverse-N1, NEON-128, 1MB L2/core",
    ),
    "epyc-7r13": Platform(
        name="epyc-7r13", kind="cpu", cores=48, freq_ghz=2.65,
        simd_bytes=32, fma_pipes=2, fma_latency=4,
        cache_bytes=512 << 10, scratch_bytes=32 << 10, mem_bw_gbs=190.0,
        description="AMD EPYC 7R13 (Milan): 48c, AVX2-256, 512KB L2/core",
    ),
    "m2-pro": Platform(
        name="m2-pro", kind="cpu", cores=8, freq_ghz=3.5,
        simd_bytes=16, fma_pipes=4, fma_latency=3,
        cache_bytes=2 << 20, scratch_bytes=128 << 10, mem_bw_gbs=200.0,
        description="Apple M2 Pro: 8 P-cores, NEON-128 x4 pipes, fat L2",
    ),
    "core-i9": Platform(
        name="core-i9", kind="cpu", cores=16, freq_ghz=5.0,
        simd_bytes=32, fma_pipes=2, fma_latency=4,
        cache_bytes=2 << 20, scratch_bytes=48 << 10, mem_bw_gbs=90.0,
        description="Intel Core i9 (Raptor-Lake-ish): 16c, AVX2-256, 2MB L2",
    ),
    "xeon-e3": Platform(
        name="xeon-e3", kind="cpu", cores=4, freq_ghz=3.8,
        simd_bytes=32, fma_pipes=2, fma_latency=4,
        cache_bytes=256 << 10, scratch_bytes=32 << 10, mem_bw_gbs=35.0,
        description="Intel Xeon E3-1275v6: 4c, AVX2-256, 256KB L2",
    ),
    # TPU target for kernel autotuning (DESIGN.md §3): one TensorCore,
    # 128x128 MXU, software-managed VMEM, HBM roofline per the task spec.
    "tpu-v5e": Platform(
        name="tpu-v5e", kind="tpu", cores=1, freq_ghz=0.94,
        simd_bytes=4 * 128, fma_pipes=1, fma_latency=1,
        cache_bytes=16 << 20, scratch_bytes=16 << 20, mem_bw_gbs=819.0,
        loop_overhead_cycles=32.0, spawn_overhead_us=0.0,
        region_overhead_us=2.0, mxu=True,
        description="TPU v5e TensorCore: 197 TF/s bf16 MXU, 16MiB VMEM, 819GB/s HBM",
    ),
}

TPU_V5E_PEAK_BF16 = 197e12
TPU_V5E_HBM_BW = 819e9
TPU_V5E_ICI_BW = 50e9  # per link


def get_platform(name: str) -> Platform:
    return PLATFORMS[name]


# ---------------------------------------------------------------------------
# Loop-nest view of a schedule (shared by oracle terms)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _LoopInst:
    axis: str
    kind: str      # SPATIAL | REDUCTION
    band: int      # 0..5 position in SSRSRS order, outer->inner
    trips: int


def loop_nest(s: Schedule) -> list[_LoopInst]:
    """Explicit loop order, outermost first (MetaSchedule S S R S R S)."""
    w = s.workload
    tm = s.tile_map
    nest: list[_LoopInst] = []
    # band 0: spatial level 0; band 1: spatial level 1; band 2: reduction 0;
    # band 3: spatial level 2; band 4: reduction 1; band 5: spatial level 3.
    for band, (kind, lvl) in enumerate(
        [(SPATIAL, 0), (SPATIAL, 1), (REDUCTION, 0),
         (SPATIAL, 2), (REDUCTION, 1), (SPATIAL, 3)]
    ):
        for l in w.loops:
            if l.kind == kind:
                nest.append(_LoopInst(l.name, kind, band, tm[l.name][lvl]))
    return [li for li in nest]


def intra_extent(s: Schedule, axis: str, from_band: int) -> int:
    """Product of this axis' trips in bands strictly inside `from_band`."""
    return math.prod(
        li.trips for li in loop_nest(s) if li.axis == axis and li.band > from_band
    )


# ---------------------------------------------------------------------------
# The analytical oracle
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CostBreakdown:
    compute_s: float
    memory_s: float
    overhead_s: float
    parallel_s: float
    epilogue_s: float
    total_s: float
    traffic_bytes: float
    cores_used: int
    notes: tuple[str, ...] = ()


class HardwareOracle:
    """Deterministic analytical `f`: schedule -> seconds on a platform."""

    def __init__(self, platform: Platform, noise: bool = True):
        self.platform = platform
        self.noise = noise
        self._cache: dict[tuple, float] = {}

    # -- public API ---------------------------------------------------------
    def measure(self, s: Schedule) -> float:
        """Latency in seconds (mean of NOISE_REPEATS noisy draws)."""
        key = s.key()
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        t = self.breakdown(s).total_s
        if self.noise:
            t *= self._noise_factor(key)
        self._cache[key] = t
        return t

    def speedup(self, s: Schedule, baseline: Optional[Schedule] = None) -> float:
        base = baseline or initial_schedule(s.workload)
        return self.measure(base) / self.measure(s)

    # -- model --------------------------------------------------------------
    def breakdown(self, s: Schedule) -> CostBreakdown:
        p = self.platform
        w = s.workload
        notes: list[str] = []

        nest = loop_nest(s)
        dtype = max(o.dtype_bytes for o in w.operands)

        # ---- parallelism ----------------------------------------------------
        # Unannotated schedules still run under the runtime's default
        # outer-loop parallelism (what TVM's pre-optimized code does), but at
        # a flat grain/imbalance penalty; explicit Parallel controls the task
        # granularity and is modeled exactly.
        tasks = 1
        auto_parallel = False
        if p.kind == "cpu" and s.parallel_levels >= 1:
            for li in nest:
                if li.kind == SPATIAL and li.band == 0:
                    tasks *= li.trips
            if s.parallel_levels >= 2:
                for li in nest:
                    if li.kind == SPATIAL and li.band == 1:
                        tasks *= li.trips
        elif p.kind == "cpu":
            auto_parallel = True
            tasks = p.cores  # default runtime chunking of the outer loop
        cores_used = max(1, min(p.cores, tasks))
        # load imbalance: ceil(tasks/cores) quantization
        if tasks >= 1 and cores_used > 1:
            waves = math.ceil(tasks / cores_used)
            imbalance = waves * cores_used / tasks
        else:
            imbalance = 1.0
        if auto_parallel:
            imbalance = 1.5  # naive static chunking, no tile-aware grain

        # ---- vector / MXU efficiency ---------------------------------------
        simd_elems = max(1, p.simd_bytes // dtype)
        if s.vector_width > 1:
            vec = min(s.vector_width, simd_elems)
        else:
            # LLVM/Mosaic auto-vectorization of the unscheduled loop nest:
            # imperfect (reduction deps, unknown trip counts) but nonzero —
            # this is what makes our p0 comparable to TVM's "pre-optimized"
            # baseline rather than a strawman scalar loop.
            vec = min(4, simd_elems)
        if p.mxu:
            eff = self._mxu_efficiency(s, notes)
            flops_per_core = TPU_V5E_PEAK_BF16 * eff
            if dtype >= 4:
                flops_per_core /= 2.0  # f32 runs the MXU at half rate
        else:
            ilp = 1
            for _, f in s.unroll:
                ilp *= f
            # inner spatial tile contributes nothing unless unrolled (TVM TIR
            # semantics); ILP saturates the FMA pipes against their latency.
            # Compiler software pipelining recovers part of the dependence
            # stall even without explicit unrolling (floor 0.4).
            ilp = min(ilp, 32)
            ilp_eff = max(0.4, min(1.0, ilp / (p.fma_latency * p.fma_pipes)))
            regs = ilp * (1 if vec == 1 else 1)  # accumulators (vector regs)
            spill = 1.0
            if regs > 24:
                spill = 0.5
                notes.append(f"register spill: {regs} accumulators")
            flops_per_core = (
                p.freq_ghz * 1e9 * 2 * p.fma_pipes * vec * ilp_eff * spill
            )

        compute_s = (
            w.flops / (flops_per_core * cores_used) * imbalance
        )

        # ---- memory traffic --------------------------------------------------
        traffic = 0.0
        cache_budget = p.cache_bytes * 0.7
        staged = set(s.cache_reads)
        for o in w.operands:
            if o.is_output:
                continue
            t = self._operand_traffic(s, o, nest, cache_budget)
            if o.name not in staged:
                t *= self._contiguity_derate(s, o)
            else:
                # explicit staging: one extra contiguous copy through scratch
                t += o.nbytes(w.loop_map)
            traffic += t

        # output: re-read+rewritten per outer reduction visit unless scratch-
        # accumulated (cache_write); scratch capacity constrains the block.
        out = w.output
        out_bytes = out.nbytes(w.loop_map)
        red_outer = 1
        for li in nest:
            if li.kind == REDUCTION and li.band == 2:
                red_outer *= li.trips
        if s.cache_write:
            out_block = dtype
            for a in out.axes:
                out_block *= intra_extent(s, a, 2)
            if out_block <= p.scratch_bytes:
                traffic += out_bytes  # written exactly once
            else:
                traffic += out_bytes * (1 + 2 * (red_outer - 1))
                notes.append("cache_write block exceeds scratch; spills")
        else:
            traffic += out_bytes * (1 + 2 * (red_outer - 1))

        # ---- epilogue (fusion decision) -------------------------------------
        epilogue_s = 0.0
        if w.epilogue_tensor_axes:
            epi_elems = math.prod(
                w.loop_map[a].extent for a in w.epilogue_tensor_axes
            )
            epi_bytes = epi_elems * dtype
            if s.compute_location < 0:
                # materialized at root: extra round trip + streaming-rate flops
                traffic += 2.0 * epi_bytes
                epi_rate = p.freq_ghz * 1e9 * vec * cores_used
                epilogue_s = w.epilogue_flops / epi_rate
                notes.append("epilogue materialized in DRAM")
            else:
                # fused at spatial level k: stays on-chip, vector-rate flops;
                # deeper fusion costs a little recompute of row statistics.
                epi_rate = p.freq_ghz * 1e9 * 2 * vec * cores_used
                recompute = 1.0 + 0.1 * s.compute_location
                epilogue_s = w.epilogue_flops * recompute / epi_rate

        memory_s = traffic / (p.mem_bw_gbs * 1e9)

        # ---- loop overhead ---------------------------------------------------
        unroll_amortize = max(1, math.prod(f for _, f in s.unroll))
        inner_iters = w.iter_space() / max(1, vec) / unroll_amortize
        overhead_s = (
            inner_iters * p.loop_overhead_cycles
            / (p.freq_ghz * 1e9) / cores_used
        )
        if p.mxu:
            # grid-step overhead instead of scalar loop overhead
            grid = 1
            for li in nest:
                if li.band in (0, 1):
                    grid *= li.trips
            overhead_s = grid * 100e-9

        parallel_s = 0.0
        if tasks > 1:
            parallel_s = (
                p.region_overhead_us * 1e-6
                + tasks * p.spawn_overhead_us * 1e-6 / cores_used
            )

        total = max(compute_s, memory_s) + overhead_s + parallel_s + epilogue_s
        return CostBreakdown(
            compute_s=compute_s, memory_s=memory_s, overhead_s=overhead_s,
            parallel_s=parallel_s, epilogue_s=epilogue_s, total_s=total,
            traffic_bytes=traffic, cores_used=cores_used, notes=tuple(notes),
        )

    # -- helpers -------------------------------------------------------------
    def _operand_traffic(
        self, s: Schedule, o, nest: Sequence[_LoopInst], cache_budget: float
    ) -> float:
        """bytes(o) x reloads, per-operand LRU residency (DESIGN.md §3)."""
        w = s.workload
        base = o.nbytes(w.loop_map)
        reloads = 1.0
        # walk loops outer->inner; a loop whose axis is not in o.axes re-streams
        # o unless o's footprint inside that loop fits in cache (hot-set LRU).
        n = list(nest)
        for i, li in enumerate(n):
            if li.axis in o.axes or li.trips == 1:
                continue
            foot = o.dtype_bytes
            for a in o.axes:
                inner = math.prod(
                    lj.trips for j, lj in enumerate(n) if lj.axis == a and j > i
                )
                foot *= inner
            if foot > cache_budget:
                reloads *= li.trips
        return base * reloads

    def _contiguity_derate(self, s: Schedule, o) -> float:
        """Strided-access bandwidth waste for the operand's minor axis."""
        p = self.platform
        axes = list(o.axes)
        if s.layout_map.get(o.name) == "col" and len(axes) >= 2:
            axes[-1], axes[-2] = axes[-2], axes[-1]
        minor = axes[-1]
        kind = s.workload.loop_map[minor].kind
        tm = s.tile_map[minor]
        run = tm[-1]
        if kind == SPATIAL:
            run = tm[SPATIAL_LEVELS - 1]
        run_bytes = run * o.dtype_bytes
        if run_bytes >= p.cacheline_bytes:
            return 1.0
        return min(8.0, p.cacheline_bytes / max(1, run_bytes))

    def _mxu_efficiency(self, s: Schedule, notes: list[str]) -> float:
        """MXU alignment: minor dim vs 128 lanes, 2nd-minor vs 8 sublanes,
        and the VMEM working set must fit (else HBM thrash derate)."""
        w = s.workload
        out_axes = w.output.axes
        minor = out_axes[-1]
        second = out_axes[-2] if len(out_axes) >= 2 else None

        def util(block: int, q: int) -> float:
            return block / (math.ceil(block / q) * q)

        m_block = intra_extent(s, minor, 1)  # within the VMEM block
        eff = util(max(1, m_block), 128)
        if second is not None:
            s_block = intra_extent(s, second, 1)
            eff *= util(max(1, s_block), 8)
        if eff < 0.99:
            notes.append("MXU tile misaligned (pad waste)")
        # VMEM capacity: all operand blocks at the grid level must fit.
        foot = 0
        for o in w.operands:
            b = o.dtype_bytes
            for a in o.axes:
                b *= intra_extent(s, a, 1)
            foot += b
        if foot > self.platform.cache_bytes:
            eff *= max(0.05, self.platform.cache_bytes / foot)
            notes.append("VMEM overflow: block working set exceeds 16MiB")
        return max(eff, 1e-3)

    def _noise_factor(self, key: tuple) -> float:
        h = hashlib.sha256(repr((self.platform.name, key)).encode()).digest()
        draws = []
        for r in range(NOISE_REPEATS):
            (u,) = struct.unpack_from("<I", h, (r * 4) % 28)
            u = (u ^ (r * 0x9E3779B9)) & 0xFFFFFFFF
            g = (u / 2**32 - 0.5) * math.sqrt(12)  # ~N(0,1)-ish via uniform
            draws.append(1.0 + NOISE_SIGMA * g)
        return sum(draws) / len(draws)


# ---------------------------------------------------------------------------
# Schedule featurization + ridge-regression surrogate
# ---------------------------------------------------------------------------

def featurize(s: Schedule) -> np.ndarray:
    """Structural features (no oracle internals): log tiles, annotations,
    and cheap derived reuse/footprint terms, fixed-length per workload."""
    w = s.workload
    feats: list[float] = []
    for l in sorted(w.loops, key=lambda x: x.name):
        for f in s.tile_map[l.name]:
            feats.append(math.log2(max(1, f)))
    feats.append(math.log2(max(1, s.vector_width)))
    feats.append(float(s.parallel_levels))
    un = s.unroll_map
    for l in sorted(w.loops, key=lambda x: x.name):
        feats.append(math.log2(max(1, un.get(l.name, 1))))
    feats.append(float(s.compute_location))
    feats.append(1.0 if s.cache_write else 0.0)
    feats.append(float(len(s.cache_reads)))
    feats.append(float(sum(1 for _, o in s.layouts if o == "col")))
    # derived: log block footprint at the cache band and task count
    foot = 0.0
    for o in w.operands:
        b = float(o.dtype_bytes)
        for a in o.axes:
            b *= intra_extent(s, a, 2)
        foot += b
    feats.append(math.log2(max(1.0, foot)))
    tasks = 1.0
    for l in w.spatial_loops:
        tasks *= s.tile_map[l.name][0]
    feats.append(math.log2(max(1.0, tasks)))
    feats.append(math.log2(max(1, s.tile_map[w.output.axes[-1]][-1])))
    return np.asarray(feats, dtype=np.float64)


class SurrogateModel:
    """Online ridge regression on log-latency (the paper's learned `f̂`)."""

    def __init__(self, l2: float = 1.0, min_samples: int = 8):
        self.l2 = l2
        self.min_samples = min_samples
        self._xs: list[np.ndarray] = []
        self._ys: list[float] = []
        self._w: Optional[np.ndarray] = None
        self._mu: Optional[np.ndarray] = None
        self._sd: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return len(self._ys)

    def observe(self, s: Schedule, latency_s: float) -> None:
        self._xs.append(featurize(s))
        self._ys.append(math.log(max(latency_s, 1e-12)))
        self._w = None  # lazy refit

    def _fit(self) -> None:
        X = np.stack(self._xs)
        y = np.asarray(self._ys)
        self._mu = X.mean(axis=0)
        self._sd = X.std(axis=0) + 1e-9
        Xn = (X - self._mu) / self._sd
        Xn = np.concatenate([Xn, np.ones((len(Xn), 1))], axis=1)
        d = Xn.shape[1]
        A = Xn.T @ Xn + self.l2 * np.eye(d)
        self._w = np.linalg.solve(A, Xn.T @ y)

    def predict(self, s: Schedule) -> Optional[float]:
        """Predicted latency (seconds), or None if undertrained."""
        if len(self._ys) < self.min_samples:
            return None
        if self._w is None:
            self._fit()
        x = (featurize(s) - self._mu) / self._sd
        x = np.concatenate([x, [1.0]])
        return float(math.exp(min(50.0, float(x @ self._w))))

    def rank_score(self, s: Schedule) -> Optional[float]:
        t = self.predict(s)
        return None if t is None else -t
