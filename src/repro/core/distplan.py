"""Distribution-plan autotuning: the Reasoning Compiler pointed at the
runtime's own knobs (beyond-paper §Perf engine, DESIGN.md §8).

The paper searches kernel schedules; at cluster scale the same sequential,
context-aware decision problem appears one level up: microbatch depth,
remat policy, MoE dispatch granularity, attention chunk size.  Here the
*program* is a (config, shape, mesh) cell, the *transformations* are knob
moves, and the objective is the three-term roofline step time of the
re-lowered cell (launch/dryrun machinery) — a real compile per sample, so
the search must be extremely sample-efficient: exactly the regime the
paper targets.

The proposal engine reuses the HeuristicReasonerLLM pattern: it reads the
dominant roofline term of the current plan and proposes the knob move whose
napkin-math effect addresses it (memory-bound -> deeper microbatching /
remat on; collective-bound -> coarser dispatch groups; compute-bound ->
shallower remat), falling back to neighborhood moves on a plateau.
Sample-efficiency matters so much here (compiles cost ~minutes at scale)
that greedy accept/reject with reasoned proposals is used instead of full
MCTS; the search trace is logged in the same
hypothesis -> change -> before -> after format as EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

KNOBS = {
    "microbatches": (1, 2, 4, 8, 16, 32),
    "remat": (False, True),
    "dispatch_groups": (8, 16, 32, 64),
    "attn_chunk": (512, 1024, 2048),
}


@dataclasses.dataclass(frozen=True)
class DistPlan:
    microbatches: int = 1
    remat: bool = True
    dispatch_groups: int = 32
    attn_chunk: int = 1024

    def with_knob(self, name: str, value) -> "DistPlan":
        return dataclasses.replace(self, **{name: value})


@dataclasses.dataclass
class PlanEval:
    plan: DistPlan
    compute_s: float
    memory_s: float
    collective_s: float
    peak_bytes: float
    fits: bool

    @property
    def step_s(self) -> float:
        t = max(self.compute_s, self.memory_s, self.collective_s)
        return t if self.fits else t * 100.0  # OOM plans are dominated

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)


@dataclasses.dataclass
class PlanStep:
    hypothesis: str
    before: PlanEval
    after: PlanEval
    accepted: bool


class DistPlanTuner:
    """Greedy reasoned search over DistPlan knobs.

    ``evaluate`` is injected (tests use an analytical stub; production uses
    a dryrun re-lower of the target cell).
    """

    def __init__(self, evaluate: Callable[[DistPlan], PlanEval],
                 hbm_bytes: float = 15.5 * 2**30):
        self.evaluate = evaluate
        self.hbm = hbm_bytes
        self.log: list[PlanStep] = []
        self.samples = 0

    # -- reasoned proposal ---------------------------------------------------
    def propose(self, cur: PlanEval) -> list[tuple[str, DistPlan]]:
        p = cur.plan
        ideas: list[tuple[str, DistPlan]] = []

        def step_in(seq, v, direction):
            i = seq.index(v) + direction
            return seq[i] if 0 <= i < len(seq) else None

        if not cur.fits or cur.dominant == "memory":
            nxt = step_in(KNOBS["microbatches"], p.microbatches, +1)
            if nxt:
                ideas.append((
                    f"memory-bound (peak {cur.peak_bytes / 2**30:.1f}GiB): "
                    f"double microbatching {p.microbatches}->{nxt} to halve "
                    f"live activations",
                    p.with_knob("microbatches", nxt)))
            if not p.remat:
                ideas.append((
                    "memory-bound: enable per-layer remat (recompute beats "
                    "saving layer internals)", p.with_knob("remat", True)))
            smaller = step_in(KNOBS["attn_chunk"], p.attn_chunk, -1)
            if smaller:
                ideas.append((
                    f"memory-bound: shrink attention chunk "
                    f"{p.attn_chunk}->{smaller} (smaller streamed score "
                    f"block)", p.with_knob("attn_chunk", smaller)))
        if cur.dominant == "collective":
            coarser = step_in(KNOBS["dispatch_groups"], p.dispatch_groups,
                              -1)
            if coarser:
                ideas.append((
                    f"collective-bound: coarsen MoE dispatch groups "
                    f"{p.dispatch_groups}->{coarser} (fewer, larger "
                    f"all-to-alls amortize latency)",
                    p.with_knob("dispatch_groups", coarser)))
            fewer = step_in(KNOBS["microbatches"], p.microbatches, -1)
            if fewer:
                ideas.append((
                    f"collective-bound: fewer microbatches "
                    f"{p.microbatches}->{fewer} (each microbatch repeats "
                    f"the TP collectives)",
                    p.with_knob("microbatches", fewer)))
        if cur.dominant == "compute" and cur.fits:
            if p.remat:
                ideas.append((
                    "compute-bound with memory headroom: disable remat "
                    "(stop paying the recompute flops)",
                    p.with_knob("remat", False)))
            fewer = step_in(KNOBS["microbatches"], p.microbatches, -1)
            if fewer:
                ideas.append((
                    "compute-bound: fewer microbatches (less per-step "
                    "overhead)", p.with_knob("microbatches", fewer)))
        if not ideas:  # plateau: nearest-neighbor moves
            for name, seq in KNOBS.items():
                v = getattr(p, name)
                for d in (-1, +1):
                    nv = step_in(seq, v, d)
                    if nv is not None:
                        ideas.append((f"plateau: nudge {name} {v}->{nv}",
                                      p.with_knob(name, nv)))
        return ideas

    # -- main loop -------------------------------------------------------------
    def tune(self, start: DistPlan, budget: int = 8) -> PlanEval:
        cur = self.evaluate(start)
        self.samples = 1
        tried = {start}
        while self.samples < budget:
            ideas = [(h, c) for h, c in self.propose(cur) if c not in tried]
            if not ideas:
                break
            hyp, cand = ideas[0]
            tried.add(cand)
            ev = self.evaluate(cand)
            self.samples += 1
            accepted = ev.step_s < cur.step_s
            self.log.append(PlanStep(hyp, cur, ev, accepted))
            if accepted:
                cur = ev
        return cur

    def report(self) -> str:
        lines = []
        for st in self.log:
            lines.append(
                f"[{'ACCEPT' if st.accepted else 'reject'}] {st.hypothesis}"
                f" | step {st.before.step_s:.4g}s -> {st.after.step_s:.4g}s"
            )
        return "\n".join(lines)
