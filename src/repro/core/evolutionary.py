"""Evolutionary-search baseline (TVM MetaSchedule's strategy, paper §4.1).

Faithful to MetaSchedule's ``EvolutionarySearch``: a population of schedules
evolves by elite selection + mutation (re-sampling one scheduling decision)
+ crossover (exchanging tile decisions between parents).  Every evaluated
candidate costs one *sample* — the same accounting as the MCTS methods — and
the best-so-far speedup curve is recorded per sample.

This is the paper's primary comparison point ("TVM with Evolutionary
Search"); its sample-INEFFICIENCY is the phenomenon the Reasoning Compiler
targets, so the implementation keeps the classic black-box structure: no
context, no history, no structural reasoning.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional

from .cost_model import HardwareOracle
from .lowering import LoweringError
from .mcts import SearchCurve
from .schedule import (
    Schedule,
    ScheduleError,
    initial_schedule,
    random_schedule,
    random_transform,
)


@dataclasses.dataclass
class EvolutionaryConfig:
    population: int = 24
    elites: int = 6
    crossover_rate: float = 0.3
    mutation_steps: tuple = (1, 3)
    init_steps: tuple = (2, 8)


class EvolutionarySearch:
    def __init__(
        self,
        workload,
        oracle: HardwareOracle,
        config: Optional[EvolutionaryConfig] = None,
        seed: int = 0,
        screen_factor: int = 4,
    ):
        self.workload = workload
        self.oracle = oracle
        self.cfg = config or EvolutionaryConfig()
        # Oracles exposing ``screen`` (the surrogate tier): offspring are
        # oversampled by this factor, the learned model ranks the pool, and
        # only the predicted-best survivors are evaluated.  Plain oracles
        # keep the classic evaluate-everything loop (bit-identical search).
        self.screen_factor = screen_factor
        self.rng = random.Random(seed)
        self.s0 = initial_schedule(workload)
        self.baseline_latency = oracle.measure(self.s0)
        self.samples = 0
        self.best: tuple = (self.baseline_latency, self.s0)
        self.curve: list = []
        self._pop: list = []  # (latency, schedule)

    # -- operators -------------------------------------------------------------
    def _mutate(self, s: Schedule) -> Optional[Schedule]:
        steps = self.rng.randint(*self.cfg.mutation_steps)
        try:
            out = s
            for _ in range(steps):
                out = random_transform(self.rng, out).apply(out)
            return out
        except ScheduleError:
            return None

    def _crossover(self, a: Schedule, b: Schedule) -> Optional[Schedule]:
        """Graft a random subset of b's per-axis tile decisions onto a."""
        from .schedule import TileSize

        try:
            out = a
            for axis, dec in b.tiles:
                if self.rng.random() < 0.5 and dec != a.tile_map[axis]:
                    out = TileSize(axis, dec).apply(out)
            # inherit one annotation family from b
            pick = self.rng.randrange(3)
            if pick == 0 and b.vector_width != out.vector_width:
                from .schedule import Vectorize

                out = Vectorize(b.vector_width).apply(out)
            elif pick == 1 and b.parallel_levels != out.parallel_levels:
                from .schedule import Parallel

                out = Parallel(b.parallel_levels).apply(out)
            elif pick == 2 and b.compute_location != out.compute_location \
                    and out.workload.epilogue_tensor_axes:
                from .schedule import ComputeLocation

                out = ComputeLocation(b.compute_location).apply(out)
            return out
        except ScheduleError:
            return None

    def top_schedules(self, n: int = 3) -> list[Schedule]:
        """Best n evaluated schedules (population elites + best-so-far)."""
        pool = {s.key(): (t, s) for t, s in self._pop}
        pool[self.best[1].key()] = self.best
        return [s for _, s in sorted(pool.values(), key=lambda x: x[0])[:n]]

    def _evaluate(self, s: Schedule) -> Optional[float]:
        """One sample; None when a measured backend refuses the program
        (no realization / grid guard) — no kernel ran, nothing counted."""
        try:
            t = self.oracle.measure(s)
        except LoweringError:
            return None
        self.samples += 1
        if t < self.best[0]:
            self.best = (t, s)
        self.curve.append((self.samples, self.baseline_latency / self.best[0]))
        return t

    def _screened_batch(self, make, need: int) -> list[Schedule]:
        """Oversample ``need * screen_factor`` candidates from ``make`` and
        let the oracle's learned model pick the ``need`` predicted-best —
        unpicked candidates cost zero samples (GOLEM dispatcher split)."""
        pool: list[Schedule] = []
        keys: set = set()
        target = need * self.screen_factor
        guard = 0
        while len(pool) < target and guard < target * 8:
            guard += 1
            s = make()
            if s is None:
                continue
            k = s.key()
            if k not in keys:
                keys.add(k)
                pool.append(s)
        if not pool:
            return []
        return self.oracle.screen(pool, k=min(need, len(pool)))

    # -- main loop ---------------------------------------------------------------
    def search(self, budget_samples: int) -> SearchCurve:
        cfg = self.cfg
        screened = hasattr(self.oracle, "screen")

        def _init_candidate() -> Optional[Schedule]:
            try:
                return random_schedule(
                    self.rng, self.s0, self.rng.randint(*cfg.init_steps)
                )
            except ScheduleError:
                return None

        # init population (guarded: a measured backend can refuse programs
        # without consuming samples, which must not spin forever)
        if screened:
            for s in self._screened_batch(_init_candidate, cfg.population):
                if self.samples >= budget_samples:
                    break
                t = self._evaluate(s)
                if t is not None:
                    self._pop.append((t, s))
        else:
            guard = 0
            while len(self._pop) < cfg.population \
                    and self.samples < budget_samples \
                    and guard < cfg.population * 20:
                guard += 1
                s = _init_candidate()
                if s is None:
                    continue
                t = self._evaluate(s)
                if t is not None:
                    self._pop.append((t, s))

        stalled = 0
        while self._pop and self.samples < budget_samples and stalled < 3:
            before = self.samples
            self._pop.sort(key=lambda x: x[0])
            elites = self._pop[: cfg.elites]
            nxt = list(elites)

            def _offspring() -> Optional[Schedule]:
                if self.rng.random() < cfg.crossover_rate and len(elites) >= 2:
                    pa, pb = self.rng.sample(elites, 2)
                    return self._crossover(pa[1], pb[1])
                return self._mutate(self.rng.choice(elites)[1])

            if screened:
                for s in self._screened_batch(
                    _offspring, cfg.population - len(nxt)
                ):
                    if self.samples >= budget_samples:
                        break
                    t = self._evaluate(s)
                    if t is not None:
                        nxt.append((t, s))
            else:
                guard = 0
                while len(nxt) < cfg.population \
                        and self.samples < budget_samples \
                        and guard < cfg.population * 20:
                    guard += 1
                    s = _offspring()
                    if s is None:
                        continue
                    t = self._evaluate(s)
                    if t is not None:
                        nxt.append((t, s))
            self._pop = nxt
            # a generation that evaluated nothing (every candidate refused
            # by a measured backend) cannot make progress; bail out rather
            # than loop forever
            stalled = stalled + 1 if self.samples == before else 0
        return SearchCurve(list(self.curve))
