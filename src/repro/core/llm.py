"""The LLM proposal engine (paper §3.1, Appendix A/G).

Three pieces, mirroring the paper's modular implementation (§4):

1. **Prompt generator** — serializes the selected node, its parent and
   grandparent (optionally great-grandparent), their transformation histories
   ``S_i, S_{i-1}, S_{i-2}``, performance estimates, the available
   transformation set ``O``, and a hardware summary, into the structured
   prompt format shown in the paper's Appendix A.

2. **LLM interface** — ``HeuristicReasonerLLM`` is a deterministic,
   context-aware chain-of-thought policy that stands in for the OpenAI/HF
   APIs this offline container cannot reach (DESIGN.md §4): it runs the same
   diagnosis -> proposal reasoning visible in the paper's Appendix A example
   (tile-alignment, cache/VMEM overflow, starved parallelism, fusion, layout,
   credit assignment over the visible ancestor trace) and emits text in the
   required ``Reasoning: ... / Transformations to apply: ...`` format.  Model
   *tiers* degrade context use and inject invalid proposal names, reproducing
   the Table 4 capability ordering and Table 8 fallback rates mechanistically.
   ``APILLM`` is a real OpenAI-compatible adapter for deployments with
   network access; it shares the exact same prompt/parse pipeline.

3. **Parser / validator / fallback** — LLM output is free text; proposals are
   regex-extracted, validated against the legal action space, invalid ones
   discarded.  Only if *all* proposals in an expansion are invalid does the
   caller fall back to the default (random) expansion policy — Appendix G
   semantics, with fallback statistics recorded.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import random
import re
import time
import urllib.error
import urllib.request
from typing import Optional, Sequence

from .cost_model import Platform
from .schedule import (
    REDUCTION_LEVELS,
    SPATIAL_LEVELS,
    CacheRead,
    CacheWrite,
    ComputeLocation,
    Layout,
    Parallel,
    Schedule,
    ScheduleError,
    TileSize,
    Transform,
    Unroll,
    Vectorize,
    available_transforms,
    divisors,
    random_transform,
)
from .workloads import REDUCTION, SPATIAL

# ---------------------------------------------------------------------------
# Prompt construction (paper §3.1 "Prompt construction", Appendix A format)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TraceEntry:
    """One node of the hierarchical context: program + score + history."""

    schedule: Schedule
    latency_s: float
    speedup: float


@dataclasses.dataclass(frozen=True)
class Prompt:
    text: str
    trace: tuple[TraceEntry, ...]  # [current, parent, grandparent, ...]
    available: tuple[str, ...]
    platform: Platform
    # Cross-task context (compiler/context.ContextHint, duck-typed: has
    # .prefer / .avoid family sets and .render()); None outside sessions.
    hint: Optional[object] = None


PROMPT_HEADER = (
    "You are a code optimization assistant performing Monte Carlo Tree "
    "Search (MCTS) on a given code to improve performance. Each code has a "
    "corresponding history of transformations and predicted cost. You are "
    "given the code of the selected node and its ancestors.\n"
)

PROMPT_TASK = (
    "Task\n"
    "Analyze the IR, trace, and predicted scores.\n"
    "Then propose a sequence of transformations (you may repeat any) to "
    "potentially improve performance.\n"
    "Output your reasoning and your suggested transformations.\n"
    "For example, your answer should be in the following format:\n"
    "Reasoning: This code still has large loop extents, so I'd tile it "
    "twice differently, then unroll.\n"
    "Transformations to apply: TileSize, TileSize, Unroll.\n"
)


def build_prompt(
    trace: Sequence[TraceEntry],
    platform: Platform,
    trace_depth: int = 2,
    hint: Optional[object] = None,
) -> Prompt:
    """Serialize the hierarchical context into the Appendix-A prompt.

    ``trace_depth=2`` is the paper's default (parent + grandparent);
    ``trace_depth=3`` adds the great-grandparent (Table 5 ablation).
    ``hint`` (a session's cross-task ContextHint) adds a "Cross-task
    context" section distilled from an already-compiled sibling workload.
    """
    visible = tuple(trace[: trace_depth + 1])
    names = ["Current", "Parent", "Grandparent", "Great-Grandparent"]
    parts = [PROMPT_HEADER]
    parts.append(
        f"Target hardware: {platform.description} "
        f"(cores={platform.cores}, simd_bytes={platform.simd_bytes}, "
        f"cache_bytes={platform.cache_bytes}, "
        f"mem_bw={platform.mem_bw_gbs:.0f}GB/s, "
        f"mxu={'yes' if platform.mxu else 'no'})\n"
    )
    for i, entry in enumerate(visible):
        s = entry.schedule
        parts.append(f"--- {names[min(i, 3)]} program ---")
        parts.append(s.render())
        parts.append(
            f"Transformation history: {list(s.history) or '[]'}"
        )
        parts.append(
            f"Performance estimate: latency={entry.latency_s:.6g}s "
            f"speedup_vs_unoptimized={entry.speedup:.3f}x\n"
        )
    avail = available_transforms(visible[0].schedule)
    parts.append(f"Available transformations:\n{', '.join(avail)}\n")
    if hint is not None:
        parts.append(hint.render())
    parts.append(PROMPT_TASK)
    return Prompt(
        text="\n".join(parts),
        trace=visible,
        available=tuple(avail),
        platform=platform,
        hint=hint,
    )


# ---------------------------------------------------------------------------
# Response parsing + validation (paper §3.1 "Transformation proposal and
# validation", Appendix G fallback semantics)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Proposal:
    """Validated result of one LLM expansion query.

    ``proposer``/``reviewer``/``review_action`` are per-call provenance:
    which pool member drafted the proposal, and — when a review tier
    escalated it — who reviewed it and what the review did
    (``accept``/``refine``/``replace``/``veto``).  A plain single-proposer
    search stamps ``proposer`` only.
    """

    transforms: list[Transform]
    reasoning: str
    raw_text: str
    n_proposed: int
    n_invalid: int
    proposer: Optional[str] = None
    reviewer: Optional[str] = None
    review_action: Optional[str] = None

    @property
    def fallback(self) -> bool:
        """All proposals invalid -> revert to the default expansion policy."""
        return not self.transforms


_CALL_RE = re.compile(r"([A-Za-z_]+)\s*(\(([^)]*)\))?")
_LIST_RE = re.compile(r"\[([^\]]*)\]")


def _parse_args(argstr: str) -> tuple[list, dict]:
    """Parse 'j, decision=[4, 8, 1, 64]' -> (positional, keyword) args."""
    args: list = []
    kwargs: dict = {}
    # protect bracketed lists from the comma split
    lists: list[str] = []

    def _stash(m):
        lists.append(m.group(1))
        return f"@L{len(lists) - 1}@"

    cooked = _LIST_RE.sub(_stash, argstr)
    for tok in [t.strip() for t in cooked.split(",") if t.strip()]:
        if "=" in tok:
            k, v = tok.split("=", 1)
            kwargs[k.strip()] = _decode(v.strip(), lists)
        else:
            args.append(_decode(tok, lists))
    return args, kwargs


def _decode(tok: str, lists: list[str]):
    m = re.fullmatch(r"@L(\d+)@", tok)
    if m:
        return [
            _decode(x.strip(), lists)
            for x in lists[int(m.group(1))].split(",")
            if x.strip()
        ]
    t = tok.strip().strip("'\"")
    if re.fullmatch(r"-?\d+", t):
        return int(t)
    if t.lower() in ("true", "false"):
        return t.lower() == "true"
    return t


_FAMILIES = {
    "tilesize": "TileSize", "tile": "TileSize", "tiling": "TileSize",
    "split": "TileSize",
    "parallel": "Parallel", "parallelize": "Parallel",
    "vectorize": "Vectorize", "vectorization": "Vectorize",
    "unroll": "Unroll", "unrolling": "Unroll",
    "computelocation": "ComputeLocation", "fuse": "ComputeLocation",
    "fusion": "ComputeLocation", "computeat": "ComputeLocation",
    "cachewrite": "CacheWrite", "cacheread": "CacheRead",
    "layout": "Layout", "layouttransform": "Layout",
}


def _materialize(
    family: str, args: list, kwargs: dict, s: Schedule, rng: random.Random
) -> Optional[Transform]:
    """Build a concrete Transform from a parsed mention; None if illegal."""
    try:
        if family == "TileSize":
            axis = kwargs.get("axis", args[0] if args else None)
            decision = kwargs.get(
                "decision", args[1] if len(args) > 1 else None
            )
            if axis is None:
                axis = rng.choice([l.name for l in s.workload.loops])
            if not isinstance(axis, str) or axis not in s.workload.loop_map:
                return None
            loop = s.workload.loop_map[axis]
            levels = SPATIAL_LEVELS if loop.kind == SPATIAL else REDUCTION_LEVELS
            if decision is None:
                from .schedule import sample_perfect_tile

                decision = list(sample_perfect_tile(rng, loop.extent, levels))
            if not isinstance(decision, list) or not all(
                isinstance(x, int) for x in decision
            ):
                return None
            t: Transform = TileSize(axis, tuple(decision))
        elif family == "Parallel":
            lv = kwargs.get("levels", args[0] if args else 1)
            t = Parallel(int(lv))
        elif family == "Vectorize":
            wd = kwargs.get("width", args[0] if args else None)
            if wd is None:
                from .schedule import VECTOR_WIDTHS, _vector_axis

                inner = s.inner_tile(_vector_axis(s.workload))
                opts = [v for v in VECTOR_WIDTHS if inner % v == 0]
                wd = max(opts)
            t = Vectorize(int(wd))
        elif family == "Unroll":
            axis = kwargs.get("axis", args[0] if args else None)
            factor = kwargs.get("factor", args[1] if len(args) > 1 else None)
            if axis is None or axis not in s.workload.loop_map:
                axis = rng.choice([l.name for l in s.workload.loops])
            if factor is None:
                from .schedule import UNROLL_FACTORS

                opts = [f for f in UNROLL_FACTORS if f <= s.inner_tile(axis)]
                factor = max(opts) if opts else 1
            t = Unroll(str(axis), int(factor))
        elif family == "ComputeLocation":
            lv = kwargs.get("level", args[0] if args else 2)
            t = ComputeLocation(int(lv))
        elif family == "CacheWrite":
            en = kwargs.get("enabled", args[0] if args else True)
            t = CacheWrite(bool(en))
        elif family == "CacheRead":
            op = kwargs.get("operand", args[0] if args else None)
            if op is None:
                opts = [
                    o.name
                    for o in s.workload.operands
                    if not o.is_output and o.name not in s.cache_reads
                ]
                if not opts:
                    return None
                op = rng.choice(opts)
            t = CacheRead(str(op))
        elif family == "Layout":
            op = kwargs.get("operand", args[0] if args else None)
            order = kwargs.get("order", args[1] if len(args) > 1 else "col")
            if op is None:
                op = rng.choice([o.name for o in s.workload.operands])
            t = Layout(str(op), str(order))
        else:
            return None
        t.apply(s)  # legality probe against the *current* state
        return t
    except (ScheduleError, ValueError, TypeError, IndexError):
        return None


def parse_response(
    text: str, s: Schedule, rng: Optional[random.Random] = None
) -> Proposal:
    """Extract and validate the proposal list from raw LLM text.

    Invalid mentions are dropped individually; `Proposal.fallback` is True
    only when nothing validates (Appendix G).
    """
    rng = rng or random.Random(0)
    reasoning = ""
    m = re.search(r"Reasoning\s*:\s*(.*?)(?:Transformations to apply|$)",
                  text, re.S | re.I)
    if m:
        reasoning = m.group(1).strip()
    tail = None
    m = re.search(r"Transformations to apply\s*:\s*(.*)", text, re.S | re.I)
    if m:
        tail = m.group(1)
    if tail is None:
        return Proposal([], reasoning, text, 0, 0)

    transforms: list[Transform] = []
    n_prop = n_invalid = 0
    cur = s
    for call in _CALL_RE.finditer(tail):
        name = call.group(1).strip()
        fam = _FAMILIES.get(name.lower())
        if fam is None and name in (
            "and", "then", "to", "apply", "the", "a", "with",
        ):
            continue
        n_prop += 1
        if fam is None or fam not in available_transforms(cur):
            n_invalid += 1
            continue
        args, kwargs = _parse_args(call.group(3) or "")
        t = _materialize(fam, args, kwargs, cur, rng)
        if t is None:
            n_invalid += 1
            continue
        transforms.append(t)
        cur = t.apply(cur)
    return Proposal(transforms, reasoning, text, n_prop, n_invalid)


# ---------------------------------------------------------------------------
# The reasoning engine tiers (Table 4 / Table 8 model zoo)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """Capability profile of one proposal model (Table 4 ablation axis)."""

    name: str
    context_depth: int        # how many ancestors the model actually uses
    diagnoses: tuple[str, ...]  # enabled reasoning passes
    invalid_name_rate: float  # P(emit an unknown transformation name)
    param_sloppiness: float   # P(emit family without parameters)
    plan_len: int             # max proposals per expansion

ALL_DIAGNOSES = (
    "vectorize", "parallel", "cache_tile", "mxu_align", "fusion",
    "cache_write", "unroll", "layout", "stage", "credit",
)

MODEL_TIERS: dict[str, TierSpec] = {
    # proprietary.  context_depth is the model's *capability* ceiling; the
    # prompt's trace_depth (Table 5 knob) controls what is actually visible.
    "gpt-4o-mini": TierSpec("gpt-4o-mini", 4, ALL_DIAGNOSES, 0.0, 0.05, 6),
    "o1-mini": TierSpec("o1-mini", 4, ALL_DIAGNOSES, 0.0, 0.02, 6),
    # large open
    "llama3.3-70b": TierSpec("llama3.3-70b", 4, ALL_DIAGNOSES, 0.001, 0.05, 6),
    "deepseek-r1-distill-32b": TierSpec(
        "deepseek-r1-distill-32b", 3,
        ("vectorize", "parallel", "cache_tile", "mxu_align", "fusion",
         "cache_write", "unroll", "credit"),
        0.002, 0.10, 5,
    ),
    # small open
    "llama3.1-8b": TierSpec(
        "llama3.1-8b", 1,
        ("vectorize", "parallel", "unroll", "cache_tile"),
        0.105, 0.30, 4,
    ),
    "deepseek-r1-distill-7b": TierSpec(
        "deepseek-r1-distill-7b", 1,
        ("vectorize", "parallel", "unroll"),
        0.172, 0.40, 3,
    ),
}

_FAKE_NAMES = ("LoopSwizzle", "AutoPack", "WarpShuffle", "Hoist", "Skew")


class LLMBase:
    """Interface: prompt text in, free text out (what an API returns)."""

    name = "llm"

    def complete(self, prompt: Prompt, rng: random.Random) -> str:
        raise NotImplementedError


class HeuristicReasonerLLM(LLMBase):
    """Deterministic CoT stand-in for the paper's API models (DESIGN.md §4).

    The reasoning below is the mechanized form of the paper's Appendix-A
    example response: diagnose the dominant inefficiency from the program
    text + hardware summary, do napkin math for the fix, and emit a
    parameterized transformation sequence in the required output format.
    """

    def __init__(self, tier: str = "gpt-4o-mini"):
        self.spec = MODEL_TIERS[tier]
        self.name = tier

    # -- diagnosis passes --------------------------------------------------
    def complete(self, prompt: Prompt, rng: random.Random) -> str:
        spec = self.spec
        trace = prompt.trace[: spec.context_depth + 1]
        s = trace[0].schedule
        p = prompt.platform
        w = s.workload
        ideas: list[tuple[float, str, str]] = []  # (priority, rationale, call)

        dtype = max(o.dtype_bytes for o in w.operands)
        simd_elems = max(1, p.simd_bytes // dtype)
        vec_axis = w.output.axes[-1]
        inner_vec = s.tile_map[vec_axis][-1]

        avoid, prefer = self._credit_assignment(trace)
        if prompt.hint is not None:
            # cross-task context: a sibling's plateau statistics bias the
            # same prefer/avoid mechanism credit assignment feeds —
            # ancestor evidence (this search) still overrides donor
            # evidence (the sibling's search)
            prefer = prefer | (frozenset(prompt.hint.prefer) - avoid)
            avoid = avoid | (frozenset(prompt.hint.avoid) - prefer)

        # Bottleneck triage (napkin math over the prompt's hardware summary):
        # compute ceiling vs. the compulsory-traffic memory floor decides
        # which diagnosis families to prioritize.
        est_compute = w.flops / max(p.peak_flops, 1.0)
        min_bytes = sum(o.nbytes(w.loop_map) for o in w.operands)
        est_mem = min_bytes / (p.mem_bw_gbs * 1e9)
        mem_bound = est_mem > est_compute * 1.5
        compute_families = {"Vectorize", "Parallel", "Unroll"}
        memory_families = {"CacheRead", "CacheWrite", "Layout",
                           "ComputeLocation"}

        def add(prio: float, why: str, call: str, family: str):
            if mem_bound and family in compute_families:
                prio *= 0.35
            if mem_bound and family in memory_families:
                prio *= 1.8
            if family in avoid:
                prio *= 0.25
            if family in prefer:
                prio *= 1.5
            ideas.append((prio, why, call))

        if "cache_tile" in spec.diagnoses and mem_bound:
            # memory-bound: the single most valuable move is to stream the
            # largest operand exactly once — give every spatial axis that
            # does NOT index it a trip count of 1 at the outer bands.
            big = max((o for o in w.operands if not o.is_output),
                      key=lambda o: o.nbytes(w.loop_map))
            for l in w.spatial_loops:
                if l.name in big.axes:
                    continue
                t = s.tile_map[l.name]
                if t[0] * t[1] > 1:
                    inner = max((d for d in divisors(l.extent) if d <= 16),
                                default=1)
                    dec = (1, 1, l.extent // inner, inner)
                    add(9.8,
                        f"workload is memory-bound (compulsory "
                        f"{min_bytes / 1e6:.0f}MB at {p.mem_bw_gbs:.0f}GB/s "
                        f"exceeds compute time); keep {big.name} streaming "
                        f"once by collapsing outer {l.name} trips",
                        f"TileSize(axis={l.name}, decision={list(dec)})",
                        "TileSize")

        if "mxu_align" in spec.diagnoses and p.mxu:
            second = w.output.axes[-2] if len(w.output.axes) > 1 else None
            if inner_vec % 128 != 0 and w.loop_map[vec_axis].extent >= 128:
                dec = self._tile_decision(w, vec_axis, inner_target=128,
                                          cache_target=512, p=p)
                if dec:
                    add(10.0,
                        f"minor dim {vec_axis} tile {inner_vec} is not a "
                        f"multiple of the 128-lane MXU; retile to x128",
                        f"TileSize(axis={vec_axis}, decision={list(dec)})",
                        "TileSize")
            if second and s.tile_map[second][-1] % 8 != 0 \
                    and w.loop_map[second].extent >= 8:
                dec = self._tile_decision(w, second, inner_target=8,
                                          cache_target=128, p=p)
                if dec:
                    add(9.0,
                        f"second-minor {second} not sublane(8)-aligned",
                        f"TileSize(axis={second}, decision={list(dec)})",
                        "TileSize")

        if "vectorize" in spec.diagnoses and not p.mxu:
            if s.vector_width < simd_elems:
                if inner_vec % simd_elems != 0:
                    dec = self._tile_decision(
                        w, vec_axis, inner_target=simd_elems * 4,
                        cache_target=256, p=p)
                    if dec:
                        add(9.5,
                            f"inner tile of {vec_axis} ({inner_vec}) cannot "
                            f"hold a full {p.simd_bytes}B vector; retile so "
                            f"the innermost tile is a multiple of "
                            f"{simd_elems}",
                            f"TileSize(axis={vec_axis}, "
                            f"decision={list(dec)})", "TileSize")
                        add(9.4, f"then vectorize {simd_elems} lanes",
                            f"Vectorize(width={simd_elems})", "Vectorize")
                else:
                    add(9.5, f"vectorize the stride-1 {vec_axis} axis to "
                        f"fill the {p.simd_bytes}B SIMD registers",
                        f"Vectorize(width={simd_elems})", "Vectorize")

        if "parallel" in spec.diagnoses and not p.mxu and p.cores > 1:
            tasks = 1
            for l in w.spatial_loops:
                tasks *= s.tile_map[l.name][0]
            if s.parallel_levels == 0:
                if tasks < p.cores or tasks > p.cores * 64:
                    axis = max(w.spatial_loops, key=lambda l: l.extent)
                    dec = self._tile_decision(
                        w, axis.name, inner_target=max(8, simd_elems),
                        cache_target=64,
                        grid_target=p.cores * 2, p=p)
                    if dec:
                        add(8.5,
                            f"outer spatial trip count {tasks} mismatched to "
                            f"{p.cores} cores; retile {axis.name} for "
                            f"~{p.cores * 2} tasks",
                            f"TileSize(axis={axis.name}, "
                            f"decision={list(dec)})", "TileSize")
                add(8.4, f"parallelize the outer tile loop across "
                    f"{p.cores} cores", "Parallel(levels=1)", "Parallel")
            elif tasks < p.cores:
                add(7.0, "expose level-1 tiles as parallel tasks too",
                    "Parallel(levels=2)", "Parallel")

        if "fusion" in spec.diagnoses and w.epilogue_tensor_axes \
                and s.compute_location < 0:
            epi = math.prod(w.loop_map[a].extent
                            for a in w.epilogue_tensor_axes) * dtype
            add(9.0 if epi > p.cache_bytes else 5.0,
                f"epilogue intermediate ({epi / 1e6:.1f}MB) is materialized "
                f"through DRAM; fuse it at the L1 tile level to keep it "
                f"on-chip", "ComputeLocation(level=2)", "ComputeLocation")

        if "cache_tile" in spec.diagnoses:
            foot = 0
            for o in w.operands:
                b = o.dtype_bytes
                for a in o.axes:
                    lvl = (SPATIAL_LEVELS if w.loop_map[a].kind == SPATIAL
                           else REDUCTION_LEVELS)
                    b *= math.prod(s.tile_map[a][2:]) \
                        if w.loop_map[a].kind == SPATIAL \
                        else s.tile_map[a][-1]
                foot += b
            if foot > p.cache_bytes * 0.7 or foot < p.cache_bytes * 0.01:
                red = max(w.reduction_loops, key=lambda l: l.extent,
                          default=None)
                if red is not None and red.extent > 1:
                    tgt = int(max(64, min(red.extent,
                                          p.cache_bytes * 0.2
                                          / max(1, dtype) ** 0.5)))
                    dec = self._reduction_decision(w, red.name, tgt)
                    if dec:
                        add(8.0,
                            f"cache-band working set {foot / 1e3:.0f}KB vs "
                            f"{p.cache_bytes // 1024}KB cache; split "
                            f"reduction {red.name} to block for reuse",
                            f"TileSize(axis={red.name}, "
                            f"decision={list(dec)})", "TileSize")
                for l in sorted(w.spatial_loops, key=lambda x: -x.extent)[:2]:
                    blk = math.prod(s.tile_map[l.name][2:])
                    if l.extent >= 64 and (blk <= 2 or blk * dtype * 64
                                           > p.cache_bytes):
                        dec = self._tile_decision(
                            w, l.name,
                            inner_target=simd_elems if l.name == vec_axis
                            else 8,
                            cache_target=64, p=p)
                        if dec:
                            add(7.5,
                                f"{l.name} has degenerate cache block "
                                f"({blk}); retile for L2 reuse",
                                f"TileSize(axis={l.name}, "
                                f"decision={list(dec)})", "TileSize")

        if "cache_write" in spec.diagnoses and not s.cache_write:
            red_outer = math.prod(
                s.tile_map[l.name][0] for l in w.reduction_loops
            )
            if red_outer > 1:
                add(7.8, f"output tile revisited {red_outer}x across the "
                    f"outer reduction; accumulate in scratch and write once",
                    "CacheWrite(enabled=True)", "CacheWrite")

        if "unroll" in spec.diagnoses:
            ilp = math.prod(f for _, f in s.unroll) if s.unroll else 1
            need = p.fma_latency * p.fma_pipes
            if not p.mxu and ilp < need:
                cands = [l for l in w.loops
                         if s.tile_map[l.name][-1] >= 4]
                if cands:
                    ax = max(cands, key=lambda l: s.tile_map[l.name][-1])
                    f = min(8, s.tile_map[ax.name][-1])
                    f = 1 << int(math.log2(f))
                    add(7.0,
                        f"only {ilp} independent FMA chains vs latency x "
                        f"pipes = {need}; unroll {ax.name} x{f} for ILP",
                        f"Unroll(axis={ax.name}, factor={f})", "Unroll")

        if "layout" in spec.diagnoses:
            for o in w.operands:
                if o.is_output or len(o.axes) < 2:
                    continue
                minor = o.axes if s.layout_map.get(o.name) != "col" else \
                    o.axes[:-2] + (o.axes[-1], o.axes[-2])
                run = s.tile_map[minor[-1]][-1]
                alt = s.tile_map[minor[-2]][-1]
                if run * o.dtype_bytes < p.cacheline_bytes \
                        and alt > run * 2:
                    order = "col" if s.layout_map.get(o.name) != "col" \
                        else "row"
                    add(6.0,
                        f"operand {o.name} minor-axis run {run} wastes "
                        f"cachelines; transpose its layout",
                        f"Layout(operand={o.name}, order={order})", "Layout")

        if "stage" in spec.diagnoses:
            for o in w.operands:
                if o.is_output or o.name in s.cache_reads:
                    continue
                run = s.tile_map[o.axes[-1]][-1]
                if run * o.dtype_bytes < p.cacheline_bytes:
                    add(5.5,
                        f"stage {o.name} through scratch to repack strided "
                        f"loads", f"CacheRead(operand={o.name})", "CacheRead")

        # ---- assemble response --------------------------------------------
        ideas.sort(key=lambda x: -x[0])
        plan = ideas[: spec.plan_len]
        if not plan:
            # nothing diagnosed: structured local exploration — shift one
            # tile boundary / fusion level instead of uniform-random jumps
            # (an LLM near a good schedule proposes adjacent variants).
            # Ancestor-score credit assignment biases which neighborhood to
            # explore — this is where deeper historical traces pay off
            # (Table 5): more visible (transform, delta) pairs -> a sharper
            # prefer/avoid signal during plateau exploration.
            moves = []
            for prio, why, call in self._plateau_moves(s, p, rng):
                fam = call.split("(")[0]
                if fam in avoid:
                    prio *= 0.2
                if fam in prefer:
                    prio *= 2.0
                moves.append((prio + 0.01 * rng.random(), why, call))
            moves.sort(key=lambda x: -x[0])
            plan = moves[:2]

        calls = []
        for _, why, call in plan:
            if rng.random() < spec.invalid_name_rate:
                calls.append(rng.choice(_FAKE_NAMES))
            elif rng.random() < spec.param_sloppiness:
                calls.append(call.split("(")[0])  # bare family name
            else:
                calls.append(call)
        reason = " ".join(f"({i + 1}) {why}." for i, (_, why, _) in
                          enumerate(plan))
        return f"Reasoning: {reason}\nTransformations to apply: " \
               + ", ".join(calls) + "."

    def _plateau_moves(
        self, s: Schedule, p: Platform, rng: random.Random
    ) -> list[tuple[float, str, str]]:
        """Adjacent-schedule moves: shift one tile factor between levels,
        nudge the fusion level, or flip an annotation."""
        w = s.workload
        moves: list[tuple[float, str, str]] = []
        for l in w.loops:
            dec = list(s.tile_map[l.name])
            if len(dec) < 2:
                continue
            # move a factor of 2 between adjacent levels (both directions)
            for i in range(len(dec) - 1):
                if dec[i] % 2 == 0:
                    d = dec.copy()
                    d[i] //= 2
                    d[i + 1] *= 2
                    moves.append((
                        1.0, f"shift a factor 2 of {l.name} inward",
                        f"TileSize(axis={l.name}, decision={d})"))
                if dec[i + 1] % 2 == 0:
                    d = dec.copy()
                    d[i + 1] //= 2
                    d[i] *= 2
                    moves.append((
                        1.0, f"shift a factor 2 of {l.name} outward",
                        f"TileSize(axis={l.name}, decision={d})"))
        if w.epilogue_tensor_axes and s.compute_location >= 0:
            alt = s.compute_location + rng.choice((-1, 1))
            if 0 <= alt < SPATIAL_LEVELS:
                moves.append((1.0, "nudge the fusion level",
                              f"ComputeLocation(level={alt})"))
        un = s.unroll_map
        for l in w.loops:
            f = un.get(l.name, 1)
            if f * 2 <= s.tile_map[l.name][-1]:
                moves.append((1.0, f"deepen {l.name} unroll",
                              f"Unroll(axis={l.name}, factor={f * 2})"))
        # re-split the hottest reduction against a target ladder
        red = max(w.reduction_loops, key=lambda l: l.extent, default=None)
        if red is not None and red.extent > 8:
            tgt = rng.choice((32, 64, 128, 256, 512, 1024))
            inner = max((d for d in divisors(red.extent) if d <= tgt),
                        default=red.extent)
            dec = (red.extent // inner, inner)
            if dec != s.tile_map[red.name]:
                moves.append((1.0, f"try a {inner}-wide {red.name} block",
                              f"TileSize(axis={red.name}, "
                              f"decision={list(dec)})"))
        for o in w.operands:
            if not o.is_output and o.name not in s.cache_reads:
                moves.append((0.8, f"stage {o.name} through scratch",
                              f"CacheRead(operand={o.name})"))
        rng.shuffle(moves)
        return moves if moves else [(
            1.0, "flip scratch accumulation",
            f"CacheWrite(enabled={not s.cache_write})")]

    # -- context credit assignment (deeper trace -> better bias, Table 5) ---
    def _credit_assignment(
        self, trace: Sequence[TraceEntry]
    ) -> tuple[set, set]:
        avoid: set = set()
        prefer: set = set()
        for child, parent in zip(trace[:-1], trace[1:]):
            new = child.schedule.history[len(parent.schedule.history):]
            delta = parent.latency_s - child.latency_s  # >0 == improvement
            for desc in new:
                fam = desc.split("(")[0]
                if delta > 0.02 * parent.latency_s:
                    prefer.add(fam)
                elif delta < -0.02 * parent.latency_s:
                    avoid.add(fam)
        return avoid - prefer, prefer

    # -- napkin-math tile synthesis ------------------------------------------
    @staticmethod
    def _tile_decision(
        w, axis: str, inner_target: int, cache_target: int, p: Platform,
        grid_target: Optional[int] = None,
    ) -> Optional[tuple[int, ...]]:
        ext = w.loop_map[axis].extent
        divs = divisors(ext)
        inner = max((d for d in divs if d <= inner_target), default=1)
        # prefer exact multiples of the target alignment
        aligned = [d for d in divs if d % inner_target == 0]
        if aligned:
            inner = min(aligned)
        rem = ext // inner
        rdivs = divisors(rem)
        cache = max((d for d in rdivs if inner * d <= cache_target),
                    default=1)
        rem2 = rem // cache
        if grid_target:
            r2d = divisors(rem2)
            grid = max((d for d in r2d if d <= grid_target), default=rem2)
            par = rem2 // grid
            dec = (grid, par, cache, inner)
        else:
            dec = (rem2, 1, cache, inner)
        if math.prod(dec) != ext:
            return None
        return dec

    @staticmethod
    def _reduction_decision(w, axis: str, inner_target: int) \
            -> Optional[tuple[int, ...]]:
        ext = w.loop_map[axis].extent
        inner = max((d for d in divisors(ext) if d <= inner_target),
                    default=ext)
        return (ext // inner, inner)


class RandomLLM(LLMBase):
    """Null proposal model: emits a random legal transformation mention
    (used to sanity-check that the *reasoning*, not the plumbing, drives
    the sample-efficiency gap)."""

    name = "random"

    def complete(self, prompt: Prompt, rng: random.Random) -> str:
        s = prompt.trace[0].schedule
        t = random_transform(rng, s)
        return f"Reasoning: random exploration.\n" \
               f"Transformations to apply: {t.describe()}."


class APILLM(LLMBase):
    """OpenAI-compatible chat-completions adapter (real deployments).

    Reads OPENAI_BASE_URL / OPENAI_API_KEY / REPRO_LLM_MODEL from the
    environment.  Never invoked in CI (this container is offline); the
    HeuristicReasonerLLM substitutes behind the same interface.

    Transient transport failures retry with bounded exponential backoff
    (+ jitter drawn from the caller's rng, so deployments stay
    reproducible given a seed): a proposer pool multiplies API calls, and
    one dropped connection must not poison a whole MCTS expansion.
    Client errors other than 429 fail immediately — retrying a 400 burns
    the budget without ever succeeding.  Each retry emits an obs instant
    (``llm-retry``) so traces show exactly where wall-time went.
    """

    def __init__(self, model: Optional[str] = None, timeout_s: float = 60.0,
                 max_attempts: int = 3, backoff_s: float = 0.5,
                 backoff_mult: float = 2.0, jitter: float = 0.25,
                 tracer=None):
        from ..obs import NULL_TRACER

        self.model = model or os.environ.get("REPRO_LLM_MODEL", "gpt-4o-mini")
        self.base = os.environ.get(
            "OPENAI_BASE_URL", "https://api.openai.com/v1"
        )
        self.key = os.environ.get("OPENAI_API_KEY", "")
        self.timeout_s = timeout_s
        self.max_attempts = max(1, max_attempts)
        self.backoff_s = backoff_s
        self.backoff_mult = backoff_mult
        self.jitter = jitter
        self.trace = tracer or NULL_TRACER
        self._sleep = time.sleep  # injectable (tests)
        self.retries = 0
        self.name = f"api:{self.model}"

    def _request(self, body: bytes) -> str:
        req = urllib.request.Request(
            f"{self.base}/chat/completions",
            data=body,
            headers={
                "Content-Type": "application/json",
                "Authorization": f"Bearer {self.key}",
            },
        )
        with urllib.request.urlopen(req, timeout=self.timeout_s) as r:
            out = json.load(r)
        return out["choices"][0]["message"]["content"]

    @staticmethod
    def _retryable(e: Exception) -> bool:
        if isinstance(e, urllib.error.HTTPError):
            return e.code == 429 or e.code >= 500
        return isinstance(e, (urllib.error.URLError, TimeoutError, OSError))

    def complete(self, prompt: Prompt, rng: random.Random) -> str:
        body = json.dumps({
            "model": self.model,
            "messages": [{"role": "user", "content": prompt.text}],
            "temperature": 0.7,
            "seed": rng.randrange(2**31),
        }).encode()
        delay = self.backoff_s
        for attempt in range(1, self.max_attempts + 1):
            try:
                return self._request(body)
            except Exception as e:  # noqa: BLE001 — classified below
                if attempt >= self.max_attempts or not self._retryable(e):
                    raise
                sleep_s = delay * (1.0 + self.jitter * rng.random())
                self.retries += 1
                self.trace.instant(
                    "llm-retry", cat="llm", model=self.model,
                    attempt=attempt, sleep_s=round(sleep_s, 3),
                    error=type(e).__name__,
                )
                self._sleep(sleep_s)
                delay *= self.backoff_mult
        raise RuntimeError("unreachable")  # pragma: no cover


def make_llm(name: str) -> LLMBase:
    if name in MODEL_TIERS:
        return HeuristicReasonerLLM(name)
    if name == "random":
        return RandomLLM()
    if name.startswith("api:"):
        return APILLM(name.split(":", 1)[1])
    raise KeyError(f"unknown LLM {name!r}; known: {sorted(MODEL_TIERS)}")


# ---------------------------------------------------------------------------
# The proposal engine wrapper used by MCTS expansion
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FallbackStats:
    """Appendix-G expansion statistics, attributable to ONE proposer tier.

    ``name`` identifies the proposer the counts belong to, so invalid-name
    and fallback rates stay per-tier when several proposers share a search
    tree (``repro.compiler.proposers``) — Table 8 needs the attribution.
    """

    expansions: int = 0
    fallbacks: int = 0
    proposed: int = 0
    invalid: int = 0
    name: str = ""

    @property
    def fallback_rate(self) -> float:
        return self.fallbacks / self.expansions if self.expansions else 0.0

    @property
    def invalid_rate(self) -> float:
        return self.invalid / self.proposed if self.proposed else 0.0

    def absorb(self, prop: Proposal) -> None:
        """Count one expansion's outcome."""
        self.expansions += 1
        self.proposed += prop.n_proposed
        self.invalid += prop.n_invalid
        if prop.fallback:
            self.fallbacks += 1

    def merge(self, other: "FallbackStats") -> None:
        self.expansions += other.expansions
        self.fallbacks += other.fallbacks
        self.proposed += other.proposed
        self.invalid += other.invalid


class LLMProposer:
    """Prompt -> LLM -> parse -> validate, with Appendix-G fallback stats."""

    def __init__(self, llm: LLMBase, platform: Platform, trace_depth: int = 2):
        self.llm = llm
        self.platform = platform
        self.trace_depth = trace_depth
        self.stats = FallbackStats(name=llm.name if llm is not None else "")

    def _build_prompt(self, trace: Sequence[TraceEntry]) -> Prompt:
        """Prompt-construction seam; a session's SeededProposer overrides
        this to weave cross-task context into every prompt."""
        return build_prompt(trace, self.platform, self.trace_depth)

    def _query(
        self, prompt: Prompt, trace: Sequence[TraceEntry], rng: random.Random
    ) -> Proposal:
        """Completion seam: one LLM call + parse + stats bookkeeping.
        ``compiler.proposers.PoolProposer`` overrides this to route the
        draft across a tiered proposer pool."""
        text = self.llm.complete(prompt, rng)
        prop = parse_response(text, trace[0].schedule, rng)
        prop.proposer = self.llm.name
        self.stats.absorb(prop)
        return prop

    def propose(
        self, trace: Sequence[TraceEntry], rng: random.Random
    ) -> Proposal:
        prompt = self._build_prompt(trace)
        return self._query(prompt, trace, rng)

    def stats_by_proposer(self) -> dict[str, FallbackStats]:
        """Per-tier attribution of the Appendix-G statistics.  A single
        proposer owns all of them; a pool reports one entry per member."""
        return {self.stats.name: self.stats}
