"""Schedule -> executable kernel: the bridge behind the measured oracle.

The paper's objective ``f`` is a *real hardware measurement* of a compiled
program.  This module closes that loop for the repo: ``lower_schedule``
maps a ``core.schedule.Schedule`` onto the runnable JAX/Pallas kernels in
``repro.kernels`` and returns a ``Lowered`` artifact that can be executed,
numerics-checked against ``kernels/ref.py``, and wall-clock timed
(``time_lowered``).  ``core/oracle.py`` builds the ``MeasuredOracle`` on
top of it.

Mapping (extends the ``core/autotuner.py`` block extraction, which now
imports ``_band_extent`` / ``_quantize_block`` from here):

* **TileSize** — the VMEM-band tile extents (spatial levels 2..3,
  reduction level 1) become Pallas BlockSpec block shapes, quantized to a
  power-of-two **divisor** of the axis extent (lane/sublane ``lo`` floors
  honored only when a legal divisor exists).
* **ComputeLocation** (fusion depth) — an epilogue fused at any spatial
  level selects the fused kernel variant (``swiglu_gateup``,
  ``flash_attention``'s online softmax); a root-materialized epilogue
  lowers to the plain kernel plus a separate jnp epilogue (extra HBM
  round trip), or — for attention, where the materialized [h, i, j] score
  tensor has no Pallas realization — to the ``kernels/ref.py``
  interpreter fallback.
* **CacheWrite** — scratch accumulation (the kernels' f32 VMEM
  accumulator) vs. read-modify-write through the output ref in output
  dtype (``matmul(..., cache_write=False)``).  Fused-epilogue kernels
  keep their accumulators regardless: fusion *is* scratch accumulation.
* **CacheRead** — an operand staged through scratch keeps the fine
  reduction-banded BlockSpec (re-fetched per reduction step); with no
  explicit staging the whole reduction strip is made resident at once
  (``bk = K`` / ``block_k = S_kv``).  This realization applies in the
  relaxed-floor (interpret / search) mode; under ``hardware_floors`` the
  reduction block is always the banded ``from_schedule`` quantization so
  the timed launch equals the persisted one (VMEM-safe on real TPUs).

Workloads with no executable realization at all (unknown loop structure)
raise ``LoweringError``; callers decide whether to fall back to the
analytical oracle.
"""
from __future__ import annotations

import dataclasses
import math
import statistics
import time
import zlib
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..kernels import ref as _ref
from ..kernels.flash_attention import flash_attention
from ..kernels.matmul import matmul as _pallas_matmul
from ..kernels.matmul import swiglu_gateup as _pallas_gateup
from .schedule import SPATIAL_LEVELS, Schedule
from .workloads import Workload


class LoweringError(ValueError):
    """The schedule/workload has no executable realization."""


# ---------------------------------------------------------------------------
# block extraction (shared with core/autotuner.py)
# ---------------------------------------------------------------------------

def _quantize_block(x: int, extent: int, lo: int = 8, hi: int = 1024) -> int:
    """Map a tile extent to a power-of-two DIVISOR of ``extent``.

    Returns the largest power-of-two divisor of ``extent`` that is
    <= clamp(x, lo, hi); when every such divisor is below ``lo`` (odd or
    prime extents, tiny axes) the smallest power-of-two divisor >= ``lo``
    is preferred if one exists within ``hi``, else the best (possibly
    sub-``lo``) divisor is returned.  The result always divides
    ``extent`` — the Pallas ``assert extent % block == 0`` launch
    invariant — unlike the previous fallback which could return a bare
    ``lo`` on extents it did not divide.
    """
    target = max(lo, min(hi, x))
    best, p = 1, 1
    while p <= min(hi, extent):
        if extent % p == 0 and p <= target:
            best = p
        p *= 2
    if best < lo:
        p = 1
        while p <= min(hi, extent):
            if p >= lo and extent % p == 0:
                return p
            p *= 2
    return best


def _band_extent(s: Schedule, axis: str) -> int:
    """Product of the VMEM-band tile levels (spatial 2..3 / reduction 1)."""
    tm = s.tile_map[axis]
    if len(tm) == SPATIAL_LEVELS:
        return tm[2] * tm[3]
    return tm[-1]


# ---------------------------------------------------------------------------
# lowered artifact
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Lowered:
    """An executable realization of one schedule."""

    kind: str                    # "matmul" | "swiglu" | "attention" | "ref"
    fn: Callable                 # jitted; call as fn(*args)
    args: tuple                  # device operands (deterministic per workload)
    ref_fn: Callable             # jnp semantics contract (kernels/ref.py)
    workload: str
    fallback: bool = False       # True -> no Pallas realization, ref path
    blocks: dict = dataclasses.field(default_factory=dict)
    grid_steps: int = 1

    @property
    def config_key(self) -> tuple:
        """Identity of the *compiled* kernel: distinct schedules that
        quantize to the same launch configuration share timings."""
        return (
            self.workload, self.kind, self.fallback,
            tuple(sorted(self.blocks.items())),
        )

    def run(self):
        return self.fn(*self.args)

    def verify(self, tol: Optional[float] = None) -> float:
        """Normalized max |kernel - ref| error; raises on mismatch.

        Default tolerance is dtype-aware: bf16 output-ref accumulation
        (``cache_write=False``) rounds each partial sum to bf16, so the
        bound scales with the number of reduction steps.
        """
        out = jax.block_until_ready(self.run())
        ref = jax.block_until_ready(self.ref_fn(*self.args))
        if tol is None:
            tol = 5e-2 if out.dtype == jnp.bfloat16 else 1e-4
        err = float(
            jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))
            / (jnp.max(jnp.abs(ref.astype(jnp.float32))) + 1e-6)
        )
        if not math.isfinite(err) or err > tol:
            raise LoweringError(
                f"numerics mismatch vs kernels/ref.py on {self.workload} "
                f"({self.kind}, blocks={self.blocks}): err={err:.2e} > {tol}"
            )
        return err


# ---------------------------------------------------------------------------
# operand synthesis
# ---------------------------------------------------------------------------

def _dtype_of(w: Workload):
    return jnp.bfloat16 if max(o.dtype_bytes for o in w.operands) == 2 \
        else jnp.float32


def operand_arrays(w: Workload, seed: int = 0) -> dict:
    """Deterministic input operands for a workload (keyed by name)."""
    dtype = _dtype_of(w)
    key = jax.random.PRNGKey(zlib.crc32(w.name.encode()) ^ seed)
    out = {}
    for o in w.operands:
        if o.is_output:
            continue
        key, sub = jax.random.split(key)
        out[o.name] = jax.random.normal(
            sub, o.shape(w.loop_map), jnp.float32
        ).astype(dtype)
    return out


# ---------------------------------------------------------------------------
# per-family lowerings
# ---------------------------------------------------------------------------

def _epilogue_fn(kind: str) -> Callable:
    if kind == "swiglu":
        # The abstract program has ONE GEMM output C with an elementwise
        # silu-gate epilogue, so both realizations compute silu(C) * C
        # (the fused kernel is passed w_up == w_gate).
        return lambda c: jax.nn.silu(c.astype(jnp.float32)).astype(c.dtype) * c
    if kind == "softmax":
        return lambda c: jax.nn.softmax(
            c.astype(jnp.float32), axis=-1
        ).astype(c.dtype)
    raise LoweringError(f"unknown epilogue kind {kind!r}")


def _lower_matmul(s: Schedule, w: Workload, ops_: dict, interpret: bool,
                  hardware_floors: bool) -> Lowered:
    m = w.loop_map["i"].extent
    n = w.loop_map["j"].extent
    k = w.loop_map["k"].extent
    # Compiled TPU launches respect the (8, 128) sublane/lane floors; the
    # interpreter has no layout constraints, and a uniform low floor keeps
    # distinct small-shape schedules distinguishable by the measurement
    # (with hardware floors, every CI-sized schedule quantizes to the same
    # launch and the measured search cannot discriminate).
    lo_n, lo_k = (128, 128) if hardware_floors else (8, 8)
    bm = _quantize_block(_band_extent(s, "i"), m, lo=8, hi=512)
    bn = _quantize_block(_band_extent(s, "j"), n, lo=lo_n, hi=1024)
    if hardware_floors:
        # exactly the launch GemmBlocks.from_schedule persists (the
        # autotuner re-rank must time what it stores)
        bk = _quantize_block(_band_extent(s, "k"), k, lo=lo_k, hi=2048)
    elif any(name in s.cache_reads for name in ("A", "B")):
        bk = _quantize_block(_band_extent(s, "k"), k, lo=lo_k, hi=2048)
    else:
        bk = k  # no explicit staging: whole reduction strip resident
    a, b = ops_["A"], ops_["B"]
    fused = bool(s.compute_location >= 0 and w.epilogue_kind)
    grid_steps = (m // bm) * (n // bn) * (k // bk)

    # blocks doubles as the launch-config identity for timing dedup
    # (Lowered.config_key): only knobs the executed kernel actually
    # consumes belong in it.
    if not w.epilogue_kind:
        blocks = dict(bm=bm, bn=bn, bk=bk, cache_write=s.cache_write)
        fn = jax.jit(lambda a, b: _pallas_matmul(
            a, b, bm=bm, bn=bn, bk=bk, cache_write=s.cache_write,
            interpret=interpret,
        ))
        ref = _ref.matmul_ref
        kind = "matmul"
    elif fused:
        if w.epilogue_kind == "swiglu":
            # fusion is scratch accumulation; cache_write is moot here
            blocks = dict(bm=bm, bn=bn, bk=bk, fused=True)
            fn = jax.jit(lambda a, b: _pallas_gateup(
                a, b, b, bm=bm, bn=bn, bk=bk, interpret=interpret,
            ))
            epi = _epilogue_fn("swiglu")
            ref = lambda a, b: epi(_ref.matmul_ref(a, b))  # noqa: E731
            kind = "swiglu"
        else:
            # fused softmax-epilogue GEMM has no Pallas kernel here: the
            # row reduction spans the full n axis — ref interpreter path
            # (block-independent, so no block params in the identity).
            epi = _epilogue_fn(w.epilogue_kind)
            fn = jax.jit(lambda a, b: epi(_ref.matmul_ref(a, b)))
            ref = fn
            return Lowered("ref", fn, (a, b), ref, w.name, fallback=True,
                           blocks=dict(epilogue=w.epilogue_kind),
                           grid_steps=1)
    else:
        # materialized at root: plain kernel + separate jnp epilogue pass
        blocks = dict(bm=bm, bn=bn, bk=bk, cache_write=s.cache_write,
                      fused=False)
        epi = _epilogue_fn(w.epilogue_kind)
        fn = jax.jit(lambda a, b: epi(_pallas_matmul(
            a, b, bm=bm, bn=bn, bk=bk, cache_write=s.cache_write,
            interpret=interpret,
        )))
        ref = lambda a, b: epi(_ref.matmul_ref(a, b))  # noqa: E731
        kind = "matmul"
    return Lowered(kind, fn, (a, b), ref, w.name, blocks=blocks,
                   grid_steps=grid_steps)


def _lower_attention(s: Schedule, w: Workload, ops_: dict, interpret: bool,
                     hardware_floors: bool) -> Lowered:
    h = w.loop_map["h"].extent
    sq = w.loop_map["i"].extent
    skv = w.loop_map["j"].extent
    # operands are [h, s, d]; kernels take [B, H, S, D]
    q = ops_["Q"][None]
    kk = ops_["K"][None]
    v = ops_["V"][None]
    ref = lambda q, k, v: _ref.attention_ref(q, k, v, causal=False)  # noqa: E731
    if s.compute_location < 0:
        # materialized softmax: the [h, i, j] score tensor never fits the
        # flash structure — kernels/ref.py interpreter fallback.
        fn = jax.jit(ref)
        return Lowered("ref", fn, (q, kk, v), ref, w.name, fallback=True,
                       blocks=dict(materialized=True), grid_steps=1)
    bq = _quantize_block(_band_extent(s, "i"), sq, lo=8, hi=512)
    if hardware_floors:
        # exactly the launch AttentionBlocks.from_schedule persists
        bk = _quantize_block(_band_extent(s, "j"), skv, lo=128, hi=1024)
    elif any(name in s.cache_reads for name in ("K", "V")):
        bk = _quantize_block(_band_extent(s, "j"), skv, lo=8, hi=1024)
    else:
        bk = skv
    blocks = dict(block_q=bq, block_k=bk)
    grid_steps = h * (sq // bq) * (skv // bk)
    fn = jax.jit(lambda q, k, v: flash_attention(
        q, k, v, causal=False, block_q=bq, block_k=bk, interpret=interpret,
    ))
    return Lowered("attention", fn, (q, kk, v), ref, w.name, blocks=blocks,
                   grid_steps=grid_steps)


def _lower_conv(s: Schedule, w: Workload, ops_: dict) -> Lowered:
    # The conv IR is im2col-degenerate (X not indexed by kh/kw): the loop
    # nest computes Y[n,oh,ow,oc] = sum_{ic,kh,kw} X[n,oh,ow,ic] W[kh,kw,ic,oc].
    def ref(x, wgt):
        return jnp.einsum(
            "nhwi,io->nhwo", x.astype(jnp.float32),
            wgt.astype(jnp.float32).sum(axis=(0, 1)),
        ).astype(x.dtype)

    fn = jax.jit(ref)
    return Lowered("ref", fn, (ops_["X"], ops_["W"]), ref, w.name,
                   fallback=True, blocks=dict(conv=True), grid_steps=1)


def lower_schedule(
    schedule: Schedule,
    workload: Optional[Workload] = None,
    *,
    interpret: Optional[bool] = None,
    hardware_floors: Optional[bool] = None,
    seed: int = 0,
) -> Lowered:
    """Lower a schedule to an executable ``Lowered`` artifact.

    ``interpret`` defaults to True off-TPU (the CPU-CI path: same kernel
    bodies run by the Pallas interpreter).  ``hardware_floors`` applies
    the compiled-TPU (8, 128) sublane/lane block floors even under the
    interpreter (default: floors follow ``interpret``) — the autotuner's
    measured re-rank uses this so the launch it times is the launch it
    persists.  Raises ``LoweringError`` when the workload's loop
    structure has no executable realization.
    """
    w = workload or schedule.workload
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if hardware_floors is None:
        hardware_floors = not interpret
    names = {l.name for l in w.loops}
    onames = {o.name for o in w.operands}
    if names == {"i", "j", "k"} and {"A", "B", "C"} <= onames:
        family = _lower_matmul
    elif names == {"h", "i", "j", "k"} and {"Q", "K", "V", "O"} <= onames:
        family = _lower_attention
    elif {"oh", "ow", "ic", "oc"} <= names:
        return _lower_conv(schedule, w, operand_arrays(w, seed))
    else:
        raise LoweringError(
            f"workload {w.name!r} (loops {sorted(names)}) has no lowering rule"
        )
    return family(schedule, w, operand_arrays(w, seed), interpret,
                  hardware_floors)


# ---------------------------------------------------------------------------
# timing harness
# ---------------------------------------------------------------------------

def time_lowered(lowered: Lowered, *, warmup: int = 1,
                 repeats: int = 3) -> float:
    """Median wall-clock seconds over ``repeats`` runs.

    Compile-once protocol: the first call (jit trace + compile) is always
    excluded, then ``warmup`` untimed runs, then ``repeats`` timed runs
    with ``block_until_ready`` inside the timed region.  Median-of-k
    rather than mean: scheduler noise on shared CI hosts is one-sided.
    """
    jax.block_until_ready(lowered.run())  # compile
    for _ in range(warmup):
        jax.block_until_ready(lowered.run())
    times = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(lowered.run())
        times.append(time.perf_counter() - t0)
    return statistics.median(times)
