"""Monte Carlo tree search over transformation sequences (paper §3.2).

Implements the paper's planner exactly:

* **Selection** — UCT ``W/N + c sqrt(ln N_parent / N)`` with ``c = sqrt(2)``,
  descending only through fully-expanded nodes (branching factor ``B = 2``
  by default, Table 6 ablates ``B = 4``).
* **Expansion** — the LLM proposer is queried with the hierarchical context
  (selected node + ancestors); its validated transformation sequence is
  applied to produce ONE new program variant.  If every proposal is invalid
  the expansion falls back to the default random policy (Appendix G).  A
  re-derived identical program is not re-added (acyclicity, §3.2).
* **Rollout** — a randomized sequence of legal transformations is applied to
  the new node and scored by the learned surrogate ``f̂`` (never the real
  objective: hardware measurement inside rollouts is what the paper calls
  prohibitively expensive).  Until the surrogate has enough observations the
  node's own measured reward is used.
* **Backpropagation** — ``W += r``, ``N += 1`` along the path to the root.

Sample accounting matches the paper's x-axis: one *sample* = one evaluated
transformation proposal, i.e. one oracle measurement of a new tree node.
Rollout surrogate queries are free.

Beyond-paper options (all default OFF; flipped on in EXPERIMENTS.md §Perf):
  * ``transposition_table`` — share statistics between identical programs
    reached by different transformation orders.
  * ``prior_weight`` — PUCT-style prior from the surrogate on fresh children.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, Optional, Sequence

from ..obs import NULL_TRACER, Tracer
from .cost_model import HardwareOracle, SurrogateModel
from .llm import LLMProposer, Proposal, TraceEntry
from .lowering import LoweringError
from .schedule import Schedule, ScheduleError, initial_schedule, random_transform


@dataclasses.dataclass
class Node:
    schedule: Schedule
    parent: Optional["Node"]
    latency_s: float
    speedup: float
    W: float = 0.0
    N: int = 0
    children: list = dataclasses.field(default_factory=list)
    prior: float = 0.0
    # per-node proposal provenance (None when the node came from the
    # default random expansion policy): which pool member drafted the
    # transforms that produced it, and any review-tier outcome
    proposer: Optional[str] = None
    reviewer: Optional[str] = None
    review_action: Optional[str] = None

    @property
    def depth(self) -> int:
        d, n = 0, self
        while n.parent is not None:
            d, n = d + 1, n.parent
        return d

    def ancestors(self) -> list["Node"]:
        """[self, parent, grandparent, ...] up to the root."""
        out, n = [], self
        while n is not None:
            out.append(n)
            n = n.parent
        return out


@dataclasses.dataclass
class SearchCurve:
    """Best-so-far speedup as a function of evaluated samples (Fig. 3)."""

    points: list  # (samples, best_speedup)

    def at(self, samples: int) -> float:
        best = 1.0
        for s, v in self.points:
            if s <= samples:
                best = v
            else:
                break
        return best

    def samples_to_reach(self, speedup: float) -> Optional[int]:
        for s, v in self.points:
            if v >= speedup:
                return s
        return None


class MCTS:
    """UCT tree search with (optionally) LLM-guided expansion."""

    def __init__(
        self,
        workload,
        oracle: HardwareOracle,
        proposer: Optional[LLMProposer] = None,
        branching: int = 2,
        c_uct: float = math.sqrt(2.0),
        rollout_depth: int = 2,
        max_depth: int = 24,
        seed: int = 0,
        surrogate: Optional[SurrogateModel] = None,
        transposition_table: bool = False,
        prior_weight: float = 0.0,
        tracer: Optional[Tracer] = None,
        screen_width: int = 8,
        escalate_topk: int = 1,
    ):
        self.workload = workload
        self.oracle = oracle
        self.trace = tracer or NULL_TRACER
        self.proposer = proposer
        self.branching = branching
        self.c_uct = c_uct
        self.rollout_depth = rollout_depth
        self.max_depth = max_depth
        self.rng = random.Random(seed)
        self.surrogate = surrogate if surrogate is not None else SurrogateModel()
        self.transposition_table = transposition_table
        self.prior_weight = prior_weight
        # Screened expansion (oracle backends exposing ``screen``, i.e. the
        # surrogate tier): pool up to ``screen_width`` candidates per
        # expansion, escalate only the predicted-best ``escalate_topk`` to a
        # real measurement.  Oracles without ``screen`` keep the exact
        # one-candidate expansion below (bit-identical search).
        self.screen_width = screen_width
        self.escalate_topk = escalate_topk

        s0 = initial_schedule(workload)
        self.baseline_latency = oracle.measure(s0)
        self.root = Node(s0, None, self.baseline_latency, 1.0)
        self.surrogate.observe(s0, self.baseline_latency)
        self._seen: dict = {s0.key(): self.root}
        self.samples = 0
        self.best: Node = self.root
        self.curve: list = []

    # -- public --------------------------------------------------------------
    def top_schedules(self, n: int = 3) -> list[Schedule]:
        """The n best evaluated schedules (by oracle latency), best first.
        Winners the autotuner re-ranks by real measurement come from here."""
        nodes = sorted(self._seen.values(), key=lambda nd: nd.latency_s)
        return [nd.schedule for nd in nodes[:n]]

    def search(self, budget_samples: int) -> SearchCurve:
        guard = 0
        while self.samples < budget_samples and guard < budget_samples * 20:
            guard += 1
            self.step()
        return SearchCurve(list(self.curve))

    def step(self) -> Optional[Node]:
        leaf = self._select()
        if hasattr(self.oracle, "screen"):
            children = self._expand_screened(leaf)
        else:
            child = self._expand(leaf)
            children = [] if child is None else [child]
        last: Optional[Node] = None
        for child in children:
            reward = self._rollout(child)
            with self.trace.span("backprop", cat="search", reward=reward,
                                 depth=child.depth):
                self._backprop(child, reward)
            last = child
        return last

    # -- phases ----------------------------------------------------------------
    def _uct(self, node: Node, parent: Node) -> float:
        exploit = node.W / node.N if node.N else 0.0
        explore = self.c_uct * math.sqrt(
            math.log(max(parent.N, 1)) / node.N if node.N else 1.0
        )
        return exploit + explore + self.prior_weight * node.prior / (1 + node.N)

    def _select(self) -> Node:
        node = self.root
        while len(node.children) >= self.branching and node.children \
                and node.depth < self.max_depth:
            node = max(node.children, key=lambda ch: self._uct(ch, node))
        return node

    def _expand(self, node: Node) -> Optional[Node]:
        """Produce one new program variant below `node` (1 sample)."""
        proposal: Optional[Proposal] = None
        if self.proposer is not None:
            trace = [
                TraceEntry(n.schedule, n.latency_s, n.speedup)
                for n in node.ancestors()
            ]
            with self.trace.span(
                "llm-proposal", cat="search", depth=node.depth,
                trace_len=len(trace),
            ) as psp:
                proposal = self.proposer.propose(trace, self.rng)
                psp.set(
                    fallback=proposal.fallback if proposal else True,
                    n_transforms=len(proposal.transforms)
                    if proposal else 0,
                )

        new_sched: Optional[Schedule] = None
        derived = False  # True iff new_sched came from the LLM proposal
        if proposal is not None and not proposal.fallback:
            s = node.schedule
            try:
                for t in proposal.transforms:
                    s = t.apply(s)
                new_sched = s
                derived = True
            except ScheduleError:
                new_sched = None
        if new_sched is None or new_sched.key() in self._seen:
            # default expansion policy (also the Appendix-G fallback path)
            derived = False
            for _ in range(16):
                try:
                    s = node.schedule
                    for _ in range(self.rng.randint(1, 3)):
                        s = random_transform(self.rng, s).apply(s)
                except ScheduleError:
                    continue
                if s.key() not in self._seen:
                    new_sched = s
                    break
            else:
                return None  # exhausted: nothing new reachable from here

        if new_sched.key() in self._seen:
            if not self.transposition_table:
                return None
            # transposition: merge statistics instead of duplicating
            twin = self._seen[new_sched.key()]
            self._backprop(twin, twin.W / max(1, twin.N))
            return None

        return self._measure_child(node, new_sched,
                                   proposal=proposal if derived else None)

    def _measure_child(
        self, node: Node, new_sched: Schedule,
        proposal: Optional[Proposal] = None,
    ) -> Optional[Node]:
        """Measure one candidate (1 sample) and attach it below `node`.

        ``proposal`` is set only when ``new_sched`` is the proposal's own
        transform sequence applied to ``node`` — the child then carries
        the drafting proposer's provenance, and a pool proposer gets its
        hit-rate feedback (did the measured draft beat its parent?)."""
        try:
            with self.trace.span(
                "oracle-measure", cat="search", depth=node.depth + 1,
            ) as msp:
                latency = self.oracle.measure(new_sched)
                msp.set(latency_s=latency)
        except LoweringError:
            # a measured backend refused this program (no realization /
            # grid guard): no kernel ran, so no sample is consumed and the
            # node is never added — the search routes around it
            return None
        self.samples += 1
        speedup = self.baseline_latency / latency
        child = Node(new_sched, node, latency, speedup)
        if proposal is not None:
            child.proposer = proposal.proposer
            child.reviewer = proposal.reviewer
            child.review_action = proposal.review_action
            feedback = getattr(self.proposer, "feedback", None)
            if feedback is not None:
                feedback(proposal, improved=latency < node.latency_s)
        if self.prior_weight:
            pred = self.surrogate.predict(new_sched)
            if pred is not None:
                child.prior = self._reward_from_latency(pred)
        node.children.append(child)
        self._seen[new_sched.key()] = child
        self.surrogate.observe(new_sched, latency)
        if latency < self.best.latency_s:
            self.best = child
        self.curve.append((self.samples, self.best.speedup))
        return child

    def _expand_screened(self, node: Node) -> list[Node]:
        """Screened expansion (surrogate oracle tier, GOLEM dispatcher
        split): pool up to ``screen_width`` candidate variants below
        ``node`` — the LLM proposal leading, random continuations filling —
        let the oracle's learned model rank the whole pool, and escalate
        only the predicted-best ``escalate_topk`` to real measurements.
        Unescalated candidates cost zero samples."""
        pool: list[Schedule] = []
        keys: set = set()
        proposal: Optional[Proposal] = None
        prop_key = None  # key of the proposal-derived candidate, if any

        def admit(s: Schedule) -> None:
            k = s.key()
            if k not in self._seen and k not in keys:
                keys.add(k)
                pool.append(s)

        if self.proposer is not None:
            trace = [
                TraceEntry(n.schedule, n.latency_s, n.speedup)
                for n in node.ancestors()
            ]
            with self.trace.span(
                "llm-proposal", cat="search", depth=node.depth,
                trace_len=len(trace),
            ) as psp:
                proposal = self.proposer.propose(trace, self.rng)
                psp.set(
                    fallback=proposal.fallback if proposal else True,
                    n_transforms=len(proposal.transforms)
                    if proposal else 0,
                )
            if proposal is not None and not proposal.fallback:
                s = node.schedule
                try:
                    for t in proposal.transforms:
                        s = t.apply(s)
                    admit(s)
                    prop_key = s.key()
                except ScheduleError:
                    pass
        tries = 0
        while len(pool) < self.screen_width and tries < 16 * self.screen_width:
            tries += 1
            try:
                s = node.schedule
                for _ in range(self.rng.randint(1, 3)):
                    s = random_transform(self.rng, s).apply(s)
            except ScheduleError:
                continue
            admit(s)
        if not pool:
            return []
        want = min(self.escalate_topk, len(pool))
        ranked = self.oracle.screen(pool, k=want)
        ranked_keys = {s.key() for s in ranked}
        backups = [s for s in pool if s.key() not in ranked_keys]
        children: list[Node] = []
        for s in ranked + backups:
            if len(children) >= want:
                break
            child = self._measure_child(
                node, s,
                proposal=proposal if s.key() == prop_key else None,
            )
            if child is not None:
                children.append(child)
        return children

    def _rollout(self, node: Node) -> float:
        """Randomized continuation scored by the surrogate (paper Fig. 2b).

        A hybrid oracle (core/oracle.py) exposes ``rollout_measure``: the
        free analytical model scores the continuation instead of the
        learned surrogate — measured node rewards, analytical rollouts,
        the paper's cost split."""
        s = node.schedule
        for _ in range(self.rollout_depth):
            try:
                s = random_transform(self.rng, s).apply(s)
            except ScheduleError:
                break
        rollout_measure = getattr(self.oracle, "rollout_measure", None)
        if rollout_measure is not None:
            t = rollout_measure(s)
            if t is not None:
                return self._reward_from_latency(t)
        pred = self.surrogate.predict(s)
        if pred is None:
            # surrogate undertrained: fall back to the node's own measurement
            return self._reward_from_latency(node.latency_s)
        # noisy but informative proxy; never consumes a sample
        return self._reward_from_latency(pred)

    def _reward_from_latency(self, latency_s: float) -> float:
        """Map latency to a bounded reward in (0, 1), normalized against the
        best speedup found so far — keeps UCT discriminating even when
        speedups grow to 2-3 orders of magnitude (a fixed normalizer
        saturates and the tree policy degenerates to uniform)."""
        su = self.baseline_latency / max(latency_s, 1e-12)
        ref = max(1.0, self.best.speedup if self.best else 1.0)
        return su / (su + ref)

    def _backprop(self, node: Node, reward: float) -> None:
        n: Optional[Node] = node
        while n is not None:
            n.W += reward
            n.N += 1
            n = n.parent
