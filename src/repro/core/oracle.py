"""The oracle layer: one protocol, three backends (paper §3.2's ``f``).

The search stack (``core/mcts.py``, ``core/search.py``,
``core/autotuner.py``) treats the objective as a black box with one
method — ``measure(schedule) -> seconds``.  This module is the seam:

* ``AnalyticalOracle`` — the existing deterministic machine model
  (``cost_model.HardwareOracle``, re-exported API-stable).  Free to
  query, platform profiles for five CPUs + TPU-v5e.
* ``MeasuredOracle`` — the paper's actual protocol: lower the schedule
  to a real Pallas kernel (``core/lowering.py``), execute it, and time
  the wall clock (compile-once, warmup, median-of-k).  Off-TPU the same
  kernel bodies run under the Pallas interpreter, so CPU CI exercises
  the identical lowering path; interpreter timings are dominated by
  per-grid-step overhead and are meaningful *relatively*, not in
  absolute microseconds (EXPERIMENTS.md §Measured).
* ``HybridOracle`` — the paper's cost split exactly: every evaluated
  tree node (one *sample*) gets a real measurement, while rollout
  continuations are scored by the free analytical model
  (``rollout_measure``), never consuming hardware time.

``make_oracle`` resolves the ``oracle="analytical"|"measured"|"hybrid"``
knob threaded through ``CompilerSession`` / ``launch.tune``.
"""
from __future__ import annotations

from typing import Optional, Protocol, runtime_checkable

import jax

from ..obs import NULL_TRACER, Tracer
from .cost_model import HardwareOracle, Platform, get_platform
from .lowering import Lowered, LoweringError, lower_schedule, time_lowered
from .schedule import Schedule, initial_schedule

# The analytical machine model, moved behind the protocol (implementation
# stays in cost_model.py next to its loop-nest helpers; this is the
# canonical import site for new code).
AnalyticalOracle = HardwareOracle


@runtime_checkable
class Oracle(Protocol):
    """What the search stack requires of an objective ``f``."""

    platform: Platform

    def measure(self, s: Schedule) -> float:
        """Latency of schedule ``s`` in seconds."""
        ...

    def speedup(self, s: Schedule, baseline: Optional[Schedule] = None) -> float:
        ...


class MeasuredOracle:
    """Real ``f``: lower to a Pallas kernel, execute, time the wall clock.

    ``measure`` is cached at two levels: per schedule key, and per
    *lowered kernel configuration* (``dedup_configs``) — many schedules
    quantize to the same (blocks, fusion, cache_write) launch, and the
    hardware cannot distinguish them, so re-timing is pure waste.

    ``check_numerics`` verifies each newly lowered kernel against its
    ``kernels/ref.py`` contract before trusting its timing (a fast wrong
    kernel must never win a search).

    ``max_grid_steps`` guards against pathological interpret-mode cost
    (each grid step is a Python-level interpreter iteration off-TPU);
    paper-scale workloads should be measured on real hardware or via
    proportionally shrunk tuning shapes.
    """

    def __init__(
        self,
        platform: str | Platform = "tpu-v5e",
        *,
        interpret: Optional[bool] = None,
        hardware_floors: Optional[bool] = None,
        warmup: int = 1,
        repeats: int = 3,
        check_numerics: bool = True,
        dedup_configs: bool = True,
        max_grid_steps: int = 8192,
        seed: int = 0,
        tracer: Optional[Tracer] = None,
    ):
        self.platform = platform if isinstance(platform, Platform) \
            else get_platform(platform)
        self.trace = tracer or NULL_TRACER
        self.interpret = (jax.default_backend() != "tpu") \
            if interpret is None else interpret
        self.hardware_floors = hardware_floors
        self.warmup = warmup
        self.repeats = repeats
        self.check_numerics = check_numerics
        self.dedup_configs = dedup_configs
        self.max_grid_steps = max_grid_steps
        self.seed = seed
        self._cache: dict[tuple, float] = {}
        self._config_cache: dict[tuple, float] = {}
        self.measurements = 0     # measure() resolutions (incl. config hits)
        self.timed_kernels = 0    # actual compile+time executions
        self.fallbacks = 0        # schedules with no Pallas realization

    # -- public API ---------------------------------------------------------
    def lower(self, s: Schedule) -> Lowered:
        return lower_schedule(
            s, interpret=self.interpret,
            hardware_floors=self.hardware_floors, seed=self.seed,
        )

    def measure(self, s: Schedule) -> float:
        key = s.key()
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        low = self.lower(s)
        if self.interpret and low.grid_steps > self.max_grid_steps:
            # interpreter cost is ~linear in grid steps (Python-level per
            # step); compiled hardware launches have no such pathology
            raise LoweringError(
                f"{s.workload.name}: lowered grid has {low.grid_steps} steps "
                f"(> max_grid_steps={self.max_grid_steps}) in interpret "
                f"mode; measure on real hardware or search a smaller "
                f"tuning shape"
            )
        self.measurements += 1
        if low.fallback:
            self.fallbacks += 1
        ckey = low.config_key
        t = self._config_cache.get(ckey) if self.dedup_configs else None
        if t is None:
            if self.check_numerics:
                low.verify()
            with self.trace.span(
                "time-kernel", cat="oracle",
                workload=s.workload.name, fallback=low.fallback,
                grid_steps=low.grid_steps,
            ) as ksp:
                t = time_lowered(low, warmup=self.warmup,
                                 repeats=self.repeats)
                ksp.set(latency_s=t)
            self.timed_kernels += 1
            self._config_cache[ckey] = t
        self._cache[key] = t
        return t

    def speedup(self, s: Schedule, baseline: Optional[Schedule] = None) -> float:
        base = baseline or initial_schedule(s.workload)
        return self.measure(base) / self.measure(s)


class HybridOracle:
    """Measured node rewards + analytical rollout scoring (the paper's
    split: hardware time only per evaluated sample, free feedback inside
    rollouts)."""

    def __init__(self, analytical: HardwareOracle, measured: MeasuredOracle):
        self.analytical = analytical
        self.measured = measured
        # measured/analytical baseline ratio per workload: rollout scores
        # must live on the MEASURED latency scale or the MCTS reward
        # normalization (su vs best-so-far speedup) mixes units and
        # saturates — analytical model-seconds and wall-clock seconds can
        # differ by orders of magnitude (interpret mode especially).
        self._scales: dict[str, float] = {}

    @property
    def platform(self) -> Platform:
        return self.measured.platform

    @property
    def trace(self) -> Tracer:
        return self.measured.trace

    @trace.setter
    def trace(self, tracer: Tracer) -> None:
        self.measured.trace = tracer

    def measure(self, s: Schedule) -> float:
        return self.measured.measure(s)

    def rollout_measure(self, s: Schedule) -> Optional[float]:
        """Free (analytical) latency for rollout continuations, calibrated
        onto the measured scale via the baseline ratio; the MCTS rollout
        phase prefers this over the learned surrogate when the oracle
        provides it."""
        name = s.workload.name
        scale = self._scales.get(name)
        if scale is None:
            s0 = initial_schedule(s.workload)
            scale = self.measured.measure(s0) \
                / max(self.analytical.measure(s0), 1e-30)
            self._scales[name] = scale
        return self.analytical.measure(s) * scale

    def speedup(self, s: Schedule, baseline: Optional[Schedule] = None) -> float:
        return self.measured.speedup(s, baseline)


ORACLES = ("analytical", "measured", "hybrid", "surrogate")


def make_oracle(
    spec,
    platform: str | Platform = "tpu-v5e",
    **measured_kwargs,
):
    """Resolve an oracle knob: an Oracle instance passes through; a name
    from ``ORACLES`` (or None -> analytical) builds the backend on
    ``platform``.

    ``"surrogate"`` builds the record-trained pre-screening tier
    (``core/surrogate.py``) wrapping a measured escalation oracle;
    ``"surrogate:<backend>"`` picks a different escalation backend
    (e.g. ``"surrogate:analytical"`` for hardware-free smoke runs).
    """
    if spec is None or spec == "analytical":
        plat = platform if isinstance(platform, Platform) \
            else get_platform(platform)
        return HardwareOracle(plat)
    if spec == "measured":
        return MeasuredOracle(platform, **measured_kwargs)
    if spec == "hybrid":
        plat = platform if isinstance(platform, Platform) \
            else get_platform(platform)
        return HybridOracle(
            HardwareOracle(plat), MeasuredOracle(plat, **measured_kwargs)
        )
    if isinstance(spec, str) and (
        spec == "surrogate" or spec.startswith("surrogate:")
    ):
        from .surrogate import SurrogateOracle

        _, _, esc = spec.partition(":")
        escalate = make_oracle(esc or "measured", platform, **measured_kwargs)
        return SurrogateOracle(escalate)
    if hasattr(spec, "measure"):
        return spec
    raise ValueError(f"unknown oracle {spec!r}; known: {ORACLES}")
