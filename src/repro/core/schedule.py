"""Schedule state + the transformation action space `O` (paper §2, §3).

A `Schedule` is the MDP state: a program variant obtained by applying a
sequence of transformations to the workload's initial loop nest.  Transformations
mirror the paper's set (TileSize, Parallel, Unroll, ComputeLocation — Appendix A)
extended with the standard TVM/MetaSchedule family the paper draws from
(Vectorize, CacheRead/CacheWrite, Layout), re-targeted at the TPU decision space
(VMEM block shapes, MXU/VPU alignment, DMA staging) per DESIGN.md §3.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Iterable, Mapping, Optional, Sequence

from .workloads import REDUCTION, SPATIAL, Loop, Workload


class ScheduleError(ValueError):
    """An illegal transformation application."""


# Number of tile levels. Spatial axes use 4 (MetaSchedule's S-S-R-S-R-S layout
# collapses to 4 effective spatial tiles on TPU: grid / parallel / vmem / reg).
SPATIAL_LEVELS = 4
REDUCTION_LEVELS = 2

VECTOR_WIDTHS = (1, 2, 4, 8, 16, 32, 64, 128)
UNROLL_FACTORS = (1, 2, 4, 8, 16)


def _factorize(n: int) -> list[int]:
    out, d = [], 2
    while d * d <= n:
        while n % d == 0:
            out.append(d)
            n //= d
        d += 1
    if n > 1:
        out.append(n)
    return out


def sample_perfect_tile(rng: random.Random, extent: int, parts: int) -> tuple[int, ...]:
    """Random factorization of `extent` into `parts` factors (product == extent)."""
    factors = [1] * parts
    for p in _factorize(extent):
        factors[rng.randrange(parts)] *= p
    return tuple(factors)


def divisors(n: int, limit: int = 10**9) -> list[int]:
    out = [d for d in range(1, min(n, limit) + 1) if n % d == 0]
    return out


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Immutable schedule state (one node of the search tree)."""

    workload: Workload
    # axis name -> tile split, outermost..innermost, product == extent.
    tiles: tuple[tuple[str, tuple[int, ...]], ...]
    # number of outermost spatial tile levels fused & parallelized (0..2).
    parallel_levels: int = 1
    # innermost-axis vector width (VPU lanes on TPU, SIMD on CPU profiles).
    vector_width: int = 1
    # axis name -> unroll factor applied to its innermost tile.
    unroll: tuple[tuple[str, int], ...] = ()
    # Fusion depth of the epilogue (softmax / activation): -1 = materialized at
    # root (extra memory round-trip), k >= 0 = fused at spatial tile level k.
    compute_location: int = -1
    # Accumulate output tile in scratch (VMEM/L1) and write once at the end.
    cache_write: bool = False
    # Operands staged through scratch (explicit DMA on TPU, L1 blocking on CPU).
    cache_reads: tuple[str, ...] = ()
    # operand name -> "row" | "col" (col = transposed copy for contiguous loads)
    layouts: tuple[tuple[str, str], ...] = ()
    # Applied transformation sequence S_i (strings, for prompts & provenance).
    history: tuple[str, ...] = ()

    # -- views ------------------------------------------------------------
    @property
    def tile_map(self) -> dict[str, tuple[int, ...]]:
        return dict(self.tiles)

    @property
    def unroll_map(self) -> dict[str, int]:
        return dict(self.unroll)

    @property
    def layout_map(self) -> dict[str, str]:
        return dict(self.layouts)

    def tile_of(self, axis: str) -> tuple[int, ...]:
        return self.tile_map[axis]

    def inner_tile(self, axis: str) -> int:
        return self.tile_map[axis][-1]

    def key(self) -> tuple:
        """Structural identity (used for the acyclicity check: a re-derived
        identical program is not re-added to the tree, paper §3.2)."""
        return (
            self.workload.name, self.tiles, self.parallel_levels,
            self.vector_width, tuple(sorted(self.unroll)),
            self.compute_location, self.cache_write,
            tuple(sorted(self.cache_reads)), tuple(sorted(self.layouts)),
        )

    # -- rendering (prompt serialization, paper Appendix A style) ----------
    def render(self) -> str:
        w = self.workload
        lines = [f"# workload {w.name}: {w.description or 'tensor program'}"]
        grids = []
        for lvl in range(SPATIAL_LEVELS):
            dims = [
                f"{l.name}_{lvl}={self.tile_map[l.name][lvl]}"
                for l in w.spatial_loops
            ]
            grids.append(f"for {', '.join(dims)}" + (" [parallel]" if lvl < self.parallel_levels else ""))
        for lvl in range(REDUCTION_LEVELS):
            dims = [
                f"{l.name}_r{lvl}={self.tile_map[l.name][lvl]}"
                for l in w.reduction_loops
            ]
            grids.append(f"for {', '.join(dims)}")
        lines += [("  " * i) + g for i, g in enumerate(grids)]
        body = "  " * len(grids)
        lines.append(f"{body}compute {w.output.name}[...]  # vector_width={self.vector_width}")
        if self.unroll:
            lines.append(f"{body}# unroll: {dict(self.unroll)}")
        lines.append(
            f"{body}# epilogue at level {self.compute_location}"
            f" cache_write={self.cache_write} cache_reads={list(self.cache_reads)}"
            f" layouts={dict(self.layouts)}"
        )
        return "\n".join(lines)


def initial_schedule(workload: Workload) -> Schedule:
    """The unoptimized program p_0: trivial tiles, no annotations."""
    tiles = []
    for l in workload.loops:
        levels = SPATIAL_LEVELS if l.kind == SPATIAL else REDUCTION_LEVELS
        tiles.append((l.name, (l.extent,) + (1,) * (levels - 1)))
    return Schedule(workload=workload, tiles=tuple(tiles), parallel_levels=0)


# ---------------------------------------------------------------------------
# Transformations (the action space O)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Transform:
    """Base class: a function o : P -> P (paper §2)."""

    name: str = dataclasses.field(init=False, default="Transform")

    def apply(self, s: Schedule) -> Schedule:
        raise NotImplementedError

    def describe(self) -> str:
        raise NotImplementedError


def _with_history(s: Schedule, desc: str, **changes) -> Schedule:
    return dataclasses.replace(s, history=s.history + (desc,), **changes)


@dataclasses.dataclass(frozen=True)
class TileSize(Transform):
    axis: str
    decision: tuple[int, ...]
    name: str = dataclasses.field(init=False, default="TileSize")

    def describe(self) -> str:
        return f"TileSize({self.axis}, decision={list(self.decision)})"

    def apply(self, s: Schedule) -> Schedule:
        loops = s.workload.loop_map
        if self.axis not in loops:
            raise ScheduleError(f"unknown axis {self.axis!r}")
        loop = loops[self.axis]
        levels = SPATIAL_LEVELS if loop.kind == SPATIAL else REDUCTION_LEVELS
        if len(self.decision) != levels:
            raise ScheduleError(
                f"axis {self.axis} needs {levels} tile levels, got {len(self.decision)}")
        if math.prod(self.decision) != loop.extent:
            raise ScheduleError(
                f"tile product {math.prod(self.decision)} != extent {loop.extent}")
        if any(f < 1 for f in self.decision):
            raise ScheduleError("tile factors must be >= 1")
        tiles = tuple(
            (a, self.decision if a == self.axis else t) for a, t in s.tiles
        )
        out = _with_history(s, self.describe(), tiles=tiles)
        # Re-validate dependent annotations; clamp rather than fail (TVM would
        # re-sample dependent decisions).
        inner = out.inner_tile(self.axis)
        un = dict(out.unroll)
        if self.axis in un and un[self.axis] > inner:
            un[self.axis] = max(f for f in UNROLL_FACTORS if f <= inner)
            out = dataclasses.replace(out, unroll=tuple(sorted(un.items())))
        vec_axis = _vector_axis(out.workload)
        if self.axis == vec_axis and out.vector_width > 1:
            vw = out.vector_width
            while vw > 1 and inner % vw != 0:
                vw //= 2
            out = dataclasses.replace(out, vector_width=vw)
        return out


@dataclasses.dataclass(frozen=True)
class Parallel(Transform):
    levels: int
    name: str = dataclasses.field(init=False, default="Parallel")

    def describe(self) -> str:
        return f"Parallel(levels={self.levels})"

    def apply(self, s: Schedule) -> Schedule:
        if not 0 <= self.levels <= 2:
            raise ScheduleError("parallel levels must be in [0, 2]")
        return _with_history(s, self.describe(), parallel_levels=self.levels)


def _vector_axis(w: Workload) -> str:
    """The axis eligible for vectorization: innermost dim of the output."""
    return w.output.axes[-1]


@dataclasses.dataclass(frozen=True)
class Vectorize(Transform):
    width: int
    name: str = dataclasses.field(init=False, default="Vectorize")

    def describe(self) -> str:
        return f"Vectorize(width={self.width})"

    def apply(self, s: Schedule) -> Schedule:
        if self.width not in VECTOR_WIDTHS:
            raise ScheduleError(f"vector width {self.width} not in {VECTOR_WIDTHS}")
        axis = _vector_axis(s.workload)
        if s.inner_tile(axis) % self.width != 0:
            raise ScheduleError(
                f"vector width {self.width} does not divide inner tile "
                f"{s.inner_tile(axis)} of axis {axis}")
        return _with_history(s, self.describe(), vector_width=self.width)


@dataclasses.dataclass(frozen=True)
class Unroll(Transform):
    axis: str
    factor: int
    name: str = dataclasses.field(init=False, default="Unroll")

    def describe(self) -> str:
        return f"Unroll({self.axis}, factor={self.factor})"

    def apply(self, s: Schedule) -> Schedule:
        if self.factor not in UNROLL_FACTORS:
            raise ScheduleError(f"unroll factor {self.factor} not in {UNROLL_FACTORS}")
        if self.axis not in s.workload.loop_map:
            raise ScheduleError(f"unknown axis {self.axis!r}")
        if self.factor > s.inner_tile(self.axis):
            raise ScheduleError(
                f"unroll {self.factor} exceeds inner tile {s.inner_tile(self.axis)}")
        un = dict(s.unroll)
        un[self.axis] = self.factor
        return _with_history(s, self.describe(), unroll=tuple(sorted(un.items())))


@dataclasses.dataclass(frozen=True)
class ComputeLocation(Transform):
    level: int  # -1 = root (materialize), 0..SPATIAL_LEVELS-1 = fused depth
    name: str = dataclasses.field(init=False, default="ComputeLocation")

    def describe(self) -> str:
        return f"ComputeLocation(level={self.level})"

    def apply(self, s: Schedule) -> Schedule:
        if not s.workload.epilogue_tensor_axes:
            raise ScheduleError("workload has no fusable epilogue")
        if not -1 <= self.level < SPATIAL_LEVELS:
            raise ScheduleError(f"compute location {self.level} out of range")
        return _with_history(s, self.describe(), compute_location=self.level)


@dataclasses.dataclass(frozen=True)
class CacheWrite(Transform):
    enabled: bool
    name: str = dataclasses.field(init=False, default="CacheWrite")

    def describe(self) -> str:
        return f"CacheWrite(enabled={self.enabled})"

    def apply(self, s: Schedule) -> Schedule:
        return _with_history(s, self.describe(), cache_write=self.enabled)


@dataclasses.dataclass(frozen=True)
class CacheRead(Transform):
    operand: str
    name: str = dataclasses.field(init=False, default="CacheRead")

    def describe(self) -> str:
        return f"CacheRead({self.operand})"

    def apply(self, s: Schedule) -> Schedule:
        names = {t.name for t in s.workload.operands if not t.is_output}
        if self.operand not in names:
            raise ScheduleError(f"unknown input operand {self.operand!r}")
        if self.operand in s.cache_reads:
            raise ScheduleError(f"{self.operand} already cached")
        return _with_history(
            s, self.describe(), cache_reads=s.cache_reads + (self.operand,))


@dataclasses.dataclass(frozen=True)
class Layout(Transform):
    operand: str
    order: str  # "row" | "col"
    name: str = dataclasses.field(init=False, default="Layout")

    def describe(self) -> str:
        return f"Layout({self.operand}, order={self.order})"

    def apply(self, s: Schedule) -> Schedule:
        names = {t.name for t in s.workload.operands}
        if self.operand not in names:
            raise ScheduleError(f"unknown operand {self.operand!r}")
        if self.order not in ("row", "col"):
            raise ScheduleError(f"order must be row|col, got {self.order!r}")
        lay = dict(s.layouts)
        lay[self.operand] = self.order
        return _with_history(s, self.describe(), layouts=tuple(sorted(lay.items())))


TRANSFORM_NAMES = (
    "TileSize", "Parallel", "Vectorize", "Unroll", "ComputeLocation",
    "CacheWrite", "CacheRead", "Layout",
)


def available_transforms(s: Schedule) -> list[str]:
    """Names of transformation families legal in state `s` (shown to the LLM)."""
    out = ["TileSize", "Parallel", "Vectorize", "Unroll", "CacheWrite",
           "CacheRead", "Layout"]
    if s.workload.epilogue_tensor_axes:
        out.insert(4, "ComputeLocation")
    return out


def random_transform(rng: random.Random, s: Schedule) -> Transform:
    """Uniform random legal transformation (default expansion / rollout policy)."""
    w = s.workload
    for _ in range(64):
        kind = rng.choice(available_transforms(s))
        try:
            if kind == "TileSize":
                loop = rng.choice(w.loops)
                levels = SPATIAL_LEVELS if loop.kind == SPATIAL else REDUCTION_LEVELS
                t = TileSize(loop.name, sample_perfect_tile(rng, loop.extent, levels))
            elif kind == "Parallel":
                t = Parallel(rng.randint(0, 2))
            elif kind == "Vectorize":
                axis = _vector_axis(w)
                inner = s.inner_tile(axis)
                opts = [v for v in VECTOR_WIDTHS if inner % v == 0]
                t = Vectorize(rng.choice(opts))
            elif kind == "Unroll":
                loop = rng.choice(w.loops)
                opts = [f for f in UNROLL_FACTORS if f <= s.inner_tile(loop.name)]
                t = Unroll(loop.name, rng.choice(opts))
            elif kind == "ComputeLocation":
                t = ComputeLocation(rng.randint(-1, SPATIAL_LEVELS - 1))
            elif kind == "CacheWrite":
                t = CacheWrite(not s.cache_write)
            elif kind == "CacheRead":
                opts = [o.name for o in w.operands
                        if not o.is_output and o.name not in s.cache_reads]
                if not opts:
                    continue
                t = CacheRead(rng.choice(opts))
            else:  # Layout
                op = rng.choice([o.name for o in w.operands])
                t = Layout(op, rng.choice(("row", "col")))
            t.apply(s)  # legality probe
            return t
        except ScheduleError:
            continue
    raise ScheduleError("could not sample a legal transformation")


def random_schedule(rng: random.Random, s0: Schedule, n_transforms: int) -> Schedule:
    s = s0
    for _ in range(n_transforms):
        s = random_transform(rng, s).apply(s)
    return s


def parse_transform(
    text: str, s: Schedule, rng: Optional[random.Random] = None
) -> Optional[Transform]:
    """Parse one transformation mention (possibly parameterless, e.g. the bare
    "TileSize" the paper's prompt format allows) into a concrete legal Transform.

    Returns None if the mention names no known transformation — the caller
    implements the Appendix G fallback policy.
    """
    rng = rng or random.Random(0)
    token = text.strip().strip(".,;:()[]").lower()
    canon = {n.lower(): n for n in TRANSFORM_NAMES}
    # accept loose mentions like "tile", "tiling", "vectorization"
    aliases = {
        "tile": "TileSize", "tiling": "TileSize", "tilesize": "TileSize",
        "split": "TileSize", "parallel": "Parallel", "parallelize": "Parallel",
        "vectorize": "Vectorize", "vectorization": "Vectorize",
        "unroll": "Unroll", "unrolling": "Unroll",
        "computelocation": "ComputeLocation", "fuse": "ComputeLocation",
        "fusion": "ComputeLocation", "computeat": "ComputeLocation",
        "cachewrite": "CacheWrite", "cacheread": "CacheRead",
        "layout": "Layout", "layouttransform": "Layout",
    }
    kind = canon.get(token) or aliases.get(token)
    if kind is None:
        return None
    if kind not in available_transforms(s):
        return None
    # Parameterless mention -> sample a legal instance of that family.
    for _ in range(32):
        try:
            t = random_transform(rng, s)
        except ScheduleError:
            return None
        if t.name == kind:
            return t
    # direct sampling fallback for rarely-hit families
    for _ in range(32):
        try:
            t = random_transform(rng, s)
            if t.name == kind:
                return t
        except ScheduleError:
            continue
    return None
