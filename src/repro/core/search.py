"""Unified search driver: the three strategies the paper compares (§4.1).

  * ``evolutionary``  — TVM MetaSchedule-style evolutionary search
  * ``mcts``          — MCTS with the default (random) expansion policy
  * ``llm-mcts``      — the REASONING COMPILER: LLM-guided MCTS

plus the paper's measurement protocol: best-so-far speedup vs. evaluated
samples, averaged over repeats, with sample-efficiency summaries
(sample reduction and speedup/#samples efficiency gain, Tables 1-2).
"""
from __future__ import annotations

import dataclasses
import math
import statistics
from typing import Optional, Sequence

from .cost_model import HardwareOracle, Platform
from .llm import FallbackStats
from .mcts import SearchCurve
from .oracle import HybridOracle, MeasuredOracle
from .schedule import Schedule
from .surrogate import SurrogateOracle

METHODS = ("evolutionary", "mcts", "llm-mcts")


@dataclasses.dataclass
class SearchResult:
    workload: str
    platform: str
    method: str
    curve: SearchCurve
    best_speedup: float
    best_schedule: Optional[Schedule]
    baseline_latency_s: float
    best_latency_s: float
    samples: int
    fallback: Optional[FallbackStats] = None
    llm: Optional[str] = None
    # which oracle backend produced the rewards + runner-up schedules for
    # measured re-ranking (core/autotuner.py)
    oracle: str = "analytical"
    top_schedules: tuple = ()
    # Per-transform-family net relative latency improvement summed over
    # every evaluated (parent, child) edge of the search tree — the
    # plateau statistics cross-task context distills into prefer/avoid
    # hints (repro.compiler.context).  None for tree-less methods.
    family_stats: Optional[dict] = None
    # Per-tier Appendix-G statistics ({name: FallbackStats}), so invalid
    # and fallback rates stay attributable when a proposer pool shares
    # the tree (repro.compiler.proposers).  A single proposer reports one
    # entry.  None for non-LLM methods.
    fallback_by_proposer: Optional[dict] = None
    # Credit for the best node found: the pool member that drafted it (or
    # the nearest LLM-drafted ancestor), plus any review-tier outcome.
    proposer: Optional[str] = None
    reviewer: Optional[str] = None
    review_action: Optional[str] = None
    # Pool routing/hit-rate snapshot at search end (ProposerPool.summary())
    pool_stats: Optional[list] = None


def _oracle_name(oracle) -> str:
    if isinstance(oracle, SurrogateOracle):
        return f"surrogate:{_oracle_name(oracle.escalate)}"
    if isinstance(oracle, HybridOracle):
        return "hybrid"
    if isinstance(oracle, MeasuredOracle):
        return "measured"
    if isinstance(oracle, HardwareOracle):
        return "analytical"
    return type(oracle).__name__


def _one_shot_search(
    workload,
    platform: str | Platform = "core-i9",
    method: str = "llm-mcts",
    budget: int = 200,
    seed: int = 0,
    llm: str = "gpt-4o-mini",
    trace_depth: int = 2,
    branching: int = 2,
    oracle=None,
    **mcts_kwargs,
) -> SearchResult:
    """One-shot session search: a fresh single-use ``CompilerSession``
    (fresh LLM, fresh oracle, no shared context) per call — the
    comparison-harness primitive.  Long-lived callers should hold a
    ``repro.compiler.CompilerSession`` so oracle caches and cross-task
    context persist across searches."""
    from ..compiler.session import CompilerSession

    session = CompilerSession(
        target=platform, oracle=oracle, proposer=llm, method=method,
        shared_context=False, trace_depth=trace_depth, branching=branching,
    )
    return session.search(workload, budget=budget, seed=seed, **mcts_kwargs)


def mean_curve(curves: Sequence[SearchCurve], grid: Sequence[int]) -> list:
    """Average best-so-far speedup over repeats at fixed sample counts."""
    return [
        (s, statistics.fmean(c.at(s) for c in curves)) for s in grid
    ]


def repeat_search(
    workload, platform: str, method: str, budget: int, repeats: int = 5,
    grid: Optional[Sequence[int]] = None, **kw,
) -> tuple[list, list[SearchResult]]:
    """Paper protocol: repeat with different seeds, report the mean curve."""
    results = [
        _one_shot_search(workload, platform, method, budget, seed=seed, **kw)
        for seed in range(repeats)
    ]
    grid = grid or default_grid(budget)
    return mean_curve([r.curve for r in results], grid), results


def default_grid(budget: int) -> list[int]:
    grid = [18, 36, 54, 72, 100, 150, 200, 300, 400, 600, 900, 1200, 1600,
            2400, 3000]
    return [g for g in grid if g <= budget] or [budget]


@dataclasses.dataclass
class EfficiencyComparison:
    """Table 1/2 row: samples + speedup for baseline vs ours, and the two
    derived improvement metrics."""

    baseline_samples: int
    baseline_speedup: float
    ours_samples: int
    ours_speedup: float

    @property
    def sample_reduction(self) -> float:
        return self.baseline_samples / max(1, self.ours_samples)

    @property
    def efficiency_gain(self) -> float:
        """(speedup/sample) ratio, the paper's sample-efficiency metric."""
        ours = self.ours_speedup / max(1, self.ours_samples)
        base = self.baseline_speedup / max(1, self.baseline_samples)
        return ours / base if base > 0 else math.inf


def compare_efficiency(
    base_curve: SearchCurve | list,
    ours_curve: SearchCurve | list,
    budget: int,
) -> EfficiencyComparison:
    """Pick the paper's reporting points: the baseline's near-converged
    (sample, speedup) point, and the smallest sample count at which ours
    reaches/exceeds a comparable speedup (else our best point)."""
    b = base_curve if isinstance(base_curve, SearchCurve) \
        else SearchCurve(list(base_curve))
    o = ours_curve if isinstance(ours_curve, SearchCurve) \
        else SearchCurve(list(ours_curve))
    base_final = b.at(budget)
    # baseline "converged" sample count: first point reaching 98% of final
    base_samples = b.samples_to_reach(base_final * 0.98) or budget
    ours_reach = o.samples_to_reach(base_final)
    if ours_reach is not None:
        return EfficiencyComparison(
            base_samples, base_final, ours_reach, o.at(ours_reach)
        )
    # ours never reaches baseline final: report our best at a low budget
    ours_samples = o.samples_to_reach(o.at(budget) * 0.98) or budget
    return EfficiencyComparison(
        base_samples, base_final, ours_samples, o.at(budget)
    )
