"""Record-trained surrogate oracle tier: learned pre-screening for search.

``BENCH_lowering.json`` showed the analytical machine model *anti-correlates*
with measured kernel latency (Spearman −0.51 attn / −0.56 gemm): exactly
where reward fidelity matters most, the search ranks candidates wrongly.
This module converts that defect into a sample-efficiency win, following the
LLM-compiler line of work (learned models predicting optimization outcomes)
and the GOLEM ``SurrogateDispatcher`` split: a cheap learned fitness model
sits in front of expensive evaluation, every proposal is *ranked* for free,
and only the top-k escalate to compile-and-time.

Three layers, cheapest first:

* ``featurize_schedule`` — a fixed-length, workload-agnostic feature vector
  (log tile-band shapes, VMEM footprint vs the platform's scratch, compute
  location, cache read/write modes, dtype, fused-epilogue kind, arch dims).
  Unlike ``cost_model.featurize`` (whose length varies per workload), every
  schedule of every workload maps into the SAME space, so rows pooled from
  a whole ``TuningRecords`` database train one model.
* ``RecordSurrogate`` — numpy-only ridge regression over log-latency with
  per-workload-family label centering (a family's constant baseline offset
  carries no ranking information and would otherwise dominate the fit).
  Trains from accumulated ``TuningRecords`` rows (the winning transform
  trace is replayed into a concrete ``Schedule``) and sharpens online as
  escalated measurements stream back in.  The model carries a version stamp
  tied to the records schema + feature schema; rows from a different
  records schema are skipped (staleness guard).
* ``SurrogateOracle`` — the fourth ``make_oracle`` backend: wraps any
  escalation oracle (``MeasuredOracle`` by default), exposes ``screen`` so
  MCTS expansion and evolutionary offspring scoring can rank whole
  candidate pools before spending hardware time, and feeds every escalated
  measurement back as a training row.

Dependency-free by design (numpy only): the surrogate must stay cheap
enough that ranking a candidate costs microseconds, not milliseconds.
"""
from __future__ import annotations

import math
import re
from typing import Optional, Sequence

import numpy as np

from ..obs import NULL_TRACER, Tracer
from .cost_model import Platform, intra_extent
from .schedule import (
    CacheRead,
    CacheWrite,
    ComputeLocation,
    Layout,
    Parallel,
    Schedule,
    ScheduleError,
    TileSize,
    Transform,
    Unroll,
    Vectorize,
    initial_schedule,
)
from .workloads import Workload, attention_workload, matmul_workload

# Bump when the feature vector changes shape/meaning: a model trained on a
# different feature schema must never score candidates silently.
FEATURE_VERSION = 1


def _log2(x: float) -> float:
    return math.log2(max(float(x), 1.0))


def featurize_schedule(s: Schedule, platform: Platform) -> np.ndarray:
    """Fixed-length structural features of (schedule, platform).

    Workload-agnostic on purpose: rows from an attention sweep and a GEMM
    sweep land in the same space, so one model pools the whole records
    database.  Everything is cheap arithmetic over the schedule state — no
    oracle internals, no lowering.
    """
    w = s.workload
    spatial = w.spatial_loops
    reduction = w.reduction_loops
    tm = s.tile_map
    feats: list[float] = []

    # -- iteration-space shape ------------------------------------------------
    feats.append(_log2(math.prod(l.extent for l in spatial)))
    feats.append(_log2(math.prod((l.extent for l in reduction), start=1)))
    feats.append(float(len(spatial)))
    feats.append(float(len(reduction)))

    # -- tile-band structure (grid / parallel / vmem / reg) -------------------
    for band in range(4):
        feats.append(_log2(math.prod(tm[l.name][band] for l in spatial)))
    for band in range(2):
        feats.append(_log2(math.prod(
            (tm[l.name][band] for l in reduction), start=1)))

    # -- innermost-tile alignment (MXU lanes / SIMD) --------------------------
    out_axis = w.output.axes[-1]
    inner = tm[out_axis][-1]
    feats.append(_log2(inner))
    feats.append(1.0 if inner % 128 == 0 else 0.0)
    feats.append(1.0 if inner % 8 == 0 else 0.0)

    # -- annotations ----------------------------------------------------------
    feats.append(_log2(s.vector_width))
    feats.append(float(s.parallel_levels))
    feats.append(_log2(math.prod((f for _, f in s.unroll), start=1)))
    feats.append(float(s.compute_location))
    feats.append(1.0 if s.compute_location >= 0 else 0.0)   # epilogue fused
    feats.append(1.0 if s.cache_write else 0.0)
    n_inputs = max(1, sum(1 for o in w.operands if not o.is_output))
    feats.append(len(s.cache_reads) / n_inputs)
    feats.append(float(sum(1 for _, o in s.layouts if o == "col")))

    # -- VMEM-band footprint vs the platform's scratch ------------------------
    foot = 0.0
    for o in w.operands:
        b = float(o.dtype_bytes)
        for a in o.axes:
            b *= intra_extent(s, a, 2)
        foot += b
    feats.append(_log2(foot))
    feats.append(_log2(foot) - _log2(platform.scratch_bytes))
    feats.append(1.0 if foot > platform.scratch_bytes else 0.0)

    # -- dtype + epilogue kind ------------------------------------------------
    feats.append(float(w.output.dtype_bytes))
    kind = w.epilogue_kind or "none"
    feats.append(1.0 if kind == "softmax" else 0.0)
    feats.append(1.0 if kind == "swiglu" else 0.0)

    # -- intensity + parallel-task shape vs arch dims -------------------------
    total_bytes = sum(
        o.dtype_bytes * math.prod(w.loop_map[a].extent for a in o.axes)
        for o in w.operands
    )
    feats.append(_log2(w.flops + w.epilogue_flops) - _log2(total_bytes))
    tasks = math.prod(tm[l.name][0] for l in spatial)
    feats.append(_log2(tasks))
    feats.append(_log2(tasks) - _log2(platform.cores))

    # -- arch dims ------------------------------------------------------------
    feats.append(_log2(platform.cores))
    feats.append(_log2(platform.mem_bw_gbs))
    feats.append(_log2(platform.scratch_bytes))
    feats.append(1.0 if platform.mxu else 0.0)

    return np.asarray(feats, dtype=np.float64)


# Feature dimensionality, probed once at import (any drift between this and
# featurize_schedule is a schema change that must bump FEATURE_VERSION).
N_FEATURES = len(featurize_schedule(
    initial_schedule(matmul_workload("_probe", 8, 8, 8)),
    Platform(name="_probe", kind="cpu", cores=1, freq_ghz=1.0, simd_bytes=16,
             fma_pipes=1, fma_latency=1, cache_bytes=1 << 16,
             scratch_bytes=1 << 14, mem_bw_gbs=1.0),
))


def workload_family(w: Workload, platform: str) -> str:
    """The label-centering group: same operator, same non-sequence dims.

    Mirrors ``compiler.tasks.Task.family_key`` — siblings of a context-
    length sweep share a family, so their baseline latency offset (which
    carries no ranking information) cancels out of the training labels.
    """
    dims = {l.name: l.extent for l in w.loops}
    if w.epilogue_kind == "softmax" and set(dims) >= {"h", "i", "j", "k"}:
        return f"{platform}/attention/h{dims['h']}/d{dims['k']}"
    if {"i", "j", "k"} <= set(dims):
        return f"{platform}/gemm/{w.epilogue_kind or 'none'}/" \
               f"n{dims['j']}/k{dims['k']}"
    return f"{platform}/{w.name}"


# ---------------------------------------------------------------------------
# Record replay: winning transform trace -> concrete Schedule
# ---------------------------------------------------------------------------

_DESC_RE = re.compile(r"^(\w+)\((.*)\)$")


def parse_transform_desc(desc: str) -> Optional[Transform]:
    """Parse one ``Transform.describe()`` string back into a Transform.

    The describe() grammar is the provenance format ``TuningRecord.history``
    persists; this is its exact inverse (None for anything unparseable —
    the caller quarantines that record from the training set).
    """
    m = _DESC_RE.match(desc.strip())
    if not m:
        return None
    kind, body = m.group(1), m.group(2)
    try:
        if kind == "TileSize":
            axis, _, rest = body.partition(",")
            nums = re.search(r"\[([\d,\s]*)\]", rest)
            if not nums:
                return None
            decision = tuple(int(x) for x in nums.group(1).split(","))
            return TileSize(axis.strip(), decision)
        if kind == "Parallel":
            return Parallel(int(body.split("=")[1]))
        if kind == "Vectorize":
            return Vectorize(int(body.split("=")[1]))
        if kind == "Unroll":
            axis, _, rest = body.partition(",")
            return Unroll(axis.strip(), int(rest.split("=")[1]))
        if kind == "ComputeLocation":
            return ComputeLocation(int(body.split("=")[1]))
        if kind == "CacheWrite":
            return CacheWrite(body.split("=")[1].strip() == "True")
        if kind == "CacheRead":
            return CacheRead(body.strip())
        if kind == "Layout":
            op, _, rest = body.partition(",")
            return Layout(op.strip(), rest.split("=")[1].strip())
    except (IndexError, ValueError):
        return None
    return None


def workload_from_record(rec) -> Optional[Workload]:
    """Rebuild the tuning workload a record was searched on (best effort).

    Dims come from the record's ``dims`` map; dtype and epilogue kind come
    from provenance when present (stamped by sessions since the surrogate
    tier landed), else from the tuning-workload conventions
    (``compiler.tasks``: tuning shapes are 2-byte, plain ``gemm`` has no
    epilogue).
    """
    dims = dict(rec.dims or {})
    prov = rec.provenance or {}
    dtype = int(prov.get("dtype_bytes", 0)) or None
    if rec.kind == "attention" and {"h", "i", "j", "k"} <= set(dims):
        return attention_workload(
            rec.workload or "attn", heads=dims["h"], seq_q=dims["i"],
            seq_kv=dims["j"], head_dim=dims["k"], dtype_bytes=dtype or 2,
        )
    if rec.kind == "gemm" and {"i", "j", "k"} <= set(dims):
        return matmul_workload(
            rec.workload or "gemm", m=dims["i"], n=dims["j"], k=dims["k"],
            batch=dims.get("b", 1), dtype_bytes=dtype or 2,
            epilogue=prov.get("epilogue", "none") or "none",
        )
    return None


def replay_record(rec) -> Optional[Schedule]:
    """Winning transform trace -> the concrete winning ``Schedule``.

    Deterministic: the describe() strings in ``history`` carry every
    decision parameter, so replay needs no random sampling.  Returns None
    when the workload cannot be rebuilt or any trace step fails to parse
    or apply — corrupt/legacy records never poison the training set.
    """
    w = workload_from_record(rec)
    if w is None:
        return None
    s = initial_schedule(w)
    for desc in rec.history:
        t = parse_transform_desc(desc)
        if t is None:
            return None
        try:
            s = t.apply(s)
        except ScheduleError:
            return None
    return s


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------

class RecordSurrogate:
    """Ridge regression over log-latency with per-family label centering.

    Rows come from two sources, kept in separate centering groups because
    their labels live on different scales:

    * record rows — label ``-log(speedup)`` (log-latency relative to the
      family's constant baseline, which centering absorbs);
    * online rows — label ``log(latency_s)`` of an escalated measurement.

    ``predict_rel`` returns a family-relative log-latency score (all that
    ranking needs); ``predict_latency`` re-anchors onto the measured scale
    via the family's online mean when one exists.
    """

    def __init__(self, l2: float = 1.0, min_rows: int = 8,
                 retrain_every: int = 8):
        self.l2 = l2
        self.min_rows = min_rows
        self.retrain_every = retrain_every
        self._xs: list[np.ndarray] = []
        self._ys: list[float] = []
        self._groups: list[str] = []
        self._w: Optional[np.ndarray] = None
        self._mu: Optional[np.ndarray] = None
        self._sd: Optional[np.ndarray] = None
        self._anchors: dict[str, tuple[float, int]] = {}  # group -> (mean, n)
        self._pending = 0
        self.retrains = 0
        self.skipped_rows = 0

    # -- identity ------------------------------------------------------------
    @property
    def version(self) -> str:
        """Staleness/version stamp: model revision + feature schema + the
        records schema the training rows were drawn from."""
        from ..compiler.records import SCHEMA_VERSION

        return f"ridge-v1/f{FEATURE_VERSION}x{N_FEATURES}/r{SCHEMA_VERSION}"

    def __len__(self) -> int:
        return len(self._ys)

    @property
    def trained(self) -> bool:
        return len(self._ys) >= self.min_rows

    # -- rows ----------------------------------------------------------------
    def add_row(self, s: Schedule, platform: Platform, y_log: float,
                group: str) -> None:
        self._xs.append(featurize_schedule(s, platform))
        self._ys.append(float(y_log))
        self._groups.append(group)
        self._pending += 1

    def observe(self, s: Schedule, platform: Platform,
                latency_s: float) -> None:
        """One escalated measurement flowing back as a training row."""
        group = "live|" + workload_family(s.workload, platform.name)
        self.add_row(s, platform, math.log(max(latency_s, 1e-12)), group)

    def train_from_records(self, records, platform: Platform) -> int:
        """Adopt every replayable record row (train-on-open).

        Rows from a different records schema are skipped (staleness guard:
        a schema bump may change what ``history``/``dims`` mean), as are
        records whose trace does not replay — both count into
        ``skipped_rows`` so callers can report coverage.
        """
        from ..compiler.records import SCHEMA_VERSION

        added = 0
        for rec in records.all():
            if rec.schema != SCHEMA_VERSION or rec.speedup <= 0:
                self.skipped_rows += 1
                continue
            s = replay_record(rec)
            if s is None:
                self.skipped_rows += 1
                continue
            w = s.workload
            group = "rec|" + workload_family(w, rec.platform)
            self.add_row(s, platform, -math.log(rec.speedup), group)
            added += 1
        return added

    # -- fitting -------------------------------------------------------------
    def _centered_labels(self) -> np.ndarray:
        y = np.asarray(self._ys)
        out = np.empty_like(y)
        self._anchors = {}
        groups = np.asarray(self._groups)
        for g in set(self._groups):
            idx = groups == g
            mean = float(y[idx].mean())
            self._anchors[g] = (mean, int(idx.sum()))
            out[idx] = y[idx] - mean
        return out

    def fit(self) -> None:
        X = np.stack(self._xs)
        y = self._centered_labels()
        self._mu = X.mean(axis=0)
        self._sd = X.std(axis=0) + 1e-9
        Xn = (X - self._mu) / self._sd
        Xn = np.concatenate([Xn, np.ones((len(Xn), 1))], axis=1)
        d = Xn.shape[1]
        A = Xn.T @ Xn + self.l2 * np.eye(d)
        self._w = np.linalg.solve(A, Xn.T @ y)
        self._pending = 0
        self.retrains += 1

    def _ensure_fit(self) -> bool:
        if not self.trained:
            return False
        if self._w is None or self._pending >= self.retrain_every:
            self.fit()
        return True

    # -- prediction ----------------------------------------------------------
    def predict_rel(self, s: Schedule, platform: Platform) -> Optional[float]:
        """Family-relative log-latency score (lower = predicted faster);
        None while undertrained."""
        if not self._ensure_fit():
            return None
        x = (featurize_schedule(s, platform) - self._mu) / self._sd
        x = np.concatenate([x, [1.0]])
        return float(np.clip(x @ self._w, -50.0, 50.0))

    def predict_latency(self, s: Schedule,
                        platform: Platform) -> Optional[float]:
        """Predicted latency in seconds on the *measured* scale, using the
        family's online anchor; None without one (relative scores cannot be
        re-anchored honestly)."""
        rel = self.predict_rel(s, platform)
        if rel is None:
            return None
        group = "live|" + workload_family(s.workload, platform.name)
        anchor = self._anchors.get(group)
        if anchor is None:
            return None
        return math.exp(min(50.0, rel + anchor[0]))


def crossval_rank_predictions(
    schedules: Sequence[Schedule],
    latencies: Sequence[float],
    platform: Platform,
    l2: float = 1.0,
) -> list[float]:
    """Leave-one-out surrogate scores for a measured pool (rank-fidelity
    eval, ``benchmarks/bench_lowering.py``): each schedule is scored by a
    model trained on every *other* (schedule, latency) row, so the Spearman
    against the held-out truths measures generalization, not memorization.
    """
    n = len(schedules)
    X = np.stack([featurize_schedule(s, platform) for s in schedules])
    y = np.asarray([math.log(max(t, 1e-12)) for t in latencies])
    preds: list[float] = []
    for i in range(n):
        keep = np.arange(n) != i
        Xi, yi = X[keep], y[keep]
        yi = yi - yi.mean()
        mu = Xi.mean(axis=0)
        sd = Xi.std(axis=0) + 1e-9
        Xn = np.concatenate(
            [(Xi - mu) / sd, np.ones((len(Xi), 1))], axis=1)
        A = Xn.T @ Xn + l2 * np.eye(Xn.shape[1])
        w = np.linalg.solve(A, Xn.T @ yi)
        x = np.concatenate([(X[i] - mu) / sd, [1.0]])
        preds.append(float(x @ w))
    return preds


# ---------------------------------------------------------------------------
# The oracle tier
# ---------------------------------------------------------------------------

class SurrogateOracle:
    """Learned pre-screening in front of an escalation oracle.

    The GOLEM ``SurrogateDispatcher`` split: ``screen`` ranks whole
    candidate pools for free and returns only the top-k worth escalating;
    ``measure`` is the escalation path (compile-and-time through the
    wrapped oracle) and feeds every new measurement back as a training
    row, so the model sharpens as the session runs.

    Counters tell the sample-efficiency story benchmarks gate on:
    ``proposals`` (candidates ranked), ``escalations`` (measure calls that
    reached the wrapped oracle), and the model's ``retrains``.
    """

    def __init__(
        self,
        escalate,
        *,
        min_rows: int = 8,
        retrain_every: int = 8,
        l2: float = 1.0,
        tracer: Optional[Tracer] = None,
    ):
        self.escalate = escalate
        self.model = RecordSurrogate(
            l2=l2, min_rows=min_rows, retrain_every=retrain_every)
        self._trace = tracer or getattr(escalate, "trace", None) \
            or NULL_TRACER
        self._cache: dict[tuple, float] = {}
        self.proposals = 0
        self.escalations = 0
        self.predictions = 0
        self.trained_from_records = 0

    # -- oracle protocol ------------------------------------------------------
    @property
    def platform(self) -> Platform:
        return self.escalate.platform

    @property
    def trace(self) -> Tracer:
        return self._trace

    @trace.setter
    def trace(self, tracer: Tracer) -> None:
        self._trace = tracer
        if hasattr(self.escalate, "trace"):
            self.escalate.trace = tracer

    def measure(self, s: Schedule) -> float:
        """Escalate to compile-and-time; the result becomes a training row."""
        key = s.key()
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        with self._trace.span(
            "escalate", cat="oracle", workload=s.workload.name,
            trained_rows=len(self.model),
        ) as sp:
            t = self.escalate.measure(s)
            sp.set(latency_s=t)
        self.escalations += 1
        self._cache[key] = t
        pending_fit = (self.model._pending + 1 >= self.model.retrain_every
                       or self.model._w is None)
        self.model.observe(s, self.platform, t)
        if self.model.trained and pending_fit:
            with self._trace.span(
                "surrogate-retrain", cat="oracle", rows=len(self.model),
                version=self.model.version,
            ):
                self.model.fit()
        return t

    def speedup(self, s: Schedule, baseline: Optional[Schedule] = None) -> float:
        base = baseline or initial_schedule(s.workload)
        return self.measure(base) / self.measure(s)

    # -- the dispatcher split --------------------------------------------------
    def predict(self, s: Schedule) -> Optional[float]:
        """Free family-relative score (lower = predicted faster); None
        while the model is undertrained."""
        self.predictions += 1
        return self.model.predict_rel(s, self.platform)

    def screen(self, candidates: Sequence[Schedule],
               k: int = 1) -> list[Schedule]:
        """Rank a candidate pool by predicted latency; return the top-k
        worth escalating.  Undertrained model -> pool order (the caller's
        own priority, e.g. the LLM proposal first) so behavior degrades to
        the unscreened policy, never to noise."""
        cands = list(candidates)
        self.proposals += len(cands)
        k = max(1, min(k, len(cands)))
        with self._trace.span(
            "surrogate-predict", cat="oracle", n_candidates=len(cands),
            k=k, trained_rows=len(self.model),
        ) as sp:
            scores = [self.model.predict_rel(s, self.platform)
                      for s in cands]
            if any(sc is None for sc in scores):
                sp.set(screened=False)
                return cands[:k]
            order = sorted(range(len(cands)), key=lambda i: scores[i])
            sp.set(screened=True)
            return [cands[i] for i in order[:k]]

    def rollout_measure(self, s: Schedule) -> Optional[float]:
        """Free rollout scoring on the measured scale (the MCTS rollout
        hook), available once the live family has an anchor."""
        return self.model.predict_latency(s, self.platform)

    # -- training + provenance -------------------------------------------------
    def train_from_records(self, records) -> int:
        """Train-on-open from a session's records database."""
        added = self.model.train_from_records(records, self.platform)
        self.trained_from_records += added
        if added and self.model.trained:
            with self._trace.span(
                "surrogate-retrain", cat="oracle", rows=len(self.model),
                version=self.model.version, source="records",
            ):
                self.model.fit()
        return added

    def surrogate_provenance(self) -> dict:
        """What a session stamps into each persisted ``TuningRecord``."""
        return {
            "version": self.model.version,
            "train_rows": len(self.model),
            "from_records": self.trained_from_records,
            "proposals": self.proposals,
            "escalations": self.escalations,
            "retrains": self.model.retrains,
        }
