"""Tensor-program workloads: the programs `p_0` the Reasoning Compiler optimizes.

A workload is a perfectly-nested loop program over dense operands (the level of
abstraction TVM TIR schedules operate on, see the paper's Appendix A example:
a (1,16,7168)x(7168,2048) MoE GEMM expressed as a T.grid loop nest).  The five
benchmark workloads below are the paper's five evaluation kernels (§4.1), with
shapes taken from the respective public model configs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

# Loop kinds, mirroring TIR block axis kinds.
SPATIAL = "S"
REDUCTION = "R"


@dataclasses.dataclass(frozen=True)
class Loop:
    """One loop axis of the perfect nest."""

    name: str
    extent: int
    kind: str  # SPATIAL | REDUCTION

    def __post_init__(self):
        assert self.kind in (SPATIAL, REDUCTION), self.kind
        assert self.extent >= 1


@dataclasses.dataclass(frozen=True)
class Operand:
    """A dense tensor operand with the loop axes each dim is indexed by."""

    name: str
    axes: tuple[str, ...]  # loop names, one per dim (innermost last)
    dtype_bytes: int = 4
    is_output: bool = False

    def shape(self, loops: Mapping[str, Loop]) -> tuple[int, ...]:
        return tuple(loops[a].extent for a in self.axes)

    def nbytes(self, loops: Mapping[str, Loop]) -> int:
        return self.dtype_bytes * math.prod(self.shape(loops))


@dataclasses.dataclass(frozen=True)
class Workload:
    """A loop-nest tensor program (the MDP's initial state `p_0`)."""

    name: str
    loops: tuple[Loop, ...]
    operands: tuple[Operand, ...]
    # Multiply-accumulates are 2 flops; elementwise epilogue flops (softmax,
    # activation) are modeled separately because fusion decisions move them.
    flops: int
    epilogue_flops: int = 0
    # Epilogue intermediate that a ComputeLocation/fusion decision can keep out
    # of main memory (e.g. attention scores, MoE gate activations), in elements
    # indexed by the spatial iteration space.
    epilogue_tensor_axes: tuple[str, ...] = ()
    description: str = ""
    # What the epilogue computes ("softmax" | "swiglu" | ""); the lowering
    # bridge (core/lowering.py) needs the semantics, not just the flop count,
    # to build an executable realization of a fusion decision.
    epilogue_kind: str = ""

    @property
    def loop_map(self) -> dict[str, Loop]:
        return {l.name: l for l in self.loops}

    @property
    def spatial_loops(self) -> tuple[Loop, ...]:
        return tuple(l for l in self.loops if l.kind == SPATIAL)

    @property
    def reduction_loops(self) -> tuple[Loop, ...]:
        return tuple(l for l in self.loops if l.kind == REDUCTION)

    @property
    def output(self) -> Operand:
        for t in self.operands:
            if t.is_output:
                return t
        raise ValueError(f"workload {self.name} has no output operand")

    def iter_space(self) -> int:
        return math.prod(l.extent for l in self.loops)


def matmul_workload(
    name: str,
    m: int,
    n: int,
    k: int,
    batch: int = 1,
    dtype_bytes: int = 4,
    epilogue: str = "none",
    description: str = "",
) -> Workload:
    """[batch, m, k] @ [k, n] -> [batch, m, n] with optional fused epilogue."""
    loops = []
    a_axes: tuple[str, ...]
    if batch > 1:
        loops.append(Loop("b", batch, SPATIAL))
        a_axes = ("b", "i", "k")
        c_axes = ("b", "i", "j")
    else:
        a_axes = ("i", "k")
        c_axes = ("i", "j")
    loops += [Loop("i", m, SPATIAL), Loop("j", n, SPATIAL), Loop("k", k, REDUCTION)]
    flops = 2 * batch * m * n * k
    epi_flops = 0
    epi_axes: tuple[str, ...] = ()
    if epilogue == "softmax":
        epi_flops = 5 * batch * m * n  # exp + max + sum + div, ~5 flops/elt
        epi_axes = c_axes
    elif epilogue == "swiglu":
        epi_flops = 4 * batch * m * n  # silu(x1)*x2
        epi_axes = c_axes
    return Workload(
        name=name,
        loops=tuple(loops),
        operands=(
            Operand("A", a_axes, dtype_bytes),
            Operand("B", ("k", "j"), dtype_bytes),
            Operand("C", c_axes, dtype_bytes, is_output=True),
        ),
        flops=flops,
        epilogue_flops=epi_flops,
        epilogue_tensor_axes=epi_axes,
        description=description,
        epilogue_kind=epilogue if epi_axes else "",
    )


def attention_workload(
    name: str,
    heads: int,
    seq_q: int,
    seq_kv: int,
    head_dim: int,
    dtype_bytes: int = 4,
    description: str = "",
) -> Workload:
    """Fused self-attention scores+AV: softmax(Q K^T) V for one layer.

    Modeled as the dominant iteration space (h, i, j) with two chained GEMM
    reductions over d; the softmax row pass is the fusable epilogue whose
    placement ComputeLocation controls (materializing the [h, i, j] score
    matrix vs. streaming it, i.e. FlashAttention-style fusion).
    """
    loops = (
        Loop("h", heads, SPATIAL),
        Loop("i", seq_q, SPATIAL),
        Loop("j", seq_kv, SPATIAL),
        Loop("k", head_dim, REDUCTION),
    )
    flops = 2 * heads * seq_q * seq_kv * head_dim * 2  # QK^T and AV
    return Workload(
        name=name,
        loops=loops,
        operands=(
            Operand("Q", ("h", "i", "k"), dtype_bytes),
            Operand("K", ("h", "j", "k"), dtype_bytes),
            Operand("V", ("h", "j", "k"), dtype_bytes),
            Operand("O", ("h", "i", "k"), dtype_bytes, is_output=True),
        ),
        flops=flops,
        epilogue_flops=5 * heads * seq_q * seq_kv,
        epilogue_tensor_axes=("h", "i", "j"),
        description=description,
        epilogue_kind="softmax",
    )


def conv2d_workload(
    name: str,
    n: int,
    h: int,
    w: int,
    c_in: int,
    c_out: int,
    kh: int,
    kw: int,
    dtype_bytes: int = 4,
    description: str = "",
) -> Workload:
    loops = (
        Loop("n", n, SPATIAL),
        Loop("oh", h, SPATIAL),
        Loop("ow", w, SPATIAL),
        Loop("oc", c_out, SPATIAL),
        Loop("ic", c_in, REDUCTION),
        Loop("kh", kh, REDUCTION),
        Loop("kw", kw, REDUCTION),
    )
    flops = 2 * n * h * w * c_out * c_in * kh * kw
    return Workload(
        name=name,
        loops=loops,
        operands=(
            # im2col view: input indexed by output spatials + reductions.
            Operand("X", ("n", "oh", "ow", "ic"), dtype_bytes),
            Operand("W", ("kh", "kw", "ic", "oc"), dtype_bytes),
            Operand("Y", ("n", "oh", "ow", "oc"), dtype_bytes, is_output=True),
        ),
        flops=flops,
        description=description,
    )


# ---------------------------------------------------------------------------
# The paper's five benchmark kernels (§4.1), shapes from public configs.
# ---------------------------------------------------------------------------

def llama3_attention() -> Workload:
    # Llama-3-8B: 32 q heads, head_dim 128; serving context 2048.
    return attention_workload(
        "llama3_8b_attention", heads=32, seq_q=2048, seq_kv=2048, head_dim=128,
        description="Llama-3-8B self-attention layer [arXiv:2407.21783]",
    )


def deepseek_moe() -> Workload:
    # Exactly the paper's Appendix A prompt: A(1,16,7168) @ B(7168,2048).
    return matmul_workload(
        "deepseek_r1_moe", m=16, n=2048, k=7168,
        description="DeepSeek-R1 MoE expert GEMM (paper Appendix A shapes)",
    )


def flux_attention() -> Workload:
    # FLUX joint transformer block: 24 heads, head_dim 128, 4096 latent tokens.
    return attention_workload(
        "flux_attention", heads=24, seq_q=4096, seq_kv=4096, head_dim=128,
        description="FLUX (rectified-flow DiT) attention layer",
    )


def flux_conv() -> Workload:
    # FLUX VAE/in-out conv: 3x3 over 128x128 latents, 512 channels.
    return conv2d_workload(
        "flux_conv", n=1, h=128, w=128, c_in=512, c_out=512, kh=3, kw=3,
        description="FLUX convolution layer (VAE 3x3, 512ch, 128x128)",
    )


def llama4_mlp() -> Workload:
    # Llama-4-Scout: d_model 5120, d_ff 8192; 1024-token tile.
    return matmul_workload(
        "llama4_scout_mlp", m=1024, n=8192, k=5120, epilogue="swiglu",
        description="Llama-4-Scout MLP (SwiGLU) layer GEMM",
    )


PAPER_WORKLOADS = {
    "llama3_8b_attention": llama3_attention,
    "deepseek_r1_moe": deepseek_moe,
    "flux_attention": flux_attention,
    "flux_conv": flux_conv,
    "llama4_scout_mlp": llama4_mlp,
}


def end_to_end_llama3_workloads() -> Sequence[tuple[Workload, float]]:
    """(workload, runtime-share weight) pairs for end-to-end Llama-3-8B (Table 2).

    One decoder layer = attention + o-proj GEMM + SwiGLU MLP; weights are the
    pre-optimization runtime shares implied by flop counts (32 identical layers,
    so one layer is representative; the lm_head GEMM is amortized).
    """
    attn = llama3_attention()
    qkv = matmul_workload("llama3_qkv_proj", m=2048, n=6144, k=4096,
                          description="fused QKV projection (GQA 32q/8kv)")
    o_proj = matmul_workload("llama3_o_proj", m=2048, n=4096, k=4096)
    mlp = matmul_workload("llama3_mlp", m=2048, n=14336, k=4096, epilogue="swiglu",
                          description="Llama-3-8B SwiGLU MLP")
    items = [attn, qkv, o_proj, mlp]
    total = sum(w.flops + w.epilogue_flops for w in items)
    return [(w, (w.flops + w.epilogue_flops) / total) for w in items]


def get_workload(name: str) -> Workload:
    return PAPER_WORKLOADS[name]()
