"""Deterministic synthetic data pipeline (per-host sharded, checkpointable).

Tokens are a pure function of ``(seed, step, position)`` via a counter-mode
threefry draw, so:

  * every host generates exactly its shard (no cross-host I/O),
  * restart-from-checkpoint is bitwise reproducible: the iterator state is
    just the step counter,
  * elastic re-mesh keeps the global stream identical (host slices are
    derived from the *global* batch index, not from host count).

A ``background=True`` mode overlaps generation with compute via a
double-buffered prefetch thread (the CPU-host analogue of an input
pipeline's h2d overlap).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeSpec


@dataclasses.dataclass
class PipelineState:
    step: int = 0


class SyntheticLMDataset:
    """Markov-ish synthetic token stream with learnable structure.

    Pure noise would make training loss flat at log(V); tokens here follow a
    hash-mixed low-order recurrence so a real model shows decreasing loss —
    useful for the end-to-end training example.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        shape: ShapeSpec,
        seed: int = 0,
        host_index: int = 0,
        host_count: int = 1,
    ):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.host_index = host_index
        self.host_count = host_count
        assert shape.global_batch % host_count == 0
        self.host_batch = shape.global_batch // host_count
        self.state = PipelineState()

    # -- deterministic generation ------------------------------------------
    def _tokens(self, step: int) -> np.ndarray:
        b, s = self.host_batch, self.shape.seq_len
        rows = (
            np.arange(b, dtype=np.uint64)
            + np.uint64(self.host_index * self.host_batch)
        )
        key = np.uint64((self.seed * 0x9E3779B97F4A7C15 + step)
                        & 0xFFFFFFFFFFFFFFFF)
        pos = np.arange(s, dtype=np.uint64)
        h = (rows[:, None] * np.uint64(0xBF58476D1CE4E5B9)) ^ \
            (pos[None, :] * np.uint64(0x94D049BB133111EB)) ^ key
        h ^= h >> np.uint64(31)
        h *= np.uint64(0x7FB5D329728EA185)
        h ^= h >> np.uint64(27)
        base = (h % np.uint64(max(2, self.cfg.vocab // 4))).astype(np.int64)
        # low-order structure: token_t depends on token_{t-1} half the time
        mix = np.roll(base, 1, axis=1)
        choose = (h >> np.uint64(40)) % np.uint64(2) == 0
        toks = np.where(choose, (mix * 31 + 7) % self.cfg.vocab, base)
        return toks.astype(np.int32)

    def batch_at(self, step: int) -> dict:
        toks = self._tokens(step)
        batch = {}
        if self.cfg.frontend == "audio":
            # frame-embedding stub: deterministic float features
            f = (self._tokens(step + 10**9).astype(np.float32)
                 % 97)[..., None]
            feats = np.repeat(f, self.cfg.frontend_dim, axis=-1)
            feats = (feats / 48.5 - 1.0)
            batch["frames"] = feats.astype(np.float32)
            batch["labels"] = toks % self.cfg.vocab
        else:
            batch["tokens"] = toks
            batch["labels"] = np.roll(toks, -1, axis=1)
            if self.cfg.frontend == "vision":
                p = self.cfg.vision_patches
                g = (self._tokens(step + 2 * 10**9)[:, :1].astype(np.float32)
                     / self.cfg.vocab)
                batch["patches"] = np.broadcast_to(
                    g[..., None], (toks.shape[0], p, self.cfg.d_model)
                ).astype(np.float32) * 0.02
        return batch

    # -- iterator protocol -----------------------------------------------------
    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> dict:
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b

    # -- checkpointable state ---------------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.state.step}

    def load_state_dict(self, d: dict) -> None:
        self.state.step = int(d["step"])


class PrefetchingLoader:
    """Double-buffered background prefetch around any dataset."""

    def __init__(self, dataset: SyntheticLMDataset, depth: int = 2):
        self.dataset = dataset
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            try:
                self._q.put(self.dataset.next_batch(), timeout=0.25)
            except queue.Full:
                continue

    def next_batch(self) -> dict:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
