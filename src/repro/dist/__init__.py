"""repro.dist — the distribution (sharding) subsystem.

``rules`` holds the name-based Megatron-TP / MoE-EP partitioning table;
``sharding`` resolves it against parameter / batch / optimizer / cache
pytrees for a given mesh.  See launch/mesh.py for the mesh axis contract
and EXPERIMENTS.md §Roofline for how layouts are evaluated.
"""
from .sharding import (  # noqa: F401
    batch_specs,
    cache_specs_tree,
    dp_axes,
    dp_degree,
    named_shardings,
    opt_state_specs,
    param_specs,
    tp_degree,
)
