"""Name-based tensor-parallel partitioning rules (Megatron-style).

Each rule maps an ``(owner, leaf-name)`` pair — the last two keys of a
parameter's pytree path — to a ``PartitionSpec`` over the leaf's OWN axes.
The stacked ``[L, ...]`` layer axis that ``model.init_params`` prepends is
NOT part of a rule's contract; ``sharding.param_specs`` prefixes ``None``
for it.  Keeping the table owner-keyed means the same rule covers a weight
wherever it appears (``layers/attn/wq`` and ``layers/mix/attn/wq`` both
resolve through the ``attn`` owner).

Conventions:
  * column-parallel (``_col``): shard the OUTPUT-feature axis over
    ``"model"`` — the producing GEMM writes a model-sharded activation.
  * row-parallel (``_row``): shard the INPUT-feature axis over ``"model"``
    — consumes a model-sharded activation; GSPMD inserts the all-reduce.
  * expert-parallel (``_expert``): shard the leading expert axis of MoE
    weights over ``"model"`` (8 experts/device on qwen3's 128 over tp=16);
    the router stays replicated so every device routes every token.
  * every rule degrades to full replication when the target dim does not
    divide the TP degree — this is the divisibility check promised by
    ``ArchConfig.padded_heads`` (e.g. hymba's 25 query heads on tp=16
    keep their true count and attention runs replicated on the model axis).

Per-architecture notes:
  * dense / moe / encoder attention: ``wq``/``wk``/``wv`` column-parallel
    by (padded) head, ``wo`` row-parallel; KV projections replicate when
    ``kv_heads < tp`` (the padded count no longer divides tp).
  * mLSTM (xlstm): up/gate/q/k/v projections column-parallel over the
    2*d_model inner dim, ``w_down`` row-parallel; the tiny per-head gate
    projections replicate.
  * sLSTM (xlstm): fully replicated — its recurrence has no parallel form
    (models/ssm.py), so sharding its small GEMMs would add per-timestep
    collectives for no win.
  * hybrid SSM path (hymba): the 2*d_model inner dim stays model-sharded
    end-to-end — ``w_in``/``w_dt``/``conv_w`` produce it (column),
    ``w_bc``/``a_log``/``d_skip``/``dt_bias``/``w_out`` follow it (row).
"""
from __future__ import annotations

import dataclasses

from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class RuleCtx:
    """Per-(config, mesh) facts the rules condition on."""

    tp: int             # size of the "model" mesh axis
    q_shardable: bool   # padded query heads divide tp
    kv_shardable: bool  # padded kv heads divide tp (False when kv < tp)

    def div(self, dim: int) -> bool:
        return self.tp > 0 and dim % self.tp == 0


def replicate(shape) -> P:
    return P(*([None] * len(shape)))


def _one_axis(shape, axis: int) -> P:
    return P(*[("model" if i == axis else None) for i in range(len(shape))])


def _col(ctx: RuleCtx, shape) -> P:
    """Column-parallel: output-feature (last) axis over "model"."""
    if not shape or not ctx.div(shape[-1]):
        return replicate(shape)
    return _one_axis(shape, len(shape) - 1)


def _row(ctx: RuleCtx, shape) -> P:
    """Row-parallel: input-feature (first) axis over "model"."""
    if not shape or not ctx.div(shape[0]):
        return replicate(shape)
    return _one_axis(shape, 0)


def _expert(ctx: RuleCtx, shape) -> P:
    """Expert-parallel: leading [E, ...] axis over "model"."""
    if not shape or not ctx.div(shape[0]):
        return replicate(shape)
    return _one_axis(shape, 0)


def _gated(ctx: RuleCtx, ok: bool, shape, kind) -> P:
    return kind(ctx, shape) if ok else replicate(shape)


def paged_leaf_spec(ctx: RuleCtx, name: str, shape) -> P:
    """PartitionSpec for one paged-KV pool leaf (serve/kvcache.py layout).

    Pool leaves are [L, P, Hkv, page, hd] (k/v) and [L, P, page] (kv_pos).
    The KV-head axis shards over "model" exactly like the weight rules
    (replicated when ``kv_heads < tp``).  The page axis P is deliberately
    REPLICATED across the DP axes: pages are a shared pool addressed by
    per-slot page-table gathers, and slot→page assignment is dynamic, so
    sharding P would turn every gather/scatter into a data-axis collective.
    """
    if name in ("k", "v") and len(shape) == 5 and ctx.kv_shardable \
            and ctx.div(shape[2]):
        return P(None, None, "model", None, None)
    return replicate(shape)


def leaf_spec(ctx: RuleCtx, owner: str, name: str, shape) -> P:
    """PartitionSpec for one parameter leaf (layer-stack axis excluded)."""
    if owner == "attn":
        if name == "wq":
            return _gated(ctx, ctx.q_shardable, shape, _col)
        if name in ("wk", "wv"):
            return _gated(ctx, ctx.kv_shardable, shape, _col)
        if name == "wo":
            return _gated(ctx, ctx.q_shardable, shape, _row)
        return replicate(shape)
    if owner == "mlp":
        if name in ("w_gate", "w_up"):
            return _col(ctx, shape)
        if name == "w_down":
            return _row(ctx, shape)
        return replicate(shape)
    if owner == "moe":
        if name == "w_router":
            return replicate(shape)
        return _expert(ctx, shape)  # w_gate / w_up / w_down: [E, ., .]
    if owner == "mlstm":
        if name in ("w_up", "w_gate", "wq", "wk", "wv"):
            return _col(ctx, shape)
        if name == "w_down":
            return _row(ctx, shape)
        return replicate(shape)  # w_if / b_if per-head gates
    if owner == "slstm":
        return replicate(shape)  # sequential recurrence: no parallel form
    if owner == "ssm":
        if name in ("w_in", "w_dt", "conv_w"):
            return _col(ctx, shape)
        if name in ("w_bc", "a_log", "w_out", "dt_bias", "d_skip"):
            return _row(ctx, shape)
        return replicate(shape)
    # --- top-level (non-layer) leaves ---
    if name == "embed":
        return _row(ctx, shape)   # vocab-parallel: [V, D] -> ("model", None)
    if name == "lm_head":
        return _col(ctx, shape)   # [D, V] -> (None, "model")
    if name == "frontend":
        return _col(ctx, shape)   # [feat, D]: project into sharded d_model
    return replicate(shape)       # norms, per-layer flags, anything unknown
