"""PartitionSpec trees for params, batches, optimizer state, and caches.

The distribution layer of the repo: every launcher (``launch/dryrun``,
``launch/train``), the serving engine (``serve/engine``), and the roofline
pipeline consume these functions instead of hand-writing shardings.  The
mesh axis contract (``launch/mesh.py``) is:

  * ``"model"`` — tensor/expert parallelism inside a layer,
  * ``"data"``  — batch data-parallelism within a pod,
  * ``"pod"``   — optional leading pure-DP axis across pods (DCN).

Entry points (all return trees of ``jax.sharding.PartitionSpec`` mirroring
their input tree; wrap with ``named_shardings`` to get ``NamedSharding``
leaves for ``jax.jit`` / ``jax.device_put``):

  * ``param_specs``      — Megatron TP + MoE EP rules (``dist.rules``)
    resolved against the model's parameter pytree by leaf path.
  * ``batch_specs``      — leading batch axis over the DP axes, replicated
    when the global batch does not divide them.
  * ``opt_state_specs``  — ZeRO-1 style: each AdamW moment additionally
    shards its largest still-replicated axis over ``"data"``.
  * ``cache_specs_tree`` — decode caches: batch over DP, KV heads (or the
    SSM inner dim) over ``"model"``; KV heads replicate when
    ``kv_heads < tp`` exactly as the weight rules do.

Rules are STRUCTURAL: a spec never changes the computed function (GSPMD
inserts whatever collectives the layout implies), so an undivisible dim
always degrades to replication rather than an error.  Concrete per-rule
expectations live in tests/test_sharding_roofline.py; the measurement
protocol that judges layout choices is EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from . import rules

# ---------------------------------------------------------------------------
# mesh introspection
# ---------------------------------------------------------------------------


def tp_degree(mesh) -> int:
    """Size of the "model" axis (1 when the mesh has none)."""
    return int(dict(mesh.shape).get("model", 1))


def dp_axes(mesh) -> tuple:
    """Data-parallel mesh axes, outermost first (("pod", "data") on the
    multi-pod production mesh, ("data",) otherwise)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_degree(mesh) -> int:
    shape = dict(mesh.shape)
    n = 1
    for a in dp_axes(mesh):
        n *= int(shape[a])
    return n


def named_shardings(tree_specs, mesh):
    """PartitionSpec tree -> NamedSharding tree (P leaves are tuples, so
    the map needs the explicit is_leaf guard)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _ctx(cfg: ArchConfig, mesh) -> rules.RuleCtx:
    tp = tp_degree(mesh)
    return rules.RuleCtx(
        tp=tp,
        q_shardable=cfg.padded_heads(tp) % tp == 0,
        kv_shardable=cfg.padded_kv_heads(tp) % tp == 0,
    )


def _path_names(path) -> list:
    return [k.key for k in path if isinstance(k, jax.tree_util.DictKey)]


def _zip_specs(fn, specs, tree):
    """Map fn(spec, leaf) over (specs, tree); specs' P leaves are opaque."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat) == len(flat_s), (len(flat), len(flat_s))
    return jax.tree_util.tree_unflatten(
        treedef, [fn(s, leaf) for s, leaf in zip(flat_s, flat)]
    )


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def param_specs(cfg: ArchConfig, params, mesh):
    """PartitionSpec tree for a ``model.init_params`` pytree (abstract or
    concrete).  Leaves under ``"layers"`` carry the stacked [L, ...] axis,
    which is never sharded (the layer scan runs it sequentially)."""
    ctx = _ctx(cfg, mesh)

    def one(path, leaf):
        names = _path_names(path)
        owner = names[-2] if len(names) >= 2 else ""
        name = names[-1] if names else ""
        if names and names[0] == "layers":
            return P(None, *rules.leaf_spec(ctx, owner, name, leaf.shape[1:]))
        return rules.leaf_spec(ctx, owner, name, leaf.shape)

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------


def batch_specs(cfg: ArchConfig, inputs, mesh):
    """Shard every input's leading (global-batch) axis over the DP axes;
    replicate when the batch does not divide them (small host-local runs)."""
    axes = dp_axes(mesh)
    dp = dp_degree(mesh)

    def one(leaf):
        shape = leaf.shape
        if axes and len(shape) >= 1 and shape[0] % dp == 0:
            return P(axes, *([None] * (len(shape) - 1)))
        return rules.replicate(shape)

    return jax.tree.map(one, inputs)


# ---------------------------------------------------------------------------
# optimizer state (ZeRO-1)
# ---------------------------------------------------------------------------


def opt_state_specs(param_specs_tree, params, mesh):
    """AdamW moment specs: start from the param spec and additionally shard
    the LARGEST still-replicated axis over "data" (ZeRO-1: optimizer state
    is the dominant f32 footprint over bf16 params, and the data axis is
    otherwise idle during the update).  Ties break toward the outermost
    axis; an axis is only taken when its extent divides the data size."""
    shape_d = dict(mesh.shape)
    if "data" not in shape_d:
        return param_specs_tree
    dsize = int(shape_d["data"])

    def one(spec, leaf):
        dims = leaf.shape
        full = tuple(spec) + (None,) * (len(dims) - len(spec))
        cands = [
            i for i in range(len(dims))
            if full[i] is None and dims[i] % dsize == 0
        ]
        if not cands:
            return P(*full)
        best = max(cands, key=lambda i: (dims[i], -i))
        return P(*[("data" if i == best else s)
                   for i, s in enumerate(full)])

    return _zip_specs(one, param_specs_tree, params)


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------


def cache_specs_tree(cfg: ArchConfig, cache, mesh):
    """Specs for a ``model.cache_spec`` tree (all leaves are [L, B, ...]).

    Batch shards over the DP axes.  The head-like axis shards over "model"
    mirroring the weight rules: KV heads for attention caches (replicated
    when ``kv_heads < tp``), mLSTM heads when they divide tp, and the
    hybrid SSM inner dim for conv/state carries.  sLSTM per-feature states
    stay replicated like their (sequential) weights."""
    ctx = _ctx(cfg, mesh)
    axes = dp_axes(mesh)
    dp = dp_degree(mesh)
    tp = ctx.tp

    def one(path, leaf):
        name = _path_names(path)[-1]
        shape = leaf.shape
        spec = [None] * len(shape)
        if axes and len(shape) >= 2 and shape[1] % dp == 0:
            spec[1] = axes
        if name in ("k", "v") and ctx.kv_shardable and ctx.div(shape[2]):
            spec[2] = "model"                      # [L, B, Hkv, r, hd]
        elif name in ("C", "n", "m") and ctx.div(shape[2]):
            spec[2] = "model"                      # mLSTM [L, B, H, ...]
        elif name == "h" and len(shape) == 4 and ctx.div(shape[2]):
            spec[2] = "model"                      # SSM state [L, B, inner, S]
        elif name == "conv" and len(shape) == 4 and ctx.div(shape[3]):
            spec[3] = "model"                      # conv tail [L, B, K-1, inner]
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache)


def paged_cache_specs_tree(cfg: ArchConfig, pool, mesh):
    """Specs for a ``serve.kvcache`` page-pool tree ([L, P, ...] leaves).

    KV heads shard over "model" mirroring the weight rules; the page axis
    replicates (``dist.rules.paged_leaf_spec`` explains why a dynamic
    page pool cannot usefully shard over the DP axes)."""
    ctx = _ctx(cfg, mesh)

    def one(path, leaf):
        name = _path_names(path)[-1]
        return rules.paged_leaf_spec(ctx, name, leaf.shape)

    return jax.tree_util.tree_map_with_path(one, pool)
