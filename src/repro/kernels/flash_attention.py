"""Pallas TPU flash attention with Reasoning-Compiler-tunable BlockSpecs.

The paper's Llama-3/FLUX attention benchmarks are schedule searches over an
attention loop nest; on TPU the corresponding decision space is the Pallas
block shape (``block_q``, ``block_k``) plus the fusion of the softmax
epilogue — which is exactly what flash attention's online softmax is
(ComputeLocation != root in the schedule IR, DESIGN.md §3).  The autotuner
(core/autotuner.py) maps a tuned schedule onto these block parameters.

Layout: Q [B, Hq, Sq, D], K/V [B, Hkv, Skv, D]; GQA via index-map head
grouping (no K/V replication in HBM).  Supports causal and sliding-window
masking; right-aligned queries for decode windows.

Grid: (batch*heads, q_blocks, k_blocks) with the k dimension innermost and
sequential ("arbitrary"); running max / sum-exp / accumulator live in VMEM
scratch across the k loop — the canonical Pallas online-softmax pattern,
hand-tiled for the (8, 128) VPU lane structure.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, sm_scale: float, causal: bool, window: int | None,
    block_q: int, block_k: int, sq: int, skv: int,
):
    qb = pl.program_id(1)
    kb = pl.program_id(2)
    nkb = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)       # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)       # [bk, d]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * sm_scale                               # [bq, bk]

    # masking: causal (right-aligned queries) and/or sliding window
    if causal or window is not None:
        qpos = (qb * block_q
                + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
                + (skv - sq))
        kpos = (kb * block_k
                + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1))
        mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]                        # [bq, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)            # rescale of old accumulator
    p = jnp.exp(s - m_new)                     # [bq, bk]
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kb == nkb - 1)
    def _finalize():
        # fully-masked rows (can happen in windowed decode) produce l == 0
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "sm_scale", "window", "block_q", "block_k", "interpret",
    ),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    window: int | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, f"GQA requires hq % hkv == 0, got {hq}/{hkv}"
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (
        f"seq lengths ({sq},{skv}) must divide blocks ({block_q},{block_k})")

    grid = (b * hq, sq // block_q, skv // block_k)

    def q_map(bh, qb, kb):
        return (bh // hq, bh % hq, qb, 0)

    def kv_map(bh, qb, kb):
        return (bh // hq, (bh % hq) // group, kb, 0)

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, sq=sq, skv=skv,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), q_map),
            pl.BlockSpec((1, 1, block_k, d), kv_map),
            pl.BlockSpec((1, 1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum-exp
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
