"""Tiled Pallas matmul + fused SwiGLU gate-up + MoE grouped GEMM.

Three kernels sharing the same VMEM-tiled accumulation structure; block
shapes (bm, bn, bk) are the Reasoning Compiler's TileSize decisions mapped
through core/autotuner.py (the paper's Llama-4-Scout MLP and DeepSeek MoE
benchmarks are exactly these GEMMs).

  * ``matmul``         [m, k] @ [k, n]
  * ``swiglu_gateup``  silu(x@Wg) * (x@Wu) — the epilogue-fused ComputeLocation
                        decision: the SwiGLU intermediate never touches HBM.
  * ``moe_gemm``       [E, cap, d] @ [E, d, f] grouped expert GEMM (expert =
                        outer grid dim, so each expert's weights are DMA'd to
                        VMEM exactly once per (m, n) tile wave).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


# ---------------------------------------------------------------------------
# plain tiled matmul
# ---------------------------------------------------------------------------

def _matmul_kernel(a_ref, b_ref, o_ref, acc_scr):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )

    @pl.when(kb == pl.num_programs(2) - 1)
    def _():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def _matmul_kernel_no_cache_write(a_ref, b_ref, o_ref):
    """CacheWrite=False realization: the output block is the accumulator.

    The partial sum round-trips through the output ref in the OUTPUT dtype
    each reduction step — exactly the re-read/rewrite-per-reduction-visit
    traffic the analytical oracle charges schedules without CacheWrite
    (cost_model.breakdown), and a real numerics difference in bf16.
    """
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "cache_write", "interpret"),
)
def matmul(
    a: jax.Array, b: jax.Array, *,
    bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
    cache_write: bool = True,
    interpret: bool = False,
) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel if cache_write else _matmul_kernel_no_cache_write,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((bk, bn), lambda i, j, kb: (kb, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=(
            [pltpu.VMEM((bm, bn), jnp.float32)] if cache_write else []
        ),
        interpret=interpret,
    )(a, b)


# ---------------------------------------------------------------------------
# fused SwiGLU gate-up: silu(x @ Wg) * (x @ Wu)
# ---------------------------------------------------------------------------

def _gateup_kernel(x_ref, wg_ref, wu_ref, o_ref, accg_scr, accu_scr):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _():
        accg_scr[...] = jnp.zeros_like(accg_scr)
        accu_scr[...] = jnp.zeros_like(accu_scr)

    x = x_ref[...].astype(jnp.float32)
    accg_scr[...] += jax.lax.dot_general(
        x, wg_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    accu_scr[...] += jax.lax.dot_general(
        x, wu_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )

    @pl.when(kb == pl.num_programs(2) - 1)
    def _():
        g = accg_scr[...]
        o_ref[...] = (g / (1.0 + jnp.exp(-g)) * accu_scr[...]).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret"),
)
def swiglu_gateup(
    x: jax.Array, w_gate: jax.Array, w_up: jax.Array, *,
    bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    k2, n = w_gate.shape
    assert k == k2 and w_up.shape == w_gate.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _gateup_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((bk, bn), lambda i, j, kb: (kb, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kb: (kb, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        interpret=interpret,
    )(x, w_gate, w_up)


# ---------------------------------------------------------------------------
# MoE grouped GEMM
# ---------------------------------------------------------------------------

def _moe_kernel(x_ref, w_ref, o_ref, acc_scr):
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )

    @pl.when(kb == pl.num_programs(3) - 1)
    def _():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret"),
)
def moe_gemm(
    x: jax.Array,  # [E, cap, d]
    w: jax.Array,  # [E, d, f]
    *,
    bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    e, cap, d = x.shape
    e2, d2, f = w.shape
    assert e == e2 and d == d2
    bm, bn, bk = min(bm, cap), min(bn, f), min(bk, d)
    assert cap % bm == 0 and f % bn == 0 and d % bk == 0
    grid = (e, cap // bm, f // bn, d // bk)
    return pl.pallas_call(
        _moe_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda ee, i, j, kb: (ee, i, kb)),
            pl.BlockSpec((1, bk, bn), lambda ee, i, j, kb: (ee, kb, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda ee, i, j, kb: (ee, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, cap, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
