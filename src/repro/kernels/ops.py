"""Backend dispatch for the kernel layer.

Every model in ``repro.models`` calls these wrappers instead of touching
Pallas directly.  Backends:

  * ``pallas``    — the real TPU kernels (pl.pallas_call, BlockSpec tiling).
  * ``interpret`` — same kernel bodies executed by the Pallas interpreter on
                    CPU; used by the correctness sweeps in tests/.
  * ``jax``       — pure-JAX implementations with identical semantics.  The
                    attention path is a chunked online-softmax lax.scan
                    (flash-style: O(S) memory, compact HLO) — this is what
                    the 512-device dry-run lowers, since Mosaic kernels do
                    not lower on the CPU host platform (DESIGN.md §4).

Block parameters default to kernel defaults but are overridden by the
Reasoning Compiler's tuning records (repro.compiler) when present —
either through the artifact set an engine binds onto ``cfg``
(models/layers.py) or, for bare callers, the read-only record-store
probe below.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import matmul as _mm
from . import ref as _ref
from .flash_attention import flash_attention

_DEFAULT_BACKEND: Optional[str] = None


def default_backend() -> str:
    global _DEFAULT_BACKEND
    if _DEFAULT_BACKEND is None:
        _DEFAULT_BACKEND = (
            "pallas" if jax.default_backend() == "tpu" else "jax"
        )
    return _DEFAULT_BACKEND


def set_default_backend(name: str) -> None:
    global _DEFAULT_BACKEND
    assert name in ("pallas", "interpret", "jax", "ref")
    _DEFAULT_BACKEND = name


_RECORDS = None  # lazy read handle on the default tuning-record store


def tuned_attention_blocks(
    cfg,
    seq_q: int,
    seq_kv: int,
    *,
    tp: int = 1,
) -> tuple[int, int]:
    """(block_q, block_k) for an ``ArchConfig``'s attention launch, from
    the tuning records.

    ``tp`` selects the post-SPMD per-device head extents via the SAME
    ``local_attention_dims`` helper ``launch/tune.py`` stores entries
    under (head padding + replication rules included), so the lookup key
    agrees with the tune-time key by construction — a TP-sharded model
    gets the block specs tuned for the local shapes the Pallas kernel
    will actually see.  Read-only: a probe straight into the default
    JSONL record store; a miss returns the kernel defaults instead of
    launching a search.
    """
    from ..compiler.artifacts import AttentionBlocks, default_records
    from ..compiler.records import record_key
    from ..compiler.tasks import (
        attention_tuning_workload,
        local_attention_dims,
    )

    global _RECORDS
    if _RECORDS is None:
        _RECORDS = default_records()

    heads, kv_heads = local_attention_dims(cfg, tp)
    w = attention_tuning_workload(
        heads, seq_q, seq_kv, cfg.hd, kv_heads=kv_heads
    )
    rec = _RECORDS.get(record_key("tpu-v5e", w))
    blocks = AttentionBlocks.from_params(rec.params) if rec \
        else AttentionBlocks()
    return blocks.block_q, blocks.block_k


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _attention_jax_chunked(
    q, k, v, *, causal: bool, sm_scale: float, window: Optional[int],
    chunk: int = 1024,
) -> jax.Array:
    """Online-softmax over KV chunks via lax.scan: O(S) memory, O(1)-depth
    HLO. Equivalent to the Pallas kernel's math, one chunk per scan step.

    Q/K/V stream in their storage dtype (bf16 on the full configs) with
    f32 accumulation — casting them to f32 up front doubled HLO bytes
    (§Perf iteration B1)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    chunk = min(chunk, skv)
    if skv % chunk:  # fall back to one chunk when sizes are ragged
        chunk = skv
    nchunks = skv // chunk
    qf = q * jnp.asarray(sm_scale, q.dtype)
    kc = jnp.moveaxis(k.reshape(b, hkv, nchunks, chunk, d), 2, 0)
    vc = jnp.moveaxis(v.reshape(b, hkv, nchunks, chunk, d), 2, 0)
    qpos = jnp.arange(sq) + (skv - sq)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, idx = xs
        kb = jnp.repeat(kb, group, axis=1)  # [b, hq, chunk, d]
        vb = jnp.repeat(vb, group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb,
                       preferred_element_type=jnp.float32)
        kpos = idx * chunk + jnp.arange(chunk)
        mask = jnp.ones((sq, chunk), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # fully-masked chunks leave m == -inf; guard the exp
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        alpha = jnp.where(
            jnp.isinf(m), 0.0, jnp.exp(m - m_safe)
        )
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l, acc), None

    m0 = jnp.full((b, hq, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    acc0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (kc, vc, jnp.arange(nchunks))
    )
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l[..., None]).astype(q.dtype)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    sm_scale: Optional[float] = None,
    window: Optional[int] = None,
    backend: Optional[str] = None,
    block_q: int = 128,
    block_k: int = 128,
    chunk: int = 1024,
) -> jax.Array:
    """softmax(QK^T)V with GQA grouping; see module docstring for backends."""
    backend = backend or default_backend()
    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)
    if backend in ("pallas", "interpret"):
        return flash_attention(
            q, k, v, causal=causal, sm_scale=sm_scale, window=window,
            block_q=block_q, block_k=block_k,
            interpret=(backend == "interpret"),
        )
    if backend == "ref":
        return _ref.attention_ref(
            q, k, v, causal=causal, sm_scale=sm_scale, window=window
        )
    # pure-JAX: direct for small score matrices, chunked scan otherwise
    # (the chunked threshold keeps the materialized score block <= ~1M
    # elements per head — beyond that the O(S^2) buffer dominates training
    # memory even under per-layer remat)
    b, hq, sq, _ = q.shape
    skv = k.shape[2]
    if sq * skv <= 1024 * 1024 and sq > 1:
        return _ref.attention_ref(
            q, k, v, causal=causal, sm_scale=sm_scale, window=window
        )
    if sq == 1:
        return _decode_attention_jax(
            q, k, v, sm_scale=sm_scale, window=window
        )
    return _attention_jax_chunked(
        q, k, v, causal=causal, sm_scale=sm_scale, window=window, chunk=chunk
    )


def _decode_attention_jax(q, k, v, *, sm_scale, window):
    """Single-token decode: q [B,Hq,1,D] against the full KV cache."""
    b, hq, _, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    qg = q.reshape(b, hkv, group, d).astype(jnp.float32) * sm_scale
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k.astype(jnp.float32))
    if window is not None:
        kpos = jnp.arange(skv)
        s = jnp.where((kpos > skv - 1 - window)[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, 1, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# GEMM family
# ---------------------------------------------------------------------------

def matmul(a, b, *, backend=None, bm=128, bn=128, bk=512):
    backend = backend or default_backend()
    if backend in ("pallas", "interpret"):
        return _mm.matmul(
            a, b, bm=bm, bn=bn, bk=bk, interpret=(backend == "interpret")
        )
    return _ref.matmul_ref(a, b)


def swiglu_gateup(x, w_gate, w_up, *, backend=None, bm=128, bn=128, bk=512):
    backend = backend or default_backend()
    if backend in ("pallas", "interpret"):
        return _mm.swiglu_gateup(
            x, w_gate, w_up, bm=bm, bn=bn, bk=bk,
            interpret=(backend == "interpret"),
        )
    return _ref.swiglu_gateup_ref(x, w_gate, w_up)


def swiglu_mlp(x, w_gate, w_up, w_down, *, backend=None, bm=128, bn=128,
               bk=512):
    h = swiglu_gateup(x, w_gate, w_up, backend=backend, bm=bm, bn=bn, bk=bk)
    return matmul(h, w_down, backend=backend, bm=bm, bn=bn, bk=bk)


def moe_gemm(x, w, *, backend=None, bm=128, bn=128, bk=512):
    backend = backend or default_backend()
    if backend in ("pallas", "interpret"):
        return _mm.moe_gemm(
            x, w, bm=bm, bn=bn, bk=bk, interpret=(backend == "interpret")
        )
    return _ref.moe_gemm_ref(x, w)
