"""Pure-jnp oracles for every Pallas kernel (the allclose reference).

These are the semantics contracts: each kernel in this package must match
its oracle to float tolerance across the shape/dtype sweeps in
``tests/test_kernels.py``.  They are also the CPU execution path for the
models during dry-runs (via ops.py backend dispatch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """[m, k] @ [k, n] in f32 accumulation."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def swiglu_gateup_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array) -> jax.Array:
    """silu(x @ w_gate) * (x @ w_up): the fused gate-up of a SwiGLU MLP."""
    g = jnp.dot(x, w_gate, preferred_element_type=jnp.float32)
    u = jnp.dot(x, w_up, preferred_element_type=jnp.float32)
    return (jax.nn.silu(g) * u).astype(x.dtype)


def swiglu_mlp_ref(
    x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array
) -> jax.Array:
    h = swiglu_gateup_ref(x, w_gate, w_up)
    return matmul_ref(h, w_down)


def attention_ref(
    q: jax.Array,  # [B, Hq, Sq, D]
    k: jax.Array,  # [B, Hkv, Skv, D]
    v: jax.Array,  # [B, Hkv, Skv, D]
    causal: bool = True,
    sm_scale: float | None = None,
    window: int | None = None,
) -> jax.Array:
    """Materialized softmax(QK^T)V with GQA head grouping and optional
    causal / sliding-window masking.  O(S^2) memory — oracle only."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * sm_scale
    skv = k.shape[2]
    qpos = jnp.arange(sq)[:, None] + (skv - sq)  # right-aligned queries
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return o.astype(q.dtype)


def moe_gemm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Grouped expert GEMM: [E, cap, d] @ [E, d, f] -> [E, cap, f]."""
    return jnp.einsum(
        "ecd,edf->ecf", x.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(x.dtype)
