"""Shared CLI plumbing for the launchers (``tune`` / ``serve``).

Both launchers expose ``main(argv)`` and the same flag names with the
same help text for the surfaces they share — the record store
(``--records``), the cost-model platform (``--platform``), and the
timeline writer (``--trace-out``) — so muscle memory and scripts
transfer between them.  The builders here are the single source of
those flags.
"""
from __future__ import annotations

import argparse
from typing import Optional

from ..obs import Tracer


def add_records_flag(ap: argparse.ArgumentParser) -> None:
    from ..compiler.records import DEFAULT_RECORDS_PATH

    ap.add_argument("--records", default=None,
                    help=f"tuning-record store path (versioned JSONL; "
                         f"default {DEFAULT_RECORDS_PATH})")


def add_platform_flag(ap: argparse.ArgumentParser,
                      default: str = "tpu-v5e") -> None:
    ap.add_argument("--platform", default=default,
                    help=f"cost-model platform the records are keyed "
                         f"under (core/cost_model.py; default {default})")


def add_trace_flag(ap: argparse.ArgumentParser, what: str) -> None:
    ap.add_argument("--trace-out", default="",
                    help=f"write the {what} timeline here (.json = "
                         f"Chrome trace-event format for "
                         f"chrome://tracing / ui.perfetto.dev, "
                         f".jsonl = raw events)")


def resolve_records(args):
    """``--records`` path -> TuningRecords (default: process store)."""
    from ..compiler import TuningRecords, default_records

    return TuningRecords(args.records) if args.records \
        else default_records()


def make_tracer(args) -> Optional[Tracer]:
    return Tracer() if args.trace_out else None


def finish_trace(tracer: Optional[Tracer], args, indent: str = "") -> None:
    if tracer is not None:
        tracer.write(args.trace_out)
        print(f"{indent}trace: {len(tracer.events())} events -> "
              f"{args.trace_out}")
