import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
)
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

THE two lines above must run before ANY other import (jax locks the device
count on first init) — which is why this module sets XLA_FLAGS at the very
top and why nothing else in the package sets it globally.

Per cell:
  * abstract params / optimizer state via jax.eval_shape (no allocation),
  * NamedShardings from dist.sharding rules,
  * ``jax.jit(step, in_shardings, out_shardings).lower(**ShapeDtypeStructs)``
  * ``.compile()`` -> memory_analysis (fits?) + cost_analysis (FLOPs/bytes)
  * post-SPMD HLO text -> collective bytes (roofline.analysis)

Results append to a JSON artifact consumed by benchmarks/roofline_table.py
and EXPERIMENTS.md.  Cells that a config declares unsupported (encoder
decode, quadratic attention at 524k) are recorded as skips with the reason.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both \
      [--arch tinyllama-1.1b ...] [--shape train_4k ...] [--out artifacts/]
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs.base import SHAPES, ArchConfig, get_config, input_specs, list_archs
from ..dist import sharding as shd
from ..models import model as M
from ..optim import adamw
from ..roofline.analysis import Roofline, cost_analysis_dict, parse_collectives
from ..train.train_step import make_train_step
from .mesh import make_production_mesh

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "artifacts", "dryrun.json")


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def _named(tree_specs, mesh):
    return shd.named_shardings(tree_specs, mesh)


def build_cell(cfg: ArchConfig, shape: str, mesh, backend: str = "jax",
               microbatches: int = 1, remat: str = "full"):
    """Returns (jitted_fn, kwargs_of_ShapeDtypeStructs, model_flops)."""
    sp = SHAPES[shape]
    tp = shd.tp_degree(mesh)
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_a = _abstract(lambda k: M.init_params(cfg, k, tp), key_spec)
    p_sh = _named(shd.param_specs(cfg, params_a, mesh), mesh)
    inputs = input_specs(cfg, shape)
    b_sh = _named(shd.batch_specs(cfg, inputs, mesh), mesh)
    n_active = cfg.active_param_count()

    if sp.kind == "train":
        from jax.sharding import NamedSharding, PartitionSpec
        opt_a = _abstract(adamw.init, params_a)
        moment_sh = _named(shd.opt_state_specs(
            shd.param_specs(cfg, params_a, mesh), params_a, mesh), mesh)
        o_sh = adamw.AdamWState(
            step=NamedSharding(mesh, PartitionSpec()),
            m=moment_sh, v=moment_sh,
        )
        step = make_train_step(
            cfg, adamw.AdamWConfig(), backend=backend, remat=remat,
            microbatches=microbatches,
        )
        fn = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
        )
        args = (params_a, opt_a, inputs)
        tokens = sp.global_batch * sp.seq_len
        model_flops = 6.0 * n_active * tokens
        return fn, args, model_flops

    if sp.kind == "prefill":
        if cfg.block == "encoder":
            def encode(params, batch):
                logits, _ = M.forward(cfg, params, batch, backend=backend)
                return logits
            fn = jax.jit(encode, in_shardings=(p_sh, b_sh),
                         out_shardings=None)
            args = (params_a, inputs)
        else:
            def pre(params, batch):
                return M.prefill(cfg, params, batch, sp.seq_len,
                                 backend=backend)
            fn = jax.jit(pre, in_shardings=(p_sh, b_sh), out_shardings=None)
            args = (params_a, inputs)
        model_flops = 2.0 * n_active * sp.global_batch * sp.seq_len
        return fn, args, model_flops

    # decode: one token against a seq_len-deep cache
    cache_a = M.cache_spec(cfg, sp.global_batch, sp.seq_len, tp)
    c_sh = _named(shd.cache_specs_tree(cfg, cache_a, mesh), mesh)
    pos_a = jax.ShapeDtypeStruct((), jnp.int32)

    def dec(params, tokens, cache, pos):
        return M.decode_step(cfg, params, tokens, cache, pos,
                             backend=backend)

    fn = jax.jit(
        dec,
        in_shardings=(p_sh, b_sh["tokens"], c_sh, None),
        out_shardings=(None, c_sh),
        donate_argnums=(2,),
    )
    args = (params_a, inputs["tokens"], cache_a, pos_a)
    model_flops = 2.0 * n_active * sp.global_batch
    return fn, args, model_flops


def _cost_point(cfg, shape: str, mesh, backend: str, layers: int,
                microbatches: int = 1):
    """Compile a fully-UNROLLED `layers`-deep variant and return
    (flops, bytes, CollectiveStats) per device.  XLA cost_analysis counts a
    while-loop body once regardless of trip count, so per-layer costs are
    extracted from two unrolled points and extrapolated exactly (scanned
    layers are homogeneous by construction; the microbatch loop is unrolled
    by the same knob)."""
    cfg_l = dataclasses.replace(cfg, layers=layers)
    M.SCAN_UNROLL["n"] = max(2, layers, microbatches)
    try:
        fn, args, _ = build_cell(cfg_l, shape, mesh, backend,
                                 microbatches=microbatches)
        with mesh:
            compiled = fn.lower(*args).compile()
    finally:
        M.SCAN_UNROLL["n"] = 1
    cost = cost_analysis_dict(compiled)
    coll = parse_collectives(compiled.as_text(), chips_per_pod=256)
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def _extrapolate(cfg, shape: str, mesh, backend: str,
                 microbatches: int = 1):
    """Two-point linear extrapolation of per-device flops/bytes/collective
    bytes to the full layer count."""
    period = 4 if cfg.block == "xlstm" else 1
    l1, l2 = period, 2 * period
    f1, b1, c1 = _cost_point(cfg, shape, mesh, backend, l1, microbatches)
    f2, b2, c2 = _cost_point(cfg, shape, mesh, backend, l2, microbatches)
    L = cfg.layers

    def fit(v1, v2):
        body = max(0.0, (v2 - v1) / (l2 - l1))
        outer = max(0.0, v1 - l1 * body)
        return outer + L * body

    from ..roofline.analysis import CollectiveStats
    counts = {
        k: int(fit(c1.counts.get(k, 0), c2.counts.get(k, 0)))
        for k in set(c1.counts) | set(c2.counts)
    }
    bkind = {
        k: fit(c1.bytes_by_kind.get(k, 0.0), c2.bytes_by_kind.get(k, 0.0))
        for k in set(c1.bytes_by_kind) | set(c2.bytes_by_kind)
    }
    coll = CollectiveStats(
        counts, bkind,
        ici_bytes=fit(c1.ici_bytes, c2.ici_bytes),
        dcn_bytes=fit(c1.dcn_bytes, c2.dcn_bytes),
    )
    return fit(f1, f2), fit(b1, b2), coll


def run_cell(arch: str, shape: str, multi_pod: bool, backend: str = "jax"):
    """Lower + compile one cell; returns a result dict."""
    cfg = get_config(arch)
    ok, why = cfg.supports(shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    base = {"arch": arch, "shape": shape, "mesh": mesh_name}
    if not ok:
        return {**base, "status": "skip", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    sp = SHAPES[shape]
    t0 = time.time()
    hbm = 15.5 * 2**30  # v5e HBM with headroom
    try:
        # auto-tune gradient-accumulation depth until the cell fits HBM —
        # the framework's standard response to an over-budget global batch.
        mb_ladder = [1, 2, 4, 8, 16, 32] if sp.kind == "train" else [1]
        mb_ladder = [m for m in mb_ladder
                     if sp.global_batch % m == 0] or [1]
        mem = compiled = hlo = None
        microbatches = 1
        for mb in mb_ladder:
            fn, args, model_flops = build_cell(cfg, shape, mesh, backend,
                                               microbatches=mb)
            with mesh:
                lowered = fn.lower(*args)
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
                mem = compiled.memory_analysis()
                hlo = compiled.as_text()
            microbatches = mb
            peak = (getattr(mem, "temp_size_in_bytes", 0)
                    + getattr(mem, "argument_size_in_bytes", 0))
            if peak <= hbm:
                break
        # cost extraction at reduced unrolled depths (exact per-layer fit)
        flops_dev, bytes_dev, coll = _extrapolate(
            cfg, shape, mesh, backend, microbatches)
    except Exception as e:  # a failure here is a bug in our sharding
        return {
            **base, "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "trace": traceback.format_exc()[-2000:],
        }
    # cost_analysis is per-partition (the SPMD module is one device's
    # program): fleet totals scale by chip count.
    flops_fleet = flops_dev * chips
    bytes_fleet = bytes_dev * chips
    mem_d = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes": getattr(mem, "temp_size_in_bytes", 0)
        + getattr(mem, "argument_size_in_bytes", 0),
    }
    roof = Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops_fleet, hlo_bytes=bytes_fleet, collective=coll,
        model_flops=model_flops, bytes_per_device=mem_d,
    )
    peak = mem_d["peak_bytes"]
    return {
        **base, "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "microbatches": microbatches,
        "fits_hbm": bool(peak <= hbm),
        **roof.to_dict(),
    }


def load_results(path: str) -> dict:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_results(path: str, results: dict) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=None)
    ap.add_argument("--shape", nargs="*", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = args.arch or list_archs()
    shapes = args.shape or list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    results = load_results(args.out)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}|{shape}|{'2x16x16' if mp else '16x16'}"
                if key in results and not args.force \
                        and results[key].get("status") in ("ok", "skip"):
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                res = run_cell(arch, shape, mp)
                results[key] = res
                save_results(args.out, results)
                status = res["status"]
                extra = res.get("reason") or res.get("error", "")
                if status == "ok":
                    extra = (f"compile={res['compile_s']}s "
                             f"dom={res['dominant']} "
                             f"mfu={res['mfu']:.3f} "
                             f"peakB/dev={res['bytes_per_device']['peak_bytes'] / 2**30:.2f}GiB")
                print(f"[dryrun] {key}: {status} {extra}", flush=True)
    n_ok = sum(1 for r in results.values() if r["status"] == "ok")
    n_skip = sum(1 for r in results.values() if r["status"] == "skip")
    n_err = sum(1 for r in results.values() if r["status"] == "error")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip, {n_err} error")


if __name__ == "__main__":
    main()
