"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets the fake-device XLA flag before
any jax import, and smoke tests must keep seeing 1 device).

Topology: one TPU v5e pod = 16x16 = 256 chips, axes ("data", "model");
multi-pod = 2 pods = 512 chips with a leading pure-DP "pod" axis whose
collectives cross the inter-pod DCN exactly once per step (gradient
all-reduce).

Axis contract (consumed by ``repro.dist.sharding``): "model" carries
tensor/expert parallelism, "data" batch parallelism within a pod, "pod"
pure DP across pods.  Any mesh honoring these names works — the sharding
rules read sizes from the mesh, so tests run the same code on (1, 1).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (tests / examples): (data=n, model=1)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
