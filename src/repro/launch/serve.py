"""Serving launcher: batched requests through the continuous-batching
engine (serve/engine.py) with Reasoning-Compiler-tuned kernels.

``python -m repro.launch.serve --arch tinyllama-1.1b --smoke --requests 8``
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs.base import get_config
from ..models import model as M
from ..serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len)
    rng = np.random.RandomState(0)
    t0 = time.perf_counter()
    for uid in range(args.requests):
        plen = args.prompt_len + int(rng.randint(-4, 5))
        engine.submit(Request(
            uid, rng.randint(0, cfg.vocab, size=max(4, plen)).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    done = engine.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
