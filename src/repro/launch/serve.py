"""Serving launcher: batched requests through a continuous-batching engine
with Reasoning-Compiler-tuned kernels.

``--engine paged`` (default) uses the paged-KV scheduler — batched
bucketed prefill, optional chunked prefill, page-pool occupancy — and
``--engine dense`` the dense-cache baseline, so the two are one flag apart
for A/B runs (protocol: EXPERIMENTS.md §Serve).  ``--speculative`` adds
the draft-and-verify decode lane (``--draft-arch``/``--draft-len``;
EXPERIMENTS.md §Speculative).

``--trace-out engine.trace.json`` records the whole run as a Chrome
trace-event timeline (admission spans, per-slot request lifetimes,
prefill buckets / chunk lanes / decode dispatches, KV page events; open
at ``chrome://tracing`` or https://ui.perfetto.dev).  A ``.jsonl``
suffix writes raw events instead (EXPERIMENTS.md §Observability).

``--retune`` closes the serve→compile loop while the run is live: a
``serve.retune.BackgroundRetuner`` thread reads the engine's observed
shape distribution every ``--retune-interval`` seconds, compiles the hot
shapes through a ``CompilerSession`` (``--retune-budget`` samples per
task, against ``--records`` / ``--platform``), and publishes new
artifact epochs that the engine hot-swaps at step boundaries — no
restart, greedy outputs bit-identical across swaps.

``python -m repro.launch.serve --arch tinyllama-1.1b --smoke --requests 8``
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs.base import get_config
from ..models import model as M
from ..serve import BackgroundRetuner, PagedServeEngine, Request, ServeEngine
from .common import (
    add_platform_flag,
    add_records_flag,
    add_trace_flag,
    finish_trace,
    make_tracer,
    resolve_records,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", default="paged", choices=["paged", "dense"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunk long prompts (dense blocks): prompts over "
                         "this many tokens prefill incrementally, "
                         "interleaved with decode (lanes batch across "
                         "slots)")
    ap.add_argument("--kv-pages", type=int, default=0,
                    help="page-pool capacity (0 = fully provisioned); "
                         "smaller overcommits and gates admission")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share prompt-prefix KV pages across requests "
                         "(radix index + copy-on-write; dense blocks)")
    ap.add_argument("--admission", default="fcfs",
                    choices=["fcfs", "spf", "slo"],
                    help="admission policy: arrival order, shortest "
                         "prefill first, or TTFT-SLO least laxity")
    ap.add_argument("--ttft-slo", type=float, default=0.5,
                    help="TTFT deadline (seconds) for --admission slo "
                         "and the under-SLO report column")
    ap.add_argument("--speculative", action="store_true",
                    help="draft-propose / batch-verify decode lane "
                         "(dense blocks): greedy output is bit-identical "
                         "to plain decode, but each target call emits "
                         "1..draft-len+1 tokens per slot")
    ap.add_argument("--draft-arch", default="",
                    help="draft model architecture for --speculative "
                         "(same vocab as --arch; empty = self-"
                         "speculative, reusing the target params)")
    ap.add_argument("--draft-len", type=int, default=4,
                    help="draft tokens proposed per speculative round")
    ap.add_argument("--retune", action="store_true",
                    help="background shape-aware retuning: recompile the "
                         "hottest observed dispatch shapes off-thread and "
                         "hot-swap the published artifact epochs at step "
                         "boundaries (serve/retune.py)")
    ap.add_argument("--retune-interval", type=float, default=2.0,
                    help="seconds between background retune cycles")
    ap.add_argument("--retune-budget", type=int, default=32,
                    help="search samples per retuned task")
    add_records_flag(ap)
    add_platform_flag(ap)
    add_trace_flag(ap, "engine")
    args = ap.parse_args(argv)

    tracer = make_tracer(args)
    cfg = get_config(args.arch, smoke=args.smoke)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    draft_cfg = draft_params = None
    if args.speculative and args.draft_arch:
        draft_cfg = get_config(args.draft_arch, smoke=args.smoke)
        draft_params = M.init_params(draft_cfg, jax.random.PRNGKey(1))
    from ..compiler import ArtifactRegistry

    registry = ArtifactRegistry(resolve_records(args),
                                platform=args.platform)
    if args.engine == "paged":
        engine = PagedServeEngine(
            cfg, params, slots=args.slots, max_len=args.max_len,
            page_size=args.page_size, prefill_chunk=args.prefill_chunk,
            capacity=args.kv_pages or None,
            prefix_cache=args.prefix_cache, admission=args.admission,
            ttft_slo_s=args.ttft_slo,
            speculative=args.speculative, draft_cfg=draft_cfg,
            draft_params=draft_params, draft_len=args.draft_len,
            tracer=tracer, registry=registry,
        )
    else:
        engine = ServeEngine(
            cfg, params, slots=args.slots, max_len=args.max_len,
            tracer=tracer, registry=registry,
        )
    retuner = None
    if args.retune:
        retuner = BackgroundRetuner(engine, budget=args.retune_budget,
                                    tracer=tracer)
        retuner.start(args.retune_interval)
    rng = np.random.RandomState(0)
    for uid in range(args.requests):
        plen = args.prompt_len + int(rng.randint(-4, 5))
        engine.submit(Request(
            uid, rng.randint(0, cfg.vocab, size=max(4, plen)).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    done = engine.run()
    if retuner is not None:
        retuner.stop()
    s = engine.metrics.summary()
    print(f"served {s['requests']}/{len(done)} requests, "
          f"{s['generated_tokens']} tokens in {s['wall_s']:.2f}s "
          f"({s['throughput_tok_s']:.1f} tok/s)")
    print(f"  ttft mean {s['ttft_mean_s'] * 1e3:.1f}ms "
          f"(p99 {s['ttft_p99_s'] * 1e3:.1f}ms, "
          f"under-slo {s['ttft_under_slo']:.2f})  "
          f"tpot mean {s['tpot_mean_s'] * 1e3:.1f}ms  "
          f"prefill calls {s['prefill_calls']} "
          f"(+{s['prefill_chunk_calls']} chunks)  "
          f"decode steps {s['decode_steps']}  "
          f"kv occupancy {s['kv_occupancy_mean']:.2f} "
          f"(max {s['kv_occupancy_max']:.2f})")
    if s["prefix_lookups"]:
        print(f"  prefix cache: hit rate {s['prefix_hit_rate']:.2f}  "
              f"cached tokens {s['prefix_cached_tokens']}  "
              f"cow copies {engine.kv.cow_copies}")
    if s["spec_steps"]:
        print(f"  speculative: acceptance {s['spec_acceptance_rate']:.2f} "
              f"({s['spec_accepted']}/{s['spec_proposed']})  "
              f"tokens/target-call {s['tokens_per_target_call']:.2f}  "
              f"verify steps {s['spec_steps']}  "
              f"draft calls {s['draft_calls']}")
    if retuner is not None:
        print(f"  retune: {retuner.cycles} cycles, "
              f"epochs published {retuner.published_epochs}, "
              f"engine swaps {s['artifact_swaps']} "
              f"(now at epoch {engine._artifact_epoch}, "
              f"{len(registry.records)} records)")
    finish_trace(tracer, args, indent="  ")


if __name__ == "__main__":
    main()
