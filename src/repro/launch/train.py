"""Training launcher.

Host-local (examples, CI): ``python -m repro.launch.train --arch <id>
--steps 50 --smoke``.  On a real multi-host TPU deployment the same entry
point runs under ``jax.distributed.initialize()`` with the production mesh;
parameters/optimizer are sharded by ``dist.sharding`` and the train loop is
mesh-agnostic (train/trainer.py).
"""
from __future__ import annotations

import argparse

from ..configs.base import SHAPES, ShapeSpec, get_config
from ..optim.adamw import AdamWConfig
from ..train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None,
                    help="assigned shape name (default: small local shape)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "full"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.shape:
        shape = SHAPES[args.shape]
    else:
        shape = ShapeSpec("local", args.seq, args.batch, "train")
    trainer = Trainer(
        cfg, shape,
        TrainerConfig(
            total_steps=args.steps,
            checkpoint_dir=args.checkpoint_dir,
            microbatches=args.microbatches,
            remat=args.remat,
            compress_grads=args.compress_grads,
        ),
        opt_cfg=AdamWConfig(lr=args.lr, total_steps=args.steps),
    )
    hist = trainer.run()
    print(f"final loss: {hist[-1]['loss']:.4f} over {len(hist)} steps")


if __name__ == "__main__":
    main()
