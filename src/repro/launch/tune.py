"""Kernel autotuning launcher: the Reasoning Compiler as a deploy-time tool.

``python -m repro.launch.tune --arch tinyllama-1.1b --seq 4096 --budget 64``
searches schedules for the arch's hot kernels on the TPU-v5e profile and
persists the winning Pallas block parameters in the tuning cache that
``repro.kernels.ops`` consumers read.
"""
from __future__ import annotations

import argparse

from ..configs.base import get_config
from ..core.autotuner import KernelTuner, local_attention_dims


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: tune against the "
                         "post-SPMD per-device head counts")
    ap.add_argument("--budget", type=int, default=64)
    ap.add_argument("--method", default="llm-mcts",
                    choices=["llm-mcts", "mcts", "evolutionary"])
    ap.add_argument("--llm", default="gpt-4o-mini")
    ap.add_argument("--oracle", default="analytical",
                    choices=["analytical", "measured", "hybrid"],
                    help="search-time objective backend (core/oracle.py); "
                         "measured/hybrid time real kernel executions per "
                         "sample (interpret mode off-TPU)")
    ap.add_argument("--measure", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="re-rank the search winners by real timed kernel "
                         "execution before persisting (--no-measure for the "
                         "pure-analytical legacy behavior)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    tuner = KernelTuner(method=args.method, budget=args.budget, llm=args.llm,
                        oracle=args.oracle, measure=args.measure)
    if cfg.block not in ("xlstm",):
        hq, hkv = local_attention_dims(cfg, args.tp)
        blocks = tuner.tune_attention(
            hq, args.seq, args.seq, cfg.hd, kv_heads=hkv
        )
        print(f"{cfg.name} attention (tp={args.tp}, local {hq}q/{hkv}kv): "
              f"block_q={blocks.block_q} block_k={blocks.block_k}")
    if cfg.d_ff:
        g = tuner.tune_gemm(args.seq, cfg.d_ff, cfg.d_model,
                            epilogue="swiglu")
        print(f"{cfg.name} mlp gate-up: bm={g.bm} bn={g.bn} bk={g.bk}")
    print(f"tuning cache: {tuner.cache_path}")


if __name__ == "__main__":
    main()
