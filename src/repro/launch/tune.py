"""Kernel autotuning launcher: the Reasoning Compiler as a deploy-time tool.

``python -m repro.launch.tune --arch tinyllama-1.1b --seq 4096 --budget 64``
opens one ``CompilerSession`` (one LLM, one oracle, one record database),
compiles the arch's hot kernels through a shared search context, and
persists provenance-carrying records in the versioned JSONL store that
``repro.kernels.ops`` / engine artifact sets read.

Extras over the v0 launcher:

* ``--seqs 1024,4096,16384`` sweeps context lengths (one record per shape;
  siblings seed each other's searches when ``--shared`` is on, default).
* ``--all-kernels`` tunes the whole per-arch task set
  (``compiler.tasks_for_config``: attention + qkv/o-proj/MLP GEMMs, MoE
  expert GEMM) instead of the historical attention+MLP pair.
* ``--migrate-cache`` one-shot migrates a v0 JSON tuning cache into the
  versioned store and exits.
"""
from __future__ import annotations

import argparse
import dataclasses

from ..compiler import (
    BudgetPolicy,
    CompilerSession,
    attention_task,
    gemm_task,
    local_attention_dims,
    migrate_json_cache,
    tasks_for_config,
)
from ..compiler.records import LEGACY_JSON_PATH
from ..configs.base import get_config
from .common import (
    add_platform_flag,
    add_records_flag,
    add_trace_flag,
    finish_trace,
    make_tracer,
    resolve_records,
)


def _parse_seqs(args) -> list[int]:
    if args.seqs:
        seqs = [int(s) for s in args.seqs.replace(" ", "").split(",") if s]
        if not seqs:
            raise SystemExit("--seqs given but no lengths parsed")
        return seqs
    return [args.seq]


def _tasks(cfg, seqs: list[int], tp: int, all_kernels: bool):
    tasks = []
    for i, seq in enumerate(sorted(seqs, reverse=True)):
        # longest context first: it is the hardest search, and its winning
        # trace seeds the shorter siblings
        prio = 10 * (len(seqs) - i)
        if all_kernels:
            for t in tasks_for_config(cfg, seq, tp=tp):
                tasks.append(dataclasses.replace(t, priority=t.priority + prio))
        else:
            # historical default: attention + MLP gate-up
            if cfg.block not in ("xlstm",):
                hq, hkv = local_attention_dims(cfg, tp)
                tasks.append(attention_task(
                    hq, seq, seq, cfg.hd, kv_heads=hkv, priority=100 + prio,
                    label=f"{cfg.name} attention tp={tp} seq={seq}",
                ))
            if cfg.d_ff:
                tasks.append(gemm_task(
                    seq, cfg.d_ff, cfg.d_model, epilogue="swiglu",
                    priority=90 + prio,
                    label=f"{cfg.name} mlp gate-up seq={seq}",
                ))
    return tasks


def _proposer_spec(args) -> str:
    """Merge --llm/--reviewer/--route into one canonical proposer spec
    (``compiler/proposers/spec.py``); plain single-tier specs pass
    through untouched so the pre-pool CLI behaves identically."""
    spec = args.llm
    if "+" in spec and not spec.startswith("pool:"):
        spec = "pool:" + spec
    if args.reviewer or args.route:
        if not spec.startswith("pool:"):
            spec = "pool:" + spec
        if args.reviewer and ":reviewer=" not in spec:
            spec += f":reviewer={args.reviewer}"
        if args.route and ":route=" not in spec:
            spec += f":route={args.route}"
    return spec


def _print_proposer_table(rows: list) -> None:
    """Per-proposer session summary: drafts, hit-rates, review outcomes."""
    if not rows:
        return
    print("proposers:")
    for row in rows:
        if "reviewer" in row:
            print(f"  {row['reviewer']:>28}  reviewer: "
                  f"{row['reviews']} reviews "
                  f"({row['accepted']} accept / {row['refined']} refine / "
                  f"{row['replaced']} replace / {row['vetoed']} veto)")
        elif "drafted" in row:
            print(f"  {row['proposer']:>28}  cost={row['cost']:<6} "
                  f"drafted={row['drafted']:<5} hits={row['hits']:<4} "
                  f"hit-rate={row['hit_rate']:.2f} "
                  f"fallback-rate={row['fallback_rate']:.2f}")
        else:
            print(f"  {row['proposer']:>28}  "
                  f"expansions={row['expansions']:<5} "
                  f"fallback-rate={row['fallback_rate']:.2f} "
                  f"invalid-rate={row['invalid_rate']:.2f}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--seqs", default=None,
                    help="comma-separated context-length sweep "
                         "(e.g. 1024,4096,16384); one record per shape")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: tune against the "
                         "post-SPMD per-device head counts")
    ap.add_argument("--budget", type=int, default=64,
                    help="sample budget PER TASK (the session reallocates "
                         "from converged tasks to stragglers)")
    ap.add_argument("--method", default="llm-mcts",
                    choices=["llm-mcts", "mcts", "evolutionary"])
    ap.add_argument("--llm", "--proposer", dest="llm", default="gpt-4o-mini",
                    help="proposal model: a tier name (core/llm.MODEL_TIERS),"
                         " 'random', 'api:<model>', or a pool spec "
                         "'pool:a+b[:reviewer=c][:route=policy]' "
                         "(compiler/proposers); 'a+b' shorthand builds a "
                         "pool too")
    ap.add_argument("--reviewer", default=None,
                    help="strong review-tier model escalated at promising "
                         "nodes (implies a pool; merged into the pool spec)")
    ap.add_argument("--route", default=None,
                    choices=["round-robin", "cost-weighted", "bandit"],
                    help="pool routing policy: which member drafts each "
                         "expansion (default round-robin)")
    ap.add_argument("--oracle", default="analytical",
                    choices=["analytical", "measured", "hybrid",
                             "surrogate", "surrogate:analytical",
                             "surrogate:hybrid"],
                    help="search-time objective backend (core/oracle.py); "
                         "measured/hybrid time real kernel executions per "
                         "sample (interpret mode off-TPU); surrogate "
                         "pre-screens candidates with the record-trained "
                         "model and escalates only the top-k to "
                         "compile-and-time (surrogate:<backend> picks the "
                         "escalation backend, default measured)")
    ap.add_argument("--escalate-topk", type=int, default=1,
                    help="with --oracle surrogate*: measurements escalated "
                         "per screened candidate pool (the rest are "
                         "rejected for free by the surrogate)")
    ap.add_argument("--screen-width", type=int, default=8,
                    help="with --oracle surrogate*: candidate pool size "
                         "ranked per MCTS expansion")
    ap.add_argument("--measure", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="re-rank the search winners by real timed kernel "
                         "execution before persisting (--no-measure for the "
                         "pure-analytical legacy behavior)")
    ap.add_argument("--all-kernels", action="store_true",
                    help="tune the whole per-arch task set "
                         "(attention + qkv/o-proj/MLP/MoE GEMMs)")
    ap.add_argument("--shared", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="cross-task shared search context (trace seeding "
                         "+ budget reallocation; --no-shared isolates "
                         "every task)")
    add_records_flag(ap)
    add_platform_flag(ap)
    ap.add_argument("--migrate-cache", nargs="?", const=LEGACY_JSON_PATH,
                    default=None, metavar="JSON_PATH",
                    help="one-shot migration of a v0 JSON tuning cache "
                         "into the versioned JSONL store, then exit")
    add_trace_flag(ap, "session")
    args = ap.parse_args(argv)

    records = resolve_records(args)

    if args.migrate_cache is not None:
        n = migrate_json_cache(args.migrate_cache, records)
        print(f"migrated {n} record(s) from {args.migrate_cache} "
              f"into {records.path}")
        return 0

    if not args.arch:
        ap.error("--arch is required (unless --migrate-cache)")
    cfg = get_config(args.arch)
    seqs = _parse_seqs(args)
    tasks = _tasks(cfg, seqs, args.tp, args.all_kernels)

    tracer = make_tracer(args)
    session = CompilerSession(
        target=args.platform,
        oracle=args.oracle,
        proposer=_proposer_spec(args),
        method=args.method,
        budget_policy=BudgetPolicy(per_task=args.budget,
                                   reallocate=args.shared),
        records=records,
        shared_context=args.shared,
        measure=args.measure,
        tracer=tracer,
        escalate_topk=args.escalate_topk,
        screen_width=args.screen_width,
    )
    artifacts = session.compile(tasks)
    for art in artifacts:
        rec = art.record
        how = "cache-hit" if art.cache_hit else \
            f"{rec.samples} samples, {rec.speedup:.2f}x"
        seeded = rec.provenance.get("seeded_from")
        if seeded:
            how += f", seeded from {seeded}"
        print(f"{art.task.describe()}: {art.blocks} ({how})")
    print(f"session: {session.tasks_compiled} searched, "
          f"{session.cache_hits} cache-hits, "
          f"{session.samples_spent} samples, "
          f"{session.seeds_played} cross-task seeds")
    _print_proposer_table(session.proposer_summary())
    if hasattr(session.oracle, "surrogate_provenance"):
        sp = session.oracle.surrogate_provenance()
        print(f"surrogate: {sp['version']}, {sp['train_rows']} rows "
              f"({sp['from_records']} from records), "
              f"{sp['proposals']} proposals screened, "
              f"{sp['escalations']} escalated to compile-and-time")
    print(f"records: {records.path} ({len(records)} entries)")
    finish_trace(tracer, args)
    return 0


if __name__ == "__main__":
    main()
