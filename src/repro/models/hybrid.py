"""Hymba hybrid block: parallel attention + Mamba-style SSM heads
[arXiv:2411.13676].

Each block runs (a) sliding-window GQA attention and (b) a selective SSM
(S6) path *in parallel* on the same input and mean-combines the normalized
outputs — Hymba's core idea (attention = snapshot memory, SSM = fading
memory).  Every ``global_layer_every``-th layer uses full attention.

The SSM path: in-proj -> causal depthwise conv(4) -> silu -> selective SSM
(input-dependent dt, B, C; diagonal A) -> gate -> out-proj.  Sequence mode
scans over time; decode keeps {conv tail, ssm state} — O(1) in context, so
``long_500k`` decode is servable (attention contributes a bounded window).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import AttnDims, attention_block, dense_init, init_attention

CONV_K = 4


def init_ssm_path(key, d: int, state: int, dtype) -> dict:
    inner = 2 * d
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], d, 2 * inner, dtype),
        "conv_w": (jax.random.normal(ks[1], (CONV_K, inner), jnp.float32)
                   / math.sqrt(CONV_K)).astype(dtype),
        "w_bc": dense_init(ks[2], inner, 2 * state, dtype),
        "w_dt": dense_init(ks[3], inner, inner, dtype, scale=0.1),
        "dt_bias": jnp.zeros((inner,), jnp.float32),
        "a_log": jnp.log(
            jnp.tile(jnp.arange(1, state + 1, dtype=jnp.float32), (inner, 1))
        ),
        "d_skip": jnp.ones((inner,), jnp.float32),
        "w_out": dense_init(ks[4], inner, d, dtype, scale=0.5),
    }


def ssm_init_state(batch: int, d: int, state: int) -> dict:
    inner = 2 * d
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, inner), jnp.float32),
        "h": jnp.zeros((batch, inner, state), jnp.float32),
    }


def _ssm_pre(x, p):
    """in-proj + split; returns (xm [B,S,inner], z gate [B,S,inner])."""
    xi = x @ p["w_in"]
    xm, z = jnp.split(xi, 2, axis=-1)
    return xm.astype(jnp.float32), jax.nn.silu(z.astype(jnp.float32))


def _ssm_conv_seq(xm, p, conv_state):
    """Causal depthwise conv over time with carried tail."""
    xpad = jnp.concatenate([conv_state, xm], axis=1)  # [B, K-1+S, inner]
    w = p["conv_w"].astype(jnp.float32)
    out = sum(
        xpad[:, i:i + xm.shape[1]] * w[i] for i in range(CONV_K)
    )
    new_state = xpad[:, -(CONV_K - 1):]
    return jax.nn.silu(out), new_state


def _ssm_scan(xc, p, h0):
    """Selective SSM over time: xc [B,S,inner] -> y [B,S,inner]."""
    bsz, s, inner = xc.shape
    state = p["a_log"].shape[1]
    bc = xc @ p["w_bc"].astype(jnp.float32)          # [B,S,2*state]
    bmat, cmat = jnp.split(bc, 2, axis=-1)           # [B,S,state]
    dt = jax.nn.softplus(xc @ p["w_dt"].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])                          # [inner, state]

    def step(h, xs):
        x_t, b_t, c_t, dt_t = xs  # [B,inner], [B,state], [B,state], [B,inner]
        da = jnp.exp(dt_t[..., None] * a)             # [B,inner,state]
        h = h * da + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bis,bs->bi", h, c_t)
        return h, y

    xs = (xc.transpose(1, 0, 2), bmat.transpose(1, 0, 2),
          cmat.transpose(1, 0, 2), dt.transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + xc * p["d_skip"]
    return y, h


def ssm_path_seq(
    x: jax.Array, p: dict, state: Optional[dict] = None
) -> tuple[jax.Array, dict]:
    b, s, d = x.shape
    nstate = p["a_log"].shape[1]
    if state is None:
        state = ssm_init_state(b, d, nstate)
    xm, z = _ssm_pre(x, p)
    xc, conv_state = _ssm_conv_seq(xm, p, state["conv"])
    y, h = _ssm_scan(xc, p, state["h"])
    out = ((y * z).astype(x.dtype)) @ p["w_out"]
    return out, {"conv": conv_state, "h": h}


def ssm_path_step(
    x_t: jax.Array, p: dict, state: dict
) -> tuple[jax.Array, dict]:
    """Single-token decode update."""
    xm, z = _ssm_pre(x_t, p)                      # [B,1,inner]
    xc, conv_state = _ssm_conv_seq(xm, p, state["conv"])
    y, h = _ssm_scan(xc, p, state["h"])
    out = ((y * z).astype(x_t.dtype)) @ p["w_out"]
    return out, {"conv": conv_state, "h": h}


# ---------------------------------------------------------------------------
# the combined hybrid block
# ---------------------------------------------------------------------------


def init_hybrid_block(key, dims: AttnDims, ssm_state: int, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "attn": init_attention(k1, dims, dtype),
        "ssm": init_ssm_path(k2, dims.d_model, ssm_state, dtype),
    }


def hybrid_block_seq(
    x: jax.Array,
    p: dict,
    dims: AttnDims,
    positions: jax.Array,
    *,
    rope_theta: float,
    window: Optional[int],
    is_global,
    ssm_state: Optional[dict] = None,
    kv_override: Optional[tuple] = None,
    backend: Optional[str] = None,
    cfg=None,
):
    """Parallel attn + SSM; `is_global` (traced per-layer scalar) disables
    the window.  Returns (y, (k, v), new_ssm_state)."""
    eff_window = None
    if window:
        # traced selection: global layers get a window >= sequence length
        eff_window = jnp.where(
            is_global, jnp.int32(2**30), jnp.int32(window)
        )
    attn_out, kv = attention_block(
        x, p["attn"], dims, positions, causal=True, rope_theta=rope_theta,
        window=eff_window, kv_override=kv_override, backend=backend, cfg=cfg,
    )
    ssm_out, new_state = ssm_path_seq(x, p["ssm"], ssm_state)
    return 0.5 * (attn_out + ssm_out), kv, new_state
