"""Shared building blocks: norms, RoPE, GQA attention, SwiGLU MLP, MoE.

Parameters are plain pytrees (nested dicts of jnp arrays); layer stacks are
stacked along a leading [L, ...] axis and driven by ``lax.scan`` so the HLO
is O(1) in depth (critical for the 512-device dry-run compile).

Sharding is expressed through ``logical_axis`` names carried next to each
initializer here and resolved to PartitionSpecs in ``repro.dist.sharding``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels import ops

# Tuned-block resolution is artifact-based: engines bind an immutable
# ``repro.compiler.ArtifactSet`` epoch at construction (tp-aware, via
# ``ArtifactRegistry.bind``) and thread it through ``cfg``, so concurrent
# engines with different sharding never race on a module global.  A bare
# model traced without an engine (no ``cfg.artifacts``) falls back to the
# default-records heuristic at tp=1.

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(
        x.dtype
    )


def layer_norm(
    x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5
) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def apply_norm(x: jax.Array, params: dict, kind: str) -> jax.Array:
    if kind == "layernorm":
        return layer_norm(x, params["scale"], params["bias"])
    return rms_norm(x, params["scale"])


def init_norm(d: int, kind: str, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(
    x: jax.Array,  # [B, H, S, D]
    positions: jax.Array,  # [B, S] or [S]
    theta: float = 10000.0,
) -> jax.Array:
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [D/2]
    if positions.ndim == 1:
        positions = positions[None]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos = jnp.cos(ang)[:, None]  # [B, 1, S, D/2]
    sin = jnp.sin(ang)[:, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense initializers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(
        dtype
    )


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnDims:
    heads: int
    kv_heads: int
    hd: int
    d_model: int


def init_attention(key, dims: AttnDims, dtype) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], dims.d_model, dims.heads * dims.hd, dtype),
        "wk": dense_init(ks[1], dims.d_model, dims.kv_heads * dims.hd, dtype),
        "wv": dense_init(ks[2], dims.d_model, dims.kv_heads * dims.hd, dtype),
        "wo": dense_init(
            ks[3], dims.heads * dims.hd, dims.d_model, dtype, scale=0.5
        ),
    }


def attention_qkv(
    x: jax.Array, p: dict, dims: AttnDims, positions: jax.Array,
    rope_theta: float,
):
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, dims.heads, dims.hd).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(b, s, dims.kv_heads, dims.hd).transpose(
        0, 2, 1, 3
    )
    v = (x @ p["wv"]).reshape(b, s, dims.kv_heads, dims.hd).transpose(
        0, 2, 1, 3
    )
    if rope_theta > 0:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def attention_block(
    x: jax.Array,
    p: dict,
    dims: AttnDims,
    positions: jax.Array,
    *,
    causal: bool = True,
    rope_theta: float = 10000.0,
    window: Optional[int] = None,
    kv_override: Optional[tuple] = None,
    backend: Optional[str] = None,
    cfg=None,
) -> tuple[jax.Array, tuple]:
    """Full attention sub-layer; returns (output, (k, v)) for cache capture.

    ``kv_override`` lets decode substitute the (cache-extended) K/V.
    ``cfg`` (an ``ArchConfig``, optional) enables the tuned-block lookup:
    the Pallas launch gets (block_q, block_k) from the artifact epoch the
    owning engine bound onto ``cfg`` (``repro.compiler.ArtifactSet``,
    resolved against that engine's tp degree), or — for bare-model
    traces without an engine — from the default record store at tp=1.
    """
    b, s, _ = x.shape
    q, k, v = attention_qkv(x, p, dims, positions, rope_theta)
    if kv_override is not None:
        k_all, v_all = kv_override
    else:
        k_all, v_all = k, v
    blocks = {}
    if cfg is not None:
        art = getattr(cfg, "artifacts", None)
        if art is not None:
            bq, bk = art.attention_blocks(cfg, q.shape[2], k_all.shape[2])
        else:
            bq, bk = ops.tuned_attention_blocks(
                cfg, q.shape[2], k_all.shape[2], tp=1
            )
        blocks = dict(block_q=bq, block_k=bk)
    o = ops.attention(
        q, k_all, v_all, causal=causal, window=window, backend=backend,
        **blocks,
    )
    o = o.transpose(0, 2, 1, 3).reshape(b, s, dims.heads * dims.hd)
    return o @ p["wo"], (k, v)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, f: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d, f, dtype),
        "w_up": dense_init(ks[1], d, f, dtype),
        "w_down": dense_init(ks[2], f, d, dtype, scale=0.5),
    }


def mlp_block(x: jax.Array, p: dict, backend: Optional[str] = None):
    b, s, d = x.shape
    h = ops.swiglu_mlp(
        x.reshape(b * s, d), p["w_gate"], p["w_up"], p["w_down"],
        backend=backend,
    )
    return h.reshape(b, s, d)


# ---------------------------------------------------------------------------
# Mixture of Experts (sort-based capacity dispatch, EP-shardable)
# ---------------------------------------------------------------------------


def init_moe(key, d: int, f: int, n_experts: int, dtype) -> dict:
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)

    def ew(k, din, dout, scale=1.0):
        return (
            jax.random.normal(k, (n_experts, din, dout), jnp.float32)
            * scale / math.sqrt(din)
        ).astype(dtype)

    return {
        "w_router": dense_init(ks[0], d, n_experts, jnp.float32),
        "w_gate": ew(ks[1], d, f),
        "w_up": ew(ks[2], d, f),
        "w_down": ew(ks[3], f, d, scale=0.5),
    }


def _maybe_constrain(x: jax.Array, candidates) -> jax.Array:
    """Apply the first sharding constraint the active mesh can satisfy.

    Models never hold a mesh; when traced under one (launch/dryrun, multi-
    host training) the constraint pins GSPMD's layout choice, and in
    mesh-free unit tests every candidate raises and the value passes
    through unannotated.
    """
    from jax.sharding import PartitionSpec as P

    for spec in candidates:
        try:
            return jax.lax.with_sharding_constraint(x, P(*spec))
        except Exception:
            continue
    return x


def moe_block(
    x: jax.Array,  # [B, S, D]
    p: dict,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    backend: Optional[str] = None,
    dispatch_groups: int = 32,
) -> tuple[jax.Array, jax.Array]:
    """Top-k token-choice MoE with GROUP-LOCAL capacity-bounded dispatch.

    Tokens are split into ``dispatch_groups`` groups aligned with the batch
    sharding; the sort/scatter runs independently per group (vmap), so no
    collective ever carries the full token stream — the only cross-device
    movement is the scatter into the [G, E, cap, D] expert buffers (group
    dim on the batch axes, expert dim on the model axis), which GSPMD lowers
    to the canonical MoE all-to-all.  (§Perf iteration A1: the previous
    global-argsort dispatch all-gathered ~TBs per device on qwen3 prefill.)

    Returns (output, aux_loss).  Dropped tokens (over per-group capacity)
    pass through the residual unchanged (GShard semantics).
    """
    b, s, d = x.shape
    e = p["w_gate"].shape[0]
    g = math.gcd(b, dispatch_groups)
    n = (b // g) * s  # tokens per group
    xf = x.reshape(g, n, d)

    logits = xf.astype(jnp.float32) @ p["w_router"]  # [G, n, E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, top_k)  # [G, n, K]
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch-style), averaged over groups
    density = jnp.mean(
        jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32), axis=(0, 1)
    )
    router_mean = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(density * router_mean)

    cap = max(1, int(math.ceil(n * top_k / e * capacity_factor)))

    def dispatch_one(xg, idxg, wg):
        e_flat = idxg.reshape(-1)                     # [n*K]
        tok = jnp.arange(n * top_k, dtype=jnp.int32) // top_k
        order = jnp.argsort(e_flat)                   # stable
        se = e_flat[order]
        st = tok[order]
        sw = wg.reshape(-1)[order]
        seg_start = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
        pos = jnp.arange(n * top_k, dtype=jnp.int32) - seg_start[se]
        keep = pos < cap
        se_safe = jnp.where(keep, se, e)  # over-capacity -> dropped row
        buf = jnp.zeros((e, cap, d), x.dtype).at[se_safe, pos].set(
            xg[st], mode="drop"
        )
        return buf, se_safe, pos, st, sw, keep

    buf, se_safe, pos, st, sw, keep = jax.vmap(dispatch_one)(
        xf, idx, weights
    )
    batch_first = (("pod", "data"), "model", None, None)
    buf = _maybe_constrain(
        buf, [batch_first, (("data",), "model", None, None)]
    )

    # ---- expert computation (grouped GEMMs, G x E blocked) -----------------
    # operands stream in storage dtype with f32 accumulation (§Perf A2:
    # f32-casting the [G,E,cap,*] buffers doubled the memory term)
    def egemm(t, w):
        return jnp.einsum(
            "gecd,edf->gecf", t, w, preferred_element_type=jnp.float32,
        )

    h = (jax.nn.silu(egemm(buf, p["w_gate"]))
         * egemm(buf, p["w_up"])).astype(x.dtype)
    out_e = egemm(h, p["w_down"]).astype(x.dtype)  # [G, E, cap, D]

    def combine_one(oe, se_s, po, stok, swt, kp):
        gathered = oe[se_s, jnp.minimum(po, cap - 1)]  # [n*K, D]
        gathered = jnp.where(kp[:, None], gathered, 0.0)
        return jnp.zeros((n, d), x.dtype).at[stok].add(
            gathered * swt[:, None].astype(x.dtype)
        )

    yf = jax.vmap(combine_one)(out_e, se_safe, pos, st, sw, keep)
    yf = _maybe_constrain(
        yf, [(("pod", "data"), None, None), (("data",), None, None)]
    )
    return yf.reshape(b, s, d), aux
