"""The model zoo: one generic LM covering all 10 assigned architectures.

``init_params`` / ``forward`` / ``loss_fn`` / ``prefill`` / ``decode_step``
dispatch on ``ArchConfig.block``:

  dense    — GQA + RoPE + SwiGLU decoder (stablelm/tinyllama/phi4; also the
             LLaVA backbone with patch-embedding concat and the HuBERT
             encoder in bidirectional mode)
  moe      — dense attention + top-k MoE FFN (qwen3-moe, llama4-scout)
  xlstm    — mLSTM blocks with sLSTM on every 4th layer (xlstm-125m)
  hybrid   — parallel sliding-window attention + SSM heads (hymba-1.5b)

All layer stacks are scanned (stacked [L, ...] params) so HLO depth is O(1);
heterogeneous layers (sLSTM/mLSTM, global/local attention) dispatch through
``lax.cond`` on per-layer flag arrays inside the scan.

TP head/vocab padding (Megatron-style, DESIGN.md §6) zero-initializes the
padded query-head slices so the padded model computes the *same function*
as the unpadded one.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import hybrid as hy
from . import ssm
from .layers import (
    AttnDims,
    apply_norm,
    attention_block,
    attention_qkv,
    dense_init,
    init_attention,
    init_mlp,
    init_moe,
    init_norm,
    mlp_block,
    moe_block,
)


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# Layer-scan unroll knob.  Default 1 (rolled scan: O(1)-in-depth HLO).  The
# dry-run's cost-extraction compiles set this >1 because XLA cost_analysis
# counts a while-loop body ONCE regardless of trip count — fully unrolling a
# 1- and a 2-layer variant yields the exact per-layer marginal cost
# (launch/dryrun.py).
SCAN_UNROLL = {"n": 1}


def _scan(body, init, xs):
    return jax.lax.scan(body, init, xs, unroll=SCAN_UNROLL["n"])


def attn_dims(cfg: ArchConfig, tp: int = 1) -> AttnDims:
    return AttnDims(
        heads=cfg.padded_heads(tp),
        kv_heads=cfg.padded_kv_heads(tp),
        hd=cfg.hd,
        d_model=cfg.d_model,
    )


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(cfg: ArchConfig, key: jax.Array, tp: int = 1) -> dict:
    dtype = _dtype(cfg)
    dims = attn_dims(cfg, tp)
    vpad = cfg.padded_vocab(tp) if tp > 1 else cfg.vocab
    keys = jax.random.split(key, cfg.layers + 4)

    def one_layer(k) -> dict:
        ks = jax.random.split(k, 4)
        p: dict = {"norm1": init_norm(cfg.d_model, cfg.norm, dtype)}
        if cfg.block == "xlstm":
            p["mlstm"] = ssm.init_mlstm(ks[0], cfg.d_model, cfg.heads, dtype)
            p["slstm"] = ssm.init_slstm(ks[1], cfg.d_model, cfg.heads, dtype)
            return p
        p["norm2"] = init_norm(cfg.d_model, cfg.norm, dtype)
        if cfg.block == "hybrid":
            p["mix"] = hy.init_hybrid_block(ks[0], dims, cfg.ssm_state, dtype)
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
            return p
        p["attn"] = _pad_attention(
            init_attention(ks[0], dims, dtype), cfg, dims
        )
        if cfg.block == "moe":
            p["moe"] = init_moe(
                ks[1], cfg.d_model, cfg.d_ff, cfg.n_experts, dtype
            )
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
        return p

    layers = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[one_layer(keys[i]) for i in range(cfg.layers)],
    )
    params = {
        "layers": layers,
        "final_norm": init_norm(cfg.d_model, cfg.norm, dtype),
    }
    if cfg.frontend == "audio":
        params["frontend"] = dense_init(
            keys[-1], cfg.frontend_dim, cfg.d_model, dtype
        )
    else:
        params["embed"] = (
            jax.random.normal(keys[-2], (vpad, cfg.d_model), jnp.float32)
            * 0.02
        ).astype(dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[-3], cfg.d_model, vpad, dtype)
    # per-layer structure flags (scanned alongside the stacked params)
    if cfg.block == "xlstm":
        params["is_slstm"] = (
            jnp.arange(cfg.layers) % 4 == 3
        ).astype(jnp.float32)
    if cfg.block == "hybrid":
        every = cfg.global_layer_every or cfg.layers + 1
        params["is_global"] = (
            (jnp.arange(cfg.layers) % every == 0)
        ).astype(jnp.float32)
    return params


def _pad_attention(p: dict, cfg: ArchConfig, dims: AttnDims) -> dict:
    """Zero the padded query-head slots so padding is function-preserving.

    Heads are padded PER KV GROUP: the padded layout is
    [kv_heads, padded_group, hd] with real weights in the first
    ``real_group`` slots of each group, so ``q_head // padded_group`` maps
    to the same kv head as the unpadded model.
    """
    if dims.heads == cfg.heads:
        return p
    pad_group = dims.heads // cfg.kv_heads
    real_group = cfg.heads // cfg.kv_heads
    head_idx = jnp.arange(dims.heads)
    real = (head_idx % pad_group) < real_group  # [H_pad]
    qmask = jnp.repeat(real, cfg.hd)            # over the H*hd output dim
    wq = p["wq"] * qmask[None, :].astype(p["wq"].dtype)
    wo = p["wo"] * qmask[:, None].astype(p["wo"].dtype)
    return dict(p, wq=wq, wo=wo)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# forward (training / scoring)
# ---------------------------------------------------------------------------


def _embed(cfg: ArchConfig, params: dict, batch: dict) -> jax.Array:
    if cfg.frontend == "audio":
        return batch["frames"].astype(_dtype(cfg)) @ params["frontend"]
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.frontend == "vision" and "patches" in batch:
        x = jnp.concatenate(
            [batch["patches"].astype(x.dtype), x], axis=1
        )
    return x


def _unembed(cfg: ArchConfig, params: dict, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["lm_head"]


def forward(
    cfg: ArchConfig,
    params: dict,
    batch: dict,
    *,
    backend: Optional[str] = None,
    remat: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward; returns (logits, aux_loss).

    ``remat=True`` checkpoints each scanned layer body (activation
    rematerialization): backward recomputes the layer instead of saving its
    internals — the standard memory/compute trade at scale."""
    x = _embed(cfg, params, batch)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None].repeat(b, axis=0)
    dims = _dims_from_params(cfg, params)

    def dense_layer(x, lp):
        h = apply_norm(x, lp["norm1"], cfg.norm)
        a, _ = attention_block(
            h, lp["attn"], dims, positions, causal=cfg.causal,
            rope_theta=cfg.rope_theta, backend=backend, cfg=cfg,
        )
        x = x + a
        h = apply_norm(x, lp["norm2"], cfg.norm)
        if cfg.block == "moe":
            m, aux = moe_block(
                h, lp["moe"], top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, backend=backend,
            )
        else:
            m, aux = mlp_block(h, lp["mlp"], backend=backend), 0.0
        return x + m, aux

    def xlstm_layer(x, lp, flag):
        h = apply_norm(x, lp["norm1"], cfg.norm)

        def do_m(h):
            y, _ = ssm.mlstm_seq(h, lp["mlstm"], cfg.heads)
            return y

        def do_s(h):
            y, _ = ssm.slstm_seq(h, lp["slstm"])
            return y

        y = jax.lax.cond(flag > 0.5, do_s, do_m, h)
        return x + y, 0.0

    def hybrid_layer(x, lp, flag):
        h = apply_norm(x, lp["norm1"], cfg.norm)
        y, _, _ = hy.hybrid_block_seq(
            h, lp["mix"], dims, positions, rope_theta=cfg.rope_theta,
            window=cfg.window, is_global=flag, backend=backend, cfg=cfg,
        )
        x = x + y
        h = apply_norm(x, lp["norm2"], cfg.norm)
        return x + mlp_block(h, lp["mlp"], backend=backend), 0.0

    aux_total = 0.0
    if cfg.block == "xlstm":
        def body(carry, xs):
            lp, flag = xs
            y, aux = xlstm_layer(carry, lp, flag)
            return y, aux
        if remat:
            body = jax.checkpoint(body)
        x, auxs = _scan(body, x, (params["layers"], params["is_slstm"]))
    elif cfg.block == "hybrid":
        def body(carry, xs):
            lp, flag = xs
            y, aux = hybrid_layer(carry, lp, flag)
            return y, aux
        if remat:
            body = jax.checkpoint(body)
        x, auxs = _scan(body, x, (params["layers"], params["is_global"]))
    else:
        def body(carry, lp):
            y, aux = dense_layer(carry, lp)
            return y, aux
        if remat:
            body = jax.checkpoint(body)
        x, auxs = _scan(body, x, params["layers"])
    aux_total = jnp.sum(jnp.asarray(auxs))

    x = apply_norm(x, params["final_norm"], cfg.norm)
    logits = _unembed(cfg, params, x)
    return logits, aux_total


def _dims_from_params(cfg: ArchConfig, params: dict) -> AttnDims:
    """Recover attention dims from the (possibly head-padded) weights."""
    if cfg.block == "xlstm":
        return attn_dims(cfg, 1)
    attn = params["layers"]["mix"]["attn"] if cfg.block == "hybrid" \
        else params["layers"]["attn"]
    return AttnDims(
        heads=attn["wq"].shape[-1] // cfg.hd,
        kv_heads=attn["wk"].shape[-1] // cfg.hd,
        hd=cfg.hd,
        d_model=cfg.d_model,
    )


def loss_fn(
    cfg: ArchConfig,
    params: dict,
    batch: dict,
    *,
    backend: Optional[str] = None,
    aux_weight: float = 0.01,
    remat: bool = False,
) -> tuple[jax.Array, dict]:
    logits, aux = forward(cfg, params, batch, backend=backend, remat=remat)
    labels = batch["labels"]
    if cfg.frontend == "vision" and logits.shape[1] != labels.shape[1]:
        logits = logits[:, -labels.shape[1]:]  # loss on text positions only
    logits = logits.astype(jnp.float32)
    vpad = logits.shape[-1]
    if vpad != cfg.vocab:  # mask padded vocab columns out of the softmax
        pad_mask = jnp.arange(vpad) >= cfg.vocab
        logits = jnp.where(pad_mask[None, None], -1e9, logits)
    if cfg.block == "encoder" or not cfg.causal:
        tgt = labels  # frame-level classification (no shift)
        lg = logits
    else:
        tgt = labels[:, 1:]
        lg = logits[:, :-1]
    logp = jax.nn.log_softmax(lg, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux, "ppl": jnp.exp(loss)}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def cache_spec(cfg: ArchConfig, batch: int, max_len: int, tp: int = 1):
    """ShapeDtypeStructs of the decode cache (used by launch.dryrun)."""
    dtype = _dtype(cfg)
    dims = attn_dims(cfg, tp)
    L = cfg.layers
    d = cfg.d_model
    if cfg.block == "xlstm":
        inner = 2 * d
        dh = inner // cfg.heads
        return {
            "C": jax.ShapeDtypeStruct((L, batch, cfg.heads, dh, dh),
                                      jnp.float32),
            "n": jax.ShapeDtypeStruct((L, batch, cfg.heads, dh), jnp.float32),
            "m": jax.ShapeDtypeStruct((L, batch, cfg.heads), jnp.float32),
            "sc": jax.ShapeDtypeStruct((L, batch, d), jnp.float32),
            "sn": jax.ShapeDtypeStruct((L, batch, d), jnp.float32),
            "sm": jax.ShapeDtypeStruct((L, batch, d), jnp.float32),
            "sh": jax.ShapeDtypeStruct((L, batch, d), jnp.float32),
        }
    r = _ring_len(cfg, max_len)
    spec = {
        "k": jax.ShapeDtypeStruct((L, batch, dims.kv_heads, r, cfg.hd),
                                  dtype),
        "v": jax.ShapeDtypeStruct((L, batch, dims.kv_heads, r, cfg.hd),
                                  dtype),
        "kv_pos": jax.ShapeDtypeStruct((L, batch, r), jnp.int32),
    }
    if cfg.block == "hybrid":
        inner = 2 * d
        spec["conv"] = jax.ShapeDtypeStruct(
            (L, batch, hy.CONV_K - 1, inner), jnp.float32
        )
        spec["h"] = jax.ShapeDtypeStruct(
            (L, batch, inner, cfg.ssm_state), jnp.float32
        )
    return spec


def _ring_len(cfg: ArchConfig, max_len: int) -> int:
    """Attention cache length: bounded by the window for very long contexts
    on windowed archs (DESIGN.md §6 — sub-quadratic serving)."""
    if cfg.window and max_len > 65536:
        return cfg.window
    return max_len


def init_cache(cfg: ArchConfig, batch: int, max_len: int, tp: int = 1):
    return jax.tree.map(
        lambda sd: jnp.full(sd.shape, -1, sd.dtype)
        if sd.dtype == jnp.int32 else jnp.zeros(sd.shape, sd.dtype),
        cache_spec(cfg, batch, max_len, tp),
    )


def prefill(
    cfg: ArchConfig,
    params: dict,
    batch: dict,
    max_len: int,
    *,
    lengths: Optional[jax.Array] = None,
    backend: Optional[str] = None,
):
    """Run the full prompt; returns (last-token logits, filled cache).

    ``lengths`` ([B] int32) enables ragged batched prefill over right-padded
    prompts: logits are taken at each row's last REAL token and cache
    positions at-or-beyond a row's length are marked invalid (kv_pos = -1),
    so later decode attends only to real tokens.  Padding is exact for
    attention caches (causal masking keeps pad tokens out of real rows);
    recurrent-state blocks (xlstm, hybrid SSM path) advance their state on
    every input token, so callers must pass equal-length rows (no padding)
    for those — the serve scheduler groups by exact length there.
    """
    assert cfg.has_decode
    x = _embed(cfg, params, batch)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None].repeat(b, axis=0)
    dims = _dims_from_params(cfg, params)
    r = _ring_len(cfg, max_len)

    def fit_ring(k):  # [B,Hkv,S,hd] -> [B,Hkv,r,hd] (keep the tail)
        if s >= r:
            return k[:, :, s - r:], jnp.arange(s - r, s, dtype=jnp.int32)
        pad = jnp.zeros(
            (k.shape[0], k.shape[1], r - s, k.shape[3]), k.dtype
        )
        pos = jnp.concatenate([
            jnp.arange(s, dtype=jnp.int32),
            jnp.full((r - s,), -1, jnp.int32),
        ])
        return jnp.concatenate([k, pad], axis=2), pos

    def ring_pos(kpos):  # [r] -> [B, r] with per-row length masking
        kvp = kpos[None].repeat(b, 0)
        if lengths is None:
            return kvp
        return jnp.where(kvp < lengths[:, None], kvp, -1)

    if cfg.block == "xlstm":
        def body(carry, xs):
            lp, flag = xs
            h = apply_norm(carry, lp["norm1"], cfg.norm)

            def do_m(op):
                y, st = ssm.mlstm_seq(op[0], op[1]["mlstm"], cfg.heads)
                sst = ssm.slstm_init_state(b, cfg.d_model)
                return y, st, sst

            def do_s(op):
                y, sst = ssm.slstm_seq(op[0], op[1]["slstm"])
                st = ssm.mlstm_init_state(b, cfg.d_model, cfg.heads)
                return y, st, sst

            y, mst, sst = jax.lax.cond(flag > 0.5, do_s, do_m, (h, lp))
            out = {
                "C": mst["C"], "n": mst["n"], "m": mst["m"],
                "sc": sst["c"], "sn": sst["n"], "sm": sst["m"],
                "sh": sst["h"],
            }
            return carry + y, out

        x, cache = _scan(body, x, (params["layers"], params["is_slstm"]))
    elif cfg.block == "hybrid":
        def body(carry, xs):
            lp, flag = xs
            h = apply_norm(carry, lp["norm1"], cfg.norm)
            y, (k, v), sst = hy.hybrid_block_seq(
                h, lp["mix"], dims, positions, rope_theta=cfg.rope_theta,
                window=cfg.window, is_global=flag, backend=backend, cfg=cfg,
            )
            x2 = carry + y
            h2 = apply_norm(x2, lp["norm2"], cfg.norm)
            x2 = x2 + mlp_block(h2, lp["mlp"], backend=backend)
            kr, kpos = fit_ring(k)
            vr, _ = fit_ring(v)
            out = {
                "k": kr, "v": vr,
                "kv_pos": ring_pos(kpos),
                "conv": sst["conv"], "h": sst["h"],
            }
            return x2, out

        x, cache = _scan(body, x, (params["layers"], params["is_global"]))
    else:
        def body(carry, lp):
            h = apply_norm(carry, lp["norm1"], cfg.norm)
            a, (k, v) = attention_block(
                h, lp["attn"], dims, positions, causal=cfg.causal,
                rope_theta=cfg.rope_theta, backend=backend, cfg=cfg,
            )
            x2 = carry + a
            h2 = apply_norm(x2, lp["norm2"], cfg.norm)
            if cfg.block == "moe":
                m, _ = moe_block(
                    h2, lp["moe"], top_k=cfg.top_k,
                    capacity_factor=cfg.capacity_factor, backend=backend,
                )
            else:
                m = mlp_block(h2, lp["mlp"], backend=backend)
            kr, kpos = fit_ring(k)
            vr, _ = fit_ring(v)
            out = {"k": kr, "v": vr, "kv_pos": ring_pos(kpos)}
            return x2 + m, out

        x, cache = _scan(body, x, params["layers"])

    x = apply_norm(x, params["final_norm"], cfg.norm)
    if lengths is None:
        logits = _unembed(cfg, params, x[:, -1:])
    else:
        last = jnp.clip(lengths - 1, 0, s - 1)
        logits = _unembed(cfg, params, x[jnp.arange(b), last][:, None])
    return logits, cache


def prefill_chunk(
    cfg: ArchConfig,
    params: dict,
    tokens: jax.Array,  # [B, C]: the next C prompt tokens of every row
    cache,
    start,              # int32: absolute position of tokens[:, 0] — a
                        # scalar shared by all rows, or a [B] vector for
                        # ragged multi-slot lanes (per-row progress)
    *,
    backend: Optional[str] = None,
):
    """One prefill chunk against an existing cache (chunked prefill).

    Processes ``C`` prompt tokens at positions ``start .. start+C-1``,
    attending to everything already in the cache plus (causally) the chunk
    itself, and writes the chunk's K/V at those cache positions.  Returns
    (last-chunk-token logits, cache) — the logits only matter on the final
    chunk of a prompt.

    A [B]-shaped ``start`` gives every batch row its own chunk offset, so
    the serving scheduler can advance several mid-prefill slots — each at
    a different point in its own prompt — in ONE jitted call (the batched
    chunked-prefill lane).  Rows are fully independent (per-row positions,
    per-row cache updates), so batching is bit-identical to B separate
    calls.

    Only stateless (attention-cache) blocks are supported: recurrent-state
    blocks would need their scan state carried between chunks, and MoE
    capacity-based token dropping depends on the tokens-per-dispatch count,
    so chunking would not be bit-identical to whole-prompt prefill there.
    Callers must ensure ``start + C <= ring length`` (serving keeps
    ``max_len`` under the ring threshold, so the ring never wraps).
    """
    x, cache = _chunk_forward(cfg, params, tokens, cache, start, backend)
    return _unembed(cfg, params, x[:, -1:]), cache


def _chunk_forward(cfg, params, tokens, cache, start, backend):
    """Shared body of ``prefill_chunk`` / ``verify_step``: run a [B, C]
    chunk at per-row (or shared) offsets against an existing cache and
    write its K/V; returns the final-norm hidden states [B, C, D] and the
    updated cache.  Callers choose which positions to unembed."""
    assert cfg.has_decode and cfg.block == "dense", \
        f"chunked prefill requires a stateless dense block, got {cfg.block}"
    x = _embed(cfg, params, {"tokens": tokens})
    b, c_len, _ = x.shape
    start = jnp.asarray(start, jnp.int32)
    per_row = start.ndim == 1
    if per_row:
        positions = start[:, None] + jnp.arange(c_len, dtype=jnp.int32)[None]
        qpos = positions                               # [B, C]
    else:
        qpos = start + jnp.arange(c_len, dtype=jnp.int32)   # [C]
        positions = qpos[None].repeat(b, axis=0)
    dims = _dims_from_params(cfg, params)

    def upd(leaf, vals, axis):
        if not per_row:
            return jax.lax.dynamic_update_slice_in_dim(
                leaf, vals, start, axis=axis
            )
        return jax.vmap(
            lambda row, val, s: jax.lax.dynamic_update_slice_in_dim(
                row, val, s, axis=axis - 1
            )
        )(leaf, vals, start)

    def body(carry, xs):
        lp, c = xs
        h = apply_norm(carry, lp["norm1"], cfg.norm)
        q, k_new, v_new = attention_qkv(
            h, lp["attn"], dims, positions, cfg.rope_theta
        )
        k = upd(c["k"], k_new, axis=2)
        v = upd(c["v"], v_new, axis=2)
        kv_pos = upd(c["kv_pos"], positions, axis=1)
        window = jnp.int32(cfg.window) if cfg.window else None
        o = _cached_attention(q, k, v, kv_pos, qpos, window)
        o = o.transpose(0, 2, 1, 3).reshape(b, c_len, dims.heads * dims.hd)
        x2 = carry + o @ lp["attn"]["wo"]
        h2 = apply_norm(x2, lp["norm2"], cfg.norm)
        m = mlp_block(h2, lp["mlp"], backend=backend)
        return x2 + m, {"k": k, "v": v, "kv_pos": kv_pos}

    x, cache = _scan(body, x, (params["layers"], cache))
    x = apply_norm(x, params["final_norm"], cfg.norm)
    return x, cache


def verify_step(
    cfg: ArchConfig,
    params: dict,
    tokens: jax.Array,  # [B, C]: committed token + the C-1 draft proposals
    cache,
    start,              # int32 scalar or [B]: absolute pos of tokens[:, 0]
    *,
    lengths: Optional[jax.Array] = None,
    backend: Optional[str] = None,
):
    """Multi-token verify step for speculative decoding.

    Runs one chunk-shaped forward over ``tokens`` at positions
    ``start .. start+C-1`` (per-row offsets supported, exactly like
    ``prefill_chunk``) and returns logits at EVERY chunk position
    ([B, C, V]) plus the updated cache: the target model scores a draft's
    k proposals — plus the already-committed token that seeds them — in
    ONE call, and position ``i``'s argmax decides the fate of draft token
    ``i`` (greedy acceptance keeps the longest matching prefix).

    The chunk's K/V is written exactly as ``prefill_chunk`` would write
    it.  Per-token K/V is a function of (token, absolute position) only
    — RoPE phases come from ``positions`` — so an accepted token's cache
    entry is bit-identical to the one single-token ``decode_step`` would
    have produced; this is the equivalence the speculative engine's
    greedy == dense guarantee rests on.

    ``lengths`` ([B] int32, optional) applies per-row accepted-length
    masking to the returned cache: row ``b`` keeps only its first
    ``lengths[b]`` chunk tokens (cache positions at or beyond
    ``start[b] + lengths[b]`` reset to kv_pos = -1).  Dense-cache callers
    use it to reject a per-row suffix in place; the paged serving engine
    instead routes rejected writes to the pool's TRASH page at scatter
    time (``kvcache.scatter_tokens``) and never mutates shared state.
    """
    x, cache = _chunk_forward(cfg, params, tokens, cache, start, backend)
    logits = _unembed(cfg, params, x)
    if lengths is not None:
        bound = jnp.asarray(start, jnp.int32) \
            + jnp.asarray(lengths, jnp.int32)        # [B] (or scalar)
        bound = jnp.broadcast_to(bound, (tokens.shape[0],))
        kvp = cache["kv_pos"]                        # [L, B, r]
        cache = dict(
            cache,
            kv_pos=jnp.where(kvp >= bound[None, :, None], -1, kvp),
        )
    return logits, cache


def decode_step(
    cfg: ArchConfig,
    params: dict,
    tokens: jax.Array,  # [B, 1]
    cache,
    pos,                # scalar int32: absolute position of the new token
    *,
    backend: Optional[str] = None,
):
    """One token through all layers, updating the cache in place."""
    assert cfg.has_decode
    batch = {"tokens": tokens}
    x = _embed(cfg, params, batch)
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    dims = _dims_from_params(cfg, params)

    if cfg.block == "xlstm":
        def body(carry, xs):
            lp, flag, c = xs
            h = apply_norm(carry, lp["norm1"], cfg.norm)
            mst = {"C": c["C"], "n": c["n"], "m": c["m"]}
            sst = {"c": c["sc"], "n": c["sn"], "m": c["sm"], "h": c["sh"]}

            def do_m(op):
                y, st = ssm.mlstm_step(op[0], op[1]["mlstm"], cfg.heads, mst)
                return y, st, sst

            def do_s(op):
                y, s2 = ssm.slstm_step(op[0], op[1]["slstm"], sst)
                return y, mst, s2

            y, mst2, sst2 = jax.lax.cond(flag > 0.5, do_s, do_m, (h, lp))
            out = {
                "C": mst2["C"], "n": mst2["n"], "m": mst2["m"],
                "sc": sst2["c"], "sn": sst2["n"], "sm": sst2["m"],
                "sh": sst2["h"],
            }
            return carry + y, out

        x, cache = _scan(body, x, (params["layers"], params["is_slstm"], cache))
        x = apply_norm(x, params["final_norm"], cfg.norm)
        return _unembed(cfg, params, x), cache

    r = cache["k"].shape[3]
    slot = pos % r

    def attend_with_cache(h, lp_attn, c, window, is_global=None):
        q, k_new, v_new = attention_qkv(
            h, lp_attn, dims, positions, cfg.rope_theta
        )
        k = jax.lax.dynamic_update_slice_in_dim(c["k"], k_new, slot, axis=2)
        v = jax.lax.dynamic_update_slice_in_dim(c["v"], v_new, slot, axis=2)
        kv_pos = jax.lax.dynamic_update_slice_in_dim(
            c["kv_pos"], jnp.full((b, 1), pos, jnp.int32), slot, axis=1
        )
        eff_window = None
        if window:
            eff_window = jnp.int32(window)
            if is_global is not None:
                eff_window = jnp.where(
                    is_global, jnp.int32(2**30), eff_window
                )
        o = _cached_attention(q, k, v, kv_pos, pos, eff_window)
        o = o.reshape(b, 1, dims.heads * dims.hd)
        return o @ lp_attn["wo"], {"k": k, "v": v, "kv_pos": kv_pos}

    if cfg.block == "hybrid":
        def body(carry, xs):
            lp, flag, c = xs
            h = apply_norm(carry, lp["norm1"], cfg.norm)
            a, kv = attend_with_cache(
                h, lp["mix"]["attn"], c, cfg.window, is_global=flag
            )
            sst = {"conv": c["conv"], "h": c["h"]}
            sout, sst2 = hy.ssm_path_step(h, lp["mix"]["ssm"], sst)
            x2 = carry + 0.5 * (a + sout)
            h2 = apply_norm(x2, lp["norm2"], cfg.norm)
            x2 = x2 + mlp_block(h2, lp["mlp"], backend=backend)
            out = dict(kv, conv=sst2["conv"], h=sst2["h"])
            return x2, out

        x, cache = _scan(body, x, (params["layers"], params["is_global"], cache))
    else:
        def body(carry, xs):
            lp, c = xs
            h = apply_norm(carry, lp["norm1"], cfg.norm)
            a, kv = attend_with_cache(h, lp["attn"], c, cfg.window or None)
            x2 = carry + a
            h2 = apply_norm(x2, lp["norm2"], cfg.norm)
            if cfg.block == "moe":
                m, _ = moe_block(
                    h2, lp["moe"], top_k=cfg.top_k,
                    capacity_factor=cfg.capacity_factor, backend=backend,
                )
            else:
                m = mlp_block(h2, lp["mlp"], backend=backend)
            return x2 + m, kv

        x, cache = _scan(body, x, (params["layers"], cache))

    x = apply_norm(x, params["final_norm"], cfg.norm)
    return _unembed(cfg, params, x), cache


def _cached_attention(q, k, v, kv_pos, qpos, window):
    """GQA attention over a (ring) cache with validity masking.

    ``q`` is [B, Hq, C, hd] (C = 1 for single-token decode, > 1 for a
    prefill chunk); ``qpos`` the absolute position(s) of the C query
    tokens — a scalar, a [C] vector shared by all rows, or a [B, C]
    matrix (ragged chunk lanes: every row at its own offset).  Cache
    entries are valid when ``0 <= kv_pos <= qpos`` (per query), i.e.
    causal within the chunk.
    """
    b, hq, c, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    qpos = jnp.asarray(qpos, jnp.int32)
    if qpos.ndim == 0:
        qpos = qpos[None]
    qp = qpos[:, :, None] if qpos.ndim == 2 else qpos[None, :, None]
    qg = q.reshape(b, hkv, group, c, d).astype(jnp.float32) / math.sqrt(d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32))
    kp = kv_pos[:, None, :]                       # [B, 1, r]
    valid = (kp >= 0) & (kp <= qp)                # [B, C, r]
    if window is not None:
        valid &= kp > qp - window
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, c, d).astype(q.dtype)
