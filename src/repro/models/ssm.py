"""xLSTM blocks: chunkwise-parallel mLSTM + recurrent sLSTM [arXiv:2405.04517].

Hardware adaptation (DESIGN.md §3): the mLSTM's matrix-memory recurrence is
computed in its *chunkwise-parallel* form — within a chunk the interactions
are dense GEMMs (MXU-friendly), and only the O(S/chunk) inter-chunk state is
sequential (lax.scan).  The sLSTM has no parallel form (its recurrence is
input-dependent elementwise, a published property of the architecture), so
it scans per timestep; the assigned xlstm-125m config places it on every 4th
layer.

Both blocks expose (sequence, single-step) entry points so training/prefill
and decode share parameters; decode state is O(1) in context length, which
is what makes the ``long_500k`` cell servable.

Numerical contract: tests/test_xlstm.py checks the chunkwise mLSTM against
the naive per-step recurrence oracle to float tolerance.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import dense_init

# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, d: int, heads: int, dtype) -> dict:
    inner = 2 * d
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], d, inner, dtype),
        "w_gate": dense_init(ks[1], d, inner, dtype),
        "wq": dense_init(ks[2], inner, inner, dtype),
        "wk": dense_init(ks[3], inner, inner, dtype),
        "wv": dense_init(ks[4], inner, inner, dtype),
        "w_if": dense_init(ks[5], inner, 2 * heads, jnp.float32),
        "b_if": jnp.concatenate(
            [jnp.zeros((heads,)), 3.0 + jnp.arange(heads, dtype=jnp.float32)]
        ),
        "w_down": dense_init(ks[6], inner, d, dtype, scale=0.5),
    }


def mlstm_init_state(batch: int, d: int, heads: int) -> dict:
    inner = 2 * d
    dh = inner // heads
    return {
        "C": jnp.zeros((batch, heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, heads, dh), jnp.float32),
        "m": jnp.full((batch, heads), -1e30, jnp.float32),
    }


def _mlstm_qkv_gates(x, p, heads):
    b, s, _ = x.shape
    inner = p["w_up"].shape[1]
    dh = inner // heads
    xi = x @ p["w_up"]                       # [B,S,inner]
    z = jax.nn.silu((x @ p["w_gate"]).astype(jnp.float32))
    def split(h):
        return h.reshape(b, s, heads, dh).transpose(0, 2, 1, 3)
    q = split(xi @ p["wq"]) / math.sqrt(dh)
    k = split(xi @ p["wk"]) / math.sqrt(dh)
    v = split(xi @ p["wv"])
    gates = xi.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    li = gates[..., :heads].transpose(0, 2, 1)            # [B,H,S] log-i
    lf = jax.nn.log_sigmoid(gates[..., heads:]).transpose(0, 2, 1)
    return q, k, v, li, lf, z


def mlstm_seq(
    x: jax.Array,  # [B, S, D]
    p: dict,
    heads: int,
    state: Optional[dict] = None,
    chunk: int = 256,
) -> tuple[jax.Array, dict]:
    """Chunkwise-parallel mLSTM over a sequence; returns (y, final_state)."""
    b, s, d = x.shape
    if state is None:
        state = mlstm_init_state(b, d, heads)
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s
    nch = s // chunk
    q, k, v, li, lf, z = _mlstm_qkv_gates(x, p, heads)
    dh = q.shape[-1]

    def resh(t):  # [B,H,S,...] -> [nch, B,H,chunk,...]
        return jnp.moveaxis(
            t.reshape(t.shape[0], t.shape[1], nch, chunk, *t.shape[3:]), 2, 0
        )

    qc, kc, vc, lic, lfc = map(resh, (q, k, v, li, lf))

    def chunk_step(carry, xs):
        C, n, m = carry  # [B,H,dh,dh], [B,H,dh], [B,H]
        qq, kk, vv, ll, ff = xs  # [B,H,c,...]
        qq = qq.astype(jnp.float32)
        kk = kk.astype(jnp.float32)
        vv = vv.astype(jnp.float32)
        bcum = jnp.cumsum(ff, axis=-1)               # [B,H,c] inclusive
        total = bcum[..., -1:]                       # [B,H,1]
        g = ll - bcum                                # li_s - b_s
        # intra stabilizer: m_intra[t] = b_t + cummax_{s<=t}(g_s)
        m_intra = bcum + jax.lax.cummax(g, axis=g.ndim - 1)
        m_inter = m[..., None] + bcum
        m_t = jnp.maximum(m_intra, m_inter)          # [B,H,c]
        # decay matrix D[t,s] = exp(b_t - b_s + li_s - m_t), s <= t
        Dlog = bcum[..., :, None] + g[..., None, :] - m_t[..., None]
        c = qq.shape[2]
        tril = jnp.tril(jnp.ones((c, c), bool))
        D = jnp.where(tril, jnp.exp(Dlog), 0.0)      # [B,H,c,c]
        S_mat = jnp.einsum("bhtd,bhsd->bhts", qq, kk) * D
        intra = jnp.einsum("bhts,bhsd->bhtd", S_mat, vv)
        inter_scale = jnp.exp(m[..., None] + bcum - m_t)[..., None]
        inter = jnp.einsum("bhtd,bhde->bhte", qq, C) * inter_scale
        num = intra + inter
        denom = jnp.einsum("bhts->bht", S_mat) + \
            jnp.einsum("bhtd,bhd->bht", qq, n) * inter_scale[..., 0]
        h = num / jnp.maximum(
            jnp.abs(denom), jnp.exp(-m_t)
        )[..., None]                                  # [B,H,c,dh]
        # state update to end of chunk
        m_new = jnp.maximum(m + total[..., 0],
                            jnp.max(ll + total - bcum, axis=-1))
        sc = jnp.exp(ll + total - bcum - m_new[..., None])  # [B,H,c]
        C_new = jnp.exp(m + total[..., 0] - m_new)[..., None, None] * C + \
            jnp.einsum("bhs,bhsd,bhse->bhde", sc, kk, vv)
        n_new = jnp.exp(m + total[..., 0] - m_new)[..., None] * n + \
            jnp.einsum("bhs,bhsd->bhd", sc, kk)
        return (C_new, n_new, m_new), h

    (C, n, m), hs = jax.lax.scan(
        chunk_step, (state["C"], state["n"], state["m"]),
        (qc, kc, vc, lic, lfc),
    )
    h = jnp.moveaxis(hs, 0, 2)  # [B,H,nch,c,dh]
    h = h.reshape(b, heads, s, dh).transpose(0, 2, 1, 3).reshape(b, s, -1)
    y = ((h * z).astype(x.dtype)) @ p["w_down"]
    return y, {"C": C, "n": n, "m": m}


def mlstm_step(
    x_t: jax.Array,  # [B, 1, D]
    p: dict,
    heads: int,
    state: dict,
) -> tuple[jax.Array, dict]:
    """Single-token recurrent update (decode)."""
    q, k, v, li, lf, z = _mlstm_qkv_gates(x_t, p, heads)
    q = q[:, :, 0].astype(jnp.float32)   # [B,H,dh]
    k = k[:, :, 0].astype(jnp.float32)
    v = v[:, :, 0].astype(jnp.float32)
    li = li[..., 0]                      # [B,H]
    lf = lf[..., 0]
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    fs = jnp.exp(lf + m - m_new)[..., None]
    is_ = jnp.exp(li - m_new)[..., None]
    C_new = fs[..., None] * C + is_[..., None] * k[..., :, None] \
        * v[..., None, :]
    n_new = fs * n + is_ * k
    num = jnp.einsum("bhd,bhde->bhe", q, C_new)
    denom = jnp.einsum("bhd,bhd->bh", q, n_new)
    h = num / jnp.maximum(jnp.abs(denom), jnp.exp(-m_new))[..., None]
    b = x_t.shape[0]
    h = h.reshape(b, 1, -1)
    y = ((h * z).astype(x_t.dtype)) @ p["w_down"]
    return y, {"C": C_new, "n": n_new, "m": m_new}


def mlstm_seq_naive(x, p, heads, state=None):
    """Per-timestep oracle for the chunkwise form (tests only)."""
    b, s, d = x.shape
    if state is None:
        state = mlstm_init_state(b, d, heads)
    ys = []
    for t in range(s):
        y, state = mlstm_step(x[:, t:t + 1], p, heads, state)
        ys.append(y)
    return jnp.concatenate(ys, axis=1), state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, d: int, heads: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gates": dense_init(ks[0], d, 4 * d, dtype),
        "r_gates": dense_init(ks[1], d, 4 * d, dtype, scale=0.3),
        "b_gates": jnp.concatenate(
            [jnp.zeros((2 * d,)), jnp.ones((d,)), jnp.zeros((d,))]
        ).astype(jnp.float32),
        "w_out": dense_init(ks[2], d, d, dtype, scale=0.5),
    }


def slstm_init_state(batch: int, d: int) -> dict:
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_cell(p, d, x_t, st):
    """x_t [B,D] float32; one recurrence step (exp-gated, stabilized)."""
    pre = x_t @ p["w_gates"].astype(jnp.float32) \
        + st["h"] @ p["r_gates"].astype(jnp.float32) + p["b_gates"]
    li, lf_pre, z_pre, o_pre = jnp.split(pre, 4, axis=-1)
    lf = jax.nn.log_sigmoid(lf_pre)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    m_new = jnp.maximum(lf + st["m"], li)
    fs = jnp.exp(lf + st["m"] - m_new)
    is_ = jnp.exp(li - m_new)
    c_new = fs * st["c"] + is_ * z
    n_new = fs * st["n"] + is_
    h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return {"c": c_new, "n": n_new, "m": m_new, "h": h_new}


def slstm_seq(
    x: jax.Array, p: dict, state: Optional[dict] = None
) -> tuple[jax.Array, dict]:
    b, s, d = x.shape
    if state is None:
        state = slstm_init_state(b, d)

    def step(st, x_t):
        st = _slstm_cell(p, d, x_t, st)
        return st, st["h"]

    state, hs = jax.lax.scan(
        step, state, x.astype(jnp.float32).transpose(1, 0, 2)
    )
    h = hs.transpose(1, 0, 2).astype(x.dtype)
    return h @ p["w_out"], state


def slstm_step(
    x_t: jax.Array, p: dict, state: dict
) -> tuple[jax.Array, dict]:
    b, _, d = x_t.shape
    st = _slstm_cell(p, d, x_t[:, 0].astype(jnp.float32), state)
    return (st["h"][:, None].astype(x_t.dtype)) @ p["w_out"], st
