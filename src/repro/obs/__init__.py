"""``repro.obs`` — zero-dependency tracing + metrics substrate.

Shared by the serving engine (``repro.serve``) and the compiler session
(``repro.compiler``): ``Tracer`` records nestable spans and instant
events into a bounded ring (exportable as Chrome trace-event JSON or
JSONL), ``Histogram``/``percentile`` give exact-at-small-n latency
percentiles, and ``export`` renders Prometheus text or a versioned JSON
snapshot.  Every instrumentation site defaults to the disabled
``NULL_TRACER``, so un-traced runs pay (measured, gated) near-zero
overhead — see EXPERIMENTS.md §Observability.
"""
from .hist import Histogram, HistSummary, percentile
from .trace import MAIN_TRACK, NULL_TRACER, TraceEvent, Tracer
from .export import SCHEMA, prometheus_text, snapshot

__all__ = [
    "Histogram",
    "HistSummary",
    "percentile",
    "MAIN_TRACK",
    "NULL_TRACER",
    "TraceEvent",
    "Tracer",
    "SCHEMA",
    "prometheus_text",
    "snapshot",
]
