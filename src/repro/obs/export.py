"""Metric export: Prometheus text exposition + a stable JSON snapshot.

Two consumers, one source of truth:

  * ``prometheus_text(counters, histograms)`` renders the standard text
    exposition format a scrape endpoint would serve — counters as
    ``# TYPE <name> counter`` singletons, histograms as cumulative
    ``_bucket{le=...}`` series with ``_sum``/``_count``, so the serving
    engine's telemetry drops straight into any Prometheus/Grafana stack.
  * ``snapshot(counters, histograms, meta=...)`` is the machine-readable
    JSON schema (``SCHEMA`` stamps the version) that benchmark artifacts
    and tests consume; histogram entries carry count/sum/min/max and the
    p50/p90/p99 from ``obs.hist`` (exact at small n).

Metric names are sanitized to Prometheus conventions (``[a-zA-Z0-9_]``,
no leading digit); the snapshot keeps the original names.
"""
from __future__ import annotations

import re
from typing import Mapping, Optional

from .hist import Histogram

SCHEMA = "repro.obs/v1"

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _prom_name(name: str, prefix: str) -> str:
    out = _NAME_RE.sub("_", prefix + name)
    return "_" + out if out[:1].isdigit() else out


def prometheus_text(
    counters: Mapping[str, float],
    histograms: Optional[Mapping[str, Histogram]] = None,
    *,
    prefix: str = "repro_",
) -> str:
    """The ``/metrics`` exposition body for one scrape."""
    lines: list[str] = []
    for name in sorted(counters):
        pn = _prom_name(name, prefix)
        val = counters[name]
        kind = "gauge" if isinstance(val, float) else "counter"
        lines.append(f"# TYPE {pn} {kind}")
        lines.append(f"{pn} {val}")
    for name in sorted(histograms or {}):
        h = histograms[name]
        pn = _prom_name(name, prefix)
        lines.append(f"# TYPE {pn} histogram")
        cum = 0
        for upper, count in h.nonzero_buckets():
            cum += count
            lines.append(f'{pn}_bucket{{le="{upper:.6g}"}} {cum}')
        lines.append(f'{pn}_bucket{{le="+Inf"}} {h.count}')
        lines.append(f"{pn}_sum {h.total}")
        lines.append(f"{pn}_count {h.count}")
    return "\n".join(lines) + "\n"


def snapshot(
    counters: Mapping[str, float],
    histograms: Optional[Mapping[str, Histogram]] = None,
    *,
    meta: Optional[dict] = None,
) -> dict:
    """Versioned JSON-ready snapshot: the stable schema trace/bench
    artifacts embed (EXPERIMENTS.md §Observability documents the
    fields)."""
    hists = {}
    for name, h in (histograms or {}).items():
        s = h.summary()
        hists[name] = {
            "count": s.count, "sum": s.total,
            "min": s.min, "max": s.max, "mean": s.mean,
            "p50": s.p50, "p90": s.p90, "p99": s.p99,
            "buckets": [[upper, count]
                        for upper, count in h.nonzero_buckets()],
        }
    out = {
        "schema": SCHEMA,
        "counters": dict(counters),
        "histograms": hists,
    }
    if meta:
        out["meta"] = dict(meta)
    return out
