"""Log-bucketed histograms with exact small-n percentiles.

The metrics layer needs percentiles twice over:

  * **One-shot lists** (a benchmark's collected TTFTs): ``percentile(xs,
    q)`` — the exact linear-interpolated quantile (numpy's default
    method, computed here without numpy so the obs package stays
    dependency-free).  This replaces the old nearest-rank ``_p50``/
    ``_p99`` helpers in ``serve.metrics``, which were biased high: for
    n=4 the old p50 returned the 3rd order statistic instead of the
    median.
  * **Streaming observation** (per-dispatch prefill/decode wall times on
    a long-running engine): ``Histogram`` keeps log-spaced buckets
    (constant relative error ``growth − 1`` per bucket) plus the exact
    samples up to ``exact_n``.  Percentile queries are *exact* while the
    sample count is small — which is every CI run and most tests — and
    degrade gracefully to bucket interpolation after, with bounded
    memory forever.

Histograms export as Prometheus cumulative buckets and as a JSON summary
(``obs.export``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional, Sequence


def percentile(xs: Sequence[float], q: float) -> float:
    """Exact q-th percentile (0..100) with linear interpolation between
    closest ranks; 0.0 on an empty sequence (the metrics-summary
    convention)."""
    if not xs:
        return 0.0
    assert 0.0 <= q <= 100.0, q
    xs = sorted(xs)
    if len(xs) == 1:
        return float(xs[0])
    rank = (q / 100.0) * (len(xs) - 1)
    lo = int(math.floor(rank))
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return float(xs[lo] + (xs[hi] - xs[lo]) * frac)


@dataclasses.dataclass
class HistSummary:
    """The stable summary shape ``obs.export.snapshot`` serializes."""

    count: int
    total: float
    min: float
    max: float
    p50: float
    p90: float
    p99: float

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class Histogram:
    """Log-bucketed streaming histogram.

    Buckets cover ``(lowest * growth**i, lowest * growth**(i+1)]``;
    values at or below ``lowest`` land in bucket 0, so zero and negative
    observations are representable (they clamp to the first edge).  The
    default ``growth=1.25`` bounds in-bucket interpolation error to ~12%
    relative — plenty for latency work where the interesting signal is
    order-of-magnitude — while ``exact_n`` raw samples keep small-n
    percentiles *exact* (CI smokes observe tens of dispatches, not
    millions)."""

    def __init__(self, *, lowest: float = 1e-7, growth: float = 1.25,
                 max_buckets: int = 128, exact_n: int = 1024):
        assert growth > 1.0 and lowest > 0.0
        self.lowest = lowest
        self.growth = growth
        self._log_g = math.log(growth)
        self.max_buckets = max_buckets
        self.exact_n = exact_n
        self.counts = [0] * max_buckets
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._exact: Optional[list[float]] = []

    # -- recording ----------------------------------------------------------
    def _bucket(self, x: float) -> int:
        if x <= self.lowest:
            return 0
        i = int(math.log(x / self.lowest) / self._log_g) + 1
        return min(i, self.max_buckets - 1)

    def observe(self, x: float) -> None:
        x = float(x)
        self.counts[self._bucket(x)] += 1
        self.count += 1
        self.total += x
        self.min = min(self.min, x)
        self.max = max(self.max, x)
        if self._exact is not None:
            self._exact.append(x)
            if len(self._exact) > self.exact_n:
                self._exact = None      # cap crossed: buckets take over

    def observe_many(self, xs: Iterable[float]) -> None:
        for x in xs:
            self.observe(x)

    @classmethod
    def from_values(cls, xs: Iterable[float], **kwargs) -> "Histogram":
        h = cls(**kwargs)
        h.observe_many(xs)
        return h

    # -- queries ------------------------------------------------------------
    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def bucket_upper(self, i: int) -> float:
        """Inclusive upper edge of bucket ``i``."""
        return self.lowest * self.growth ** i

    def percentile(self, q: float) -> float:
        """Exact while the sample cap holds; otherwise interpolated
        within the covering log bucket (clamped to observed min/max)."""
        if self.count == 0:
            return 0.0
        if self._exact is not None:
            return percentile(self._exact, q)
        target = (q / 100.0) * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= target:
                lo = self.lowest * self.growth ** (i - 1) if i else 0.0
                hi = self.bucket_upper(i)
                frac = (target - seen) / c
                val = lo + (hi - lo) * frac
                return float(min(max(val, self.min), self.max))
            seen += c
        return float(self.max)

    def summary(self) -> HistSummary:
        return HistSummary(
            count=self.count, total=self.total,
            min=self.min if self.count else 0.0,
            max=self.max if self.count else 0.0,
            p50=self.percentile(50), p90=self.percentile(90),
            p99=self.percentile(99),
        )

    def nonzero_buckets(self) -> list[tuple[float, int]]:
        """(upper_edge, count) for occupied buckets — the Prometheus
        exposition and JSON snapshot read these."""
        return [(self.bucket_upper(i), c)
                for i, c in enumerate(self.counts) if c]
