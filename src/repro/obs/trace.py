"""Tracing substrate: nestable spans + instant events on named tracks.

One ``Tracer`` per process-level run (an engine, a compiler session, a
benchmark).  The design constraints, in order:

  * **Near-zero cost when off.**  Every instrumentation site goes through
    a tracer; the module-level ``NULL_TRACER`` is permanently disabled and
    its ``span``/``instant``/``begin``/``end`` are constant-time no-ops,
    so un-traced runs pay one attribute check per site.  The traced-vs-
    untraced overhead bound is measured and gated in
    ``benchmarks/bench_serving.py`` (EXPERIMENTS.md §Observability).
  * **Bounded memory.**  Events land in a ring buffer (``capacity``,
    oldest dropped first, ``dropped`` counts the loss) — a serving engine
    can trace indefinitely without growing without bound.
  * **Deterministic tests.**  The clock is injectable, exactly like
    ``serve.metrics.EngineMetrics``.
  * **Standard formats out.**  ``export_chrome`` writes the Chrome
    trace-event JSON (open in ``chrome://tracing`` / Perfetto: one row
    per track, spans nest by time containment), ``export_jsonl`` one
    event per line for ad-hoc ``jq``/pandas analysis; ``write`` picks by
    file suffix.

Spans nest per thread (a thread-local stack supplies the implicit
``track``), and an explicit ``track="slot3"`` pins an event to a named
timeline row — the serving engine uses per-slot tracks so a trace renders
as the classic per-slot request Gantt chart.  ``begin``/``end`` cover
spans whose start and end live in different call frames (one request's
admit → finish lifetime).
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from collections import deque
from typing import Callable, Optional

# Default ring capacity: ~64k events is hours of engine steps, and a few
# MB of host memory at most.
DEFAULT_CAPACITY = 1 << 16

MAIN_TRACK = "main"


@dataclasses.dataclass
class TraceEvent:
    """One trace event.  ``ph`` follows the Chrome trace-event phases:
    "X" complete span (ts + dur), "B"/"E" begin/end pair, "i" instant."""

    __slots__ = ("name", "cat", "ph", "ts", "dur", "track", "args")

    name: str
    cat: str
    ph: str
    ts: float                    # seconds on the tracer clock
    dur: float                   # seconds ("X" only; else 0.0)
    track: str
    args: Optional[dict]


class _Span:
    """Context manager for one "X" span.  ``set(**kw)`` merges result
    fields into the span's args before it is recorded (the span is
    appended at *exit*, so late fields — a measured latency, an accepted
    count — land on the same event)."""

    __slots__ = ("_tracer", "name", "cat", "track", "args", "_t0")

    def __init__(self, tracer, name, cat, track, args):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.track = track
        self.args = args

    def set(self, **kwargs) -> "_Span":
        if self.args is None:
            self.args = {}
        self.args.update(kwargs)
        return self

    def __enter__(self) -> "_Span":
        t = self._tracer
        self._t0 = t.clock()
        stack = t._stack()
        if self.track is None:
            self.track = stack[-1] if stack else MAIN_TRACK
        stack.append(self.track)
        return self

    def __exit__(self, *exc) -> None:
        t = self._tracer
        t1 = t.clock()
        t._stack().pop()
        t._append(TraceEvent(self.name, self.cat, "X", self._t0,
                             t1 - self._t0, self.track, self.args))


class _NullSpan:
    """The disabled tracer's span: one shared instance, no clock reads."""

    __slots__ = ()

    def set(self, **kwargs) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Tracer:
    """Thread-safe bounded event recorder with span/instant primitives."""

    def __init__(
        self,
        clock: Callable[[], float] = time.perf_counter,
        *,
        capacity: int = DEFAULT_CAPACITY,
        enabled: bool = True,
    ):
        assert capacity >= 1
        self.clock = clock
        self.capacity = capacity
        self.enabled = enabled
        self.dropped = 0
        self._buf: deque[TraceEvent] = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._local = threading.local()

    # -- recording ----------------------------------------------------------
    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _append(self, ev: TraceEvent) -> None:
        with self._lock:
            if len(self._buf) == self.capacity:
                self.dropped += 1
            self._buf.append(ev)

    def span(self, name: str, cat: str = "", track: Optional[str] = None,
             **args):
        """``with tracer.span("prefill", rows=4) as sp: ...`` — records one
        "X" event spanning the block.  Nested spans inherit the enclosing
        span's track unless ``track`` pins one explicitly."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, track, args or None)

    def instant(self, name: str, cat: str = "",
                track: Optional[str] = None, **args) -> None:
        """One zero-duration "i" event (page alloc/free, COW copy, ...)."""
        if not self.enabled:
            return
        if track is None:
            stack = self._stack()
            track = stack[-1] if stack else MAIN_TRACK
        self._append(TraceEvent(name, cat, "i", self.clock(), 0.0, track,
                                args or None))

    def begin(self, name: str, cat: str = "",
              track: Optional[str] = None, **args) -> None:
        """Open a long-lived span whose end happens in another call frame
        (e.g. one request's admit → finish).  Pair with ``end(name,
        track=...)``; Chrome matches "B"/"E" by name within a track."""
        if not self.enabled:
            return
        self._append(TraceEvent(name, cat, "B", self.clock(), 0.0,
                                track or MAIN_TRACK, args or None))

    def end(self, name: str, cat: str = "",
            track: Optional[str] = None, **args) -> None:
        if not self.enabled:
            return
        self._append(TraceEvent(name, cat, "E", self.clock(), 0.0,
                                track or MAIN_TRACK, args or None))

    # -- inspection ---------------------------------------------------------
    def events(self) -> list[TraceEvent]:
        """Snapshot of the ring (oldest first)."""
        with self._lock:
            return list(self._buf)

    def spans(self, name: Optional[str] = None) -> list[TraceEvent]:
        """Completed "X" spans, optionally filtered by name."""
        return [e for e in self.events()
                if e.ph == "X" and (name is None or e.name == name)]

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self.dropped = 0

    # -- export -------------------------------------------------------------
    def _track_ids(self, events) -> dict[str, int]:
        """Stable track → tid mapping: main first, then first-seen order
        (per-slot tracks therefore render in admission order)."""
        ids: dict[str, int] = {MAIN_TRACK: 0}
        for e in events:
            if e.track not in ids:
                ids[e.track] = len(ids)
        return ids

    def chrome_trace(self) -> dict:
        """The Chrome trace-event JSON object (``chrome://tracing`` /
        Perfetto).  Timestamps convert to microseconds; per-track
        metadata events name the rows."""
        events = self.events()
        tids = self._track_ids(events)
        out = [
            {"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
             "args": {"name": track}}
            for track, tid in tids.items()
        ]
        for e in events:
            rec = {
                "name": e.name, "cat": e.cat or "default", "ph": e.ph,
                "ts": e.ts * 1e6, "pid": 0, "tid": tids[e.track],
            }
            if e.ph == "X":
                rec["dur"] = e.dur * 1e6
            if e.ph == "i":
                rec["s"] = "t"           # thread-scoped instant
            if e.args:
                rec["args"] = e.args
            out.append(rec)
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def export_chrome(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
            f.write("\n")
        return path

    def export_jsonl(self, path: str) -> str:
        """One event per line: ``{"name", "cat", "ph", "ts", "dur",
        "track", "args"}`` with times in seconds."""
        with open(path, "w") as f:
            for e in self.events():
                f.write(json.dumps({
                    "name": e.name, "cat": e.cat, "ph": e.ph,
                    "ts": e.ts, "dur": e.dur, "track": e.track,
                    "args": e.args or {},
                }) + "\n")
        return path

    def write(self, path: str) -> str:
        """Suffix-dispatched export: ``*.jsonl`` → JSONL, anything else
        (canonically ``*.trace.json``) → Chrome trace format."""
        if path.endswith(".jsonl"):
            return self.export_jsonl(path)
        return self.export_chrome(path)


# The shared disabled tracer: instrumentation sites default to this, so
# construction-time ``tracer or NULL_TRACER`` is the whole integration.
NULL_TRACER = Tracer(enabled=False, capacity=1)
