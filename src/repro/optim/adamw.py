"""AdamW + schedules + gradient utilities (pure-pytree, sharding-aware).

Includes the distributed-optimization tricks the runtime uses:
  * fp32 master moments over bf16 params,
  * global-norm clipping,
  * cosine schedule with linear warmup,
  * gradient accumulation (lax.scan over microbatches — XLA overlaps the DP
    all-reduce of microbatch i with the compute of i+1 under donation),
  * optional int8 gradient compression applied per-microbatch before
    accumulation (bandwidth/memory reduction on the DP axis).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig) -> Callable:
    def lr_at(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, step / max(1, cfg.warmup_steps))
        prog = jnp.clip(
            (step - cfg.warmup_steps)
            / max(1, cfg.total_steps - cfg.warmup_steps),
            0.0, 1.0,
        )
        cos = 0.5 * (1.0 + jnp.cos(math.pi * prog))
        frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
        return cfg.lr * warm * frac

    return lr_at


def init(params) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=zeros,
        v=jax.tree.map(jnp.copy, zeros),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    # keep each leaf in its storage dtype (bf16 grads stay bf16)
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def update(
    cfg: AdamWConfig, params, state: AdamWState, grads
) -> tuple[dict, AdamWState, dict]:
    # Casts to f32 fold INTO the clip/moment expressions (no standalone
    # f32 copy of the gradient tree — §Perf iteration B2 halved optimizer
    # HLO bytes on bf16 models).
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cosine_schedule(cfg)(step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(
        lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g.astype(jnp.float32),
        state.m, grads,
    )
    new_v = jax.tree.map(
        lambda v, g: cfg.b2 * v
        + (1 - cfg.b2) * jnp.square(g.astype(jnp.float32)),
        state.v, grads,
    )

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, AdamWState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr,
    }


# ---------------------------------------------------------------------------
# int8 gradient compression (per-tensor symmetric quantization)
# ---------------------------------------------------------------------------


def compress_grads(grads):
    """tree of f/bf grads -> tree of (int8 q, f32 scale)."""

    def q(g):
        gf = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        return (jnp.round(gf / scale).astype(jnp.int8), scale)

    return jax.tree.map(q, grads)


def decompress_grads(cgrads):
    return jax.tree.map(
        lambda qs: qs[0].astype(jnp.float32) * qs[1],
        cgrads,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2,
    )
