"""Roofline terms from a compiled dry-run artifact (EXPERIMENTS.md §Roofline).

    compute term    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
    memory term     = HLO_bytes / (chips x 819e9 B/s HBM)
    collective term = collective_bytes / (chips x 3 links x 50e9 B/s ICI)

Sources: ``compiled.cost_analysis()`` supplies HLO FLOPs / bytes accessed
(fleet-wide: per-partition values x chips).  collective_bytes is parsed from
the post-SPMD HLO text (``compiled.as_text()``): per collective op we take
the per-device result-shape bytes, apply a ring-transfer factor (all-reduce
moves ~2x its bytes, all-gather/reduce-scatter ~1x, all-to-all/permute 1x),
and attribute DCN-crossing collectives (those whose replica groups span
pods) to the much slower DCN link instead of ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

TPU_V5E = {
    "peak_flops_bf16": 197e12,
    "hbm_bw": 819e9,
    "ici_bw_per_link": 50e9,
    "ici_links": 3,          # per chip on a 2D torus (conservative)
    "dcn_bw_per_chip": 6.25e9,   # ~50 Gb/s NIC share per chip
    "hbm_bytes": 16 * 2**30,
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jaxlib versions:
    older releases return a one-element list of dicts (per executable),
    newer ones a flat dict.  Callers always get the flat dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,128]' -> bytes; '(bf16[..], f32[..])' -> sum."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict
    ici_bytes: float     # per-device bytes over ICI
    dcn_bytes: float     # per-device bytes over DCN (pod-crossing)

    @property
    def total_bytes(self) -> float:
        return self.ici_bytes + self.dcn_bytes


def parse_collectives(hlo_text: str, chips_per_pod: int = 256) -> CollectiveStats:
    counts: dict = {}
    bytes_by_kind: dict = {}
    ici = dcn = 0.0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s+"
                     r"([\w\-]+)\(", stripped)
        if not m:
            continue
        op = m.group(2)
        kind = next((c for c in _COLLECTIVES if op == c
                     or op == c + "-start"), None)
        if kind is None:
            continue
        size = _shape_bytes(m.group(1))
        factor = 2.0 if kind == "all-reduce" else 1.0
        moved = size * factor
        counts[kind] = counts.get(kind, 0) + 1
        bytes_by_kind[kind] = bytes_by_kind.get(kind, 0.0) + moved
        # pod-crossing detection: replica_groups containing ids >= one pod
        # apart within a group
        crossing = False
        rg = re.search(r"replica_groups=\{(.*?)\}\}?", stripped)
        if rg:
            first_group = re.search(r"\{([\d,]+)\}", rg.group(0))
            if first_group:
                ids = [int(x) for x in first_group.group(1).split(",")]
                pods = {i // chips_per_pod for i in ids}
                crossing = len(pods) > 1
        if crossing:
            dcn += moved
        else:
            ici += moved
    return CollectiveStats(counts, bytes_by_kind, ici, dcn)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float          # fleet-wide
    hlo_bytes: float          # fleet-wide
    collective: CollectiveStats
    model_flops: float        # 6ND (train) / 2ND (decode), fleet-wide work
    bytes_per_device: Optional[dict] = None

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * TPU_V5E["peak_flops_bf16"])

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * TPU_V5E["hbm_bw"])

    @property
    def collective_s(self) -> float:
        ici = self.collective.ici_bytes / (
            TPU_V5E["ici_links"] * TPU_V5E["ici_bw_per_link"]
        )
        dcn = self.collective.dcn_bytes / TPU_V5E["dcn_bw_per_chip"]
        return ici + dcn

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline estimate: dominant term bounds the step."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: remat/redundancy waste detector."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline-estimated step time."""
        denom = self.chips * TPU_V5E["peak_flops_bf16"] * self.step_time_s
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_ici_bytes": self.collective.ici_bytes,
            "collective_dcn_bytes": self.collective.dcn_bytes,
            "collective_counts": self.collective.counts,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu": self.mfu,
            "bytes_per_device": self.bytes_per_device,
        }
