"""Serving subsystem: batching, paged KV caching, prefix reuse, telemetry.

  * ``engine``    — dense-cache continuous-batching baseline engine.
  * ``kvcache``   — paged KV pool (fixed-size pages, per-slot page tables,
                    free-list allocation, per-page refcounts with
                    copy-on-write sharing, prompt-prefix radix index,
                    dense-compatibility view).
  * ``scheduler`` — ``PagedServeEngine``: prefix-cached, policy-ordered,
                    batched/bucketed + batched-chunked prefill admission
                    over the paged cache, donated mesh-committed buffers.
  * ``policy``    — pluggable admission ordering: FCFS,
                    shortest-prefill-first, TTFT-SLO-aware least laxity.
  * ``speculative`` — draft-propose / batch-verify / merge decode lane
                    over shared COW pages (greedy output bit-identical
                    to token-by-token decode).
  * ``metrics``   — TTFT / TPOT / throughput / occupancy / prefix-hit /
                    speculation counters plus ``ShapeStats``, the live
                    dispatch-shape distribution (protocol:
                    EXPERIMENTS.md §Serve, §Speculative, §Retune).
  * ``retune``    — ``BackgroundRetuner``: the serve→compile loop —
                    hot observed shapes recompiled through a
                    ``CompilerSession`` and published as hot-swappable
                    ``ArtifactRegistry`` epochs.
"""
from .engine import Request, ServeEngine
from .kvcache import PagedKVCache, PrefixIndex, PrefixMatch
from .metrics import EngineMetrics, RequestMetrics, ShapeStats
from .retune import BackgroundRetuner
from .speculative import SpeculativeDecoder
from .policy import (
    AdmissionPolicy,
    Candidate,
    ShortestPrefillFirst,
    SLOAware,
    make_policy,
)
from .scheduler import PagedServeEngine

__all__ = [
    "Request",
    "ServeEngine",
    "PagedKVCache",
    "PrefixIndex",
    "PrefixMatch",
    "PagedServeEngine",
    "SpeculativeDecoder",
    "EngineMetrics",
    "RequestMetrics",
    "ShapeStats",
    "BackgroundRetuner",
    "AdmissionPolicy",
    "Candidate",
    "ShortestPrefillFirst",
    "SLOAware",
    "make_policy",
]
