"""Serving subsystem: batching, paged KV caching, and telemetry.

  * ``engine``    — dense-cache continuous-batching baseline engine.
  * ``kvcache``   — paged KV pool (fixed-size pages, per-slot page tables,
                    free-list allocation, dense-compatibility view).
  * ``scheduler`` — ``PagedServeEngine``: batched/bucketed + chunked
                    prefill admission over the paged cache, donated
                    mesh-committed buffers.
  * ``metrics``   — TTFT / TPOT / throughput / occupancy counters
                    (protocol: EXPERIMENTS.md §Serve).
"""
from .engine import Request, ServeEngine
from .kvcache import PagedKVCache
from .metrics import EngineMetrics, RequestMetrics
from .scheduler import PagedServeEngine

__all__ = [
    "Request",
    "ServeEngine",
    "PagedKVCache",
    "PagedServeEngine",
    "EngineMetrics",
    "RequestMetrics",
]
