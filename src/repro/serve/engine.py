"""Batched serving engine: continuous-batching prefill/decode scheduler.

Requests enter a queue, are prefilled into free KV-cache slots, and decode
advances all active slots in one batched step per iteration (continuous
batching).  The decode step is ``vmap``-ed over slots with a *per-slot
position*, so sequences of different lengths share the batch exactly (no
padding approximations); finished sequences free their slot immediately and
the next queued request is admitted.

This engine is what the Reasoning Compiler accelerates end-to-end: its
attention/MLP kernels take their block configs from the artifact epoch
bound by ``repro.compiler.ArtifactRegistry`` and hot-swap to newly
published epochs at step boundaries, mirroring the paper's
model-serving framing.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..dist import sharding as shd
from ..models import model as M
from ..obs import NULL_TRACER, Tracer
from .metrics import EngineMetrics


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


def batched_decode_fn(cfg: ArchConfig, backend: Optional[str]):
    """vmapped per-slot decode: a [slots]-batch of single-token
    ``decode_step``s with PER-SLOT positions, so sequences of different
    lengths share the batch exactly.  Shared by the dense engine and the
    paged scheduler (which composes it with page gather/scatter)."""

    def dec_row(p, tok, cache_row, pos):
        cache1 = jax.tree.map(lambda x: x[:, None], cache_row)
        logits, cache1 = M.decode_step(
            cfg, p, tok[None, None], cache1, pos, backend=backend
        )
        return logits[0], jax.tree.map(lambda x: x[:, 0], cache1)

    return jax.vmap(dec_row, in_axes=(None, 0, 1, 0), out_axes=(0, 1))


class ServeEngine:
    """Slot-based continuous batching over a shared decode cache."""

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        slots: int = 4,
        max_len: int = 512,
        backend: Optional[str] = None,
        mesh=None,
        tp: int = 1,
        tracer: Optional[Tracer] = None,
        registry=None,
    ):
        """``tp`` must match the degree the params were built with
        (``init_params(cfg, key, tp)``) so the cache's padded KV-head
        axis lines up with the weights."""
        assert cfg.has_decode, f"{cfg.name} is encoder-only"
        # Tuned-kernel resolution: bind an artifact epoch for this
        # engine's tp degree onto cfg (repro.compiler.ArtifactRegistry).
        # Every trace below reads blocks from this engine-owned resolver
        # — no module global, so differently-sharded engines in one
        # process cannot race.  The engine keeps the registry handle and
        # hot-swaps to newly published epochs at step boundaries.
        from ..compiler.artifacts import ArtifactRegistry

        self.registry = registry if registry is not None \
            else ArtifactRegistry()
        cfg, self._block_tp = self.registry.bind(cfg, mesh=mesh, tp=tp)
        self._artifact_epoch = getattr(cfg.artifacts, "epoch", 0)
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.backend = backend
        self.mesh = mesh
        self.trace = tracer or NULL_TRACER

        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}       # slot -> request
        self.positions = np.zeros((slots,), np.int32)

        self.cache = M.init_cache(cfg, slots, max_len, tp)
        if mesh is not None:
            # Commit params and the shared KV/state cache to the mesh layout
            # from dist.sharding (TP weights, slot axis over "data", KV
            # heads over "model"); jit then compiles against these committed
            # layouts with no in_shardings plumbing.
            self.params = jax.device_put(
                params,
                shd.named_shardings(
                    shd.param_specs(cfg, params, mesh), mesh
                ),
            )
            self.cache = jax.device_put(
                self.cache,
                shd.named_shardings(
                    shd.cache_specs_tree(cfg, self.cache, mesh), mesh
                ),
            )
        self.metrics = EngineMetrics()
        self._prefill_one = self._build_prefill()

        def _slot_write(full_cache, one_cache, slot):
            # Jitted (donated) so the committed mesh layout of the shared
            # cache is updated in place: an eager `.at[].set` produced
            # fresh arrays that silently dropped the NamedSharding and
            # replicated the cache on every admission.
            return jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_index_in_dim(
                    full,
                    _pad_row(one[:, 0], full.shape[:1] + full.shape[2:],
                             full.dtype),
                    slot, axis=1,
                ),
                full_cache, one_cache,
            )

        self._slot_write = jax.jit(_slot_write, donate_argnums=(0,))

        self._decode = self._build_decode()

    def _build_prefill(self):
        cfg, backend, max_len = self.cfg, self.backend, self.max_len
        return jax.jit(
            lambda p, toks: M.prefill(
                cfg, p, {"tokens": toks}, max_len, backend=backend
            )
        )

    def _build_decode(self):
        return jax.jit(
            batched_decode_fn(self.cfg, self.backend), donate_argnums=(2,)
        )

    def _maybe_swap_artifacts(self) -> bool:
        """Adopt the registry's current artifact epoch if it moved.

        Called at the top of ``step()`` only, so a concurrent
        ``publish()`` never mixes epochs inside one admit/decode round:
        every trace within a step resolves against exactly one epoch.
        The stale jits are dropped so the next dispatch re-traces
        against the new blocks (block choice changes tiling, not math —
        greedy outputs are bit-identical across a swap)."""
        reg = self.registry
        if reg is None or reg.epoch == self._artifact_epoch:
            return False
        art = reg.acquire(tp=self._block_tp)
        old = self._artifact_epoch
        self.cfg = dataclasses.replace(self.cfg, artifacts=art)
        self._prefill_one = self._build_prefill()
        self._decode = self._build_decode()
        self._artifact_epoch = art.epoch
        try:
            reg.unpin(old)
        except (KeyError, ValueError):
            pass  # pre-bound cfg: epoch was never pinned by this engine
        self.metrics.artifact_swaps += 1
        self.trace.instant("artifact-swap", cat="serve", epoch=art.epoch,
                           from_epoch=old, records=len(art.records))
        return True

    # -- public API -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)
        self.metrics.on_submit(req.uid, len(req.prompt))

    def run(self, max_iters: int = 10_000) -> list[Request]:
        """Drive until queue + active drain; returns completed requests."""
        finished: list[Request] = []
        for _ in range(max_iters):
            if not self.queue and not self.active:
                break
            finished.extend(self.step())
        return finished

    def step(self) -> list[Request]:
        """One engine iteration: admit, then one decode round (same
        contract as ``PagedServeEngine.step`` — arrival-driven harnesses
        can interleave ``submit`` with steps on either engine).  Newly
        published artifact epochs are adopted here, at the step
        boundary, so one step never mixes epochs."""
        self._maybe_swap_artifacts()
        self._admit()
        return self._decode_iteration()

    # -- internals ----------------------------------------------------------------
    def _free_slots(self) -> list[int]:
        return [s for s in range(self.slots) if s not in self.active]

    def _admit(self) -> None:
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            self.metrics.on_admit(req.uid)
            self.trace.begin(f"req{req.uid}", cat="request",
                             track=f"slot{slot}", uid=req.uid,
                             prompt_len=len(req.prompt))
            toks = jnp.asarray(req.prompt, jnp.int32)[None]
            plen = len(req.prompt)
            self.metrics.shapes.observe("prefill_bucket", (plen, 1))
            self.metrics.shapes.observe("attention", (plen, plen))
            with self.trace.span("prefill", cat="serve",
                                 track=f"slot{slot}",
                                 tokens=len(req.prompt)):
                t0 = self.metrics.clock()
                logits, cache1 = self._prefill_one(self.params, toks)
                self.metrics.prefill_calls += 1
                self.metrics.prefill_tokens += len(req.prompt)
                self.metrics.on_prefill_time(
                    self.metrics.clock() - t0, len(req.prompt)
                )
            self.cache = self._slot_write(
                self.cache, cache1, jnp.int32(slot)
            )
            req.output.append(int(jnp.argmax(logits[0, -1])))
            self.active[slot] = req
            self.positions[slot] = len(req.prompt)
            self.metrics.on_first_token(req.uid)
            self.trace.instant("first-token", cat="request",
                               track=f"slot{slot}", uid=req.uid)

    def _decode_iteration(self) -> list[Request]:
        if not self.active:
            return []
        toks = np.zeros((self.slots,), np.int32)
        for slot, req in self.active.items():
            toks[slot] = req.output[-1]
        self.metrics.shapes.observe(
            "decode_batch", (len(self.active),), weight=len(self.active))
        with self.trace.span("decode", cat="serve",
                             rows=len(self.active)):
            t0 = self.metrics.clock()
            logits, self.cache = self._decode(
                self.params, jnp.asarray(toks), self.cache,
                jnp.asarray(self.positions),
            )
            self.metrics.on_decode_time(self.metrics.clock() - t0)
        self.metrics.decode_steps += 1
        self.metrics.decode_tokens += len(self.active)
        self.metrics.on_occupancy(len(self.active) / self.slots)
        done = []
        for slot, req in list(self.active.items()):
            nxt = int(jnp.argmax(logits[slot, -1]))
            req.output.append(nxt)
            self.positions[slot] += 1
            if (len(req.output) >= req.max_new_tokens
                    or (req.eos_id is not None and nxt == req.eos_id)
                    or int(self.positions[slot]) >= self.max_len - 1):
                req.done = True
                done.append(req)
                del self.active[slot]
                self.positions[slot] = 0
                self.metrics.on_finish(req.uid, len(req.output))
                self.trace.end(f"req{req.uid}", cat="request",
                               track=f"slot{slot}",
                               new_tokens=len(req.output))
        return done


def _pad_row(one_row, shape, dtype):
    """Pad a single-request cache row onto the shared cache row's (slot-
    stripped) shape; integer (kv_pos) pads use -1 (= invalid) so masks
    stay correct."""
    if one_row.shape == tuple(shape):
        return one_row.astype(dtype)
    pads = [(0, f - o) for o, f in zip(one_row.shape, shape)]
    fill = -1 if jnp.issubdtype(dtype, jnp.integer) else 0
    return jnp.pad(one_row, pads, constant_values=fill).astype(dtype)
