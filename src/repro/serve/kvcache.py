"""Paged KV cache: fixed-size pages, per-slot page tables, free-list alloc,
copy-on-write page sharing, and a prompt-prefix radix index.

Dense decode caches waste memory on ragged prompts: every slot owns a full
``[layer, max_len]`` strip whether its request is 5 or 500 tokens long.
This module stores the per-token attention-cache leaves (``k``/``v``/
``kv_pos``) in a shared *page pool* instead — ``[L, P, Hkv, page, hd]`` —
with a small per-slot page table mapping ring positions to pool pages and a
free list for allocation/reclaim.  Per-request state leaves that are O(1)
in sequence length (hybrid conv/SSM carries, xLSTM states) stay dense
per-slot; paging only ever applies to per-token storage.

Two layers:

  * **Functional core** — ``gather_view`` / ``scatter_pages`` /
    ``scatter_token`` / ``scatter_tokens`` / ``copy_page`` are pure,
    traceable pytree ops, so
    the scheduler can fuse gather → decode → scatter into one jitted,
    buffer-donated call.
  * **Stateful shell** — ``PagedKVCache`` owns the pool buffers plus the
    host-side page table, free list, per-page refcounts, and admission
    reservations, and wraps the core ops in cached ``jax.jit`` calls with
    pool donation so the committed (mesh) layout is reused in place rather
    than re-materialized.

**Prefix caching.**  Pages are reference-counted: a page may back several
slots' tables at once (shared prompt prefixes) and survive its original
request inside the ``PrefixIndex`` — a radix tree over page-granular token
chunks that maps incoming prompts to already-computed KV pages
(``match_prefix`` / ``index_prompt``).  ``release`` *decrefs* instead of
invalidating: only pages whose refcount reaches zero return to the free
list.  Writes must go through the copy-on-write guard
(``ensure_writable``): mutating a page another slot or the index still
references first copies it into a fresh page (invalidating the copied
tail beyond the writer's valid token count), so sharers never observe the
write.  Sharing is exact because every request's prompt starts at absolute
position 0 — identical prefix tokens produce bit-identical K/V and RoPE
phases, so a shared page is indistinguishable from a recomputed one.

Exactness contract: ``dense_view()`` reproduces precisely the dense cache
``models.model.decode_step`` expects — unallocated table entries point at a
permanent *null page* whose ``kv_pos`` is all ``-1`` (invalid), so masked
attention sees the same valid set as the dense engine and decodes
token-for-token identically (tests/test_serve.py equivalence test).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import model as M
from ..obs import NULL_TRACER, Tracer

# Per-token attention-cache leaves; everything else is per-slot state.
PAGED_LEAVES = ("k", "v", "kv_pos")

# Reserved pool pages.  NULL is never written: it backs every unallocated
# page-table entry with an all-invalid (kv_pos = -1) page.  TRASH absorbs
# writes from inactive decode rows (the batched decode step advances every
# slot; rows without a request redirect their token write here) and from
# bulk-scatter rows covering shared pages (which must never be rewritten).
NULL_PAGE = 0
TRASH_PAGE = 1
RESERVED_PAGES = 2


def split_leaves(cache: dict) -> tuple[dict, dict]:
    """Split a dense cache dict into (paged leaves, per-slot state leaves)."""
    paged = {k: v for k, v in cache.items() if k in PAGED_LEAVES}
    state = {k: v for k, v in cache.items() if k not in PAGED_LEAVES}
    return paged, state


# ---------------------------------------------------------------------------
# functional core (traceable)
# ---------------------------------------------------------------------------


def gather_view(pool: dict, table: jax.Array) -> dict:
    """Assemble the dense-compatibility view from the page pool.

    ``table`` is [slots, pages_per_slot] int32 page ids.  Returns leaves
    shaped exactly like the dense cache ([L, slots, Hkv, view_len, hd] /
    [L, slots, view_len]) where view_len = pages_per_slot * page_size.
    """
    slots, pps = table.shape
    flat = table.reshape(-1)

    def one(name, leaf):
        g = jnp.take(leaf, flat, axis=1)
        if name == "kv_pos":                   # [L, slots*pps, page]
            L = g.shape[0]
            return g.reshape(L, slots, pps * g.shape[-1])
        L, _, hkv, page, hd = g.shape          # [L, slots*pps, Hkv, page, hd]
        g = g.reshape(L, slots, pps, hkv, page, hd)
        return g.transpose(0, 1, 3, 2, 4, 5).reshape(
            L, slots, hkv, pps * page, hd
        )

    return {k: one(k, v) for k, v in pool.items()}


def scatter_pages(pool: dict, rows: dict, page_ids: jax.Array) -> dict:
    """Write whole cache rows into pages (prefill admission).

    ``rows`` leaves are [L, N, Hkv, S_pad, hd] / [L, N, S_pad] with
    ``S_pad = n_pages * page_size``; ``page_ids`` is [N, n_pages].  Rows
    must arrive fully masked (kv_pos = -1 beyond each row's real length),
    which ``models.model.prefill(..., lengths=...)`` guarantees.
    """
    n, n_pages = page_ids.shape
    flat = page_ids.reshape(-1)

    def one(name, leaf, row):
        if name == "kv_pos":                   # row [L, N, S_pad]
            L = row.shape[0]
            vals = row.reshape(L, n * n_pages, -1)
            return leaf.at[:, flat].set(vals)
        L, _, hkv, s_pad, hd = row.shape
        page = s_pad // n_pages
        vals = row.reshape(L, n, hkv, n_pages, page, hd)
        vals = vals.transpose(0, 1, 3, 2, 4, 5).reshape(
            L, n * n_pages, hkv, page, hd
        )
        return leaf.at[:, flat].set(vals)

    return {k: one(k, v, rows[k]) for k, v in pool.items()}


def scatter_token(
    pool: dict,
    rows: dict,
    page_ids: jax.Array,   # [slots] target page per slot (TRASH if inactive)
    offsets: jax.Array,    # [slots] in-page offset of the written token
    positions: jax.Array,  # [slots] absolute position (kv_pos value)
) -> dict:
    """Write one decoded token's K/V per slot back into the pool.

    ``rows`` carries the token rows extracted from the decoded dense view:
    k/v are [L, slots, Hkv, hd].  Inactive slots must point ``page_ids`` at
    ``TRASH_PAGE`` so the null page stays pristine.
    """
    out = dict(pool)
    if "kv_pos" in pool:
        # adjacent advanced indices (axes 1, 2) stay in place: [L, slots]
        out["kv_pos"] = pool["kv_pos"].at[:, page_ids, offsets].set(
            positions[None]
        )
    for name in ("k", "v"):
        if name not in pool:
            continue
        # advanced indices split by a slice move to the front: the target
        # selection pool[:, ids, :, offs] is [slots, L, Hkv, hd]
        vals = rows[name].transpose(1, 0, 2, 3)
        out[name] = pool[name].at[:, page_ids, :, offsets].set(vals)
    return out


def scatter_tokens(
    pool: dict,
    rows: dict,
    page_ids: jax.Array,   # [N, C] target page per token (TRASH to drop)
    offsets: jax.Array,    # [N, C] in-page offsets
    positions: jax.Array,  # [N, C] absolute positions (kv_pos values)
) -> dict:
    """Write a [N, C]-block of per-token K/V rows back into the pool —
    the speculative commit: C is the verify-chunk length, and every
    (row, token) pair carries its own target page/offset/position.

    ``rows`` k/v leaves are [L, N, Hkv, C, hd] (the token rows extracted
    from a verify forward's cache view).  Entries whose write must NOT
    land — rejected draft tokens, padded rows, inactive slots — point
    ``page_ids`` at ``TRASH_PAGE``: a rejected proposal therefore never
    touches a real page, so shared pages need no rollback and sharers
    can never observe a speculative write.
    """
    n, c = page_ids.shape
    flat_p = page_ids.reshape(-1)
    flat_o = offsets.reshape(-1)
    out = dict(pool)
    if "kv_pos" in pool:
        # adjacent advanced indices (axes 1, 2) stay in place: [L, N*C]
        out["kv_pos"] = pool["kv_pos"].at[:, flat_p, flat_o].set(
            positions.reshape(-1)[None]
        )
    for name in ("k", "v"):
        if name not in pool:
            continue
        leaf = rows[name]          # [L, N, Hkv, C, hd]
        L, _, hkv, _, hd = leaf.shape
        # advanced indices split by a slice move to the front: the target
        # selection pool[:, ids, :, offs] is [N*C, L, Hkv, hd]
        vals = leaf.transpose(1, 3, 0, 2, 4).reshape(n * c, L, hkv, hd)
        out[name] = pool[name].at[:, flat_p, :, flat_o].set(vals)
    return out


def reset_pages(pool: dict, page_ids: jax.Array) -> dict:
    """Invalidate freed pages (kv_pos = -1) so reuse never leaks stale
    positions into a future gather.  K/V bytes are left as-is (masked)."""
    if "kv_pos" not in pool:
        return pool
    out = dict(pool)
    out["kv_pos"] = pool["kv_pos"].at[:, page_ids].set(-1)
    return out


def copy_page(pool: dict, src, dst, keep) -> dict:
    """Copy-on-write core: duplicate page ``src`` onto page ``dst``.

    Only the first ``keep`` in-page token positions stay valid in the copy
    (``kv_pos`` beyond them resets to -1): the writer semantically owns a
    prefix of the shared page, and the donor's tail tokens must never leak
    into the writer's attention masks.  K/V tail bytes are left as-is —
    they are masked, and the writer overwrites them next.
    """
    out = dict(pool)
    for name, leaf in pool.items():
        row = leaf[:, src]
        if name == "kv_pos":                   # [L, page]
            offs = jnp.arange(row.shape[-1])
            row = jnp.where(offs[None, :] < keep, row, -1)
        out[name] = leaf.at[:, dst].set(row)
    return out


# ---------------------------------------------------------------------------
# prompt-prefix radix index
# ---------------------------------------------------------------------------


class _RadixNode:
    """One page-granular edge of the prefix trie: the ``page_size`` tokens
    that label the edge, the pool page holding their K/V, and an LRU
    stamp."""

    __slots__ = ("children", "page", "tokens", "stamp")

    def __init__(self, page: int = -1, tokens: Optional[np.ndarray] = None):
        self.children: dict[bytes, _RadixNode] = {}
        self.page = page
        self.tokens = tokens
        self.stamp = 0


@dataclasses.dataclass
class PrefixMatch:
    """Result of a prompt-prefix lookup, already capped so at least one
    prompt token is always recomputed (the suffix prefill must produce the
    first generated token's logits)."""

    tokens: int                      # total cached tokens (full + boundary)
    pages: list                      # full-page ids, shareable as-is
    boundary_page: Optional[int]     # page holding a *partial* chunk match
    boundary_keep: int               # valid tokens inside the boundary page


class PrefixIndex:
    """Radix tree over page-granular token chunks → pool page ids.

    Nodes hold one reference each on their page (taken by the cache when a
    node is created, dropped on eviction), so indexed prefixes outlive the
    requests that computed them.  Matching walks full ``page_size`` chunks
    and finishes with a longest-common-prefix scan for a partial boundary
    chunk; eviction removes least-recently-used leaves (``evict_lru``) so
    interior pages — shared by more cached prompts — die last.
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.root = _RadixNode()
        self._clock = 0
        self.nodes = 0

    def _touch(self, node: _RadixNode) -> None:
        self._clock += 1
        node.stamp = self._clock

    def match(self, tokens: np.ndarray,
              touch: bool = True) -> tuple[list, Optional[int], int]:
        """Longest indexed prefix of ``tokens``: (full-page ids,
        boundary page id or None, boundary matched-token count).
        ``touch=False`` leaves the LRU stamps alone — for cost *estimates*
        (admission-policy ranking), which must not perturb eviction order.
        """
        pg = self.page_size
        tokens = np.asarray(tokens, np.int32)
        node, pages = self.root, []
        i = 0
        while True:
            chunk = tokens[i * pg: (i + 1) * pg]
            child = None
            if len(chunk) == pg:
                child = node.children.get(chunk.tobytes())
            if child is not None:
                pages.append(child.page)
                if touch:
                    self._touch(child)
                node = child
                i += 1
                continue
            # partial boundary: longest common prefix with any child edge
            best, m = None, 0
            for cand in node.children.values():
                lcp = int((np.cumprod(
                    cand.tokens[: len(chunk)] == chunk
                )).sum()) if len(chunk) else 0
                if lcp > m:
                    best, m = cand, lcp
            if best is not None:
                if touch:
                    self._touch(best)
                return pages, best.page, m
            return pages, None, 0

    def insert(
        self,
        tokens: np.ndarray,
        pages: list,
        on_new_ref: Callable[[int], None],
    ) -> int:
        """Index the full-page chunks of ``tokens`` backed by ``pages``
        (one id per full chunk).  Existing nodes are deduplicated (the
        original donor's page stays indexed); each newly created node calls
        ``on_new_ref(page)`` so the cache can pin it.  Returns the number
        of nodes created."""
        pg = self.page_size
        tokens = np.asarray(tokens, np.int32)
        node, added = self.root, 0
        for i, page in enumerate(pages):
            chunk = tokens[i * pg: (i + 1) * pg]
            if len(chunk) < pg:
                break
            key = chunk.tobytes()
            child = node.children.get(key)
            if child is None:
                child = _RadixNode(page=page, tokens=chunk.copy())
                node.children[key] = child
                self.nodes += 1
                added += 1
                on_new_ref(page)
            self._touch(child)
            node = child
        return added

    def evict_lru(
        self,
        n_pages: int,
        decref: Callable[[int], bool],
        freeable: Callable[[int], bool] = lambda page: True,
    ) -> int:
        """Drop LRU leaves until ``decref`` reports ``n_pages`` pages hit
        refcount zero.  Only leaves whose page ``freeable`` says would
        actually free are touched: evicting a node whose page an active
        slot still holds reclaims nothing, so those stay indexed (their
        page frees later, when the slot releases).  One tree walk per
        call — evicting a leaf exposes its parent as the next candidate
        incrementally (heap by LRU stamp), so freeing a whole chain is
        O(nodes + n log n), not O(n · nodes).  Returns pages freed."""
        parents: dict[int, tuple] = {}   # id(node) -> (parent, key, node)
        heap: list[tuple[int, int]] = []

        def walk(node):
            for key, child in node.children.items():
                parents[id(child)] = (node, key, child)
                if child.children:
                    walk(child)
                elif freeable(child.page):
                    heapq.heappush(heap, (child.stamp, id(child)))

        walk(self.root)
        freed = 0
        while freed < n_pages and heap:
            _, nid = heapq.heappop(heap)
            parent, key, node = parents.pop(nid)
            if node.children or parent.children.get(key) is not node:
                continue   # stale entry (already detached this call)
            del parent.children[key]
            self.nodes -= 1
            if decref(node.page):
                freed += 1
            if parent is not self.root and not parent.children \
                    and freeable(parent.page):
                heapq.heappush(heap, (parent.stamp, id(parent)))
        return freed


# ---------------------------------------------------------------------------
# stateful shell
# ---------------------------------------------------------------------------


class PagedKVCache:
    """Page pool + page tables + free list + refcounts for one engine.

    ``capacity`` (data pages) defaults to full provisioning
    (slots × pages_per_slot = the dense cache's footprint); pass a smaller
    value to overcommit — admission then gates on reservations
    (``reserve``) and short prompts pack more requests into the same
    memory, which is the whole point of paging.  ``prefix_cache=True``
    attaches a ``PrefixIndex`` so finished prompts' full pages stay
    resident for reuse; reservation shortfalls evict LRU index entries
    before failing.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        slots: int,
        max_len: int,
        *,
        page_size: int = 16,
        capacity: Optional[int] = None,
        prefix_cache: bool = False,
        mesh=None,
        tp: int = 1,
        tracer: Optional[Tracer] = None,
    ):
        assert page_size >= 1
        self.trace = tracer or NULL_TRACER
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.page_size = page_size

        spec = M.cache_spec(cfg, slots, max_len, tp)
        ring = spec["k"].shape[3] if "k" in spec else max_len
        self.pages_per_slot = max(1, math.ceil(ring / page_size))
        self.view_len = self.pages_per_slot * page_size
        self.capacity = capacity or slots * self.pages_per_slot
        n_pool = self.capacity + RESERVED_PAGES

        def pool_leaf(name, sd):
            if name == "kv_pos":
                return jnp.full((sd.shape[0], n_pool, page_size), -1,
                                jnp.int32)
            L, _, hkv, _, hd = sd.shape
            return jnp.zeros((L, n_pool, hkv, page_size, hd), sd.dtype)

        self.pool = {
            k: pool_leaf(k, sd) for k, sd in spec.items()
            if k in PAGED_LEAVES
        }
        self.state = {
            k: (jnp.full(sd.shape, -1, sd.dtype) if sd.dtype == jnp.int32
                else jnp.zeros(sd.shape, sd.dtype))
            for k, sd in spec.items() if k not in PAGED_LEAVES
        }
        self.mesh = mesh
        if mesh is not None:
            from ..dist import sharding as shd
            self.pool = jax.device_put(
                self.pool,
                shd.named_shardings(
                    shd.paged_cache_specs_tree(cfg, self.pool, mesh), mesh
                ),
            )
            if self.state:
                self.state = jax.device_put(
                    self.state,
                    shd.named_shardings(
                        shd.cache_specs_tree(cfg, self.state, mesh), mesh
                    ),
                )

        # host-side bookkeeping
        self.table = np.full((slots, self.pages_per_slot), NULL_PAGE,
                             np.int32)
        self._free: list[int] = list(
            range(RESERVED_PAGES, n_pool)
        )
        self._owned: dict[int, list[int]] = {s: [] for s in range(slots)}
        self._reserved: dict[int, int] = {s: 0 for s in range(slots)}
        # per-page reference counts: a page is live while any slot's table
        # or the prefix index points at it; reserved pages stay at 0
        self._ref = np.zeros((n_pool,), np.int32)
        self.prefix = PrefixIndex(page_size) if prefix_cache else None
        self.cow_copies = 0

        self._gather_j = jax.jit(gather_view)
        self._scatter_pages_j = jax.jit(scatter_pages, donate_argnums=(0,))
        self._reset_j = jax.jit(reset_pages, donate_argnums=(0,))
        self._copy_page_j = jax.jit(copy_page, donate_argnums=(0,))
        # jitted + donated for the same reason as ServeEngine._slot_write:
        # an eager .at[].set would rebuild the state tree and silently
        # drop its mesh-committed sharding on every admission
        self._state_write_j = jax.jit(
            lambda state, rows, idx: jax.tree.map(
                lambda full, one: full.at[:, idx].set(
                    one.astype(full.dtype)
                ),
                state, rows,
            ),
            donate_argnums=(0,),
        )

    # -- accounting ---------------------------------------------------------
    def pages_needed(self, n_tokens: int) -> int:
        return max(1, math.ceil(min(n_tokens, self.view_len)
                                / self.page_size))

    @property
    def used_pages(self) -> int:
        return self.capacity - len(self._free)

    @property
    def available_pages(self) -> int:
        """Free pages not already promised to an admitted request."""
        return len(self._free) - sum(self._reserved.values())

    @property
    def shared_pages(self) -> int:
        """Pages currently referenced more than once (slots + index)."""
        return int((self._ref > 1).sum())

    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    def occupancy(self) -> float:
        return self.used_pages / max(1, self.capacity)

    def reserve(self, slot: int, n_pages: int, cow: int = 0) -> bool:
        """Admission gate: promise ``n_pages`` of future growth to a slot.
        Pages the slot already holds (including attached shared prefix
        pages) count toward the need; ``cow`` adds back pages that are
        attached but will need a private copy (a shared boundary page
        counts as held AND needs one fresh page).  A shortfall first
        evicts LRU prefix entries; if the pool still cannot honor the
        worst case, nothing is reserved and the request must wait for a
        release."""
        n_pages = min(n_pages, self.pages_per_slot)
        extra = max(0, n_pages - len(self._owned[slot]) + cow)
        short = extra - self.available_pages
        if short > 0 and self.prefix is not None:
            self._evict_prefix(short)
        if extra > self.available_pages:
            return False
        self._reserved[slot] += extra
        return True

    def alloc_upto(self, slot: int, n_tokens: int) -> None:
        """Ensure pages covering token positions [0, n_tokens) exist for the
        slot, drawing from its reservation (decode growth is lazy)."""
        need = self.pages_needed(n_tokens)
        own = self._owned[slot]
        grabbed: list[int] = []
        while len(own) < need:
            page = self._free.pop()
            self._ref[page] = 1
            own.append(page)
            grabbed.append(page)
            self.table[slot, len(own) - 1] = page
            self._reserved[slot] = max(0, self._reserved[slot] - 1)
        if grabbed:
            self.trace.instant("page-alloc", cat="kv", slot=slot,
                               pages=grabbed)

    def attach(self, slot: int, page_ids: list) -> None:
        """Share already-live pages into a slot's table (prefix reuse): each
        page is increfed and appended after the slot's current pages.  The
        slot must not write them without ``ensure_writable``."""
        own = self._owned[slot]
        for page in page_ids:
            assert self._ref[page] >= 1, f"attach of dead page {page}"
            self._ref[page] += 1
            own.append(page)
            self.table[slot, len(own) - 1] = page

    def ensure_writable(self, slot: int, page_idx: int,
                        n_valid: int) -> bool:
        """Copy-on-write guard: before writing the slot's ``page_idx``-th
        page, copy it into a fresh page if anyone else still references it.
        ``n_valid`` is the slot's valid token count — copied in-page
        positions at or beyond it are invalidated so the donor's tail never
        leaks into this slot's masks.  Returns True when a copy happened."""
        own = self._owned[slot]
        if page_idx >= len(own):
            return False
        page = own[page_idx]
        if self._ref[page] <= 1:
            return False
        if not self._free and self.prefix is not None:
            self._evict_prefix(1)
        assert self._free, "COW with an exhausted free list (reserve bug)"
        new = self._free.pop()
        self._reserved[slot] = max(0, self._reserved[slot] - 1)
        self._ref[new] = 1
        self._ref[page] -= 1
        keep = max(0, min(n_valid - page_idx * self.page_size,
                          self.page_size))
        if self.pool:
            self.pool = self._copy_page_j(
                self.pool, jnp.int32(page), jnp.int32(new), jnp.int32(keep)
            )
        own[page_idx] = new
        self.table[slot, page_idx] = new
        self.cow_copies += 1
        self.trace.instant("cow-copy", cat="kv", slot=slot, src=int(page),
                           dst=int(new), keep=int(keep))
        return True

    def fork_slot(self, src: int, dst: int) -> None:
        """Branch a slot: ``dst``'s page table becomes a shared (increfed)
        copy of ``src``'s — the page-table fork behind n-way speculative
        branches / best-of-n sampling.  No data moves: both slots read the
        same pages until either writes, at which point ``ensure_writable``
        copy-on-writes the touched page.  The fork carries no reservation;
        callers that will grow the branch must ``reserve`` for it."""
        assert not self._owned[dst], "fork into a non-empty slot"
        self.attach(dst, self._owned[src])

    def rollback(self, slot: int, n_valid: int) -> list[int]:
        """Discard a slot's tokens at or beyond position ``n_valid`` — the
        reject path for a partially-written speculative branch.  Whole
        pages past the bound detach (freed + invalidated if this slot was
        their last holder; merely decrefed if a sibling or the prefix
        index still shares them).  A page *straddling* the bound first
        goes private via the copy-on-write guard — a sharer keeps its own
        tail — and then has its in-page tail invalidated.  Returns the
        page ids actually freed.  The slot's reservation is unchanged
        (rollback un-writes tokens; it does not re-promise growth)."""
        pg = self.page_size
        own = self._owned[slot]
        keep = min(0 if n_valid <= 0 else math.ceil(n_valid / pg), len(own))
        freed: list[int] = []
        for page in own[keep:]:
            self._ref[page] -= 1
            if self._ref[page] == 0:
                freed.append(page)
        del own[keep:]
        self.table[slot, keep:] = NULL_PAGE
        if freed:
            self.invalidate(freed)
            self._free.extend(freed)
        self.trace.instant("rollback", cat="kv", slot=slot,
                           n_valid=int(n_valid), freed=list(freed))
        if keep and n_valid < keep * pg:
            # boundary page: COW already invalidates the copied tail; a
            # page that was private needs the explicit tail reset
            idx = keep - 1
            if not self.ensure_writable(slot, idx, n_valid) and self.pool:
                self.pool = self._copy_page_j(
                    self.pool, jnp.int32(own[idx]), jnp.int32(own[idx]),
                    jnp.int32(n_valid - idx * pg),
                )
        return freed

    def release(self, slot: int, *, invalidate: bool = True) -> list[int]:
        """Decref a finished request's pages; returns the ids that actually
        hit refcount zero (pages still shared — by other slots or the
        prefix index — stay live).

        ``invalidate=False`` skips the jitted kv_pos reset so a caller
        freeing several slots in one engine step can batch the resets
        into a single ``invalidate()`` dispatch — freed pages MUST be
        invalidated before they can be reallocated."""
        freed: list[int] = []
        for page in self._owned[slot]:
            self._ref[page] -= 1
            if self._ref[page] == 0:
                freed.append(page)
        if freed:
            if invalidate:
                self.invalidate(freed)
            self._free.extend(freed)
            self.trace.instant("page-free", cat="kv", slot=slot,
                               pages=list(freed))
        self._owned[slot] = []
        self._reserved[slot] = 0
        self.table[slot] = NULL_PAGE
        return freed

    def invalidate(self, page_ids: list[int]) -> None:
        """One jitted reset marking the given pages all-invalid; the id
        array pads to a page-count multiple to bound retraces."""
        if not page_ids or not self.pool:
            return
        n = math.ceil(len(page_ids) / self.pages_per_slot) \
            * self.pages_per_slot
        ids = np.full((n,), TRASH_PAGE, np.int32)
        ids[: len(page_ids)] = page_ids
        self.pool = self._reset_j(self.pool, jnp.asarray(ids))

    def page_ids(self, slot: int) -> list[int]:
        return list(self._owned[slot])

    def table_device(self) -> jax.Array:
        return jnp.asarray(self.table)

    # -- prefix caching -----------------------------------------------------
    def match_prefix(self, tokens: np.ndarray,
                     touch: bool = True) -> Optional[PrefixMatch]:
        """Longest cached prefix of a prompt, capped at len(tokens) - 1 so
        the suffix prefill always recomputes at least one token (its logits
        seed generation).  Returns None on a miss or when disabled.
        ``touch=False`` = LRU-neutral estimate (see ``PrefixIndex.match``).
        """
        if self.prefix is None:
            return None
        tokens = np.asarray(tokens, np.int32)
        pages, boundary, m = self.prefix.match(tokens, touch=touch)
        pg = self.page_size
        n = len(pages) * pg + m
        n = min(n, len(tokens) - 1)
        if n <= 0:
            return None
        k_full = n // pg
        keep = n - k_full * pg
        if keep == 0:
            return PrefixMatch(n, pages[:k_full], None, 0)
        # boundary source: a partial chunk match, or a full-page match
        # pulled back by the cap — either way the page at chunk k_full
        bpage = pages[k_full] if len(pages) > k_full else boundary
        return PrefixMatch(n, pages[:k_full], bpage, keep)

    def attach_prefix(self, slot: int, match: PrefixMatch) -> None:
        """Seed a fresh slot from a prefix match: full pages AND the
        boundary page (if any) attach shared — pure host bookkeeping, so
        a caller whose reservation then fails rolls back with a cheap
        ``release`` (nothing was copied, nothing needs invalidating, and
        holding the refs protects the matched pages from eviction in the
        meantime).  On success the caller must make the boundary page
        private (``ensure_writable``) BEFORE any gather for this slot —
        reserve with ``cow=1`` so a free page is guaranteed for the copy."""
        assert not self._owned[slot], "attach_prefix needs an empty slot"
        self.attach(slot, match.pages)
        if match.boundary_page is not None:
            self.attach(slot, [match.boundary_page])

    def index_prompt(self, slot: int, tokens: np.ndarray) -> int:
        """Register a prefilled prompt's FULL pages in the prefix index
        (partial last pages are excluded: decode writes into them).  Each
        newly indexed page gains one index-held reference."""
        if self.prefix is None:
            return 0
        tokens = np.asarray(tokens, np.int32)
        n_full = min(len(tokens) // self.page_size,
                     len(self._owned[slot]))

        def pin(page):
            self._ref[page] += 1

        return self.prefix.insert(
            tokens, self._owned[slot][:n_full], pin
        )

    def _evict_prefix(self, n_pages: int) -> int:
        """Reclaim ``n_pages`` by dropping LRU prefix-index entries whose
        pages nobody else holds; freed pages are invalidated and returned
        to the free list."""
        freed: list[int] = []

        def decref(page: int) -> bool:
            self._ref[page] -= 1
            if self._ref[page] == 0:
                freed.append(page)
                return True
            return False

        n = self.prefix.evict_lru(
            n_pages, decref, freeable=lambda page: self._ref[page] == 1
        )
        if freed:
            self.invalidate(freed)
            self._free.extend(freed)
            self.trace.instant("prefix-evict", cat="kv",
                               pages=list(freed))
        return n

    # -- data movement ------------------------------------------------------
    def dense_view(self) -> dict:
        """Materialize the dense cache ([L, slots, ...]) the model decodes
        against; unallocated positions are invalid by construction."""
        view = self._gather_j(self.pool, self.table_device()) if self.pool \
            else {}
        return {**view, **self.state}

    def gather_row(self, slot: int) -> dict:
        """Dense scratch row [L, 1, ...] of one slot's current pages —
        seeds a chunked-prefill lane with its shared prefix K/V."""
        if not self.pool:
            return {}
        return self._gather_j(
            self.pool, jnp.asarray(self.table[slot: slot + 1])
        )

    def write_prefill(self, slots: list[int], rows: dict) -> None:
        """Admit prefilled rows: paged leaves scatter into each slot's
        pages ([L, N, ..., S_pad, ...] with S_pad a page multiple, already
        allocated via ``alloc_upto``); state leaves land dense per slot.
        Rows beyond ``len(slots)`` are padding and scatter into TRASH —
        as do pages the slot only *shares* (refcount > 1): a bulk prefill
        write never mutates another owner's data."""
        paged_rows, state_rows = split_leaves(rows)
        if paged_rows:
            n = next(iter(paged_rows.values())).shape[1]
            s_pad = paged_rows["kv_pos"].shape[2] if "kv_pos" in paged_rows \
                else paged_rows["k"].shape[3]
            n_pages = s_pad // self.page_size
            ids = np.full((n, n_pages), TRASH_PAGE, np.int32)
            for i, slot in enumerate(slots):
                own = self._owned[slot][:n_pages]
                for j, page in enumerate(own):
                    ids[i, j] = page if self._ref[page] <= 1 else TRASH_PAGE
            self.pool = self._scatter_pages_j(
                self.pool, paged_rows, jnp.asarray(ids)
            )
        if state_rows and slots:
            idx = jnp.asarray(np.asarray(slots, np.int32))
            real = {k: v[:, : len(slots)] for k, v in state_rows.items()}
            self.state = self._state_write_j(self.state, real, idx)

    def token_targets(self, positions: np.ndarray) -> tuple:
        """(page_ids, offsets) arrays routing each slot's next token write;
        slots without an allocated page at that position go to TRASH."""
        pages = np.full((self.slots,), TRASH_PAGE, np.int32)
        offs = np.zeros((self.slots,), np.int32)
        for slot in range(self.slots):
            pos = int(positions[slot])
            idx = pos // self.page_size
            if 0 <= idx < self.pages_per_slot:
                page = int(self.table[slot, idx])
                if page != NULL_PAGE:
                    pages[slot] = page
                    offs[slot] = pos % self.page_size
        return pages, offs
