"""Paged KV cache: fixed-size pages, per-slot page tables, free-list alloc.

Dense decode caches waste memory on ragged prompts: every slot owns a full
``[layer, max_len]`` strip whether its request is 5 or 500 tokens long.
This module stores the per-token attention-cache leaves (``k``/``v``/
``kv_pos``) in a shared *page pool* instead — ``[L, P, Hkv, page, hd]`` —
with a small per-slot page table mapping ring positions to pool pages and a
free list for allocation/reclaim.  Per-request state leaves that are O(1)
in sequence length (hybrid conv/SSM carries, xLSTM states) stay dense
per-slot; paging only ever applies to per-token storage.

Two layers:

  * **Functional core** — ``gather_view`` / ``scatter_pages`` /
    ``scatter_token`` are pure, traceable pytree ops, so the scheduler can
    fuse gather → decode → scatter into one jitted, buffer-donated call.
  * **Stateful shell** — ``PagedKVCache`` owns the pool buffers plus the
    host-side page table, free list, and admission reservations, and wraps
    the core ops in cached ``jax.jit`` calls with pool donation so the
    committed (mesh) layout is reused in place rather than re-materialized.

Exactness contract: ``dense_view()`` reproduces precisely the dense cache
``models.model.decode_step`` expects — unallocated table entries point at a
permanent *null page* whose ``kv_pos`` is all ``-1`` (invalid), so masked
attention sees the same valid set as the dense engine and decodes
token-for-token identically (tests/test_serve.py equivalence test).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import model as M

# Per-token attention-cache leaves; everything else is per-slot state.
PAGED_LEAVES = ("k", "v", "kv_pos")

# Reserved pool pages.  NULL is never written: it backs every unallocated
# page-table entry with an all-invalid (kv_pos = -1) page.  TRASH absorbs
# writes from inactive decode rows (the batched decode step advances every
# slot; rows without a request redirect their token write here).
NULL_PAGE = 0
TRASH_PAGE = 1
RESERVED_PAGES = 2


def split_leaves(cache: dict) -> tuple[dict, dict]:
    """Split a dense cache dict into (paged leaves, per-slot state leaves)."""
    paged = {k: v for k, v in cache.items() if k in PAGED_LEAVES}
    state = {k: v for k, v in cache.items() if k not in PAGED_LEAVES}
    return paged, state


# ---------------------------------------------------------------------------
# functional core (traceable)
# ---------------------------------------------------------------------------


def gather_view(pool: dict, table: jax.Array) -> dict:
    """Assemble the dense-compatibility view from the page pool.

    ``table`` is [slots, pages_per_slot] int32 page ids.  Returns leaves
    shaped exactly like the dense cache ([L, slots, Hkv, view_len, hd] /
    [L, slots, view_len]) where view_len = pages_per_slot * page_size.
    """
    slots, pps = table.shape
    flat = table.reshape(-1)

    def one(name, leaf):
        g = jnp.take(leaf, flat, axis=1)
        if name == "kv_pos":                   # [L, slots*pps, page]
            L = g.shape[0]
            return g.reshape(L, slots, pps * g.shape[-1])
        L, _, hkv, page, hd = g.shape          # [L, slots*pps, Hkv, page, hd]
        g = g.reshape(L, slots, pps, hkv, page, hd)
        return g.transpose(0, 1, 3, 2, 4, 5).reshape(
            L, slots, hkv, pps * page, hd
        )

    return {k: one(k, v) for k, v in pool.items()}


def scatter_pages(pool: dict, rows: dict, page_ids: jax.Array) -> dict:
    """Write whole cache rows into pages (prefill admission).

    ``rows`` leaves are [L, N, Hkv, S_pad, hd] / [L, N, S_pad] with
    ``S_pad = n_pages * page_size``; ``page_ids`` is [N, n_pages].  Rows
    must arrive fully masked (kv_pos = -1 beyond each row's real length),
    which ``models.model.prefill(..., lengths=...)`` guarantees.
    """
    n, n_pages = page_ids.shape
    flat = page_ids.reshape(-1)

    def one(name, leaf, row):
        if name == "kv_pos":                   # row [L, N, S_pad]
            L = row.shape[0]
            vals = row.reshape(L, n * n_pages, -1)
            return leaf.at[:, flat].set(vals)
        L, _, hkv, s_pad, hd = row.shape
        page = s_pad // n_pages
        vals = row.reshape(L, n, hkv, n_pages, page, hd)
        vals = vals.transpose(0, 1, 3, 2, 4, 5).reshape(
            L, n * n_pages, hkv, page, hd
        )
        return leaf.at[:, flat].set(vals)

    return {k: one(k, v, rows[k]) for k, v in pool.items()}


def scatter_token(
    pool: dict,
    rows: dict,
    page_ids: jax.Array,   # [slots] target page per slot (TRASH if inactive)
    offsets: jax.Array,    # [slots] in-page offset of the written token
    positions: jax.Array,  # [slots] absolute position (kv_pos value)
) -> dict:
    """Write one decoded token's K/V per slot back into the pool.

    ``rows`` carries the token rows extracted from the decoded dense view:
    k/v are [L, slots, Hkv, hd].  Inactive slots must point ``page_ids`` at
    ``TRASH_PAGE`` so the null page stays pristine.
    """
    out = dict(pool)
    if "kv_pos" in pool:
        # adjacent advanced indices (axes 1, 2) stay in place: [L, slots]
        out["kv_pos"] = pool["kv_pos"].at[:, page_ids, offsets].set(
            positions[None]
        )
    for name in ("k", "v"):
        if name not in pool:
            continue
        # advanced indices split by a slice move to the front: the target
        # selection pool[:, ids, :, offs] is [slots, L, Hkv, hd]
        vals = rows[name].transpose(1, 0, 2, 3)
        out[name] = pool[name].at[:, page_ids, :, offsets].set(vals)
    return out


def reset_pages(pool: dict, page_ids: jax.Array) -> dict:
    """Invalidate freed pages (kv_pos = -1) so reuse never leaks stale
    positions into a future gather.  K/V bytes are left as-is (masked)."""
    if "kv_pos" not in pool:
        return pool
    out = dict(pool)
    out["kv_pos"] = pool["kv_pos"].at[:, page_ids].set(-1)
    return out


# ---------------------------------------------------------------------------
# stateful shell
# ---------------------------------------------------------------------------


class PagedKVCache:
    """Page pool + page tables + free list for one serving engine.

    ``capacity`` (data pages) defaults to full provisioning
    (slots × pages_per_slot = the dense cache's footprint); pass a smaller
    value to overcommit — admission then gates on reservations
    (``reserve``) and short prompts pack more requests into the same
    memory, which is the whole point of paging.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        slots: int,
        max_len: int,
        *,
        page_size: int = 16,
        capacity: Optional[int] = None,
        mesh=None,
        tp: int = 1,
    ):
        assert page_size >= 1
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.page_size = page_size

        spec = M.cache_spec(cfg, slots, max_len, tp)
        ring = spec["k"].shape[3] if "k" in spec else max_len
        self.pages_per_slot = max(1, math.ceil(ring / page_size))
        self.view_len = self.pages_per_slot * page_size
        self.capacity = capacity or slots * self.pages_per_slot
        n_pool = self.capacity + RESERVED_PAGES

        def pool_leaf(name, sd):
            if name == "kv_pos":
                return jnp.full((sd.shape[0], n_pool, page_size), -1,
                                jnp.int32)
            L, _, hkv, _, hd = sd.shape
            return jnp.zeros((L, n_pool, hkv, page_size, hd), sd.dtype)

        self.pool = {
            k: pool_leaf(k, sd) for k, sd in spec.items()
            if k in PAGED_LEAVES
        }
        self.state = {
            k: (jnp.full(sd.shape, -1, sd.dtype) if sd.dtype == jnp.int32
                else jnp.zeros(sd.shape, sd.dtype))
            for k, sd in spec.items() if k not in PAGED_LEAVES
        }
        self.mesh = mesh
        if mesh is not None:
            from ..dist import sharding as shd
            self.pool = jax.device_put(
                self.pool,
                shd.named_shardings(
                    shd.paged_cache_specs_tree(cfg, self.pool, mesh), mesh
                ),
            )
            if self.state:
                self.state = jax.device_put(
                    self.state,
                    shd.named_shardings(
                        shd.cache_specs_tree(cfg, self.state, mesh), mesh
                    ),
                )

        # host-side bookkeeping
        self.table = np.full((slots, self.pages_per_slot), NULL_PAGE,
                             np.int32)
        self._free: list[int] = list(
            range(RESERVED_PAGES, n_pool)
        )
        self._owned: dict[int, list[int]] = {s: [] for s in range(slots)}
        self._reserved: dict[int, int] = {s: 0 for s in range(slots)}

        self._gather_j = jax.jit(gather_view)
        self._scatter_pages_j = jax.jit(scatter_pages, donate_argnums=(0,))
        self._reset_j = jax.jit(reset_pages, donate_argnums=(0,))
        # jitted + donated for the same reason as ServeEngine._slot_write:
        # an eager .at[].set would rebuild the state tree and silently
        # drop its mesh-committed sharding on every admission
        self._state_write_j = jax.jit(
            lambda state, rows, idx: jax.tree.map(
                lambda full, one: full.at[:, idx].set(
                    one.astype(full.dtype)
                ),
                state, rows,
            ),
            donate_argnums=(0,),
        )

    # -- accounting ---------------------------------------------------------
    def pages_needed(self, n_tokens: int) -> int:
        return max(1, math.ceil(min(n_tokens, self.view_len)
                                / self.page_size))

    @property
    def used_pages(self) -> int:
        return self.capacity - len(self._free)

    @property
    def available_pages(self) -> int:
        """Free pages not already promised to an admitted request."""
        return len(self._free) - sum(self._reserved.values())

    def occupancy(self) -> float:
        return self.used_pages / max(1, self.capacity)

    def reserve(self, slot: int, n_pages: int) -> bool:
        """Admission gate: promise ``n_pages`` of future growth to a slot.
        Returns False (and reserves nothing) when the pool cannot honor the
        worst case — the request must wait for a release."""
        n_pages = min(n_pages, self.pages_per_slot)
        extra = max(0, n_pages - len(self._owned[slot]))
        if extra > self.available_pages:
            return False
        self._reserved[slot] += extra
        return True

    def alloc_upto(self, slot: int, n_tokens: int) -> None:
        """Ensure pages covering token positions [0, n_tokens) exist for the
        slot, drawing from its reservation (decode growth is lazy)."""
        need = self.pages_needed(n_tokens)
        own = self._owned[slot]
        while len(own) < need:
            page = self._free.pop()
            own.append(page)
            self.table[slot, len(own) - 1] = page
            self._reserved[slot] = max(0, self._reserved[slot] - 1)

    def release(self, slot: int, *, invalidate: bool = True) -> list[int]:
        """Reclaim a finished request's pages; returns the freed ids.

        ``invalidate=False`` skips the jitted kv_pos reset so a caller
        freeing several slots in one engine step can batch the resets
        into a single ``invalidate()`` dispatch — freed pages MUST be
        invalidated before they can be reallocated."""
        own = self._owned[slot]
        if own:
            if invalidate:
                self.invalidate(own)
            self._free.extend(own)
        self._owned[slot] = []
        self._reserved[slot] = 0
        self.table[slot] = NULL_PAGE
        return own

    def invalidate(self, page_ids: list[int]) -> None:
        """One jitted reset marking the given pages all-invalid; the id
        array pads to a page-count multiple to bound retraces."""
        if not page_ids or not self.pool:
            return
        n = math.ceil(len(page_ids) / self.pages_per_slot) \
            * self.pages_per_slot
        ids = np.full((n,), TRASH_PAGE, np.int32)
        ids[: len(page_ids)] = page_ids
        self.pool = self._reset_j(self.pool, jnp.asarray(ids))

    def page_ids(self, slot: int) -> list[int]:
        return list(self._owned[slot])

    def table_device(self) -> jax.Array:
        return jnp.asarray(self.table)

    # -- data movement ------------------------------------------------------
    def dense_view(self) -> dict:
        """Materialize the dense cache ([L, slots, ...]) the model decodes
        against; unallocated positions are invalid by construction."""
        view = self._gather_j(self.pool, self.table_device()) if self.pool \
            else {}
        return {**view, **self.state}

    def write_prefill(self, slots: list[int], rows: dict) -> None:
        """Admit prefilled rows: paged leaves scatter into each slot's
        pages ([L, N, ..., S_pad, ...] with S_pad a page multiple, already
        allocated via ``alloc_upto``); state leaves land dense per slot.
        Rows beyond ``len(slots)`` are padding and scatter into TRASH."""
        paged_rows, state_rows = split_leaves(rows)
        if paged_rows:
            n = next(iter(paged_rows.values())).shape[1]
            s_pad = paged_rows["kv_pos"].shape[2] if "kv_pos" in paged_rows \
                else paged_rows["k"].shape[3]
            n_pages = s_pad // self.page_size
            ids = np.full((n, n_pages), TRASH_PAGE, np.int32)
            for i, slot in enumerate(slots):
                own = self._owned[slot][:n_pages]
                ids[i, : len(own)] = own
            self.pool = self._scatter_pages_j(
                self.pool, paged_rows, jnp.asarray(ids)
            )
        if state_rows and slots:
            idx = jnp.asarray(np.asarray(slots, np.int32))
            real = {k: v[:, : len(slots)] for k, v in state_rows.items()}
            self.state = self._state_write_j(self.state, real, idx)

    def token_targets(self, positions: np.ndarray) -> tuple:
        """(page_ids, offsets) arrays routing each slot's next token write;
        slots without an allocated page at that position go to TRASH."""
        pages = np.full((self.slots,), TRASH_PAGE, np.int32)
        offs = np.zeros((self.slots,), np.int32)
        for slot in range(self.slots):
            pos = int(positions[slot])
            idx = pos // self.page_size
            if 0 <= idx < self.pages_per_slot:
                page = int(self.table[slot, idx])
                if page != NULL_PAGE:
                    pages[slot] = page
                    offs[slot] = pos % self.page_size
        return pages, offs
