"""Serving telemetry: TTFT / TPOT / throughput / cache occupancy.

One ``EngineMetrics`` per engine; one ``RequestMetrics`` per request.  The
engine calls the ``on_*`` hooks at submit / admit / first token / finish
and bumps step counters from its scheduling loop; ``summary()`` folds
everything into the flat dict that ``benchmarks/bench_serving.py`` emits
and EXPERIMENTS.md §Serve defines the measurement rules for:

  * **TTFT** — submit → first generated token (queueing + prefill).
  * **TPOT** — (finish − first token) / (new_tokens − 1): steady decode.
  * **throughput** — generated tokens / (first submit → last finish).
  * **occupancy** — used / capacity KV pages, sampled once per engine step.

Percentiles come from ``repro.obs.hist`` (exact linear-interpolated at
small n); per-dispatch wall times stream into log-bucketed
``obs.Histogram``s so a long-running engine keeps bounded-memory latency
distributions — ``histograms()``/``prometheus()`` expose them to the
export layer.  The clock is injectable for deterministic tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from ..obs import Histogram, percentile, prometheus_text


@dataclasses.dataclass
class RequestMetrics:
    """Lifecycle timestamps and counts for one request."""

    uid: int
    prompt_len: int = 0
    submit_t: Optional[float] = None
    admit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    new_tokens: int = 0

    @property
    def ttft(self) -> Optional[float]:
        if self.submit_t is None or self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t

    @property
    def tpot(self) -> Optional[float]:
        """Mean per-token latency over the decode phase."""
        if (self.first_token_t is None or self.finish_t is None
                or self.new_tokens < 2):
            return None
        return (self.finish_t - self.first_token_t) / (self.new_tokens - 1)


def _mean(xs: list) -> float:
    return sum(xs) / len(xs) if xs else 0.0


class ShapeStats:
    """Decayed histogram of the dispatch shapes an engine actually serves
    — the live workload distribution the background retuner
    (``serve/retune.py``) feeds back into a ``CompilerSession``.

    Four shape kinds, each weighted by observed dispatch count:

      * ``attention``      — (seq_q, seq_kv) pairs exactly as the traced
        attention launch resolves them against ``cfg.artifacts``, so a
        retuned record lands under the key the engine will look up;
      * ``prefill_bucket`` — (bucket_tokens, rows) batched-prefill shapes;
      * ``chunk_lane``     — (chunk_tokens, lanes) chunked-prefill lanes;
      * ``decode_batch``   — (active_rows,) decode batch widths.

    ``decay(factor)`` ages every weight (the retuner calls it once per
    cycle), so a shifted workload's new hot shapes overtake stale ones in
    bounded time; ``top_k`` ordering is deterministic — ties break on the
    shape tuple — so retune task lists are stable run-to-run.
    """

    KINDS = ("attention", "prefill_bucket", "chunk_lane", "decode_batch")

    def __init__(self):
        self._weights: dict[str, dict[tuple, float]] = {
            k: {} for k in self.KINDS
        }

    def observe(self, kind: str, shape: tuple, weight: float = 1.0) -> None:
        """Record one dispatch of ``shape`` (any extra weight lets callers
        fold in, e.g., token counts instead of call counts)."""
        if kind not in self._weights:
            raise KeyError(f"unknown shape kind {kind!r}; "
                           f"one of {self.KINDS}")
        shape = tuple(int(x) for x in shape)
        bucket = self._weights[kind]
        bucket[shape] = bucket.get(shape, 0.0) + float(weight)

    def decay(self, factor: float = 0.5, floor: float = 1e-3) -> None:
        """Age every weight by ``factor``; entries below ``floor`` are
        dropped so a long-running engine's stats stay bounded."""
        assert 0.0 <= factor <= 1.0
        for bucket in self._weights.values():
            for shape in list(bucket):
                bucket[shape] *= factor
                if bucket[shape] < floor:
                    del bucket[shape]

    def top_k(self, kind: str, k: int) -> list[tuple[tuple, float]]:
        """The ``k`` heaviest shapes of ``kind`` as [(shape, weight)],
        heaviest first; deterministic under ties (shape ascending)."""
        bucket = self._weights[kind]
        ranked = sorted(bucket.items(), key=lambda it: (-it[1], it[0]))
        return ranked[: max(0, int(k))]

    def weight(self, kind: str, shape: tuple) -> float:
        return self._weights[kind].get(tuple(int(x) for x in shape), 0.0)

    def total(self, kind: str) -> float:
        return sum(self._weights[kind].values())

    def counts(self) -> dict:
        """{kind: number of distinct shapes} — cheap summary column."""
        return {k: len(b) for k, b in self._weights.items()}


class EngineMetrics:
    """Per-engine counters + the registry of per-request metrics.

    ``ttft_slo_s`` (set by the engine when an SLO-aware policy is active,
    or directly for reporting) turns on the ``ttft_under_slo`` summary
    column: the fraction of finished requests whose TTFT met the deadline.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self.requests: dict[int, RequestMetrics] = {}
        # jitted-call counters: the batching win shows up here directly
        # (N queued prompts admitted in far fewer prefill calls)
        self.prefill_calls = 0
        self.prefill_chunk_calls = 0
        self.prefill_tokens = 0        # real prompt tokens prefilled
        self.prefill_padded_tokens = 0  # bucket-padding overhead tokens
        self.prefill_time_s = 0.0      # wall time inside prefill dispatches
        # prefix-cache counters: hit rate is per admitted request; cached
        # tokens are prompt tokens whose prefill was skipped entirely
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.prefix_cached_tokens = 0
        self.decode_steps = 0
        self.decode_tokens = 0
        # speculative-decode counters (serve.speculative): acceptance rate
        # and tokens-per-target-call are THE speculation win metrics — a
        # non-speculative engine pays one target call per emitted token
        # per slot (tokens/call == 1.0 by definition); speculation beats
        # it exactly when acceptance is nonzero
        self.spec_steps = 0          # batched verify dispatches
        self.spec_slot_steps = 0     # per-slot verify calls (slot, round)
        self.spec_proposed = 0       # draft tokens offered for verification
        self.spec_accepted = 0       # draft tokens the target reproduced
        self.spec_emitted = 0        # tokens emitted via the spec lane
        self.draft_calls = 0         # draft-model decode dispatches
        self.draft_prefill_calls = 0
        self.admitted = 0            # requests granted a slot (on_admit)
        self.finished = 0
        # artifact-epoch swaps adopted at step boundaries (serve→compile
        # loop: how many times this engine picked up retuned kernels)
        self.artifact_swaps = 0
        # live dispatch-shape distribution — what the background retuner
        # reads to decide which shapes deserve search budget
        self.shapes = ShapeStats()
        self.ttft_slo_s: Optional[float] = None
        self._occ_sum = 0.0
        self._occ_max = 0.0
        self._occ_n = 0
        # streaming per-dispatch wall-time distributions (bounded memory)
        self.prefill_hist = Histogram()
        self.decode_hist = Histogram()

    # -- request lifecycle hooks -------------------------------------------
    def on_submit(self, uid: int, prompt_len: int) -> None:
        self.requests[uid] = RequestMetrics(
            uid, prompt_len=prompt_len, submit_t=self.clock()
        )

    def on_admit(self, uid: int) -> None:
        """The request won a slot (admission — NOT first token: a chunked
        prefill admits many steps before its first token emerges)."""
        self.admitted += 1
        r = self.requests.get(uid)
        if r is not None and r.admit_t is None:
            r.admit_t = self.clock()

    def on_first_token(self, uid: int) -> None:
        r = self.requests.get(uid)
        if r is not None and r.first_token_t is None:
            r.first_token_t = self.clock()

    def on_finish(self, uid: int, new_tokens: int) -> None:
        r = self.requests.get(uid)
        if r is not None:
            r.finish_t = self.clock()
            r.new_tokens = new_tokens
        self.finished += 1

    def on_occupancy(self, occ: float) -> None:
        self._occ_sum += occ
        self._occ_max = max(self._occ_max, occ)
        self._occ_n += 1

    def on_prefix_lookup(self, hit: bool, cached_tokens: int) -> None:
        """One admission-time prefix-index lookup (hit ⇒ that many prompt
        tokens skip prefill)."""
        self.prefix_lookups += 1
        if hit:
            self.prefix_hits += 1
            self.prefix_cached_tokens += cached_tokens

    def on_prefill_time(self, dt: float, tokens: int) -> None:
        """Wall time of one prefill dispatch — feeds the SLO policy's
        seconds-per-token estimate.  ``tokens`` is informational (the
        token counters are bumped by the engine alongside)."""
        self.prefill_time_s += dt
        self.prefill_hist.observe(dt)

    def on_decode_time(self, dt: float) -> None:
        """Wall time of one decode (or speculative verify-round)
        dispatch."""
        self.decode_hist.observe(dt)

    def prefill_rate(self) -> float:
        """Observed seconds per prefilled token (0.0 before any data):
        the service-time model behind SLO-aware admission."""
        done = self.prefill_tokens + self.prefill_padded_tokens
        if done <= 0 or self.prefill_time_s <= 0:
            return 0.0
        return self.prefill_time_s / done

    # -- aggregation --------------------------------------------------------
    def summary(self) -> dict:
        done = [r for r in self.requests.values() if r.finish_t is not None]
        ttfts = [r.ttft for r in done if r.ttft is not None]
        tpots = [r.tpot for r in done if r.tpot is not None]
        toks = sum(r.new_tokens for r in done)
        t0 = min((r.submit_t for r in done), default=0.0)
        t1 = max((r.finish_t for r in done), default=0.0)
        wall = max(t1 - t0, 1e-9)
        under_slo = 1.0
        if self.ttft_slo_s is not None and ttfts:
            under_slo = sum(t <= self.ttft_slo_s for t in ttfts) / len(ttfts)
        return {
            "requests": len(done),
            "generated_tokens": toks,
            "wall_s": wall,
            "throughput_tok_s": toks / wall,
            "ttft_mean_s": _mean(ttfts),
            "ttft_p50_s": percentile(ttfts, 50),
            "ttft_p99_s": percentile(ttfts, 99),
            "ttft_under_slo": under_slo,
            "tpot_mean_s": _mean(tpots),
            "prefill_calls": self.prefill_calls,
            "prefill_chunk_calls": self.prefill_chunk_calls,
            "prefill_tokens": self.prefill_tokens,
            "prefill_padded_tokens": self.prefill_padded_tokens,
            "prefix_lookups": self.prefix_lookups,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_rate": self.prefix_hits / max(1, self.prefix_lookups),
            "prefix_cached_tokens": self.prefix_cached_tokens,
            "decode_steps": self.decode_steps,
            "decode_tokens": self.decode_tokens,
            "spec_steps": self.spec_steps,
            "spec_proposed": self.spec_proposed,
            "spec_accepted": self.spec_accepted,
            "spec_emitted": self.spec_emitted,
            "spec_acceptance_rate":
                self.spec_accepted / max(1, self.spec_proposed),
            # emitted tokens per per-slot target call: the sequential
            # token-by-token equivalent is exactly 1.0, so > 1.0 is the
            # speculation speedup (in target-call units); 0.0 = lane unused
            "tokens_per_target_call":
                self.spec_emitted / self.spec_slot_steps
                if self.spec_slot_steps else 0.0,
            "draft_calls": self.draft_calls,
            "draft_prefill_calls": self.draft_prefill_calls,
            "kv_occupancy_mean": self._occ_sum / max(1, self._occ_n),
            "kv_occupancy_max": self._occ_max,
            "artifact_swaps": self.artifact_swaps,
        }

    # -- export surfaces (repro.obs) ----------------------------------------
    def counters(self) -> dict:
        """Monotonic counters + admitted/finished — the Prometheus-side
        view (summary() is the benchmark-side one)."""
        return {
            "admitted": self.admitted,
            "finished": self.finished,
            "prefill_calls": self.prefill_calls,
            "prefill_chunk_calls": self.prefill_chunk_calls,
            "prefill_tokens": self.prefill_tokens,
            "prefix_hits": self.prefix_hits,
            "prefix_cached_tokens": self.prefix_cached_tokens,
            "decode_steps": self.decode_steps,
            "decode_tokens": self.decode_tokens,
            "spec_steps": self.spec_steps,
            "spec_accepted": self.spec_accepted,
            "draft_calls": self.draft_calls,
            "artifact_swaps": self.artifact_swaps,
        }

    def histograms(self) -> dict:
        """Latency distributions: streaming dispatch hists + request-level
        TTFT/TPOT built from the finished-request registry."""
        done = [r for r in self.requests.values() if r.finish_t is not None]
        out = {
            "prefill_dispatch_s": self.prefill_hist,
            "decode_dispatch_s": self.decode_hist,
            "ttft_s": Histogram.from_values(
                t for t in (r.ttft for r in done) if t is not None
            ),
            "tpot_s": Histogram.from_values(
                t for t in (r.tpot for r in done) if t is not None
            ),
        }
        return out

    def prometheus(self, prefix: str = "repro_serve_") -> str:
        """Prometheus text exposition of the engine's telemetry."""
        return prometheus_text(
            self.counters(), self.histograms(), prefix=prefix
        )
