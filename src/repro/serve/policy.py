"""Admission policies: which queued requests claim free slots first.

``PagedServeEngine`` asks its policy to rank the queue every admission
round; the engine then admits in ranked order until slots or KV pages run
out.  Reservation failure stops the round (head-of-line blocking on the
*ranked* head), which keeps the dense engine's deadlock-freedom argument:
``submit()`` rejects requests that can never fit, so a failed reservation
always resolves once a running request releases pages.

Three built-ins:

  * **fcfs** — arrival order; the PR-2 behavior and the fairness baseline.
  * **spf** — shortest-prefill-first: fewest *tokens still to compute*
    (prompt length minus any cached-prefix match) first.  Short requests
    stop queueing behind long prompts, which collapses mean TTFT; the
    prefix-cache interaction is the interesting part — a long prompt with
    a hot cached prefix ranks as a short one.
  * **slo** — TTFT-SLO-aware least-laxity ordering: rank by
    ``(submit + slo) − now − est_prefill``, the latest instant admission
    could start and still make the deadline.  The prefill-time estimate is
    driven by ``metrics.py`` observations (measured seconds per prefilled
    token so far), so the policy adapts to the platform without tuning.

Custom policies subclass ``AdmissionPolicy`` and override ``order``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .metrics import EngineMetrics


@dataclasses.dataclass
class Candidate:
    """One queued request as the policy sees it."""

    req: object                   # serve.engine.Request
    submit_t: float               # metrics submit timestamp
    prefill_tokens: int           # tokens still to compute (after prefix hit)
    order: int                    # arrival index (FCFS tie-break)
    match: object = None          # kvcache.PrefixMatch | None (estimate)


class AdmissionPolicy:
    """Base/FCFS policy: admit in arrival order."""

    name = "fcfs"
    # does ranking read Candidate.prefill_tokens?  When False the engine
    # skips the per-candidate prefix-match estimates entirely (FCFS never
    # looks, so walking the radix tree per queued prompt per round would
    # be pure overhead)
    needs_prefill_estimate = False

    def order(self, cands: list[Candidate], now: float,
              metrics: EngineMetrics) -> list[Candidate]:
        return sorted(cands, key=lambda c: c.order)


class ShortestPrefillFirst(AdmissionPolicy):
    """Fewest prefill tokens first (cached prefixes count as free)."""

    name = "spf"
    needs_prefill_estimate = True

    def order(self, cands, now, metrics):
        return sorted(cands, key=lambda c: (c.prefill_tokens, c.order))


class SLOAware(AdmissionPolicy):
    """Least-laxity-first against a TTFT SLO.

    Laxity = (submit + slo) − now − estimated prefill time; the request
    closest to blowing its deadline (after accounting for how long its
    remaining prefill will take at the observed rate) admits first.
    Requests already past their deadline sort by how overdue they are.
    """

    name = "slo"
    needs_prefill_estimate = True

    def __init__(self, ttft_slo_s: float = 0.5):
        assert ttft_slo_s > 0
        self.ttft_slo_s = ttft_slo_s

    def order(self, cands, now, metrics):
        rate = metrics.prefill_rate()  # observed seconds / prefill token

        def laxity(c: Candidate) -> float:
            deadline = c.submit_t + self.ttft_slo_s
            return deadline - now - c.prefill_tokens * rate

        return sorted(cands, key=lambda c: (laxity(c), c.order))


def make_policy(spec, ttft_slo_s: Optional[float] = None) -> AdmissionPolicy:
    """Resolve an engine's ``admission=`` argument: a policy instance
    passes through; a name picks a built-in (``ttft_slo_s`` feeds the SLO
    policy's deadline)."""
    if isinstance(spec, AdmissionPolicy):
        return spec
    if spec == "fcfs":
        return AdmissionPolicy()
    if spec == "spf":
        return ShortestPrefillFirst()
    if spec == "slo":
        return SLOAware(ttft_slo_s or 0.5)
    raise ValueError(
        f"unknown admission policy {spec!r} (fcfs | spf | slo | instance)"
    )
