"""Background retuning: the serve→compile feedback loop.

The tuning launcher compiles the shapes you *predict*; a serving engine
dispatches the shapes you *get*.  ``BackgroundRetuner`` closes that gap:
it reads the live shape distribution an engine accumulates in
``EngineMetrics.shapes`` (``ShapeStats`` — prefill buckets, chunk lanes,
decode batch widths, attention (seq_q, seq_kv) pairs, each weighted by
observed dispatch count), converts the top-k hot shapes into prioritized
``compiler.tasks.Task``s, and compiles them through a ``CompilerSession``
— reusing the session's cross-task seeding, surrogate oracle tier, and
proposer pool.  When a cycle produces any freshly searched record it
``publish()``-es a new epoch on the engine's ``ArtifactRegistry``; the
engine hot-swaps to it at its next step boundary (no restart, no
mid-step epoch mixing — see ``ArtifactRegistry`` / engine
``_maybe_swap_artifacts``).

The retuner never touches engine internals beyond the three public
surfaces it is built on: ``engine.metrics.shapes``, ``engine.registry``,
``engine.cfg``.  ``run_once()`` is the synchronous unit (and what tests
drive); ``start(interval_s)``/``stop()`` wrap it in a daemon thread for
actual background operation.
"""
from __future__ import annotations

import threading
from typing import Optional

from ..obs import NULL_TRACER, Tracer


class BackgroundRetuner:
    """Shape-aware retuning loop bound to one serving engine.

    Parameters
    ----------
    engine:
        A ``ServeEngine`` / ``PagedServeEngine`` (anything exposing
        ``metrics.shapes``, ``registry`` and ``cfg``).
    session:
        Optional pre-built ``CompilerSession``.  Its ``records`` MUST be
        the registry's ``TuningRecords`` instance, else published epochs
        would not contain the newly compiled records (asserted).  When
        omitted, a measurement-free analytical session over the
        registry's records is built (cheap enough for CI; pass your own
        session to retune with llm-mcts / proposer pools / measured
        re-rank).
    top_k:
        Hot shapes per kind fed into each cycle.
    budget:
        Per-task sample budget of the default session (ignored when a
        session is passed).
    decay:
        ``ShapeStats.decay`` factor applied after every cycle, so a
        shifted workload's new hot shapes overtake stale ones.
    """

    def __init__(
        self,
        engine,
        session=None,
        *,
        top_k: int = 4,
        budget: int = 32,
        decay: float = 0.5,
        method: str = "mcts",
        tracer: Optional[Tracer] = None,
    ):
        from ..compiler.session import CompilerSession

        self.engine = engine
        self.registry = engine.registry
        if self.registry is None:
            raise ValueError("engine has no ArtifactRegistry to publish "
                             "retuned epochs into")
        self.trace = tracer or getattr(engine, "trace", None) or NULL_TRACER
        if session is None:
            session = CompilerSession(
                self.registry.platform,
                oracle="analytical",
                method=method,
                budget_policy=budget,
                records=self.registry.records,
                measure=False,
                tracer=self.trace,
            )
        assert session.records is self.registry.records, (
            "retune session must write the registry's TuningRecords — "
            "published epochs snapshot registry.records"
        )
        self.session = session
        self.top_k = top_k
        self.decay = decay
        # telemetry
        self.cycles = 0
        self.published_epochs: list[int] = []
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------------
    # one synchronous cycle
    # ------------------------------------------------------------------
    def hot_tasks(self) -> list:
        """Observed top-k shapes → prioritized compile tasks (pure)."""
        from ..compiler.tasks import tasks_for_shapes

        stats = self.engine.metrics.shapes
        attention = stats.top_k("attention", self.top_k)
        # MLP/GEMM m dim == tokens per dispatch: prefill buckets feed
        # s_tok, chunk lanes feed chunk_tokens.  Merge by m.
        gemm_m: dict[int, float] = {}
        for (shape, w) in stats.top_k("prefill_bucket", self.top_k):
            gemm_m[shape[0]] = gemm_m.get(shape[0], 0.0) + w
        for (shape, w) in stats.top_k("chunk_lane", self.top_k):
            gemm_m[shape[0]] = gemm_m.get(shape[0], 0.0) + w
        return tasks_for_shapes(
            self.engine.cfg,
            attention=attention,
            gemm_m=sorted(gemm_m.items(), key=lambda it: (-it[1], it[0])),
            tp=getattr(self.engine, "_block_tp", 1),
        )

    def run_once(self) -> dict:
        """One retune cycle: read stats → compile hot shapes → publish.

        Returns a summary dict ``{tasks, fresh, cache_hits, epoch}``;
        ``epoch`` is ``None`` when nothing new was compiled (every hot
        shape already had a record, so there is nothing to publish and
        engines keep their current epoch — swaps stay meaningful).
        """
        with self.trace.span("retune-cycle", cat="retune",
                             cycle=self.cycles) as sp:
            tasks = self.hot_tasks()
            arts = self.session.compile(tasks) if tasks else []
            fresh = [a for a in arts if not a.cache_hit]
            epoch = None
            if fresh:
                epoch = self.registry.publish()
                self.published_epochs.append(epoch)
                self.trace.instant(
                    "artifact-publish", cat="retune", epoch=epoch,
                    fresh=len(fresh),
                )
            self.engine.metrics.shapes.decay(self.decay)
            self.cycles += 1
            summary = {
                "tasks": len(tasks),
                "fresh": len(fresh),
                "cache_hits": len(arts) - len(fresh),
                "epoch": epoch,
            }
            sp.set(**summary)
        return summary

    # ------------------------------------------------------------------
    # thread driver
    # ------------------------------------------------------------------
    def start(self, interval_s: float = 5.0) -> None:
        """Run ``run_once`` every ``interval_s`` seconds in a daemon
        thread until ``stop()``.  The engine only observes the loop
        through atomic ``registry.publish`` epochs, so no engine lock is
        taken; compile work happens entirely off the serving thread."""
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError("retuner already running")
        self._stop.clear()

        def loop():
            while not self._stop.wait(interval_s):
                self.run_once()

        self._thread = threading.Thread(
            target=loop, name="repro-retune", daemon=True
        )
        self._thread.start()

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None
