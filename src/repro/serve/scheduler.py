"""Admission/step scheduler over the paged KV cache: prefix-cached,
policy-ordered, batched + chunked prefill with continuous-batching decode.

The dense ``ServeEngine`` admits one request per jitted prefill call and
re-traces per distinct prompt length — admission serializes behind
sequential prefill, exactly the bottleneck ROADMAP's "serving-engine batch
sharding" item names.  ``PagedServeEngine`` replaces that path with:

  * **Batched prefill** — every admission round fills all free slots from
    the queue in one jitted ``models.model.prefill`` call per *bucket*
    (prompt lengths padded to power-of-two page counts, batch rows padded
    to power-of-two; padding is exact because ``lengths`` masking
    invalidates pad positions and causal attention never lets pad tokens
    into real rows).  Archs where extra tokens are NOT function-preserving
    — recurrent state (xlstm / hybrid) advances on every input token, MoE
    capacity dropping depends on the dispatched token count — still batch,
    but group by exact prompt length with no padding.
  * **Prefix caching** (``prefix_cache=True``, dense blocks) — admission
    matches each prompt against the ``kvcache.PrefixIndex`` radix tree of
    previously computed pages.  Matched full pages attach to the new slot
    by reference (copy-on-write protected); only the unmatched suffix is
    prefilled, through a chunk lane seeded with the shared prefix K/V.
    Cached tokens skip prefill FLOPs entirely and the result is
    bit-identical to a from-scratch prefill (same tokens at the same
    absolute positions produce the same K/V).
  * **Batched chunked prefill** — prompts longer than ``prefill_chunk``
    (dense blocks only) and prefix-hit suffixes advance through *lanes*:
    all mid-prefill slots with the same chunk length advance in ONE jitted
    ``models.model.prefill_chunk`` call per length bucket (per-row start
    offsets), interleaved with decode so active requests' TPOT does not
    stall behind long admissions.
  * **Speculative decode lane** (``speculative=True``, dense blocks) —
    the single-token decode iteration is replaced by a draft-propose /
    batch-verify / merge round (``serve.speculative``): a draft model
    proposes ``draft_len`` tokens per slot, one batched
    ``models.model.verify_step`` call scores them all, and accepted
    tokens commit to the page pool in one TRASH-routed scatter.  Greedy
    output is bit-identical to the single-token path; admission, chunk
    lanes, and prefix caching compose unchanged.
  * **Policy-ordered admission** — a pluggable ``AdmissionPolicy``
    (``policy.py``) ranks the queue each round: FCFS,
    shortest-prefill-first, or TTFT-SLO-aware least-laxity ordering driven
    by observed prefill rates.
  * **Paged KV + donated buffers** — cache storage lives in
    ``kvcache.PagedKVCache``; the decode step fuses page-gather → batched
    decode → token-scatter in ONE jitted call whose pool/state buffers are
    donated, so the mesh-committed layout is updated in place (no
    per-iteration ``device_put``).  Params are committed once at
    construction.

Telemetry (``serve.metrics``) records TTFT / TPOT / throughput / page
occupancy / prefix hit rate / jitted-call counts;
``benchmarks/bench_serving.py`` turns them into the repo's serving perf
number (protocol: EXPERIMENTS.md §Serve).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import model as M
from ..obs import NULL_TRACER, Tracer
from . import kvcache as KV
from .engine import Request, batched_decode_fn
from .metrics import EngineMetrics
from .policy import AdmissionPolicy, Candidate, make_policy


@dataclasses.dataclass
class _Prefilling:
    """A slot mid-way through (possibly prefix-seeded) chunked prefill."""

    req: Request
    done: int      # prompt tokens already processed (cached or computed)
    cache: dict    # dense scratch row [L, 1, ...] the chunks write into


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


class PagedServeEngine:
    """Continuous batching over a paged KV cache with batched admission,
    prompt-prefix reuse, and policy-ordered scheduling."""

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        slots: int = 4,
        max_len: int = 512,
        page_size: int = 16,
        capacity: Optional[int] = None,
        prefill_chunk: int = 0,
        prefix_cache: bool = False,
        admission: Union[str, AdmissionPolicy] = "fcfs",
        ttft_slo_s: Optional[float] = None,
        speculative: bool = False,
        draft_cfg: Optional[ArchConfig] = None,
        draft_params=None,
        draft_len: int = 4,
        backend: Optional[str] = None,
        mesh=None,
        tp: int = 1,
        registry=None,
        metrics: Optional[EngineMetrics] = None,
        tracer: Optional[Tracer] = None,
    ):
        """``tp`` must match the degree the params were built with
        (``init_params(cfg, key, tp)``) so the pool's padded KV-head axis
        lines up with the weights — and can shard over "model"."""
        assert cfg.has_decode, f"{cfg.name} is encoder-only"
        # Tuned-kernel resolution: bind the registry's current artifact
        # epoch for this engine's tp degree onto cfg (repro.compiler) —
        # every lazy trace below resolves blocks from this engine-owned
        # immutable epoch, so concurrent engines with different sharding
        # cannot race on a global, and a ``registry.publish()`` (e.g.
        # from a background retuner) is adopted at the next step boundary
        # without restart.
        from ..compiler.artifacts import ArtifactRegistry

        self.registry = registry if registry is not None \
            else ArtifactRegistry()
        cfg, self._block_tp = self.registry.bind(cfg, mesh=mesh, tp=tp)
        self._artifact_epoch = getattr(cfg.artifacts, "epoch", 0)
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.backend = backend
        self.mesh = mesh
        # chunked prefill and prefix reuse need stateless layers AND
        # deterministic token dispatch (MoE capacity dropping is
        # count-dependent), so both only engage on dense blocks
        self.prefill_chunk = prefill_chunk if cfg.block == "dense" else 0
        self.prefix_enabled = prefix_cache and cfg.block == "dense"
        self.trace = tracer or NULL_TRACER

        self.kv = KV.PagedKVCache(
            cfg, slots, max_len, page_size=page_size, capacity=capacity,
            prefix_cache=self.prefix_enabled, mesh=mesh, tp=tp,
            tracer=tracer,
        )
        self.params = params
        if mesh is not None:
            from ..dist import sharding as shd
            self.params = jax.device_put(
                params,
                shd.named_shardings(
                    shd.param_specs(cfg, params, mesh), mesh
                ),
            )

        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}
        self.prefilling: dict[int, _Prefilling] = {}
        self.positions = np.zeros((slots,), np.int32)
        self.metrics = metrics or EngineMetrics()
        self.policy = make_policy(admission, ttft_slo_s)
        if ttft_slo_s is not None:
            self.metrics.ttft_slo_s = ttft_slo_s
        elif getattr(self.policy, "ttft_slo_s", None) is not None:
            self.metrics.ttft_slo_s = self.policy.ttft_slo_s
        self._arrivals = 0
        self._arrival_order: dict[int, int] = {}

        self._prefill_jits: dict[int, callable] = {}
        self._chunk_jits: dict[tuple[int, int], callable] = {}
        self._decode_j = self._build_decode()

        # speculative decode lane (draft-propose / batch-verify / merge):
        # replaces the single-token decode iteration; admission, chunked
        # prefill lanes, and prefix caching are unchanged and compose
        self.spec = None
        if speculative:
            from .speculative import SpeculativeDecoder

            self.spec = SpeculativeDecoder(
                cfg, self.params, self.kv, slots=slots,
                draft_cfg=draft_cfg, draft_params=draft_params,
                draft_len=draft_len, backend=backend,
                metrics=self.metrics, tracer=tracer,
            )

    # -- public API ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        assert len(req.prompt) < self.max_len, (
            f"prompt of {len(req.prompt)} tokens does not fit "
            f"max_len={self.max_len}"
        )
        budget = min(len(req.prompt) + req.max_new_tokens, self.max_len)
        if self.kv.pages_needed(budget) > self.kv.capacity:
            # reject up front: once queued, an unserveable request would
            # deadlock admission after the pool drains
            raise ValueError(
                f"request {req.uid} needs {self.kv.pages_needed(budget)} "
                f"KV pages but the pool capacity is {self.kv.capacity}"
            )
        self.queue.append(req)
        self._arrival_order[req.uid] = self._arrivals
        self._arrivals += 1
        self.metrics.on_submit(req.uid, len(req.prompt))

    def run(self, max_iters: int = 10_000) -> list[Request]:
        """Drive until queue + active + prefilling drain."""
        finished: list[Request] = []
        for _ in range(max_iters):
            if not self.queue and not self.active and not self.prefilling:
                break
            finished.extend(self.step())
        return finished

    def step(self) -> list[Request]:
        """One engine iteration: admit, advance chunked prefills, decode.

        The artifact-epoch check runs first, so a retuner's
        ``registry.publish()`` lands exactly AT a step boundary: every
        dispatch inside one step resolves against a single epoch (no
        mid-step mixing), and the swap changes tiling only — greedy
        outputs are bit-identical across it (tier-1 asserted)."""
        self._maybe_swap_artifacts()
        self._admit()
        self._advance_prefill()
        return self._decode_iteration()

    def _maybe_swap_artifacts(self) -> bool:
        """Adopt a newer published artifact epoch between steps: rebind
        cfg and drop every jit cache that closed over the old epoch's
        blocks (they re-trace lazily against the new ones).  The old
        epoch stays pinned — resolvable in the registry — until this
        boundary, then its refcount drops."""
        reg = self.registry
        if reg is None or reg.epoch == self._artifact_epoch:
            return False
        art = reg.acquire(tp=self._block_tp)
        old = self._artifact_epoch
        self.cfg = dataclasses.replace(self.cfg, artifacts=art)
        self._prefill_jits.clear()
        self._chunk_jits.clear()
        self._decode_j = self._build_decode()
        if self.spec is not None:
            self.spec.rebind_artifacts(self.cfg)
        self._artifact_epoch = art.epoch
        try:
            reg.unpin(old)
        except (KeyError, ValueError):
            pass  # pre-bound cfg: epoch was never pinned by this engine
        self.metrics.artifact_swaps += 1
        self.trace.instant(
            "artifact-swap", cat="serve", epoch=art.epoch, from_epoch=old,
            records=len(art.records),
        )
        return True

    # -- admission ----------------------------------------------------------
    def _free_slots(self) -> list[int]:
        return [
            s for s in range(self.slots)
            if s not in self.active and s not in self.prefilling
        ]

    def _candidates(self, now: float) -> list[Candidate]:
        """The queue as the admission policy sees it: arrival order,
        submit time, and — only when the policy ranks by cost — the
        prefill cost *after* prefix matching.  The estimate match is
        LRU-neutral (``touch=False``) and admission re-matches fresh, so
        ranking can neither perturb eviction order nor hand out pages
        evicted between rank and admit."""
        estimate = self.prefix_enabled \
            and self.policy.needs_prefill_estimate
        out = []
        for req in self.queue:
            match = self.kv.match_prefix(req.prompt, touch=False) \
                if estimate else None
            rm = self.metrics.requests.get(req.uid)
            out.append(Candidate(
                req=req,
                submit_t=rm.submit_t if rm is not None else now,
                prefill_tokens=len(req.prompt)
                - (match.tokens if match else 0),
                order=self._arrival_order.get(req.uid, 0),
                match=match,
            ))
        return out

    def _admit(self) -> None:
        free = self._free_slots()
        if not free or not self.queue:
            return
        with self.trace.span("admit", cat="serve", queued=len(self.queue),
                             free_slots=len(free)) as sp:
            self._admit_ranked(free, sp)

    def _admit_ranked(self, free: list[int], sp) -> None:
        now = self.metrics.clock()
        ranked = self.policy.order(self._candidates(now), now, self.metrics)
        admitted: set[int] = set()
        batch: list[tuple[int, Request]] = []
        for cand in ranked:
            if not free:
                break
            req = cand.req
            slot = free[0]
            budget = min(len(req.prompt) + req.max_new_tokens, self.max_len)
            need = self.kv.pages_needed(budget)
            # fresh match: ranking used a touch-free estimate, but pages
            # may have been evicted while earlier candidates reserved
            match = self.kv.match_prefix(req.prompt) \
                if self.prefix_enabled else None
            if match is not None:
                # attach BEFORE reserving: host-only refs that (a) keep
                # the matched pages safe from reserve's eviction and
                # (b) roll back for free if the reservation fails — the
                # boundary copy is deferred until admission is certain
                self.kv.attach_prefix(slot, match)
                ok = self.kv.reserve(
                    slot, need,
                    cow=1 if match.boundary_page is not None else 0,
                )
                if not ok and match.boundary_page is not None:
                    # tight pool: give up the boundary copy and retry on
                    # the full pages alone — detaching makes the donor's
                    # boundary page itself evictable, which can be the
                    # very page the shortfall needs
                    self.kv.release(slot)
                    trimmed = len(match.pages) * self.kv.page_size
                    match = KV.PrefixMatch(trimmed, match.pages, None, 0) \
                        if trimmed else None
                    if match is not None:
                        self.kv.attach_prefix(slot, match)
                        ok = self.kv.reserve(slot, need)
                    else:
                        ok = self.kv.reserve(slot, need)
                if not ok and match is not None:
                    self.kv.release(slot)  # decrefs only: nothing copied
            else:
                ok = self.kv.reserve(slot, need)
            if not ok:
                # submit() rejects requests that can NEVER fit, so a failed
                # reservation always resolves once running requests release
                break  # wait for a release to free pages
            if self.prefix_enabled:
                self.metrics.on_prefix_lookup(
                    match is not None, match.tokens if match else 0
                )
            self.metrics.on_admit(req.uid)
            self.trace.begin(
                f"req{req.uid}", cat="request", track=f"slot{slot}",
                uid=req.uid, prompt_len=len(req.prompt),
                cached_tokens=match.tokens if match else 0,
            )
            if match is not None:
                # lane seeded with the shared prefix K/V: only the suffix
                # is ever computed.  The boundary page goes private first
                # (reserve counted its copy), so the gather below never
                # exposes a donor's tail tokens
                if match.boundary_page is not None:
                    self.kv.ensure_writable(
                        slot, len(match.pages), match.tokens
                    )
                self.prefilling[slot] = _Prefilling(
                    req, match.tokens, self.kv.gather_row(slot)
                )
            elif self.prefill_chunk and \
                    len(req.prompt) > self.prefill_chunk:
                self.prefilling[slot] = _Prefilling(
                    req, 0, M.init_cache(self.cfg, 1, self.kv.view_len)
                )
            else:
                batch.append((slot, req))
            free.pop(0)
            admitted.add(req.uid)
        if admitted:
            self.queue = deque(
                r for r in self.queue if r.uid not in admitted
            )
            for uid in admitted:   # only read while queued: keep bounded
                self._arrival_order.pop(uid, None)
        sp.set(admitted=len(admitted))
        self._batched_prefill(batch)

    def _bucket_tokens(self, plen: int) -> int:
        """Prompt-length bucket: power-of-two page count (bounds jit
        retraces to O(log max_len) distinct prefill shapes)."""
        pages = min(_next_pow2(self.kv.pages_needed(plen)),
                    self.kv.pages_per_slot)
        return pages * self.kv.page_size

    def _prefill_fn(self, cache_len: int):
        fn = self._prefill_jits.get(cache_len)
        if fn is None:
            cfg, backend = self.cfg, self.backend

            def f(p, toks, lens):
                return M.prefill(
                    cfg, p, {"tokens": toks}, cache_len, lengths=lens,
                    backend=backend,
                )

            if cfg.block == "moe":
                # MoE capacity dispatch pools tokens across batch rows
                # (group-local, gcd-based), so a b=N prefill drops
                # different tokens than the dense engine's b=1 calls.
                # vmap keeps one jitted admission call but gives every
                # row its own b=1 dispatch — bit-identical to dense.
                def one(p, t, l):
                    lg, cache = M.prefill(
                        cfg, p, {"tokens": t[None]}, cache_len,
                        lengths=l[None], backend=backend,
                    )
                    return lg[0], jax.tree.map(lambda x: x[:, 0], cache)

                def f(p, toks, lens):  # noqa: F811
                    return jax.vmap(
                        one, in_axes=(None, 0, 0), out_axes=(0, 1)
                    )(p, toks, lens)

            fn = self._prefill_jits[cache_len] = jax.jit(f)
        return fn

    def _batched_prefill(self, items: list[tuple[int, Request]]) -> None:
        if not items:
            return
        # Padding is only function-preserving for pure attention blocks:
        # recurrent state advances on pad tokens, and MoE capacity-based
        # dropping depends on the dispatched token count, so both group by
        # EXACT length (batched, but no pad tokens and no dummy rows).
        pad_ok = self.cfg.block == "dense"
        groups: dict[int, list[tuple[int, Request]]] = {}
        for slot, req in items:
            plen = len(req.prompt)
            key = self._bucket_tokens(plen) if pad_ok else plen
            groups.setdefault(key, []).append((slot, req))

        for key, group in groups.items():
            n = len(group)
            n_pad = min(_next_pow2(n), self.slots) if pad_ok else n
            s_tok = key                                  # tokens fed in
            cache_len = key if pad_ok else \
                self.kv.pages_needed(key) * self.kv.page_size
            toks = np.zeros((n_pad, s_tok), np.int32)
            lens = np.ones((n_pad,), np.int32)
            for i, (_, req) in enumerate(group):
                toks[i, : len(req.prompt)] = req.prompt
                lens[i] = len(req.prompt)
            # Live shape distribution: the dispatched bucket plus the
            # attention (seq_q, seq_kv) pair this bucket resolves through
            # cfg.artifacts — what a background retuner should tune next.
            self.metrics.shapes.observe(
                "prefill_bucket", (s_tok, n_pad), weight=n)
            self.metrics.shapes.observe(
                "attention", (s_tok, s_tok), weight=n)
            with self.trace.span(
                "prefill-bucket", cat="serve", bucket_tokens=s_tok,
                rows=n_pad, slots=[s for s, _ in group],
            ):
                t0 = self.metrics.clock()
                logits, rows = self._prefill_fn(cache_len)(
                    self.params, jnp.asarray(toks), jnp.asarray(lens)
                )
                self.metrics.prefill_calls += 1
                real = int(sum(len(r.prompt) for _, r in group))
                self.metrics.prefill_tokens += real
                self.metrics.prefill_padded_tokens += n_pad * s_tok - real
                self.metrics.on_prefill_time(
                    self.metrics.clock() - t0, n_pad * s_tok
                )
                for slot, req in group:
                    self.kv.alloc_upto(slot, len(req.prompt))
                self.kv.write_prefill([s for s, _ in group], rows)
            if self.spec is not None:
                self.spec.prefill([s for s, _ in group], toks, lens)
            for i, (slot, req) in enumerate(group):
                self.kv.index_prompt(slot, req.prompt)
                req.output.append(int(jnp.argmax(logits[i, -1])))
                self.active[slot] = req
                self.positions[slot] = len(req.prompt)
                self.metrics.on_first_token(req.uid)
                self.trace.instant("first-token", cat="request",
                                   track=f"slot{slot}", uid=req.uid)

    # -- chunked prefill lanes ----------------------------------------------
    def _chunk_fn(self, take: int, n: int):
        """One jitted lane advance per (chunk length, lane count): the n
        scratch rows concatenate inside the jit (donated), prefill_chunk
        runs with per-row starts, and callers split the result back out."""
        key = (take, n)
        fn = self._chunk_jits.get(key)
        if fn is None:
            cfg, backend = self.cfg, self.backend

            def f(p, toks, rows, starts):
                cache = jax.tree.map(
                    lambda *xs: jnp.concatenate(xs, axis=1), *rows
                )
                return M.prefill_chunk(
                    cfg, p, toks, cache, starts, backend=backend
                )

            fn = self._chunk_jits[key] = jax.jit(f, donate_argnums=(2,))
        return fn

    def _advance_prefill(self) -> None:
        """Advance every mid-prefill slot one chunk — batched: lanes with
        the same chunk length this step share one jitted call (per-row
        start offsets make the batch exact; see ``M.prefill_chunk``)."""
        if not self.prefilling:
            return
        groups: dict[int, list[tuple[int, _Prefilling]]] = {}
        for slot, st in self.prefilling.items():
            remain = len(st.req.prompt) - st.done
            take = min(self.prefill_chunk, remain) if self.prefill_chunk \
                else remain
            groups.setdefault(take, []).append((slot, st))
        for take, group in groups.items():
            n = len(group)
            toks = np.zeros((n, take), np.int32)
            starts = np.zeros((n,), np.int32)
            for i, (_, st) in enumerate(group):
                toks[i] = st.req.prompt[st.done: st.done + take]
                starts[i] = st.done
            rows = [st.cache for _, st in group]
            self.metrics.shapes.observe("chunk_lane", (take, n), weight=n)
            with self.trace.span(
                "chunk-lane", cat="serve", chunk_tokens=take, lanes=n,
                slots=[s for s, _ in group],
            ):
                t0 = self.metrics.clock()
                logits, cache = self._chunk_fn(take, n)(
                    self.params, jnp.asarray(toks), rows,
                    jnp.asarray(starts)
                )
                self.metrics.prefill_chunk_calls += 1
                self.metrics.prefill_tokens += n * take
                self.metrics.on_prefill_time(
                    self.metrics.clock() - t0, n * take
                )
            for i, (slot, st) in enumerate(group):
                st.cache = jax.tree.map(lambda x: x[:, i: i + 1], cache)
                st.done += take
                if st.done >= len(st.req.prompt):
                    self._finish_lane(slot, st, logits[i])

    def _finish_lane(self, slot: int, st: _Prefilling, logits_row) -> None:
        """Final chunk done: move the scratch row into pages (shared
        prefix pages are skipped — the bulk scatter never writes a page
        with refcount > 1) and activate the request."""
        req = st.req
        plen = len(req.prompt)
        self.kv.alloc_upto(slot, plen)
        s_pad = self.kv.pages_needed(plen) * self.kv.page_size
        rows = {
            name: (leaf[:, :, :, :s_pad] if name in ("k", "v")
                   else leaf[:, :, :s_pad] if name == "kv_pos"
                   else leaf)
            for name, leaf in st.cache.items()
        }
        self.kv.write_prefill([slot], rows)
        self.kv.index_prompt(slot, req.prompt)
        if self.spec is not None:
            # the draft holds no pages: it prefills the full prompt even
            # when the target side adopted a cached prefix
            s_tok = self._bucket_tokens(plen)
            dtoks = np.zeros((1, s_tok), np.int32)
            dtoks[0, :plen] = req.prompt
            self.spec.prefill([slot], dtoks, np.asarray([plen], np.int32))
        req.output.append(int(jnp.argmax(logits_row[-1])))
        self.active[slot] = req
        self.positions[slot] = plen
        self.metrics.on_first_token(req.uid)
        self.trace.instant("first-token", cat="request",
                           track=f"slot{slot}", uid=req.uid)
        del self.prefilling[slot]

    # -- decode -------------------------------------------------------------
    def _build_decode(self):
        vdec = batched_decode_fn(self.cfg, self.backend)

        def step(p, toks, pool, state, table, positions, page_ids, offs):
            view = KV.gather_view(pool, table) if pool else {}
            logits, cache2 = vdec(p, toks, {**view, **state}, positions)
            paged2, state2 = KV.split_leaves(cache2)
            rows = {}
            for name in ("k", "v"):
                if name in paged2:
                    idx = positions[None, :, None, None, None]
                    rows[name] = jnp.take_along_axis(
                        paged2[name], idx, axis=3
                    )[:, :, :, 0]
            pool2 = KV.scatter_token(pool, rows, page_ids, offs, positions) \
                if pool else pool
            return logits, pool2, state2

        return jax.jit(step, donate_argnums=(2, 3))

    def _decode_iteration(self) -> list[Request]:
        if not self.active:
            return []
        if self.spec is not None:
            return self._spec_iteration()
        toks = np.zeros((self.slots,), np.int32)
        for slot, req in self.active.items():
            toks[slot] = req.output[-1]
            pos = int(self.positions[slot])
            self.kv.alloc_upto(slot, pos + 1)
            # COW guard: decoding into a page another slot or the prefix
            # index still references copies it first
            self.kv.ensure_writable(slot, pos // self.kv.page_size, pos)
        page_ids, offs = self.kv.token_targets(self.positions)
        self.metrics.shapes.observe(
            "decode_batch", (len(self.active),), weight=len(self.active))
        with self.trace.span("decode", cat="serve",
                             rows=len(self.active)):
            t0 = self.metrics.clock()
            logits, self.kv.pool, self.kv.state = self._decode_j(
                self.params, jnp.asarray(toks), self.kv.pool,
                self.kv.state, self.kv.table_device(),
                jnp.asarray(self.positions),
                jnp.asarray(page_ids), jnp.asarray(offs),
            )
            self.metrics.on_decode_time(self.metrics.clock() - t0)
        self.metrics.decode_steps += 1
        self.metrics.decode_tokens += len(self.active)
        self.metrics.on_occupancy(self.kv.occupancy())
        done = []
        freed: list[int] = []
        for slot, req in list(self.active.items()):
            nxt = int(jnp.argmax(logits[slot, -1]))
            req.output.append(nxt)
            self.positions[slot] += 1
            if (len(req.output) >= req.max_new_tokens
                    or (req.eos_id is not None and nxt == req.eos_id)
                    or int(self.positions[slot]) >= self.max_len - 1):
                req.done = True
                done.append(req)
                del self.active[slot]
                self.positions[slot] = 0
                freed.extend(self.kv.release(slot, invalidate=False))
                self.metrics.on_finish(req.uid, len(req.output))
                self.trace.end(f"req{req.uid}", cat="request",
                               track=f"slot{slot}",
                               new_tokens=len(req.output))
        self.kv.invalidate(freed)  # one reset dispatch per step
        return done

    def _spec_iteration(self) -> list[Request]:
        """Speculative decode round: the decoder proposes/verifies/merges
        (1..draft_len+1 tokens per slot); request lifecycle — finish
        detection, slot release, metrics — stays here and mirrors the
        single-token path token-for-token."""
        with self.trace.span("spec-round", cat="serve",
                             rows=len(self.active)):
            t0 = self.metrics.clock()
            emitted = self.spec.step(self.active, self.positions)
            self.metrics.on_decode_time(self.metrics.clock() - t0)
        self.metrics.decode_steps += 1
        self.metrics.on_occupancy(self.kv.occupancy())
        done = []
        freed: list[int] = []
        for slot, req in list(self.active.items()):
            toks = emitted[slot]
            req.output.extend(toks)
            self.positions[slot] += len(toks)
            self.metrics.decode_tokens += len(toks)
            nxt = toks[-1]
            if (len(req.output) >= req.max_new_tokens
                    or (req.eos_id is not None and nxt == req.eos_id)
                    or int(self.positions[slot]) >= self.max_len - 1):
                req.done = True
                done.append(req)
                del self.active[slot]
                self.positions[slot] = 0
                freed.extend(self.kv.release(slot, invalidate=False))
                self.metrics.on_finish(req.uid, len(req.output))
                self.trace.end(f"req{req.uid}", cat="request",
                               track=f"slot{slot}",
                               new_tokens=len(req.output))
        self.kv.invalidate(freed)
        return done
