"""Admission/step scheduler over the paged KV cache: batched + chunked
prefill with continuous-batching decode.

The dense ``ServeEngine`` admits one request per jitted prefill call and
re-traces per distinct prompt length — admission serializes behind
sequential prefill, exactly the bottleneck ROADMAP's "serving-engine batch
sharding" item names.  ``PagedServeEngine`` replaces that path with:

  * **Batched prefill** — every admission round fills all free slots from
    the queue in one jitted ``models.model.prefill`` call per *bucket*
    (prompt lengths padded to power-of-two page counts, batch rows padded
    to power-of-two; padding is exact because ``lengths`` masking
    invalidates pad positions and causal attention never lets pad tokens
    into real rows).  Archs where extra tokens are NOT function-preserving
    — recurrent state (xlstm / hybrid) advances on every input token, MoE
    capacity dropping depends on the dispatched token count — still batch,
    but group by exact prompt length with no padding.
  * **Chunked prefill** — prompts longer than ``prefill_chunk`` (dense
    blocks only) advance one chunk per engine step via
    ``models.model.prefill_chunk``, interleaved with decode so active
    requests' TPOT does not stall behind a long admission.
  * **Paged KV + donated buffers** — cache storage lives in
    ``kvcache.PagedKVCache``; the decode step fuses page-gather → batched
    decode → token-scatter in ONE jitted call whose pool/state buffers are
    donated, so the mesh-committed layout is updated in place (no
    per-iteration ``device_put``).  Params are committed once at
    construction.

Telemetry (``serve.metrics``) records TTFT / TPOT / throughput / page
occupancy / jitted-call counts; ``benchmarks/bench_serving.py`` turns them
into the repo's serving perf number (protocol: EXPERIMENTS.md §Serve).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import model as M
from . import kvcache as KV
from .engine import Request, batched_decode_fn
from .metrics import EngineMetrics


@dataclasses.dataclass
class _Prefilling:
    """A slot mid-way through chunked prefill."""

    req: Request
    done: int      # prompt tokens already processed
    cache: dict    # dense scratch row [L, 1, ...] the chunks write into


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


class PagedServeEngine:
    """Continuous batching over a paged KV cache with batched admission."""

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        slots: int = 4,
        max_len: int = 512,
        page_size: int = 16,
        capacity: Optional[int] = None,
        prefill_chunk: int = 0,
        backend: Optional[str] = None,
        mesh=None,
        tp: int = 1,
        metrics: Optional[EngineMetrics] = None,
    ):
        """``tp`` must match the degree the params were built with
        (``init_params(cfg, key, tp)``) so the pool's padded KV-head axis
        lines up with the weights — and can shard over "model"."""
        assert cfg.has_decode, f"{cfg.name} is encoder-only"
        # Tuned-kernel resolution: bind an artifact set for this engine's
        # tp degree onto cfg (repro.compiler) — every lazy trace below
        # resolves blocks from this engine-owned object, so concurrent
        # engines with different sharding cannot race on a global.
        from ..compiler import bind_artifacts

        cfg, self._block_tp = bind_artifacts(cfg, mesh=mesh, tp=tp)
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.backend = backend
        self.mesh = mesh
        # chunked prefill needs stateless layers AND deterministic token
        # dispatch (MoE capacity dropping is count-dependent), so it only
        # engages on dense blocks
        self.prefill_chunk = prefill_chunk if cfg.block == "dense" else 0

        self.kv = KV.PagedKVCache(
            cfg, slots, max_len, page_size=page_size, capacity=capacity,
            mesh=mesh, tp=tp,
        )
        self.params = params
        if mesh is not None:
            from ..dist import sharding as shd
            self.params = jax.device_put(
                params,
                shd.named_shardings(
                    shd.param_specs(cfg, params, mesh), mesh
                ),
            )

        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}
        self.prefilling: dict[int, _Prefilling] = {}
        self.positions = np.zeros((slots,), np.int32)
        self.metrics = metrics or EngineMetrics()

        self._prefill_jits: dict[int, callable] = {}
        self._chunk_j = jax.jit(
            lambda p, toks, cache, start: M.prefill_chunk(
                cfg, p, toks, cache, start, backend=backend
            ),
            donate_argnums=(2,),
        )
        self._decode_j = self._build_decode()

    # -- public API ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        assert len(req.prompt) < self.max_len, (
            f"prompt of {len(req.prompt)} tokens does not fit "
            f"max_len={self.max_len}"
        )
        budget = min(len(req.prompt) + req.max_new_tokens, self.max_len)
        if self.kv.pages_needed(budget) > self.kv.capacity:
            # reject up front: once queued, an unserveable request would
            # deadlock admission after the pool drains
            raise ValueError(
                f"request {req.uid} needs {self.kv.pages_needed(budget)} "
                f"KV pages but the pool capacity is {self.kv.capacity}"
            )
        self.queue.append(req)
        self.metrics.on_submit(req.uid, len(req.prompt))

    def run(self, max_iters: int = 10_000) -> list[Request]:
        """Drive until queue + active + prefilling drain."""
        finished: list[Request] = []
        for _ in range(max_iters):
            if not self.queue and not self.active and not self.prefilling:
                break
            finished.extend(self.step())
        return finished

    def step(self) -> list[Request]:
        """One engine iteration: admit, advance chunked prefills, decode."""
        self._admit()
        self._advance_prefill()
        return self._decode_iteration()

    # -- admission ----------------------------------------------------------
    def _free_slots(self) -> list[int]:
        return [
            s for s in range(self.slots)
            if s not in self.active and s not in self.prefilling
        ]

    def _admit(self) -> None:
        batch: list[tuple[int, Request]] = []
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue[0]
            budget = min(len(req.prompt) + req.max_new_tokens, self.max_len)
            if not self.kv.reserve(slot, self.kv.pages_needed(budget)):
                # submit() rejects requests that can NEVER fit, so a failed
                # reservation always resolves once running requests release
                break  # FCFS: wait for a release to free pages
            self.queue.popleft()
            batch.append((slot, req))
        if not batch:
            return
        if self.prefill_chunk:
            long = [(s, r) for s, r in batch
                    if len(r.prompt) > self.prefill_chunk]
            batch = [(s, r) for s, r in batch
                     if len(r.prompt) <= self.prefill_chunk]
            for slot, req in long:
                self.prefilling[slot] = _Prefilling(
                    req, 0, M.init_cache(self.cfg, 1, self.kv.view_len)
                )
        self._batched_prefill(batch)

    def _bucket_tokens(self, plen: int) -> int:
        """Prompt-length bucket: power-of-two page count (bounds jit
        retraces to O(log max_len) distinct prefill shapes)."""
        pages = min(_next_pow2(self.kv.pages_needed(plen)),
                    self.kv.pages_per_slot)
        return pages * self.kv.page_size

    def _prefill_fn(self, cache_len: int):
        fn = self._prefill_jits.get(cache_len)
        if fn is None:
            cfg, backend = self.cfg, self.backend

            def f(p, toks, lens):
                return M.prefill(
                    cfg, p, {"tokens": toks}, cache_len, lengths=lens,
                    backend=backend,
                )

            if cfg.block == "moe":
                # MoE capacity dispatch pools tokens across batch rows
                # (group-local, gcd-based), so a b=N prefill drops
                # different tokens than the dense engine's b=1 calls.
                # vmap keeps one jitted admission call but gives every
                # row its own b=1 dispatch — bit-identical to dense.
                def one(p, t, l):
                    lg, cache = M.prefill(
                        cfg, p, {"tokens": t[None]}, cache_len,
                        lengths=l[None], backend=backend,
                    )
                    return lg[0], jax.tree.map(lambda x: x[:, 0], cache)

                def f(p, toks, lens):  # noqa: F811
                    return jax.vmap(
                        one, in_axes=(None, 0, 0), out_axes=(0, 1)
                    )(p, toks, lens)

            fn = self._prefill_jits[cache_len] = jax.jit(f)
        return fn

    def _batched_prefill(self, items: list[tuple[int, Request]]) -> None:
        if not items:
            return
        # Padding is only function-preserving for pure attention blocks:
        # recurrent state advances on pad tokens, and MoE capacity-based
        # dropping depends on the dispatched token count, so both group by
        # EXACT length (batched, but no pad tokens and no dummy rows).
        pad_ok = self.cfg.block == "dense"
        groups: dict[int, list[tuple[int, Request]]] = {}
        for slot, req in items:
            plen = len(req.prompt)
            key = self._bucket_tokens(plen) if pad_ok else plen
            groups.setdefault(key, []).append((slot, req))

        for key, group in groups.items():
            n = len(group)
            n_pad = min(_next_pow2(n), self.slots) if pad_ok else n
            s_tok = key                                  # tokens fed in
            cache_len = key if pad_ok else \
                self.kv.pages_needed(key) * self.kv.page_size
            toks = np.zeros((n_pad, s_tok), np.int32)
            lens = np.ones((n_pad,), np.int32)
            for i, (_, req) in enumerate(group):
                toks[i, : len(req.prompt)] = req.prompt
                lens[i] = len(req.prompt)
            logits, rows = self._prefill_fn(cache_len)(
                self.params, jnp.asarray(toks), jnp.asarray(lens)
            )
            self.metrics.prefill_calls += 1
            real = int(sum(len(r.prompt) for _, r in group))
            self.metrics.prefill_tokens += real
            self.metrics.prefill_padded_tokens += n_pad * s_tok - real
            for slot, req in group:
                self.kv.alloc_upto(slot, len(req.prompt))
            self.kv.write_prefill([s for s, _ in group], rows)
            for i, (slot, req) in enumerate(group):
                req.output.append(int(jnp.argmax(logits[i, -1])))
                self.active[slot] = req
                self.positions[slot] = len(req.prompt)
                self.metrics.on_first_token(req.uid)

    # -- chunked prefill ----------------------------------------------------
    def _advance_prefill(self) -> None:
        for slot, st in list(self.prefilling.items()):
            plen = len(st.req.prompt)
            take = min(self.prefill_chunk, plen - st.done)
            chunk = np.asarray(st.req.prompt[st.done: st.done + take],
                               np.int32)
            logits, st.cache = self._chunk_j(
                self.params, jnp.asarray(chunk)[None], st.cache,
                jnp.int32(st.done),
            )
            self.metrics.prefill_chunk_calls += 1
            self.metrics.prefill_tokens += take
            st.done += take
            if st.done < plen:
                continue
            # final chunk: move the scratch row into pages and activate
            self.kv.alloc_upto(slot, plen)
            s_pad = self.kv.pages_needed(plen) * self.kv.page_size
            rows = {
                name: (leaf[:, :, :, :s_pad] if name in ("k", "v")
                       else leaf[:, :, :s_pad] if name == "kv_pos"
                       else leaf)
                for name, leaf in st.cache.items()
            }
            self.kv.write_prefill([slot], rows)
            req = st.req
            req.output.append(int(jnp.argmax(logits[0, -1])))
            self.active[slot] = req
            self.positions[slot] = plen
            self.metrics.on_first_token(req.uid)
            del self.prefilling[slot]

    # -- decode -------------------------------------------------------------
    def _build_decode(self):
        vdec = batched_decode_fn(self.cfg, self.backend)

        def step(p, toks, pool, state, table, positions, page_ids, offs):
            view = KV.gather_view(pool, table) if pool else {}
            logits, cache2 = vdec(p, toks, {**view, **state}, positions)
            paged2, state2 = KV.split_leaves(cache2)
            rows = {}
            for name in ("k", "v"):
                if name in paged2:
                    idx = positions[None, :, None, None, None]
                    rows[name] = jnp.take_along_axis(
                        paged2[name], idx, axis=3
                    )[:, :, :, 0]
            pool2 = KV.scatter_token(pool, rows, page_ids, offs, positions) \
                if pool else pool
            return logits, pool2, state2

        return jax.jit(step, donate_argnums=(2, 3))

    def _decode_iteration(self) -> list[Request]:
        if not self.active:
            return []
        toks = np.zeros((self.slots,), np.int32)
        for slot, req in self.active.items():
            toks[slot] = req.output[-1]
            self.kv.alloc_upto(slot, int(self.positions[slot]) + 1)
        page_ids, offs = self.kv.token_targets(self.positions)
        logits, self.kv.pool, self.kv.state = self._decode_j(
            self.params, jnp.asarray(toks), self.kv.pool, self.kv.state,
            self.kv.table_device(), jnp.asarray(self.positions),
            jnp.asarray(page_ids), jnp.asarray(offs),
        )
        self.metrics.decode_steps += 1
        self.metrics.decode_tokens += len(self.active)
        self.metrics.on_occupancy(self.kv.occupancy())
        done = []
        freed: list[int] = []
        for slot, req in list(self.active.items()):
            nxt = int(jnp.argmax(logits[slot, -1]))
            req.output.append(nxt)
            self.positions[slot] += 1
            if (len(req.output) >= req.max_new_tokens
                    or (req.eos_id is not None and nxt == req.eos_id)
                    or int(self.positions[slot]) >= self.max_len - 1):
                req.done = True
                done.append(req)
                del self.active[slot]
                self.positions[slot] = 0
                freed.extend(self.kv.release(slot, invalidate=False))
                self.metrics.on_finish(req.uid, len(req.output))
        self.kv.invalidate(freed)  # one reset dispatch per step
        return done
