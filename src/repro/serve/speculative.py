"""Speculative decoding over shared COW pages.

Draft-and-verify decode — the highest-leverage decode-side optimization in
the serving surveys PAPERS.md tracks, and the regime the reasoning-traffic
study says dominates real workloads (long decode, short prefill): a cheap
*draft* model proposes ``k`` tokens per decode slot, and ONE batched
target-model call (``models.model.verify_step``) scores every slot's
proposals at once.  Greedy acceptance keeps the longest prefix of each
slot's proposals whose argmax the target reproduces, then emits the
target's own next token after that prefix (a correction on mismatch, a
bonus token on full acceptance) — so every verify call yields between 1
and k+1 tokens per slot and the emitted stream is **bit-identical to
running the target model token-by-token** (greedy speculative decoding is
lossless by construction; tests/test_serve.py asserts it).

Page-pool integration — the part the PR-5 refcount/COW machinery buys:

  * the verify forward runs over a *gathered* view of the shared page
    pool (extended with scratch TRASH columns so a near-``max_len`` chunk
    never clamps) and does not write the pool;
  * accepted tokens' K/V rows are extracted from the verify cache and
    committed by one ``kvcache.scatter_tokens`` dispatch whose targets
    route every rejected or padded proposal to the pool's TRASH page — a
    rejected draft token therefore never lands in a real page, shared
    pages need no rollback, and sharers (prefix index, forked siblings)
    can never observe a speculative write;
  * pages inside the speculative window ``[pos, pos + k]`` pass through
    the ``ensure_writable`` copy-on-write guard first, exactly like the
    non-speculative decode path, so speculation composes with prefix
    caching and page-table forks (``kvcache.fork_slot``).

The draft model keeps its own DENSE cache (it shares nothing with the
page pool): self-speculative serving (draft == target, ~100% greedy
acceptance) reuses the target params; cross-arch drafting only needs a
matching vocab.  The draft advances ``k + 1`` feeds per round — the
committed token plus its own k proposals — so that on a full acceptance
its cache already holds K/V for every accepted position; after
acceptance one jitted mask resets the draft cache beyond each slot's
accepted bound (per-row accepted-length masking, the dense-cache
analogue of TRASH routing).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import model as M
from ..obs import NULL_TRACER, Tracer
from . import kvcache as KV
from .engine import Request, batched_decode_fn
from .metrics import EngineMetrics


class SpeculativeDecoder:
    """Draft-propose / batch-verify / merge-accepted decode lane over a
    ``PagedKVCache``, driven by ``PagedServeEngine._spec_iteration``."""

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        kv: KV.PagedKVCache,
        *,
        slots: int,
        draft_cfg: Optional[ArchConfig] = None,
        draft_params=None,
        draft_len: int = 4,
        backend: Optional[str] = None,
        metrics: Optional[EngineMetrics] = None,
        tracer: Optional[Tracer] = None,
    ):
        assert cfg.block == "dense", (
            "speculative decoding needs a stateless dense block "
            f"(verify is a chunked forward), got {cfg.block}"
        )
        assert draft_len >= 1
        self.cfg = cfg
        self.params = params
        self.kv = kv
        self.slots = slots
        self.k = int(draft_len)
        self.backend = backend
        self.metrics = metrics if metrics is not None else EngineMetrics()
        self.trace = tracer or NULL_TRACER
        self.draft_cfg = draft_cfg if draft_cfg is not None else cfg
        self.draft_params = draft_params if draft_params is not None \
            else params
        assert self.draft_cfg.block == "dense", (
            f"draft arch must be dense, got {self.draft_cfg.block}"
        )
        assert self.draft_cfg.vocab == cfg.vocab, (
            "draft/target vocab mismatch: "
            f"{self.draft_cfg.vocab} vs {cfg.vocab}"
        )
        assert not kv.state, "speculation over per-slot state leaves"
        # Draft ring is view_len + k + 1 so speculative feeds near max_len
        # never wrap (a wrap would overwrite live low positions; the wrapped
        # entries themselves are masked away after acceptance).
        self.draft_cache = M.init_cache(
            self.draft_cfg, slots, kv.view_len + self.k + 1
        )
        # Verify-view extension: enough TRASH columns appended to the slot
        # tables that start + k + 1 <= view length for every row — the
        # chunk write inside verify_step is a dynamic_update_slice, which
        # would otherwise *clamp* a near-the-end chunk backwards and
        # corrupt every verify position in that row.  The gathered TRASH
        # copies are scratch: the verify view is discarded, and rejected
        # rows' extracted K/V re-routes to TRASH at scatter time anyway.
        self._ext_cols = math.ceil((self.k + 1) / kv.page_size)
        self._draft_dec = jax.jit(
            batched_decode_fn(self.draft_cfg, backend), donate_argnums=(2,)
        )
        self._verify_j = self._build_verify()
        self._scatter_j = jax.jit(KV.scatter_tokens, donate_argnums=(0,))
        self._mask_j = jax.jit(self._mask_tail, donate_argnums=(0,))
        self._draft_admit_jits: dict[tuple, callable] = {}

    def rebind_artifacts(self, cfg) -> None:
        """Adopt the owning engine's newly swapped artifact epoch: take
        the rebound target cfg and rebuild the verify jit so its traces
        resolve blocks from the new epoch (the draft lane keeps its own
        cfg — draft artifacts are not epoch-managed)."""
        self.cfg = cfg
        self._verify_j = self._build_verify()

    # -- jit builders -------------------------------------------------------
    @staticmethod
    def _mask_tail(cache, bounds):
        """Per-row accepted-length masking of the dense draft cache: row
        ``s`` keeps positions < ``bounds[s]`` (0 wipes the row)."""
        kvp = cache["kv_pos"]
        return dict(
            cache, kv_pos=jnp.where(kvp >= bounds[None, :, None], -1, kvp)
        )

    def _build_verify(self):
        cfg, backend, k1 = self.cfg, self.backend, self.k + 1
        vl = self.kv.view_len

        def verify(p, toks, pool, table, starts):
            view = KV.gather_view(pool, table)
            # The gathered TRASH extension columns can carry *valid-looking*
            # kv_pos values (write_prefill routes shared pages and padding
            # rows into TRASH with their real positions) — mask them, or
            # every verify query would attend to TRASH garbage.  Chunk
            # writes landing in the extension (a row near max_len writing
            # past its k_eff) re-enter with kv_pos > every real query's
            # position, so they stay invisible.
            view = dict(
                view, kv_pos=view["kv_pos"].at[:, :, vl:].set(-1)
            )
            logits, cache2 = M.verify_step(
                cfg, p, toks, view, starts, backend=backend
            )
            # extract the chunk's K/V token rows: [L, S, Hkv, k1, hd]
            idx = starts[:, None] + jnp.arange(k1, dtype=jnp.int32)[None]
            rows = {
                name: jnp.take_along_axis(
                    cache2[name], idx[None, :, None, :, None], axis=3
                )
                for name in ("k", "v")
            }
            return logits, rows

        return jax.jit(verify)

    # -- draft admission ----------------------------------------------------
    def prefill(self, slots: list, toks: np.ndarray,
                lens: np.ndarray) -> None:
        """Prefill the draft cache rows for newly admitted requests.

        ``toks`` is [n_pad, S] right-padded prompts, ``lens`` [n_pad] real
        lengths; rows beyond ``len(slots)`` are padding.  The draft always
        prefills the FULL prompt — even when the target side adopted a
        cached prefix, the draft holds no pages to share — which keeps the
        draft a strict add-on cost: speculation can only win decode-side.
        """
        s = int(toks.shape[1])
        key = (s, int(toks.shape[0]), len(slots))
        fn = self._draft_admit_jits.get(key)
        if fn is None:
            dcfg, backend = self.draft_cfg, self.backend

            def f(p, t, l, cache, idx):
                n = idx.shape[0]
                _, rows = M.prefill(
                    dcfg, p, {"tokens": t}, s, lengths=l, backend=backend
                )
                kvp = cache["kv_pos"].at[:, idx].set(-1)
                kvp = kvp.at[:, idx, :s].set(rows["kv_pos"][:, :n])
                return {
                    "k": cache["k"].at[:, idx, :, :s].set(rows["k"][:, :n]),
                    "v": cache["v"].at[:, idx, :, :s].set(rows["v"][:, :n]),
                    "kv_pos": kvp,
                }

            fn = self._draft_admit_jits[key] = jax.jit(
                f, donate_argnums=(3,)
            )
        self.draft_cache = fn(
            self.draft_params, jnp.asarray(toks), jnp.asarray(lens),
            self.draft_cache, jnp.asarray(np.asarray(slots, np.int32)),
        )
        self.metrics.draft_prefill_calls += 1

    # -- one speculative round ----------------------------------------------
    def step(self, active: dict[int, Request],
             positions: np.ndarray) -> dict[int, list]:
        """One draft-propose → batch-verify → merge round over all active
        slots.  Returns ``{slot: emitted tokens}`` — 1..k+1 tokens per
        slot, already truncated at eos / token-budget / max_len bounds —
        greedy-equivalent to stepping the target one token at a time."""
        kv, S, k, pg = self.kv, self.slots, self.k, self.kv.page_size
        pos0 = np.asarray(positions, np.int32).copy()
        t0 = np.zeros((S,), np.int32)
        k_eff = np.zeros((S,), np.int32)
        for slot, req in active.items():
            t0[slot] = req.output[-1]
            # emit-budget for this round: never propose past the request's
            # token budget or the slot's page reservation (budget =
            # min(plen + max_new, max_len) pages were promised at admit)
            e_max = min(req.max_new_tokens - len(req.output),
                        kv.max_len - 1 - int(pos0[slot]))
            k_eff[slot] = max(0, min(k, e_max - 1))

        # 1) draft proposals: k+1 feeds (committed token, then each
        #    proposal) so a full acceptance leaves the draft cache already
        #    holding K/V through pos + k
        drafts = np.zeros((S, k), np.int32)
        cur = jnp.asarray(t0)
        with self.trace.span("draft", cat="spec", k=k,
                             rows=len(active)):
            for j in range(k + 1):
                lg, self.draft_cache = self._draft_dec(
                    self.draft_params, cur, self.draft_cache,
                    jnp.asarray(pos0 + j),
                )
                self.metrics.draft_calls += 1
                if j < k:
                    cur = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
                    drafts[:, j] = np.asarray(cur)

        # 2) COW/alloc the speculative window [pos, pos + k_eff]: writes
        #    only ever land in private pages
        for slot in active:
            p0, ke = int(pos0[slot]), int(k_eff[slot])
            kv.alloc_upto(slot, p0 + ke + 1)
            for idx in range(p0 // pg, (p0 + ke) // pg + 1):
                kv.ensure_writable(slot, idx, p0)

        # 3) ONE batched target verify over [t0, d_1 .. d_k] per slot
        vtoks = np.zeros((S, k + 1), np.int32)
        vtoks[:, 0] = t0
        vtoks[:, 1:] = drafts
        table = np.concatenate([
            kv.table,
            np.full((S, self._ext_cols), KV.TRASH_PAGE, np.int32),
        ], axis=1)
        with self.trace.span("verify", cat="spec", rows=len(active)):
            logits, rows = self._verify_j(
                self.params, jnp.asarray(vtoks), kv.pool,
                jnp.asarray(table), jnp.asarray(pos0),
            )
            self.metrics.spec_steps += 1
            y = np.asarray(jnp.argmax(logits, axis=-1))    # [S, k+1]

        # 4) greedy acceptance + eos truncation (host): position j's
        #    target argmax y[j] judges draft j; the first mismatch (or the
        #    bonus token after k_eff matches) is emitted as-is
        emitted: dict[int, list] = {}
        for slot, req in active.items():
            ke = int(k_eff[slot])
            m = 0
            while m < ke and int(drafts[slot, m]) == int(y[slot, m]):
                m += 1
            toks: list = []
            for j in range(m + 1):
                toks.append(int(y[slot, j]))
                if req.eos_id is not None and toks[-1] == req.eos_id:
                    break
            emitted[slot] = toks
            self.trace.instant("spec-accept", cat="spec",
                               track=f"slot{slot}", proposed=ke,
                               accepted=m, emitted=len(toks))
            self.metrics.spec_slot_steps += 1
            self.metrics.spec_proposed += ke
            self.metrics.spec_accepted += m
            self.metrics.spec_emitted += len(toks)

        # 5) commit accepted K/V in one dispatch; rejected proposals,
        #    emit-truncated tails, and inactive rows all route to TRASH.
        #    Position pos0+j holds the token *fed* there (t0, d_1, ...),
        #    and every fed token below the accepted bound equals its
        #    emitted counterpart — so the committed pages are exactly what
        #    token-by-token decode would have written.
        pages = np.full((S, k + 1), KV.TRASH_PAGE, np.int32)
        offs = np.zeros((S, k + 1), np.int32)
        posv = np.full((S, k + 1), -1, np.int32)
        for slot in active:
            p0 = int(pos0[slot])
            for j in range(len(emitted[slot])):
                p = p0 + j
                pages[slot, j] = kv.table[slot, p // pg]
                offs[slot, j] = p % pg
                posv[slot, j] = p
        with self.trace.span(
            "spec-commit", cat="spec",
            committed=sum(len(t) for t in emitted.values()),
        ):
            kv.pool = self._scatter_j(
                kv.pool, rows, jnp.asarray(pages), jnp.asarray(offs),
                jnp.asarray(posv),
            )

        # 6) draft-cache accepted-length masking: drop draft K/V beyond
        #    each slot's accepted bound (and wipe inactive rows, which the
        #    batched draft feeds scribbled at low positions)
        bounds = np.zeros((S,), np.int32)
        for slot in active:
            bounds[slot] = int(pos0[slot]) + len(emitted[slot])
        self.draft_cache = self._mask_j(self.draft_cache,
                                        jnp.asarray(bounds))
        return emitted
