"""Sharded checkpointing with elastic re-mesh restore.

Format: one ``.npy`` per pytree leaf (path-encoded filename) + a JSON
manifest carrying step, shapes, dtypes, and the data-pipeline state.  Saves
are atomic (write to ``.tmp`` dir, fsync, rename), so a preemption mid-save
never corrupts the latest checkpoint; ``keep`` old checkpoints are retained
for rollback.

Restore is *elastic*: leaves are loaded host-side and ``jax.device_put`` to
whatever NamedSharding the (possibly different) target mesh dictates —
restarting 2-pod training on 1 pod (or vice versa) is a no-op for model
state.  Bitwise-reproducible data resume comes from the pipeline state being
derived from ``step`` alone (data/pipeline.py).
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = leaf
    return flat


def save(
    directory: str,
    step: int,
    params,
    opt_state=None,
    extra: Optional[dict] = None,
    keep: int = 2,
) -> str:
    """Atomically write checkpoint ``<dir>/step_<n>``; returns its path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    trees = {"params": params}
    if opt_state is not None:
        trees["opt"] = opt_state
    for prefix, tree in trees.items():
        for key, leaf in _flatten(tree).items():
            arr = np.asarray(jax.device_get(leaf))
            fname = f"{prefix}__{key.replace('/', '__')}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][f"{prefix}/{key}"] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    ckpts = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in ckpts[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(
    directory: str,
    step: Optional[int],
    params_template,
    opt_template=None,
    shardings=None,
    opt_shardings=None,
) -> tuple[int, Any, Any, dict]:
    """Load ``step`` (default: latest) onto the target mesh (elastic)."""
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoint in {directory}"
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    def load_tree(prefix, template, shard_tree):
        flat_t = _flatten(template)
        flat_s = _flatten(shard_tree) if shard_tree is not None else {}
        loaded = {}
        for key, leaf in flat_t.items():
            meta = manifest["leaves"][f"{prefix}/{key}"]
            arr = np.load(os.path.join(path, meta["file"]))
            arr = arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr
            sh = flat_s.get(key)
            loaded[key] = (
                jax.device_put(arr, sh) if sh is not None
                else jax.numpy.asarray(arr)
            )
        # rebuild the pytree in template order
        leaves_order = [
            loaded[k] for k in _flatten(template).keys()
        ]
        treedef = jax.tree_util.tree_structure(template)
        return jax.tree_util.tree_unflatten(treedef, leaves_order)

    params = load_tree("params", params_template, shardings)
    opt = None
    if opt_template is not None and any(
        k.startswith("opt/") for k in manifest["leaves"]
    ):
        opt = load_tree("opt", opt_template, opt_shardings)
    return step, params, opt, manifest.get("extra", {})


class PreemptionHandler:
    """SIGTERM-safe checkpointing: on preemption, request a save at the next
    step boundary instead of dying mid-update."""

    def __init__(self):
        self.requested = threading.Event()
        self._orig = None

    def install(self):
        self._orig = signal.signal(signal.SIGTERM, self._on_signal)
        return self

    def _on_signal(self, signum, frame):
        self.requested.set()

    def uninstall(self):
        if self._orig is not None:
            signal.signal(signal.SIGTERM, self._orig)
