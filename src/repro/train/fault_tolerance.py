"""Fault-tolerance machinery: straggler watchdog, heartbeats, retry policy.

At 1000+ nodes the dominant failure modes are (a) full node loss — handled
by checkpoint/restart (checkpoint.py, elastic re-mesh), (b) stragglers —
slow-but-alive hosts that stall synchronous steps, and (c) transient step
failures.  This module provides the detection half; the Trainer wires it to
the restart policy (tests inject delays/failures to exercise the paths).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    baseline_s: float

    @property
    def slowdown(self) -> float:
        return self.duration_s / max(self.baseline_s, 1e-9)


class StragglerWatchdog:
    """EWMA step-time baseline; flags steps slower than ``threshold``x.

    In a multi-host deployment the flagged events feed the controller's
    restart/reassign policy; here they are surfaced in trainer metrics and
    asserted in tests with injected delays.
    """

    def __init__(self, threshold: float = 2.5, alpha: float = 0.1,
                 warmup_steps: int = 3):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup_steps = warmup_steps
        self.baseline: Optional[float] = None
        self.events: list[StragglerEvent] = []
        self._seen = 0

    def observe(self, step: int, duration_s: float) -> Optional[StragglerEvent]:
        self._seen += 1
        if self._seen <= self.warmup_steps:
            # warmup (JIT compile, cache fill) must not poison the baseline
            return None
        if self.baseline is None:
            self.baseline = duration_s
            return None
        if duration_s > self.threshold * self.baseline:
            ev = StragglerEvent(step, duration_s, self.baseline)
            self.events.append(ev)
            # do not fold outliers into the baseline
            return ev
        self.baseline = (1 - self.alpha) * self.baseline \
            + self.alpha * duration_s
        return None


class Heartbeat:
    """Liveness signal a controller polls; a silent host => presumed dead."""

    def __init__(self, timeout_s: float = 60.0, clock: Callable = time.time):
        self.timeout_s = timeout_s
        self._clock = clock
        self._last: dict[int, float] = {}

    def beat(self, host: int) -> None:
        self._last[host] = self._clock()

    def dead_hosts(self) -> list[int]:
        now = self._clock()
        return [h for h, t in self._last.items()
                if now - t > self.timeout_s]


@dataclasses.dataclass
class RetryPolicy:
    """Bounded retry with checkpoint rollback on repeated failure."""

    max_retries: int = 3
    failures: int = 0

    def record_failure(self) -> str:
        """Returns the action: 'retry' | 'restore' | 'abort'."""
        self.failures += 1
        if self.failures <= 1:
            return "retry"
        if self.failures <= self.max_retries:
            return "restore"
        return "abort"

    def record_success(self) -> None:
        self.failures = 0
