"""pjit train-step builder: loss + grads + AdamW under named shardings.

``make_train_step`` returns a jit-able pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` with:

  * optional activation rematerialization of the layer scan
    (``remat="full"`` checkpoints each scanned layer body),
  * optional gradient accumulation over ``microbatches`` (lax.scan; the DP
    all-reduce of each microbatch's grads overlaps the next microbatch's
    compute under buffer donation),
  * optional int8 gradient compression between microbatch accumulations.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import model as M
from ..optim import adamw


def make_loss(cfg: ArchConfig, backend: Optional[str], remat: str):
    def loss(params, batch):
        # "full" checkpoints each scanned layer body inside the model —
        # wrapping the whole loss would NOT change what the layer scan saves.
        return M.loss_fn(
            cfg, params, batch, backend=backend, remat=(remat == "full")
        )

    return loss


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: adamw.AdamWConfig,
    *,
    backend: Optional[str] = None,
    microbatches: int = 1,
    remat: str = "none",
    compress: bool = False,
):
    loss = make_loss(cfg, backend, remat)
    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def single(params, opt_state, batch):
        (l, metrics), grads = grad_fn(params, batch)
        params, opt_state, opt_metrics = adamw.update(
            opt_cfg, params, opt_state, grads
        )
        return params, opt_state, {**metrics, **opt_metrics, "total": l}

    if microbatches <= 1:
        return single

    def accumulated(params, opt_state, batch):
        def resh(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        micro = jax.tree.map(resh, batch)

        def mb_step(acc, mb):
            (l, metrics), grads = grad_fn(params, mb)
            if compress:
                grads = adamw.decompress_grads(adamw.compress_grads(grads))
            acc_g, acc_l = acc
            acc_g = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc_g, grads
            )
            return (acc_g, acc_l + l), metrics

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (gsum, lsum), metrics = jax.lax.scan(
            mb_step, (zero, 0.0), micro, unroll=M.SCAN_UNROLL["n"]
        )
        grads = jax.tree.map(lambda g: g / microbatches, gsum)
        params, opt_state, opt_metrics = adamw.update(
            opt_cfg, params, opt_state, grads
        )
        out = {k: jnp.mean(v) for k, v in metrics.items()}
        return params, opt_state, {
            **out, **opt_metrics, "total": lsum / microbatches,
        }

    return accumulated
