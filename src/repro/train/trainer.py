"""The training driver: wires data, train_step, checkpointing, and FT.

Single-host usage (examples/train_lm.py) runs on whatever devices exist;
multi-pod usage goes through ``launch/train.py`` which builds the production
mesh and shards params/batches via ``dist.sharding`` before handing off to
this loop.  The loop itself is mesh-agnostic.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from ..configs.base import ArchConfig, ShapeSpec
from ..data.pipeline import SyntheticLMDataset
from ..models import model as M
from ..optim import adamw
from . import checkpoint as ckpt
from .fault_tolerance import RetryPolicy, StragglerWatchdog
from .train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    microbatches: int = 1
    remat: str = "none"
    compress_grads: bool = False
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        shape: ShapeSpec,
        tcfg: TrainerConfig,
        opt_cfg: Optional[adamw.AdamWConfig] = None,
        backend: Optional[str] = None,
        inject_failure_at: Optional[int] = None,  # tests: simulated fault
        inject_delay_at: Optional[int] = None,    # tests: simulated straggler
    ):
        self.cfg = cfg
        self.shape = shape
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or adamw.AdamWConfig(
            total_steps=tcfg.total_steps
        )
        self.data = SyntheticLMDataset(cfg, shape, seed=tcfg.seed)
        self.watchdog = StragglerWatchdog()
        self.retry = RetryPolicy()
        self._inject_failure_at = inject_failure_at
        self._inject_delay_at = inject_delay_at

        key = jax.random.PRNGKey(tcfg.seed)
        self.params = M.init_params(cfg, key)
        self.opt_state = adamw.init(self.params)
        self.step = 0
        self.history: list[dict] = []

        fn = make_train_step(
            cfg, self.opt_cfg, backend=backend,
            microbatches=tcfg.microbatches, remat=tcfg.remat,
            compress=tcfg.compress_grads,
        )
        self.train_step = jax.jit(fn, donate_argnums=(0, 1))

    # -- checkpointing -------------------------------------------------------
    def save(self) -> Optional[str]:
        if not self.tcfg.checkpoint_dir:
            return None
        return ckpt.save(
            self.tcfg.checkpoint_dir, self.step, self.params,
            self.opt_state, extra={"data": self.data.state_dict()},
        )

    def restore(self, step: Optional[int] = None) -> None:
        assert self.tcfg.checkpoint_dir
        self.step, self.params, self.opt_state, extra = ckpt.restore(
            self.tcfg.checkpoint_dir, step, self.params, self.opt_state
        )
        if "data" in extra:
            self.data.load_state_dict(extra["data"])

    # -- main loop --------------------------------------------------------------
    def run(self) -> list[dict]:
        preempt = ckpt.PreemptionHandler().install()
        try:
            while self.step < self.tcfg.total_steps:
                t0 = time.perf_counter()
                batch = self.data.next_batch()
                try:
                    if self._inject_failure_at == self.step:
                        self._inject_failure_at = None
                        raise RuntimeError("injected node failure")
                    out = self.train_step(
                        self.params, self.opt_state, batch
                    )
                    self.params, self.opt_state, metrics = out
                    self.retry.record_success()
                except RuntimeError:
                    action = self.retry.record_failure()
                    if action == "retry":
                        self.data.state.step -= 1  # replay the batch
                        continue
                    if action == "restore" and self.tcfg.checkpoint_dir \
                            and ckpt.latest_step(self.tcfg.checkpoint_dir) \
                            is not None:
                        self.restore()
                        continue
                    raise
                if self._inject_delay_at == self.step:
                    self._inject_delay_at = None
                    time.sleep(0.2)
                dur = time.perf_counter() - t0
                self.watchdog.observe(self.step, dur)
                self.step += 1
                rec = {
                    "step": self.step,
                    "loss": float(metrics["loss"]),
                    "grad_norm": float(metrics["grad_norm"]),
                    "time_s": dur,
                }
                self.history.append(rec)
                if self.step % self.tcfg.log_every == 0:
                    print(
                        f"step {rec['step']:5d} loss {rec['loss']:.4f} "
                        f"gnorm {rec['grad_norm']:.3f} {dur * 1e3:.0f}ms"
                    )
                if (self.tcfg.checkpoint_dir
                        and (self.step % self.tcfg.checkpoint_every == 0
                             or preempt.requested.is_set())):
                    self.save()
                    if preempt.requested.is_set():
                        print("preemption requested: saved and exiting")
                        break
        finally:
            preempt.uninstall()
        return self.history
