import os
import sys

# Keep smoke tests on the single real device (the dry-run sets its own
# fake-device count in a subprocess; never globally — see launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
