"""Autotuner compat surface: schedule -> Pallas block extraction +
session-backed tuning records (the retired KernelTuner's behaviors, now
expressed through ``CompilerSession``)."""
import os
import tempfile

from repro.core import schedule as S
from repro.compiler import BudgetPolicy, CompilerSession
from repro.compiler.records import TuningRecords
from repro.compiler.tasks import attention_task, gemm_task
from repro.core.autotuner import (
    AttentionBlocks,
    GemmBlocks,
    _quantize_block,
    attention_tuning_workload,
)


def _session(tmp_path, budget=12, **kw):
    """Single-task-semantics session over a tmp record store (what the
    retired KernelTuner used to construct per instance)."""
    records = kw.pop(
        "records", TuningRecords(os.path.join(tmp_path, "records.jsonl")))
    return CompilerSession(
        target="tpu-v5e",
        budget_policy=BudgetPolicy(per_task=budget, early_stop=False,
                                   reallocate=False),
        records=records, shared_context=False, seed=0, **kw,
    )


def test_quantize_block():
    assert _quantize_block(100, 4096, lo=8) == 64
    assert _quantize_block(128, 4096, lo=8) == 128
    assert _quantize_block(3, 4096, lo=8) == 8
    assert _quantize_block(2000, 4096, lo=8, hi=1024) == 1024
    # must divide the extent
    assert 4096 % _quantize_block(100, 4096) == 0


def test_quantize_block_always_divides():
    """Regression: the old fallback returned a bare ``lo`` on extents with
    no power-of-two divisor >= lo (prime/odd extents), which failed the
    Pallas ``extent % block == 0`` launch assert."""
    for extent in (7, 11, 12, 24, 48, 96, 100, 384, 1000, 4097):
        for x in (1, 3, 8, 100, 5000):
            for lo in (8, 128):
                b = _quantize_block(x, extent, lo=lo)
                assert extent % b == 0, (x, extent, lo, b)
                assert b >= 1
    # divisors >= lo are preferred when they exist...
    assert _quantize_block(3, 48, lo=8) == 8
    assert _quantize_block(2, 384, lo=128) == 128
    # ...else the largest legal power-of-two divisor wins
    assert _quantize_block(100, 12, lo=8) == 4
    assert _quantize_block(8, 7, lo=8) == 1


def test_blocks_from_schedule():
    w = attention_tuning_workload(8, 1024, 1024, 128)
    s = S.initial_schedule(w)
    s = S.TileSize("i", (8, 1, 2, 64)).apply(s)
    s = S.TileSize("j", (4, 1, 2, 128)).apply(s)
    b = AttentionBlocks.from_schedule(s)
    assert b.block_q == 128 and b.block_k == 256
    assert 1024 % b.block_q == 0 and 1024 % b.block_k == 0


def test_session_records_cache_across_instances(tmp_path):
    path = os.path.join(tmp_path, "records.jsonl")
    s1 = _session(tmp_path, records=TuningRecords(path))
    (a1,) = s1.compile([gemm_task(256, 512, 512)])
    assert os.path.exists(path)
    assert not a1.cache_hit
    # second session over the same store hits the record (no search)
    s2 = _session(tmp_path, records=TuningRecords(path))
    (a2,) = s2.compile([gemm_task(256, 512, 512)])
    assert a2.cache_hit and s2.cache_hits == 1
    b1, b2 = a1.blocks, a2.blocks
    assert (b1.bm, b1.bn, b1.bk) == (b2.bm, b2.bn, b2.bk)


def test_tuned_blocks_are_legal_for_pallas(tmp_path):
    s = _session(tmp_path, budget=16)
    a, g = s.compile([
        attention_task(8, 512, 512, 64),
        gemm_task(512, 1024, 2048),
    ])
    b = a.blocks
    assert 512 % b.block_q == 0 and 512 % b.block_k == 0
    g = g.blocks
    assert 512 % g.bm == 0 and 1024 % g.bn == 0 and 2048 % g.bk == 0


def test_kv_heads_in_cache_key(tmp_path):
    """GQA shapes must not collide in the tuning records: the same
    query-head count with different (tp-local) KV head counts are
    distinct entries."""
    from repro.compiler.records import record_key

    s = _session(tmp_path)
    s.compile([
        attention_task(8, 256, 256, 64),              # MHA: kv == heads
        attention_task(8, 256, 256, 64, kv_heads=2),  # GQA group of 4
        attention_task(8, 256, 256, 64, kv_heads=1),  # replicated kv
    ])
    keys = s.records.keys()
    assert len(keys) == 3
    assert sum(".kv2" in k for k in keys) == 1
    assert sum(".kv1" in k for k in keys) == 1
    # read-only probe hits without searching; a miss returns None
    hit = record_key("tpu-v5e", attention_tuning_workload(
        8, 256, 256, 64, kv_heads=2))
    assert s.records.get(hit) is not None
    miss = record_key("tpu-v5e", attention_tuning_workload(8, 999, 999, 64))
    assert s.records.get(miss) is None


def test_tuner_measured_rerank_provenance(tmp_path):
    """measure=True re-ranks winners by real timed execution and persists
    measured_latency_s + provenance alongside the block params."""
    s = _session(tmp_path, budget=8, measure=True, rerank_top=2)
    (art,) = s.compile([gemm_task(64, 128, 128)])
    b = art.blocks
    assert 64 % b.bm == 0 and 128 % b.bn == 0 and 128 % b.bk == 0
    (entry,) = s.records.legacy_view().values()
    assert entry["measured_latency_s"] > 0
    prov = entry["provenance"]
    assert prov["oracle"] == "measured"
    assert prov["interpret"] is True          # CPU CI path
    assert prov["repeats"] >= 1 and prov["candidates"] >= 1
    assert prov["search_oracle"] == "analytical"


def test_tuner_measured_search_oracle(tmp_path):
    """oracle="measured" makes every search sample a timed execution."""
    s = _session(tmp_path, budget=6, oracle="measured", method="mcts")
    s.compile([gemm_task(32, 64, 64)])
    (entry,) = s.records.legacy_view().values()
    assert entry["samples"] >= 1


def test_attention_block_uses_tp_local_tuned_blocks(tmp_path, monkeypatch):
    """models/layers.attention_block must launch with the blocks tuned for
    the BOUND tp degree's local head counts — the tp travels inside the
    registry-bound cfg.artifacts, never a module global."""
    import jax
    import jax.numpy as jnp

    from repro.compiler import ArtifactRegistry
    from repro.configs import get_config
    from repro.core.autotuner import local_attention_dims
    from repro.kernels import ops
    from repro.models import layers as L

    cfg = get_config("tinyllama-1.1b")          # 32q / 4kv
    tp = 4
    hq, hkv = local_attention_dims(cfg, tp)     # (8, 1)
    s = _session(tmp_path)
    (art,) = s.compile([attention_task(hq, 128, 128, cfg.hd,
                                       kv_heads=hkv)])
    tuned = art.blocks
    reg = ArtifactRegistry(s.records)
    bound, _ = reg.bind(cfg, tp=tp)

    seen = {}
    real_attention = ops.attention

    def spy(q, k, v, **kw):
        seen.update(kw)
        return real_attention(q, k, v, **kw)

    monkeypatch.setattr(ops, "attention", spy)
    dims = L.AttnDims(heads=hq, kv_heads=hkv, hd=cfg.hd, d_model=128)
    p = L.init_attention(jax.random.PRNGKey(0), dims, jnp.float32)
    x = jnp.zeros((1, 128, 128), jnp.float32)
    pos = jnp.arange(128)[None]
    L.attention_block(x, p, dims, pos, cfg=bound, backend="jax")
    assert (seen["block_q"], seen["block_k"]) == \
        (tuned.block_q, tuned.block_k)


def test_local_attention_dims_match_sharding_rules():
    from repro.configs import get_config
    from repro.core.autotuner import local_attention_dims

    cfg = get_config("tinyllama-1.1b")      # 32q / 4kv
    assert local_attention_dims(cfg, 1) == (32, 4)
    assert local_attention_dims(cfg, 4) == (8, 1)
    # kv (4) < tp (8): kv heads replicate, exactly like dist.rules
    assert local_attention_dims(cfg, 8) == (4, 4)


def test_ops_tuned_lookup_defaults(tmp_path, monkeypatch):
    """kernels.ops consumers get kernel defaults on a cache miss and the
    tuned entry (keyed by tp-local shapes) on a hit — through the SAME
    local_attention_dims mapping launch/tune.py stores entries under,
    including head padding (phi4's 10 kv heads pad to 12 at tp=4)."""
    import json

    from repro.configs import get_config
    from repro.core.autotuner import local_attention_dims
    from repro.kernels import ops

    cfg = get_config("phi4-mini-3.8b")          # 24q / 8kv... padded rules
    hq, hkv = local_attention_dims(cfg, 4)
    s = _session(tmp_path)
    (art,) = s.compile([attention_task(hq, 256, 256, cfg.hd,
                                       kv_heads=hkv)])
    tuned = art.blocks
    cache = os.path.join(tmp_path, "tc.json")
    s.records.export_json(cache)                 # v0 mirror for old readers
    monkeypatch.setattr(
        ops, "_RECORDS", TuningRecords(None, legacy_json=cache))
    bq, bk = ops.tuned_attention_blocks(cfg, 256, 256, tp=4)
    assert (bq, bk) == (tuned.block_q, tuned.block_k)
    assert json.load(open(cache))  # persisted
    # miss -> defaults, no search side effects
    assert ops.tuned_attention_blocks(cfg, 64, 64, tp=1) == (128, 128)
