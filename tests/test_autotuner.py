"""Autotuner: schedule -> Pallas block extraction + tuning cache."""
import os
import tempfile

from repro.core import schedule as S
from repro.core.autotuner import (
    AttentionBlocks,
    GemmBlocks,
    KernelTuner,
    _quantize_block,
    attention_tuning_workload,
)


def test_quantize_block():
    assert _quantize_block(100, 4096, lo=8) == 64
    assert _quantize_block(128, 4096, lo=8) == 128
    assert _quantize_block(3, 4096, lo=8) == 8
    assert _quantize_block(2000, 4096, lo=8, hi=1024) == 1024
    # must divide the extent
    assert 4096 % _quantize_block(100, 4096) == 0


def test_blocks_from_schedule():
    w = attention_tuning_workload(8, 1024, 1024, 128)
    s = S.initial_schedule(w)
    s = S.TileSize("i", (8, 1, 2, 64)).apply(s)
    s = S.TileSize("j", (4, 1, 2, 128)).apply(s)
    b = AttentionBlocks.from_schedule(s)
    assert b.block_q == 128 and b.block_k == 256
    assert 1024 % b.block_q == 0 and 1024 % b.block_k == 0


def test_tuner_caches(tmp_path):
    cache = os.path.join(tmp_path, "cache.json")
    t = KernelTuner(budget=12, cache_path=cache)
    b1 = t.tune_gemm(256, 512, 512)
    assert os.path.exists(cache)
    # second tuner instance hits the cache (no search)
    t2 = KernelTuner(budget=12, cache_path=cache)
    b2 = t2.tune_gemm(256, 512, 512)
    assert (b1.bm, b1.bn, b1.bk) == (b2.bm, b2.bn, b2.bk)


def test_tuned_blocks_are_legal_for_pallas(tmp_path):
    t = KernelTuner(budget=16,
                    cache_path=os.path.join(tmp_path, "c.json"))
    b = t.tune_attention(8, 512, 512, 64)
    assert 512 % b.block_q == 0 and 512 % b.block_k == 0
    g = t.tune_gemm(512, 1024, 2048)
    assert 512 % g.bm == 0 and 1024 % g.bn == 0 and 2048 % g.bk == 0
