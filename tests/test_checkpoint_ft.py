"""Checkpoint atomicity/restore + fault-tolerance machinery + trainer
integration (injected failures and stragglers)."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.models import model as M
from repro.optim import adamw
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import (
    Heartbeat,
    RetryPolicy,
    StragglerWatchdog,
)
from repro.train.trainer import Trainer, TrainerConfig

CFG = get_config("tinyllama-1.1b", smoke=True)


def test_checkpoint_roundtrip_bitwise():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, params, opt, extra={"data": {"step": 7}})
        step, p2, o2, extra = ckpt.restore(d, None, params, opt)
        assert step == 7 and extra["data"]["step"] == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_latest():
    params = {"w": jnp.ones((4,))}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            ckpt.save(d, s, params, keep=2)
        steps = sorted(
            int(x.split("_")[1]) for x in os.listdir(d)
            if x.startswith("step_")
        )
        assert steps == [3, 4]
        assert ckpt.latest_step(d) == 4


def test_checkpoint_no_tmp_left_behind():
    params = {"w": jnp.ones((4,))}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, params)
        assert not [x for x in os.listdir(d) if x.endswith(".tmp")]


def test_straggler_watchdog_flags_outliers():
    w = StragglerWatchdog(threshold=2.0, warmup_steps=2)
    for i in range(2):
        assert w.observe(i, 10.0) is None  # warmup (compile) ignored
    for i in range(2, 8):
        assert w.observe(i, 0.1) is None
    ev = w.observe(8, 0.5)
    assert ev is not None and ev.slowdown > 2.0
    # outlier did not poison the baseline
    assert w.observe(9, 0.1) is None


def test_heartbeat_detects_dead_hosts():
    t = [0.0]
    hb = Heartbeat(timeout_s=5.0, clock=lambda: t[0])
    hb.beat(0)
    hb.beat(1)
    t[0] = 3.0
    hb.beat(0)
    t[0] = 7.0
    assert hb.dead_hosts() == [1]


def test_retry_policy_escalates():
    rp = RetryPolicy(max_retries=3)
    assert rp.record_failure() == "retry"
    assert rp.record_failure() == "restore"
    assert rp.record_failure() == "restore"
    assert rp.record_failure() == "abort"
    rp.record_success()
    assert rp.failures == 0


@pytest.mark.slow
def test_trainer_recovers_from_failure_and_flags_straggler():
    shape = ShapeSpec("t", 32, 4, "train")
    with tempfile.TemporaryDirectory() as d:
        t = Trainer(
            CFG, shape,
            TrainerConfig(total_steps=8, checkpoint_every=4,
                          checkpoint_dir=d, log_every=100),
            inject_failure_at=5, inject_delay_at=6,
        )
        hist = t.run()
        assert len(hist) == 8            # failure retried, not fatal
        assert t.watchdog.events         # straggler flagged
        # restart from checkpoint continues the run (elastic restore path)
        t2 = Trainer(CFG, shape, TrainerConfig(
            total_steps=10, checkpoint_dir=d, log_every=100))
        t2.restore()
        assert t2.step == 8
        t2.run()
        assert t2.step == 10


@pytest.mark.slow
def test_trainer_loss_decreases():
    shape = ShapeSpec("t", 64, 8, "train")
    t = Trainer(CFG, shape, TrainerConfig(total_steps=30, log_every=100))
    hist = t.run()
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first, (first, last)


@pytest.mark.slow
def test_trainer_microbatch_equivalence():
    """Grad accumulation must match the monolithic step (same seed)."""
    shape = ShapeSpec("t", 32, 8, "train")
    t1 = Trainer(CFG, shape, TrainerConfig(total_steps=3, log_every=100))
    t2 = Trainer(CFG, shape, TrainerConfig(total_steps=3, microbatches=4,
                                           log_every=100))
    h1, h2 = t1.run(), t2.run()
    np.testing.assert_allclose(
        [h["loss"] for h in h1], [h["loss"] for h in h2], rtol=2e-2
    )
