"""Oracle fidelity + structure tests (DESIGN.md §4).

The headline test times REAL jitted matmuls of different shapes on this
container's CPU and asserts the oracle's latency ranking correlates
(Spearman) with wall-clock reality — the analytical model must order
workloads correctly even though the schedule knobs themselves cannot be
A/B-ed through XLA.
"""
import math
import random
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import schedule as S
from repro.core.cost_model import (
    HardwareOracle,
    PLATFORMS,
    SurrogateModel,
    featurize,
    get_platform,
)
from repro.core.workloads import get_workload, matmul_workload


def test_oracle_deterministic():
    o = HardwareOracle(get_platform("core-i9"))
    w = get_workload("deepseek_r1_moe")
    s = S.initial_schedule(w)
    assert o.measure(s) == o.measure(s)
    o2 = HardwareOracle(get_platform("core-i9"))
    assert o.measure(s) == o2.measure(s)


def test_noise_is_small_and_platform_dependent():
    w = get_workload("deepseek_r1_moe")
    s = S.initial_schedule(w)
    t = {}
    for p in ("core-i9", "xeon-e3"):
        on = HardwareOracle(get_platform(p), noise=True).measure(s)
        off = HardwareOracle(get_platform(p), noise=False).measure(s)
        assert abs(on - off) / off < 0.05
        t[p] = off
    assert t["xeon-e3"] > t["core-i9"]  # 4 cores vs 16


def test_directional_effects():
    """Known-good optimizations must help; known-bad must hurt."""
    o = HardwareOracle(get_platform("core-i9"), noise=False)
    w = matmul_workload("m", m=512, n=512, k=512, epilogue="swiglu")
    s = S.initial_schedule(w)
    base = o.measure(s)
    s_tiled = S.TileSize("j", (8, 1, 8, 8)).apply(s)
    s_vec = S.Vectorize(8).apply(s_tiled)
    assert o.measure(s_vec) < o.measure(s_tiled)  # vectorize helps
    s_unroll = S.Unroll("j", 8).apply(s_vec)
    assert o.measure(s_unroll) < o.measure(s_vec)  # ILP helps
    fused = S.ComputeLocation(2).apply(s)
    assert o.measure(fused) <= base * 1.05  # fusing epilogue never disastrous


def test_mxu_alignment_matters_on_tpu():
    o = HardwareOracle(get_platform("tpu-v5e"), noise=False)
    w = matmul_workload("m", m=512, n=512, k=512)
    s = S.initial_schedule(w)
    aligned = S.TileSize("j", (2, 1, 2, 128)).apply(s)
    misaligned = S.TileSize("j", (2, 1, 64, 4)).apply(s)
    assert o.measure(aligned) < o.measure(misaligned)


@pytest.mark.slow
def test_oracle_ranks_real_wallclock():
    """Spearman(oracle, real CPU wall-time) across matmul shapes >= 0.7."""
    shapes = [
        (64, 64, 64), (256, 256, 256), (512, 512, 512),
        (1024, 1024, 256), (128, 2048, 2048), (2048, 128, 4096),
    ]
    real, pred = [], []
    o = HardwareOracle(get_platform("core-i9"), noise=False)
    rng = random.Random(0)
    for m, n, k in shapes:
        a = jnp.ones((m, k), jnp.float32)
        b = jnp.ones((k, n), jnp.float32)
        f = jax.jit(lambda x, y: x @ y)
        f(a, b).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            f(a, b).block_until_ready()
        real.append((time.perf_counter() - t0) / 5)
        # oracle: best of a short random search approximates tuned code
        w = matmul_workload(f"m{m}x{n}x{k}", m=m, n=n, k=k)
        s0 = S.initial_schedule(w)
        best = o.measure(s0)
        for _ in range(150):
            try:
                s = S.random_schedule(rng, s0, rng.randint(1, 6))
            except S.ScheduleError:
                continue
            best = min(best, o.measure(s))
        pred.append(best)

    def spearman(a, b):
        ra = np.argsort(np.argsort(a)).astype(float)
        rb = np.argsort(np.argsort(b)).astype(float)
        return float(np.corrcoef(ra, rb)[0, 1])

    rho = spearman(real, pred)
    assert rho >= 0.7, (rho, real, pred)


def test_surrogate_learns_ranking():
    o = HardwareOracle(get_platform("core-i9"))
    w = get_workload("llama4_scout_mlp")
    s0 = S.initial_schedule(w)
    rng = random.Random(0)
    sur = SurrogateModel()
    train, test = [], []
    for i in range(120):
        try:
            s = S.random_schedule(rng, s0, rng.randint(1, 8))
        except S.ScheduleError:
            continue
        (train if i % 3 else test).append((s, o.measure(s)))
    for s, t in train:
        sur.observe(s, t)
    preds = [sur.predict(s) for s, _ in test]
    assert all(p is not None for p in preds)
    truth = [t for _, t in test]
    ra = np.argsort(np.argsort(preds)).astype(float)
    rb = np.argsort(np.argsort(truth)).astype(float)
    rho = float(np.corrcoef(ra, rb)[0, 1])
    assert rho > 0.5, rho


def test_featurize_fixed_length():
    w = get_workload("flux_conv")
    s0 = S.initial_schedule(w)
    rng = random.Random(0)
    n = len(featurize(s0))
    for _ in range(10):
        s = S.random_schedule(rng, s0, 3)
        assert len(featurize(s)) == n


def test_all_platforms_defined():
    assert set(PLATFORMS) == {
        "graviton2", "epyc-7r13", "m2-pro", "core-i9", "xeon-e3", "tpu-v5e",
    }
    for p in PLATFORMS.values():
        assert p.peak_flops > 0 and p.mem_bw_gbs > 0
