"""Data pipeline determinism + optimizer correctness + grad compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev dependency (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import PrefetchingLoader, SyntheticLMDataset
from repro.optim import adamw

CFG = get_config("tinyllama-1.1b", smoke=True)
SHAPE = ShapeSpec("t", 32, 8, "train")


def test_pipeline_deterministic_and_checkpointable():
    d1 = SyntheticLMDataset(CFG, SHAPE, seed=3)
    d2 = SyntheticLMDataset(CFG, SHAPE, seed=3)
    for _ in range(3):
        b1, b2 = d1.next_batch(), d2.next_batch()
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # restore mid-stream: identical continuation
    state = d1.state_dict()
    want = d1.next_batch()
    d3 = SyntheticLMDataset(CFG, SHAPE, seed=3)
    d3.load_state_dict(state)
    got = d3.next_batch()
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


def test_pipeline_host_sharding_partitions_global_batch():
    full = SyntheticLMDataset(CFG, SHAPE, seed=0).next_batch()["tokens"]
    parts = [
        SyntheticLMDataset(CFG, SHAPE, seed=0, host_index=i, host_count=4)
        .next_batch()["tokens"]
        for i in range(4)
    ]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), full)


def test_pipeline_tokens_in_range():
    b = SyntheticLMDataset(CFG, SHAPE, seed=1).next_batch()
    assert b["tokens"].min() >= 0 and b["tokens"].max() < CFG.vocab


def test_prefetching_loader():
    loader = PrefetchingLoader(SyntheticLMDataset(CFG, SHAPE, seed=0))
    ref = SyntheticLMDataset(CFG, SHAPE, seed=0)
    np.testing.assert_array_equal(
        loader.next_batch()["tokens"], ref.next_batch()["tokens"]
    )
    loader.close()


def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                            weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init(params)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = adamw.update(cfg, params, state, g)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    assert np.isclose(float(adamw.global_norm(clipped)), 1.0, atol=1e-5)


def test_cosine_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lr = adamw.cosine_schedule(cfg)
    assert float(lr(0)) == 0.0
    assert np.isclose(float(lr(10)), 1.0)
    assert float(lr(100)) == np.float32(0.1)
    assert float(lr(55)) < float(lr(11))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_grad_compression_roundtrip_bound(seed):
    g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 3.0}
    out = adamw.decompress_grads(adamw.compress_grads(g))
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(out["w"] - g["w"]))) <= scale * 0.5 + 1e-6
