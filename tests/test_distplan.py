"""Distribution-plan tuner: reasoned proposals drive the dominant roofline
term down on an analytical stand-in cell (the production evaluator is a
dryrun re-lower; tests/test_dryrun_integration.py covers that path)."""
from repro.core.distplan import DistPlan, DistPlanTuner, PlanEval


def _toy_cell(plan: DistPlan) -> PlanEval:
    """Analytical cell: memory shrinks with microbatching/remat, collectives
    grow with microbatching and dispatch granularity, compute grows with
    remat. Optimum is an interior point, not a corner."""
    act = 40.0 / plan.microbatches * (0.55 if plan.remat else 1.0) \
        * (plan.attn_chunk / 1024) ** 0.3
    peak = act * 2**30
    mem_s = 2.0 / plan.microbatches * (0.7 if plan.remat else 1.0)
    coll_s = 0.4 + 0.05 * plan.microbatches + 0.004 * plan.dispatch_groups
    comp_s = 0.8 * (1.33 if plan.remat else 1.0)
    return PlanEval(plan, comp_s, mem_s, coll_s, peak, peak <= 15.5 * 2**30)


def test_tuner_fixes_oom_then_improves():
    t = DistPlanTuner(_toy_cell)
    start = DistPlan(microbatches=1, remat=False)
    assert not _toy_cell(start).fits  # starts OOM
    best = t.tune(start, budget=10)
    assert best.fits
    assert best.step_s < _toy_cell(start).step_s
    assert t.log and any(s.accepted for s in t.log)
    # the log reads as hypothesis -> before -> after
    rep = t.report()
    assert "ACCEPT" in rep and "->" in rep


def test_tuner_respects_budget():
    t = DistPlanTuner(_toy_cell)
    t.tune(DistPlan(), budget=4)
    assert t.samples <= 4


def test_proposals_target_dominant_term():
    t = DistPlanTuner(_toy_cell)
    ev = _toy_cell(DistPlan(microbatches=16, remat=True))
    assert ev.dominant == "collective"
    ideas = t.propose(ev)
    assert any("collective-bound" in h for h, _ in ideas)


def test_plan_knob_navigation():
    p = DistPlan()
    assert p.with_knob("microbatches", 8).microbatches == 8
    assert p.with_knob("remat", False).remat is False
