"""Dry-run integration: the full lower+compile+roofline path on a small
fake-device mesh (subprocess, so the device-count flag never leaks into the
rest of the suite)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, %r)
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch import dryrun
    from repro.roofline.analysis import cost_analysis_dict, parse_collectives

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("tinyllama-1.1b", smoke=True)
    out = {}
    for shape in ("train_4k", "decode_32k"):
        fn, args, mf = dryrun.build_cell(cfg, shape, mesh)
        with mesh:
            compiled = fn.lower(*args).compile()
        cost = cost_analysis_dict(compiled)
        coll = parse_collectives(compiled.as_text(), chips_per_pod=4)
        mem = compiled.memory_analysis()
        out[shape] = {
            "flops": float(cost.get("flops", 0.0)),
            "collectives": sum(coll.counts.values()),
            "temp": int(mem.temp_size_in_bytes),
        }
    print("RESULT:" + json.dumps(out))
""") % os.path.abspath(SRC)


@pytest.mark.slow
def test_dryrun_small_mesh_compiles():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    for shape in ("train_4k", "decode_32k"):
        assert out[shape]["flops"] > 0
        assert out[shape]["temp"] > 0
    # TP over 4-way model axis must introduce collectives in training
    assert out["train_4k"]["collectives"] > 0
