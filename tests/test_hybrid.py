"""Hymba hybrid block: SSM path sequence/step consistency + windowing."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import hybrid as hy

KEY = jax.random.PRNGKey(0)


def test_ssm_path_seq_equals_steps():
    d, state = 32, 4
    p = hy.init_ssm_path(KEY, d, state, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 14, d)) * 0.5
    y_seq, _ = hy.ssm_path_seq(x, p)
    st = hy.ssm_init_state(2, d, state)
    outs = []
    for t in range(14):
        y, st = hy.ssm_path_step(x[:, t:t + 1], p, st)
        outs.append(y)
    np.testing.assert_allclose(
        jnp.concatenate(outs, 1), y_seq, atol=1e-4, rtol=1e-3
    )


def test_ssm_state_continuation():
    d, state = 32, 4
    p = hy.init_ssm_path(KEY, d, state, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 20, d)) * 0.5
    y_full, _ = hy.ssm_path_seq(x, p)
    y1, st1 = hy.ssm_path_seq(x[:, :9], p)
    y2, _ = hy.ssm_path_seq(x[:, 9:], p, state=st1)
    np.testing.assert_allclose(
        jnp.concatenate([y1, y2], 1), y_full, atol=1e-4, rtol=1e-3
    )


def test_conv_causality():
    """Output at t must not depend on inputs after t."""
    d, state = 16, 4
    p = hy.init_ssm_path(KEY, d, state, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 12, d)) * 0.5
    y1, _ = hy.ssm_path_seq(x, p)
    x2 = x.at[:, 8:].set(99.0)  # perturb the future
    y2, _ = hy.ssm_path_seq(x2, p)
    np.testing.assert_allclose(y1[:, :8], y2[:, :8], atol=1e-5)
