"""Per-kernel correctness sweeps: Pallas (interpret mode) vs pure-jnp
oracles across shapes, dtypes, and masking variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul import matmul, moe_gemm, swiglu_gateup

KEY = jax.random.PRNGKey(0)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return (x * 0.25).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,sq,skv,d,causal,window",
    [
        (1, 2, 2, 64, 64, 32, True, None),     # MHA causal
        (2, 4, 2, 64, 64, 64, True, None),     # GQA
        (1, 8, 1, 64, 64, 32, False, None),    # MQA bidirectional
        (1, 4, 4, 32, 128, 32, True, None),    # cross lengths (right-aligned)
        (1, 2, 2, 64, 64, 32, True, 48),       # sliding window
        (1, 4, 2, 32, 32, 128, True, None),    # wide head_dim
    ],
)
def test_flash_attention_vs_ref(b, hq, hkv, sq, skv, d, causal, window,
                                dtype):
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (b, hq, sq, d), dtype)
    k = _rand(ks[1], (b, hkv, skv, d), dtype)
    v = _rand(ks[2], (b, hkv, skv, d), dtype)
    out = flash_attention(
        q, k, v, causal=causal, window=window, block_q=32, block_k=32,
        interpret=True,
    )
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        out.astype(np.float32), want.astype(np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("blocks", [(16, 32, 32), (64, 128, 128)])
def test_flash_attention_block_shape_invariance(blocks):
    bq, bk, _ = blocks
    q = _rand(KEY, (1, 2, 128, 32), jnp.float32)
    k = _rand(KEY, (1, 2, 128, 32), jnp.float32)
    v = _rand(jax.random.PRNGKey(1), (1, 2, 128, 32), jnp.float32)
    want = ref.attention_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,n,k,bm,bn,bk", [
    (64, 64, 64, 32, 32, 32),
    (128, 256, 512, 64, 128, 128),
])
def test_matmul_vs_ref(m, n, k, bm, bn, bk, dtype):
    a = _rand(KEY, (m, k), dtype)
    b = _rand(jax.random.PRNGKey(1), (k, n), dtype)
    out = matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        out.astype(np.float32), ref.matmul_ref(a, b).astype(np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("m,n,k", [(64, 128, 64), (128, 256, 256)])
def test_swiglu_gateup_vs_ref(m, n, k):
    x = _rand(KEY, (m, k), jnp.float32)
    wg = _rand(jax.random.PRNGKey(1), (k, n), jnp.float32)
    wu = _rand(jax.random.PRNGKey(2), (k, n), jnp.float32)
    out = swiglu_gateup(x, wg, wu, bm=32, bn=64, bk=32, interpret=True)
    np.testing.assert_allclose(
        out, ref.swiglu_gateup_ref(x, wg, wu), atol=1e-4, rtol=1e-4
    )


@pytest.mark.parametrize("e,cap,d,f", [(4, 32, 64, 128), (8, 64, 128, 64)])
def test_moe_gemm_vs_ref(e, cap, d, f):
    x = _rand(KEY, (e, cap, d), jnp.float32)
    w = _rand(jax.random.PRNGKey(1), (e, d, f), jnp.float32)
    out = moe_gemm(x, w, bm=16, bn=64, bk=32, interpret=True)
    np.testing.assert_allclose(
        out, ref.moe_gemm_ref(x, w), atol=1e-4, rtol=1e-4
    )


def test_chunked_attention_matches_ref_across_chunks():
    q = _rand(KEY, (2, 4, 256, 32), jnp.float32)
    k = _rand(jax.random.PRNGKey(1), (2, 2, 256, 32), jnp.float32)
    v = _rand(jax.random.PRNGKey(2), (2, 2, 256, 32), jnp.float32)
    want = ref.attention_ref(q, k, v, causal=True)
    for chunk in (32, 64, 256):
        out = ops._attention_jax_chunked(
            q, k, v, causal=True, sm_scale=32 ** -0.5, window=None,
            chunk=chunk,
        )
        np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


def test_ops_backend_dispatch():
    q = _rand(KEY, (1, 2, 64, 32), jnp.float32)
    k = _rand(jax.random.PRNGKey(1), (1, 2, 64, 32), jnp.float32)
    v = _rand(jax.random.PRNGKey(2), (1, 2, 64, 32), jnp.float32)
    a = ops.attention(q, k, v, backend="jax")
    b = ops.attention(q, k, v, backend="interpret", block_q=32, block_k=32)
    c = ops.attention(q, k, v, backend="ref")
    np.testing.assert_allclose(a, c, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(b, c, atol=2e-5, rtol=2e-5)


def test_swiglu_mlp_pipeline():
    x = _rand(KEY, (64, 128), jnp.float32)
    wg = _rand(jax.random.PRNGKey(1), (128, 256), jnp.float32)
    wu = _rand(jax.random.PRNGKey(2), (128, 256), jnp.float32)
    wd = _rand(jax.random.PRNGKey(3), (256, 128), jnp.float32)
    a = ops.swiglu_mlp(x, wg, wu, wd, backend="jax")
    b = ops.swiglu_mlp(x, wg, wu, wd, backend="interpret", bm=32, bn=128,
                       bk=64)
    np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)
