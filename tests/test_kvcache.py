"""Paged KV cache unit tests: page accounting, gather/scatter round-trips,
reservation gating — plus the serving metrics aggregation (fake clock)."""
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serve.kvcache import (
    NULL_PAGE,
    PagedKVCache,
    TRASH_PAGE,
    split_leaves,
)
from repro.serve.metrics import EngineMetrics

CFG = get_config("tinyllama-1.1b", smoke=True)


def _cache_rows(n, s_pad, seed=0):
    """Dense prefill-shaped rows with recognizable values."""
    rng = np.random.RandomState(seed)
    spec = M.cache_spec(CFG, n, s_pad)
    rows = {}
    for name, sd in spec.items():
        if name == "kv_pos":
            rows[name] = jnp.asarray(
                np.broadcast_to(np.arange(s_pad, dtype=np.int32),
                                sd.shape).copy()
            )
        else:
            rows[name] = jnp.asarray(
                rng.randn(*sd.shape).astype(np.float32)
            )
    return rows


def test_alloc_release_accounting():
    kv = PagedKVCache(CFG, slots=2, max_len=64, page_size=16)
    assert kv.pages_per_slot == 4 and kv.capacity == 8
    assert kv.available_pages == 8
    assert kv.reserve(0, 3)
    assert kv.available_pages == 5
    kv.alloc_upto(0, 33)            # 3 pages (33 tokens / 16 per page)
    assert kv.used_pages == 3 and kv.available_pages == 5
    assert all(kv.table[0, :3] >= 2) and kv.table[0, 3] == NULL_PAGE
    kv.release(0)
    assert kv.used_pages == 0 and kv.available_pages == 8
    assert (kv.table[0] == NULL_PAGE).all()


def test_reserve_gates_admission():
    kv = PagedKVCache(CFG, slots=4, max_len=64, page_size=16, capacity=4)
    assert kv.reserve(0, 3)
    assert not kv.reserve(1, 2)     # only 1 unreserved page left
    assert kv.reserve(1, 1)
    assert not kv.reserve(2, 1)


def test_dense_view_roundtrip():
    """scatter_pages → gather_view reproduces the dense layout exactly."""
    kv = PagedKVCache(CFG, slots=3, max_len=64, page_size=16)
    rows = _cache_rows(2, 32)
    paged_rows, state_rows = split_leaves(rows)
    assert not state_rows           # dense arch: everything is per-token
    kv.reserve(0, 2), kv.reserve(2, 2)
    kv.alloc_upto(0, 32)
    kv.alloc_upto(2, 32)
    kv.write_prefill([0, 2], rows)
    view = kv.dense_view()
    for name in ("k", "v"):
        got = np.asarray(view[name])
        want = np.asarray(rows[name])
        assert got.shape[1] == 3 and got.shape[3] == kv.view_len
        np.testing.assert_array_equal(got[:, 0, :, :32], want[:, 0])
        np.testing.assert_array_equal(got[:, 2, :, :32], want[:, 1])
        assert (got[:, 1] == 0).all()      # never written
    kvp = np.asarray(view["kv_pos"])
    np.testing.assert_array_equal(kvp[:, 0, :32],
                                  np.asarray(rows["kv_pos"])[:, 0])
    assert (kvp[:, 1] == -1).all()         # null page: all invalid
    assert (kvp[:, :, 32:] == -1).all()    # beyond allocation: invalid


def test_release_invalidates_reused_pages():
    kv = PagedKVCache(CFG, slots=1, max_len=32, page_size=16)
    rows = _cache_rows(1, 32)
    kv.reserve(0, 2)
    kv.alloc_upto(0, 32)
    kv.write_prefill([0], rows)
    kv.release(0)
    kv.reserve(0, 1)
    kv.alloc_upto(0, 1)             # reuse a freed page for one token
    kvp = np.asarray(kv.dense_view()["kv_pos"])
    assert (kvp == -1).all()        # no stale positions leak through


def test_deferred_release_batches_invalidation():
    kv = PagedKVCache(CFG, slots=2, max_len=32, page_size=16)
    rows = _cache_rows(2, 32)
    for s in (0, 1):
        kv.reserve(s, 2)
        kv.alloc_upto(s, 32)
    kv.write_prefill([0, 1], rows)
    freed = kv.release(0, invalidate=False) + \
        kv.release(1, invalidate=False)
    assert len(freed) == 4 and kv.used_pages == 0
    kv.invalidate(freed)            # one dispatch for both slots' pages
    for s in (0, 1):
        kv.reserve(s, 1)
        kv.alloc_upto(s, 1)
    assert (np.asarray(kv.dense_view()["kv_pos"]) == -1).all()


def test_token_targets_trash_for_unallocated():
    kv = PagedKVCache(CFG, slots=2, max_len=32, page_size=16)
    kv.reserve(0, 1)
    kv.alloc_upto(0, 5)
    pages, offs = kv.token_targets(np.asarray([4, 9], np.int32))
    assert pages[0] == kv.table[0, 0] and offs[0] == 4
    assert pages[1] == TRASH_PAGE            # slot 1 owns nothing


def test_metrics_summary_fake_clock():
    t = [0.0]
    m = EngineMetrics(clock=lambda: t[0])
    m.on_submit(7, prompt_len=5)
    t[0] = 2.0
    m.on_first_token(7)
    t[0] = 6.0
    m.on_finish(7, new_tokens=5)
    m.on_occupancy(0.25)
    m.on_occupancy(0.75)
    s = m.summary()
    assert s["requests"] == 1 and s["generated_tokens"] == 5
    assert s["ttft_mean_s"] == 2.0
    assert s["tpot_mean_s"] == 1.0           # 4s over 4 decode intervals
    assert s["throughput_tok_s"] == 5 / 6.0
    assert s["kv_occupancy_mean"] == 0.5 and s["kv_occupancy_max"] == 0.75
