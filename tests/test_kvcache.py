"""Paged KV cache unit tests: page accounting, gather/scatter round-trips,
reservation gating, refcounted sharing + copy-on-write, the prompt-prefix
radix index — plus the serving metrics aggregation (fake clock) and a
property-style interleaving test proving the pool never leaks pages."""
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serve.kvcache import (
    NULL_PAGE,
    RESERVED_PAGES,
    PagedKVCache,
    PrefixIndex,
    TRASH_PAGE,
    split_leaves,
)
from repro.serve.metrics import EngineMetrics

CFG = get_config("tinyllama-1.1b", smoke=True)


def _check_invariants(kv: PagedKVCache) -> None:
    """The pool conservation law: every data page is exactly one of
    free / live, refcounts equal the number of holders (slot tables +
    prefix-index nodes), and the free list never double-lists a page."""
    holders: dict[int, int] = {}
    for own in kv._owned.values():
        assert len(set(own)) == len(own), "slot owns a page twice"
        for p in own:
            holders[p] = holders.get(p, 0) + 1
    if kv.prefix is not None:
        def walk(node):
            for child in node.children.values():
                holders[child.page] = holders.get(child.page, 0) + 1
                walk(child)
        walk(kv.prefix.root)
    live = set()
    for p in range(RESERVED_PAGES, kv.capacity + RESERVED_PAGES):
        assert kv._ref[p] == holders.get(p, 0), \
            f"page {p}: ref {kv._ref[p]} != holders {holders.get(p, 0)}"
        if holders.get(p, 0):
            live.add(p)
    free = set(kv._free)
    assert len(free) == len(kv._free), "double-free: dup in free list"
    assert free.isdisjoint(live), "page both free and referenced"
    assert len(free) + len(live) == kv.capacity, "page leak"


def _cache_rows(n, s_pad, seed=0):
    """Dense prefill-shaped rows with recognizable values."""
    rng = np.random.RandomState(seed)
    spec = M.cache_spec(CFG, n, s_pad)
    rows = {}
    for name, sd in spec.items():
        if name == "kv_pos":
            rows[name] = jnp.asarray(
                np.broadcast_to(np.arange(s_pad, dtype=np.int32),
                                sd.shape).copy()
            )
        else:
            rows[name] = jnp.asarray(
                rng.randn(*sd.shape).astype(np.float32)
            )
    return rows


def test_alloc_release_accounting():
    kv = PagedKVCache(CFG, slots=2, max_len=64, page_size=16)
    assert kv.pages_per_slot == 4 and kv.capacity == 8
    assert kv.available_pages == 8
    assert kv.reserve(0, 3)
    assert kv.available_pages == 5
    kv.alloc_upto(0, 33)            # 3 pages (33 tokens / 16 per page)
    assert kv.used_pages == 3 and kv.available_pages == 5
    assert all(kv.table[0, :3] >= 2) and kv.table[0, 3] == NULL_PAGE
    kv.release(0)
    assert kv.used_pages == 0 and kv.available_pages == 8
    assert (kv.table[0] == NULL_PAGE).all()


def test_reserve_gates_admission():
    kv = PagedKVCache(CFG, slots=4, max_len=64, page_size=16, capacity=4)
    assert kv.reserve(0, 3)
    assert not kv.reserve(1, 2)     # only 1 unreserved page left
    assert kv.reserve(1, 1)
    assert not kv.reserve(2, 1)


def test_dense_view_roundtrip():
    """scatter_pages → gather_view reproduces the dense layout exactly."""
    kv = PagedKVCache(CFG, slots=3, max_len=64, page_size=16)
    rows = _cache_rows(2, 32)
    paged_rows, state_rows = split_leaves(rows)
    assert not state_rows           # dense arch: everything is per-token
    kv.reserve(0, 2), kv.reserve(2, 2)
    kv.alloc_upto(0, 32)
    kv.alloc_upto(2, 32)
    kv.write_prefill([0, 2], rows)
    view = kv.dense_view()
    for name in ("k", "v"):
        got = np.asarray(view[name])
        want = np.asarray(rows[name])
        assert got.shape[1] == 3 and got.shape[3] == kv.view_len
        np.testing.assert_array_equal(got[:, 0, :, :32], want[:, 0])
        np.testing.assert_array_equal(got[:, 2, :, :32], want[:, 1])
        assert (got[:, 1] == 0).all()      # never written
    kvp = np.asarray(view["kv_pos"])
    np.testing.assert_array_equal(kvp[:, 0, :32],
                                  np.asarray(rows["kv_pos"])[:, 0])
    assert (kvp[:, 1] == -1).all()         # null page: all invalid
    assert (kvp[:, :, 32:] == -1).all()    # beyond allocation: invalid


def test_release_invalidates_reused_pages():
    kv = PagedKVCache(CFG, slots=1, max_len=32, page_size=16)
    rows = _cache_rows(1, 32)
    kv.reserve(0, 2)
    kv.alloc_upto(0, 32)
    kv.write_prefill([0], rows)
    kv.release(0)
    kv.reserve(0, 1)
    kv.alloc_upto(0, 1)             # reuse a freed page for one token
    kvp = np.asarray(kv.dense_view()["kv_pos"])
    assert (kvp == -1).all()        # no stale positions leak through


def test_deferred_release_batches_invalidation():
    kv = PagedKVCache(CFG, slots=2, max_len=32, page_size=16)
    rows = _cache_rows(2, 32)
    for s in (0, 1):
        kv.reserve(s, 2)
        kv.alloc_upto(s, 32)
    kv.write_prefill([0, 1], rows)
    freed = kv.release(0, invalidate=False) + \
        kv.release(1, invalidate=False)
    assert len(freed) == 4 and kv.used_pages == 0
    kv.invalidate(freed)            # one dispatch for both slots' pages
    for s in (0, 1):
        kv.reserve(s, 1)
        kv.alloc_upto(s, 1)
    assert (np.asarray(kv.dense_view()["kv_pos"]) == -1).all()


def test_token_targets_trash_for_unallocated():
    kv = PagedKVCache(CFG, slots=2, max_len=32, page_size=16)
    kv.reserve(0, 1)
    kv.alloc_upto(0, 5)
    pages, offs = kv.token_targets(np.asarray([4, 9], np.int32))
    assert pages[0] == kv.table[0, 0] and offs[0] == 4
    assert pages[1] == TRASH_PAGE            # slot 1 owns nothing


def test_release_decrefs_shared_pages():
    """Shared pages survive their original owner's release and free only
    when the last holder lets go."""
    kv = PagedKVCache(CFG, slots=2, max_len=64, page_size=16)
    kv.reserve(0, 2)
    kv.alloc_upto(0, 32)
    pages = kv.page_ids(0)
    kv.attach(1, pages)
    assert kv.refcount(pages[0]) == 2 and kv.shared_pages == 2
    assert kv.release(0) == []            # still held by slot 1
    assert kv.used_pages == 2
    _check_invariants(kv)
    assert sorted(kv.release(1)) == sorted(pages)
    assert kv.used_pages == 0
    _check_invariants(kv)


def test_cow_isolates_sharers():
    """The acceptance bar for sharing: a shared page mutated by one slot
    leaves the other slot's tokens unchanged."""
    kv = PagedKVCache(CFG, slots=2, max_len=64, page_size=16)
    rows = _cache_rows(1, 32)
    kv.reserve(0, 2)
    kv.alloc_upto(0, 32)
    kv.write_prefill([0], rows)
    before = {k: np.asarray(v).copy() for k, v in kv.dense_view().items()}
    kv.attach(1, kv.page_ids(0))
    # slot 1 owns tokens [0, 24): writing must COW page 1 first
    assert kv.ensure_writable(1, 1, n_valid=24)
    assert kv.cow_copies == 1
    assert kv.page_ids(1)[1] != kv.page_ids(0)[1]   # private copy
    assert kv.page_ids(1)[0] == kv.page_ids(0)[0]   # prefix still shared
    _check_invariants(kv)
    # the copy keeps slot 1's 8 in-page tokens and invalidates the donor
    # tail; slot 0's own tail stays valid
    mid = np.asarray(kv.dense_view()["kv_pos"])
    assert (mid[:, 1, 16:24] == np.arange(16, 24)).all()
    assert (mid[:, 1, 24:32] == -1).all()
    assert (mid[:, 0, 24:32] == np.arange(24, 32)).all()
    # mutate slot 1's strip: write_prefill skips the shared page 0
    # (refcount 2) and lands new values only in the private copy
    other = _cache_rows(1, 32, seed=9)
    kv.write_prefill([1], other)
    view = kv.dense_view()
    for name in ("k", "v", "kv_pos"):
        np.testing.assert_array_equal(          # slot 0 untouched
            np.asarray(view[name])[:, 0], before[name][:, 0], err_msg=name
        )
    got_k = np.asarray(view["k"])
    np.testing.assert_array_equal(              # shared page: donor data
        got_k[:, 1, :, :16], np.asarray(rows["k"])[:, 0, :, :16]
    )
    np.testing.assert_array_equal(              # private page: new data
        got_k[:, 1, :, 16:32], np.asarray(other["k"])[:, 0, :, 16:32]
    )
    _check_invariants(kv)


def test_prefix_index_match_insert_evict():
    idx = PrefixIndex(page_size=4)
    refs: dict[int, int] = {}

    def pin(p):
        refs[p] = refs.get(p, 0) + 1

    toks = np.arange(12, dtype=np.int32)
    assert idx.insert(toks, [10, 11, 12], pin) == 3
    assert refs == {10: 1, 11: 1, 12: 1}
    # full re-insert dedups; a diverging prompt adds only its new chunk
    assert idx.insert(toks, [20, 21, 22], pin) == 0
    fork = np.concatenate([toks[:8], np.asarray([99, 98, 97, 96], np.int32)])
    assert idx.insert(fork, [10, 11, 30], pin) == 1
    # exact full-page walk
    pages, boundary, m = idx.match(toks)
    assert pages == [10, 11, 12] and boundary is None and m == 0
    # partial tail chunk: longest-common-prefix against a child edge
    pages, boundary, m = idx.match(toks[:10])
    assert pages == [10, 11] and boundary == 12 and m == 2
    # mid-page divergence
    div = np.concatenate([toks[:6], np.asarray([77] * 6, np.int32)])
    pages, boundary, m = idx.match(div)
    assert pages == [10] and boundary == 11 and m == 2
    # LRU eviction drops leaves first (12 was matched least recently
    # after we touch the fork branch)
    idx.match(fork)
    dead: list[int] = []

    def decref(p):
        refs[p] -= 1
        if refs[p] == 0:
            dead.append(p)
        return refs[p] == 0

    assert idx.evict_lru(1, decref) == 1
    assert dead == [12] and idx.nodes == 3


def test_prefix_eviction_frees_pool_pressure():
    """Index-held pages yield to admission demand: a reservation that
    would fail evicts LRU prefix entries instead."""
    kv = PagedKVCache(CFG, slots=2, max_len=64, page_size=16,
                      capacity=4, prefix_cache=True)
    kv.reserve(0, 2)
    kv.alloc_upto(0, 32)
    prompt = np.arange(32, dtype=np.int32)
    assert kv.index_prompt(0, prompt) == 2
    kv.release(0)                    # pages survive inside the index
    assert kv.used_pages == 2 and kv.available_pages == 2
    _check_invariants(kv)
    assert kv.reserve(1, 4)          # forces eviction of both entries
    assert kv.available_pages == 0 and kv.prefix.nodes == 0
    _check_invariants(kv)


def test_eviction_skips_slot_held_pages():
    """A reservation shortfall must not wipe index entries whose pages
    active slots still hold — evicting them reclaims nothing (regression:
    evict_lru used to loop the whole tree empty with freed == 0)."""
    kv = PagedKVCache(CFG, slots=2, max_len=64, page_size=16,
                      capacity=4, prefix_cache=True)
    kv.reserve(0, 2)
    kv.alloc_upto(0, 32)
    assert kv.index_prompt(0, np.arange(32, dtype=np.int32)) == 2
    # slot 0 is still running: its indexed pages are not freeable, so the
    # failing reservation leaves the index intact
    assert not kv.reserve(1, 4)
    assert kv.prefix.nodes == 2
    _check_invariants(kv)
    kv.release(0)                    # now only the index holds the pages
    assert kv.reserve(1, 4)          # eviction frees them this time
    assert kv.prefix.nodes == 0
    _check_invariants(kv)


def test_property_interleaved_share_cow_release_never_leaks():
    """Property-style: random interleavings of admission (with prefix
    adoption), sharing, COW writes, decode growth, release, and index
    pressure keep the pool conserved — free + live == capacity, refcounts
    == holders, no double-free — after every single operation."""
    rng = np.random.RandomState(0)
    kv = PagedKVCache(CFG, slots=3, max_len=64, page_size=8,
                      capacity=16, prefix_cache=True)
    vocab = 50
    base = rng.randint(0, vocab, size=40).astype(np.int32)
    active: dict[int, np.ndarray] = {}   # slot -> prompt
    grown: dict[int, int] = {}           # slot -> token count incl. decode
    for step in range(250):
        op = rng.randint(0, 4)
        free_slots = [s for s in range(3) if s not in active]
        if op == 0 and free_slots:       # admit (maybe via prefix)
            slot = free_slots[0]
            plen = int(rng.randint(9, 40))
            if rng.rand() < 0.6:         # shared-prefix prompt family
                cut = int(rng.randint(8, len(base)))
                prompt = np.concatenate([
                    base[:cut],
                    rng.randint(0, vocab, size=max(1, plen - cut)),
                ]).astype(np.int32)
            else:
                prompt = rng.randint(0, vocab, size=plen).astype(np.int32)
            match = kv.match_prefix(prompt)
            if match is not None:
                kv.attach_prefix(slot, match)
            cow = 1 if match is not None \
                and match.boundary_page is not None else 0
            if kv.reserve(slot, kv.pages_needed(len(prompt) + 8), cow=cow):
                if cow:
                    kv.ensure_writable(slot, len(match.pages),
                                       match.tokens)
                kv.alloc_upto(slot, len(prompt))
                kv.index_prompt(slot, prompt)
                active[slot] = prompt
                grown[slot] = len(prompt)
            elif match is not None:
                kv.release(slot)         # rollback, like the scheduler
        elif op == 1 and active:         # decode growth + COW guard
            slot = list(active)[rng.randint(len(active))]
            pos = grown[slot]
            if pos + 1 < kv.view_len:
                kv.alloc_upto(slot, pos + 1)
                kv.ensure_writable(slot, pos // kv.page_size, pos)
                grown[slot] = pos + 1
        elif op == 2 and active:         # finish + release
            slot = list(active)[rng.randint(len(active))]
            kv.release(slot)
            del active[slot], grown[slot]
        else:                            # index pressure
            kv._evict_prefix(1)
        _check_invariants(kv)
    for slot in list(active):
        kv.release(slot)
        _check_invariants(kv)
    kv._evict_prefix(kv.capacity)
    _check_invariants(kv)
    assert kv.used_pages == 0            # everything came back


def test_metrics_summary_fake_clock():
    t = [0.0]
    m = EngineMetrics(clock=lambda: t[0])
    m.on_submit(7, prompt_len=5)
    t[0] = 2.0
    m.on_first_token(7)
    t[0] = 6.0
    m.on_finish(7, new_tokens=5)
    m.on_occupancy(0.25)
    m.on_occupancy(0.75)
    s = m.summary()
    assert s["requests"] == 1 and s["generated_tokens"] == 5
    assert s["ttft_mean_s"] == 2.0
    assert s["tpot_mean_s"] == 1.0           # 4s over 4 decode intervals
    assert s["throughput_tok_s"] == 5 / 6.0
    assert s["kv_occupancy_mean"] == 0.5 and s["kv_occupancy_max"] == 0.75
