"""LLM interface: prompt construction, parsing, validation, fallback
(paper §3.1, Appendix A/G)."""
import dataclasses
import random
import urllib.error

import pytest

from repro.core import schedule as S
from repro.core.cost_model import get_platform
from repro.core.llm import (
    _FAKE_NAMES,
    MODEL_TIERS,
    APILLM,
    HeuristicReasonerLLM,
    LLMProposer,
    TraceEntry,
    build_prompt,
    make_llm,
    parse_response,
)
from repro.core.workloads import get_workload


def _trace(wname="deepseek_r1_moe", n=3):
    w = get_workload(wname)
    s = S.initial_schedule(w)
    entries = [TraceEntry(s, 1.0, 1.0)]
    rng = random.Random(0)
    for i in range(n - 1):
        s = S.random_transform(rng, s).apply(s)
        entries.insert(0, TraceEntry(s, 1.0 / (i + 2), float(i + 2)))
    return entries


def test_prompt_contains_paper_sections():
    p = build_prompt(_trace(), get_platform("core-i9"), trace_depth=2)
    for frag in (
        "Monte Carlo Tree Search", "Transformation history",
        "Performance estimate", "Available transformations",
        "Transformations to apply", "Reasoning:",
    ):
        assert frag in p.text, frag
    assert len(p.trace) == 3  # current + parent + grandparent


def test_prompt_trace_depth():
    assert len(build_prompt(_trace(n=4), get_platform("core-i9"),
                            trace_depth=1).trace) == 2
    assert len(build_prompt(_trace(n=4), get_platform("core-i9"),
                            trace_depth=3).trace) == 4


def test_parse_paper_example_format():
    """The exact output format from the paper's Appendix A."""
    w = get_workload("deepseek_r1_moe")
    s = S.initial_schedule(w)
    text = ("Reasoning: The current schedule tiles the j-axis as 2048; "
            "I would retile and unroll.\n"
            "Transformations to apply: TileSize, TileSize, ComputeLocation, "
            "Parallel, Unroll, Unroll.")
    prop = parse_response(text, s, random.Random(0))
    assert not prop.fallback
    assert prop.n_proposed == 6
    # ComputeLocation is illegal on an epilogue-free matmul -> dropped
    names = [t.name for t in prop.transforms]
    assert "ComputeLocation" not in names
    assert names.count("TileSize") == 2
    assert "reasoning" not in prop.reasoning.lower()[:0]  # parsed non-empty
    assert prop.reasoning.startswith("The current schedule")


def test_parse_parameterized_calls():
    w = get_workload("deepseek_r1_moe")
    s = S.initial_schedule(w)
    text = ("Reasoning: x.\nTransformations to apply: "
            "TileSize(axis=j, decision=[4, 4, 2, 64]), Vectorize(width=8), "
            "Parallel(levels=1), CacheRead(operand=B)")
    prop = parse_response(text, s, random.Random(0))
    assert [t.name for t in prop.transforms] == [
        "TileSize", "Vectorize", "Parallel", "CacheRead",
    ]
    ts = prop.transforms[0]
    assert ts.axis == "j" and ts.decision == (4, 4, 2, 64)
    # sequence is applied cumulatively: Vectorize(8) legal only AFTER retile
    out = s
    for t in prop.transforms:
        out = t.apply(out)
    assert out.vector_width == 8


def test_all_invalid_triggers_fallback():
    w = get_workload("deepseek_r1_moe")
    s = S.initial_schedule(w)
    text = "Reasoning: x.\nTransformations to apply: WarpShuffle, Hoist."
    prop = parse_response(text, s, random.Random(0))
    assert prop.fallback and prop.n_invalid == 2


def test_invalid_params_fall_back_to_family_sampling():
    w = get_workload("deepseek_r1_moe")
    s = S.initial_schedule(w)
    text = ("Reasoning: x.\nTransformations to apply: "
            "TileSize(axis=zz, decision=[4]), Vectorize(width=8)")
    prop = parse_response(text, s, random.Random(0))
    # bad TileSize dropped; Vectorize(8) illegal on inner tile 1 -> dropped
    assert prop.n_invalid >= 1


def test_tier_fallback_ordering():
    """Weaker tiers emit more invalid mentions and fall back more
    (Table 8); strong tiers essentially never do."""
    plat = get_platform("core-i9")
    fb, inv = {}, {}
    for tier in ("gpt-4o-mini", "llama3.1-8b", "deepseek-r1-distill-7b"):
        prop = LLMProposer(make_llm(tier), plat)
        rng = random.Random(0)
        trace = _trace()
        for _ in range(300):
            prop.propose(trace, rng)
        fb[tier] = prop.stats.fallback_rate
        inv[tier] = prop.stats.invalid_rate
    assert fb["gpt-4o-mini"] <= 0.01
    assert inv["deepseek-r1-distill-7b"] > inv["llama3.1-8b"] \
        > inv["gpt-4o-mini"]
    assert fb["deepseek-r1-distill-7b"] >= fb["gpt-4o-mini"]
    assert fb["llama3.1-8b"] >= fb["gpt-4o-mini"]


def test_reasoner_output_is_paper_format():
    llm = HeuristicReasonerLLM("gpt-4o-mini")
    p = build_prompt(_trace(), get_platform("core-i9"))
    text = llm.complete(p, random.Random(0))
    assert text.startswith("Reasoning:")
    assert "Transformations to apply:" in text


def test_reasoner_deterministic():
    llm = HeuristicReasonerLLM("gpt-4o-mini")
    p = build_prompt(_trace(), get_platform("graviton2"))
    assert llm.complete(p, random.Random(7)) == \
        llm.complete(p, random.Random(7))


def test_api_llm_constructs_offline():
    api = APILLM(model="gpt-4o-mini")
    assert api.name == "api:gpt-4o-mini"
    assert make_llm("api:gpt-4o-mini").model == "gpt-4o-mini"


def test_make_llm_rejects_unknown():
    with pytest.raises(KeyError):
        make_llm("gpt-17")


def test_tier_registry_matches_paper_models():
    assert set(MODEL_TIERS) == {
        "gpt-4o-mini", "o1-mini", "llama3.3-70b",
        "deepseek-r1-distill-32b", "llama3.1-8b", "deepseek-r1-distill-7b",
    }


# ---------------------------------------------------------------------------
# Adversarial completions: parse_response must degrade, never raise
# ---------------------------------------------------------------------------

ADVERSARIAL_COMPLETIONS = [
    "",                                         # empty completion
    "Reasoning: truncated mid-sent",            # cut off before the plan
    "Transformations to apply:",                # empty plan section
    "Transformations to apply: " + ", ".join(_FAKE_NAMES),
    "%%% garbage {not a proposal} <<<>>>",
    "Reasoning: x.\nTransformations to apply: "
    + ", ".join(_FAKE_NAMES) + ".",
]


@pytest.mark.parametrize("text", ADVERSARIAL_COMPLETIONS)
def test_adversarial_completion_degrades_to_fallback(text):
    w = get_workload("deepseek_r1_moe")
    s = S.initial_schedule(w)
    prop = parse_response(text, s, random.Random(0))
    assert prop.fallback
    assert prop.transforms == []


@pytest.mark.parametrize("tier", sorted(MODEL_TIERS))
def test_fully_sloppy_tier_never_raises(tier):
    """Every tier pushed to max param_sloppiness (all families emitted
    parameterless): the proposer samples defaults or falls back — it
    never raises, and every surviving transform applies cleanly."""
    llm = HeuristicReasonerLLM(tier)
    llm.spec = dataclasses.replace(llm.spec, param_sloppiness=1.0)
    plat = get_platform("core-i9")
    prop = LLMProposer(llm, plat)
    rng = random.Random(1)
    trace = _trace()
    for _ in range(50):
        p = prop.propose(trace, rng)
        s = trace[0].schedule
        for t in p.transforms:
            s = t.apply(s)  # raises ScheduleError on an invalid survivor
    assert prop.stats.expansions == 50
    assert prop.stats.name == tier


@pytest.mark.parametrize("tier", sorted(MODEL_TIERS))
def test_fake_name_storm_per_tier(tier):
    """Every tier forced to emit ONLY unknown transform names: each
    expansion degrades to the Appendix-G fallback without raising."""
    llm = HeuristicReasonerLLM(tier)
    llm.spec = dataclasses.replace(
        llm.spec, invalid_name_rate=1.0, param_sloppiness=1.0)
    prop = LLMProposer(llm, get_platform("core-i9"))
    rng = random.Random(2)
    trace = _trace()
    for _ in range(30):
        p = prop.propose(trace, rng)
        if p.n_proposed:
            # only real families survive validation
            assert all(t.name not in _FAKE_NAMES for t in p.transforms)
    assert prop.stats.invalid > 0


# ---------------------------------------------------------------------------
# APILLM retry-with-backoff (satellite: bounded attempts, jitter, obs)
# ---------------------------------------------------------------------------


def _retry_llm(**kw):
    llm = APILLM("test-model", backoff_s=0.01, **kw)
    llm._sleep = lambda s: llm.__dict__.setdefault("_slept", []).append(s)
    return llm


def _prompt():
    return build_prompt(_trace(), get_platform("core-i9"), trace_depth=2)


def test_api_llm_retries_transient_then_succeeds():
    llm = _retry_llm(max_attempts=3)
    calls = []

    def req(body):
        calls.append(body)
        if len(calls) < 3:
            raise urllib.error.URLError("connection reset")
        return "Reasoning: ok.\nTransformations to apply: Vectorize(width=8)."

    llm._request = req
    out = llm.complete(_prompt(), random.Random(0))
    assert out.startswith("Reasoning:")
    assert llm.retries == 2
    sleeps = llm.__dict__["_slept"]
    assert len(sleeps) == 2
    # exponential: second delay base doubles; jitter <= 25% cannot mask it
    assert sleeps[1] > sleeps[0]
    # one request body for all attempts: the rng seed is drawn exactly once
    assert calls[0] == calls[1] == calls[2]


def test_api_llm_client_error_fails_immediately():
    llm = _retry_llm(max_attempts=5)
    llm._request = lambda body: (_ for _ in ()).throw(
        urllib.error.HTTPError("u", 400, "bad request", None, None))
    with pytest.raises(urllib.error.HTTPError):
        llm.complete(_prompt(), random.Random(0))
    assert llm.retries == 0


def test_api_llm_rate_limit_is_retryable():
    llm = _retry_llm(max_attempts=2)
    attempts = []

    def req(body):
        attempts.append(1)
        if len(attempts) == 1:
            raise urllib.error.HTTPError("u", 429, "slow down", None, None)
        return "Reasoning: ok.\nTransformations to apply: Parallel(levels=1)."

    llm._request = req
    assert llm.complete(_prompt(), random.Random(0))
    assert llm.retries == 1


def test_api_llm_bounded_attempts_then_raises():
    llm = _retry_llm(max_attempts=3)
    n = []

    def req(body):
        n.append(1)
        raise urllib.error.URLError("down")

    llm._request = req
    with pytest.raises(urllib.error.URLError):
        llm.complete(_prompt(), random.Random(0))
    assert len(n) == 3  # bounded: exactly max_attempts requests
    assert llm.retries == 2


def test_api_llm_retry_emits_obs_instants():
    from repro.obs import Tracer

    tracer = Tracer()
    llm = APILLM("test-model", backoff_s=0.0, max_attempts=2, tracer=tracer)
    llm._sleep = lambda s: None
    flaky = []

    def req(body):
        flaky.append(1)
        if len(flaky) == 1:
            raise TimeoutError("slow")
        return "Reasoning: ok.\nTransformations to apply: Unroll(factor=2)."

    llm._request = req
    llm.complete(_prompt(), random.Random(0))
    retries = [e for e in tracer.events() if e.name == "llm-retry"]
    assert len(retries) == 1
    assert retries[0].args["error"] == "TimeoutError"
    assert retries[0].args["attempt"] == 1
