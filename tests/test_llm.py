"""LLM interface: prompt construction, parsing, validation, fallback
(paper §3.1, Appendix A/G)."""
import random

import pytest

from repro.core import schedule as S
from repro.core.cost_model import get_platform
from repro.core.llm import (
    MODEL_TIERS,
    APILLM,
    HeuristicReasonerLLM,
    LLMProposer,
    TraceEntry,
    build_prompt,
    make_llm,
    parse_response,
)
from repro.core.workloads import get_workload


def _trace(wname="deepseek_r1_moe", n=3):
    w = get_workload(wname)
    s = S.initial_schedule(w)
    entries = [TraceEntry(s, 1.0, 1.0)]
    rng = random.Random(0)
    for i in range(n - 1):
        s = S.random_transform(rng, s).apply(s)
        entries.insert(0, TraceEntry(s, 1.0 / (i + 2), float(i + 2)))
    return entries


def test_prompt_contains_paper_sections():
    p = build_prompt(_trace(), get_platform("core-i9"), trace_depth=2)
    for frag in (
        "Monte Carlo Tree Search", "Transformation history",
        "Performance estimate", "Available transformations",
        "Transformations to apply", "Reasoning:",
    ):
        assert frag in p.text, frag
    assert len(p.trace) == 3  # current + parent + grandparent


def test_prompt_trace_depth():
    assert len(build_prompt(_trace(n=4), get_platform("core-i9"),
                            trace_depth=1).trace) == 2
    assert len(build_prompt(_trace(n=4), get_platform("core-i9"),
                            trace_depth=3).trace) == 4


def test_parse_paper_example_format():
    """The exact output format from the paper's Appendix A."""
    w = get_workload("deepseek_r1_moe")
    s = S.initial_schedule(w)
    text = ("Reasoning: The current schedule tiles the j-axis as 2048; "
            "I would retile and unroll.\n"
            "Transformations to apply: TileSize, TileSize, ComputeLocation, "
            "Parallel, Unroll, Unroll.")
    prop = parse_response(text, s, random.Random(0))
    assert not prop.fallback
    assert prop.n_proposed == 6
    # ComputeLocation is illegal on an epilogue-free matmul -> dropped
    names = [t.name for t in prop.transforms]
    assert "ComputeLocation" not in names
    assert names.count("TileSize") == 2
    assert "reasoning" not in prop.reasoning.lower()[:0]  # parsed non-empty
    assert prop.reasoning.startswith("The current schedule")


def test_parse_parameterized_calls():
    w = get_workload("deepseek_r1_moe")
    s = S.initial_schedule(w)
    text = ("Reasoning: x.\nTransformations to apply: "
            "TileSize(axis=j, decision=[4, 4, 2, 64]), Vectorize(width=8), "
            "Parallel(levels=1), CacheRead(operand=B)")
    prop = parse_response(text, s, random.Random(0))
    assert [t.name for t in prop.transforms] == [
        "TileSize", "Vectorize", "Parallel", "CacheRead",
    ]
    ts = prop.transforms[0]
    assert ts.axis == "j" and ts.decision == (4, 4, 2, 64)
    # sequence is applied cumulatively: Vectorize(8) legal only AFTER retile
    out = s
    for t in prop.transforms:
        out = t.apply(out)
    assert out.vector_width == 8


def test_all_invalid_triggers_fallback():
    w = get_workload("deepseek_r1_moe")
    s = S.initial_schedule(w)
    text = "Reasoning: x.\nTransformations to apply: WarpShuffle, Hoist."
    prop = parse_response(text, s, random.Random(0))
    assert prop.fallback and prop.n_invalid == 2


def test_invalid_params_fall_back_to_family_sampling():
    w = get_workload("deepseek_r1_moe")
    s = S.initial_schedule(w)
    text = ("Reasoning: x.\nTransformations to apply: "
            "TileSize(axis=zz, decision=[4]), Vectorize(width=8)")
    prop = parse_response(text, s, random.Random(0))
    # bad TileSize dropped; Vectorize(8) illegal on inner tile 1 -> dropped
    assert prop.n_invalid >= 1


def test_tier_fallback_ordering():
    """Weaker tiers emit more invalid mentions and fall back more
    (Table 8); strong tiers essentially never do."""
    plat = get_platform("core-i9")
    fb, inv = {}, {}
    for tier in ("gpt-4o-mini", "llama3.1-8b", "deepseek-r1-distill-7b"):
        prop = LLMProposer(make_llm(tier), plat)
        rng = random.Random(0)
        trace = _trace()
        for _ in range(300):
            prop.propose(trace, rng)
        fb[tier] = prop.stats.fallback_rate
        inv[tier] = prop.stats.invalid_rate
    assert fb["gpt-4o-mini"] <= 0.01
    assert inv["deepseek-r1-distill-7b"] > inv["llama3.1-8b"] \
        > inv["gpt-4o-mini"]
    assert fb["deepseek-r1-distill-7b"] >= fb["gpt-4o-mini"]
    assert fb["llama3.1-8b"] >= fb["gpt-4o-mini"]


def test_reasoner_output_is_paper_format():
    llm = HeuristicReasonerLLM("gpt-4o-mini")
    p = build_prompt(_trace(), get_platform("core-i9"))
    text = llm.complete(p, random.Random(0))
    assert text.startswith("Reasoning:")
    assert "Transformations to apply:" in text


def test_reasoner_deterministic():
    llm = HeuristicReasonerLLM("gpt-4o-mini")
    p = build_prompt(_trace(), get_platform("graviton2"))
    assert llm.complete(p, random.Random(7)) == \
        llm.complete(p, random.Random(7))


def test_api_llm_constructs_offline():
    api = APILLM(model="gpt-4o-mini")
    assert api.name == "api:gpt-4o-mini"
    assert make_llm("api:gpt-4o-mini").model == "gpt-4o-mini"


def test_make_llm_rejects_unknown():
    with pytest.raises(KeyError):
        make_llm("gpt-17")


def test_tier_registry_matches_paper_models():
    assert set(MODEL_TIERS) == {
        "gpt-4o-mini", "o1-mini", "llama3.3-70b",
        "deepseek-r1-distill-32b", "llama3.1-8b", "deepseek-r1-distill-7b",
    }
