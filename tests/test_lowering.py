"""Lowering bridge + measured oracle: every lowerable schedule variant must
match kernels/ref.py (interpret mode, CPU CI), and the oracle backends must
honor the protocol the search stack assumes."""
import itertools
import random

import pytest

from repro.core import schedule as S
from repro.core.lowering import (
    LoweringError,
    _quantize_block,
    lower_schedule,
    time_lowered,
)
from repro.core.oracle import (
    AnalyticalOracle,
    HybridOracle,
    MeasuredOracle,
    make_oracle,
)
from repro.core.cost_model import HardwareOracle, get_platform
from repro.core.schedule import initial_schedule, random_schedule
from repro.core.search import _one_shot_search
from repro.core.workloads import (
    attention_workload,
    conv2d_workload,
    matmul_workload,
)


def _gemm(epilogue="none"):
    return matmul_workload("t_gemm" + epilogue, m=32, n=128, k=64,
                           dtype_bytes=4, epilogue=epilogue)


def _attn():
    return attention_workload("t_attn", heads=2, seq_q=64, seq_kv=64,
                              head_dim=32, dtype_bytes=4)


# ---------------------------------------------------------------------------
# correctness sweep: tiles x fusion x cache_write vs kernels/ref.py
# ---------------------------------------------------------------------------

def test_matmul_variants_match_ref():
    w = _gemm()
    s0 = initial_schedule(w)
    tilings = [
        None,                                   # trivial tiles
        {"i": (2, 1, 2, 8), "j": (1, 1, 1, 128), "k": (2, 32)},
        {"i": (1, 1, 32, 1), "j": (2, 1, 2, 32), "k": (4, 16)},
    ]
    for tiles, cw, staged in itertools.product(
        tilings, (False, True), ((), ("A",), ("A", "B"))
    ):
        s = s0
        if tiles:
            for axis, dec in tiles.items():
                s = S.TileSize(axis, dec).apply(s)
        s = S.CacheWrite(cw).apply(s)
        for op in staged:
            s = S.CacheRead(op).apply(s)
        low = lower_schedule(s, interpret=True)
        assert not low.fallback, (tiles, cw, staged)
        assert low.kind == "matmul"
        assert low.blocks["cache_write"] == cw
        # unstaged operands keep the whole reduction strip resident
        assert (low.blocks["bk"] == 64) == (not staged)
        low.verify()  # raises on mismatch


def test_swiglu_fusion_depths_match_ref():
    w = _gemm("swiglu")
    s0 = initial_schedule(w)
    kinds = set()
    for loc in (-1, 0, 2):
        s = S.ComputeLocation(loc).apply(s0) if loc >= 0 else s0
        low = lower_schedule(s, interpret=True)
        low.verify()
        kinds.add(low.kind)
        assert not low.fallback
    # fused (ComputeLocation >= 0) selects the gate-up kernel; materialized
    # lowers to plain matmul + jnp epilogue
    assert kinds == {"matmul", "swiglu"}


def test_attention_fused_vs_materialized():
    w = _attn()
    s0 = initial_schedule(w)
    mat = lower_schedule(s0, interpret=True)           # softmax at root
    assert mat.fallback and mat.kind == "ref"
    mat.verify()
    fused = lower_schedule(S.ComputeLocation(1).apply(s0), interpret=True)
    assert not fused.fallback and fused.kind == "attention"
    fused.verify()
    assert w.loop_map["i"].extent % fused.blocks["block_q"] == 0
    assert w.loop_map["j"].extent % fused.blocks["block_k"] == 0


def test_attention_cache_read_staging():
    w = _attn()
    s = S.ComputeLocation(1).apply(initial_schedule(w))
    s = S.TileSize("j", (4, 1, 1, 16)).apply(s)
    unstaged = lower_schedule(s, interpret=True)
    assert unstaged.blocks["block_k"] == 64      # whole KV strip resident
    staged = lower_schedule(S.CacheRead("K").apply(s), interpret=True)
    assert staged.blocks["block_k"] == 16        # banded re-fetch per step
    staged.verify()


def test_random_schedules_all_verify():
    rng = random.Random(7)
    for w in (_gemm(), _gemm("swiglu"), _attn()):
        s0 = initial_schedule(w)
        for _ in range(8):
            s = random_schedule(rng, s0, rng.randint(1, 5))
            lower_schedule(s, interpret=True).verify()


def test_conv_falls_back_to_ref():
    w = conv2d_workload("t_conv", n=1, h=8, w=8, c_in=16, c_out=16,
                        kh=3, kw=3)
    low = lower_schedule(initial_schedule(w), interpret=True)
    assert low.fallback and low.kind == "ref"
    low.verify()


def test_unknown_workload_raises():
    import dataclasses

    w = _gemm()
    bad = dataclasses.replace(
        w, loops=tuple(dataclasses.replace(l, name="z" + l.name)
                       for l in w.loops),
    )
    with pytest.raises(LoweringError):
        lower_schedule(initial_schedule(bad), interpret=True)


# ---------------------------------------------------------------------------
# the timing harness + oracle backends
# ---------------------------------------------------------------------------

def test_time_lowered_positive_and_finite():
    low = lower_schedule(initial_schedule(_gemm()), interpret=True)
    t = time_lowered(low, warmup=1, repeats=3)
    assert 0 < t < 60


def test_measured_oracle_caches_and_dedups():
    w = _gemm()
    mo = MeasuredOracle("tpu-v5e", repeats=2)
    s0 = initial_schedule(w)
    t1 = mo.measure(s0)
    assert mo.measurements == 1 and mo.timed_kernels == 1
    assert mo.measure(s0) == t1                     # schedule-key cache
    assert mo.measurements == 1
    # a different schedule quantizing to the same launch reuses the timing
    s2 = S.Parallel(2).apply(s0)
    assert mo.measure(s2) == t1
    assert mo.measurements == 2 and mo.timed_kernels == 1
    assert mo.speedup(s0) == pytest.approx(1.0)


def test_measured_oracle_grid_guard():
    big = matmul_workload("t_big", m=4096, n=4096, k=4096, dtype_bytes=4)
    s = initial_schedule(big)
    s = S.TileSize("i", (512, 1, 1, 8)).apply(s)
    s = S.CacheRead("A").apply(s)
    s = S.TileSize("k", (32, 128)).apply(s)
    mo = MeasuredOracle("tpu-v5e", max_grid_steps=64)
    with pytest.raises(LoweringError):
        mo.measure(s)


def test_hybrid_oracle_split():
    plat = get_platform("tpu-v5e")
    hy = HybridOracle(HardwareOracle(plat, noise=False),
                      MeasuredOracle(plat, repeats=2))
    w = _gemm()
    s0 = initial_schedule(w)
    assert hy.measure(s0) == hy.measured.measure(s0)
    # rollout scores are analytical but CALIBRATED onto the measured
    # latency scale (baseline ratio), so MCTS reward normalization does
    # not mix units: at the baseline the two backends agree exactly
    assert hy.rollout_measure(s0) == pytest.approx(hy.measure(s0))
    s1 = S.TileSize("i", (4, 1, 1, 8)).apply(s0)
    ratio = hy.rollout_measure(s1) / hy.rollout_measure(s0)
    assert ratio == pytest.approx(
        hy.analytical.measure(s1) / hy.analytical.measure(s0)
    )
    assert hy.platform.name == "tpu-v5e"


def test_make_oracle_specs():
    assert isinstance(make_oracle(None, "core-i9"), AnalyticalOracle)
    assert isinstance(make_oracle("analytical", "core-i9"), HardwareOracle)
    assert isinstance(make_oracle("measured"), MeasuredOracle)
    assert isinstance(make_oracle("hybrid"), HybridOracle)
    mo = MeasuredOracle()
    assert make_oracle(mo) is mo
    with pytest.raises(ValueError):
        make_oracle("quantum")


# ---------------------------------------------------------------------------
# measured search end-to-end (the acceptance run)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_measured_llm_mcts_20_samples():
    """>= 20-sample llm-mcts on a matmul workload, interpret mode, every
    node reward from an actually-timed kernel execution."""
    w = matmul_workload("t_measured_search", m=64, n=128, k=128,
                        dtype_bytes=4)
    mo = MeasuredOracle("tpu-v5e", repeats=2)
    r = _one_shot_search(w, "tpu-v5e", "llm-mcts", budget=20, seed=0, oracle=mo)
    assert r.samples >= 20
    assert r.oracle == "measured"
    # every sample (tree node) + the baseline resolved through the oracle,
    # each backed by a timed execution of its lowered kernel config
    assert mo.measurements >= r.samples + 1
    assert mo.timed_kernels >= 1
    assert all(t > 0 for t in mo._config_cache.values())
    assert r.best_speedup > 0


def test_one_shot_search_accepts_oracle_strings():
    w = matmul_workload("t_oracle_knob", m=32, n=128, k=64, dtype_bytes=4)
    for spec in ("analytical", "measured", "hybrid"):
        r = _one_shot_search(w, "tpu-v5e", "mcts", budget=4, seed=0, oracle=spec)
        assert r.samples >= 4 and r.oracle == spec
        assert len(r.top_schedules) >= 1
