"""MCTS invariants (paper §3.2): UCT accounting, acyclicity, sample
accounting, fallback integration, and the search-method ordering."""
import math
import random

import pytest

from repro.core.cost_model import HardwareOracle, get_platform
from repro.core.evolutionary import EvolutionarySearch
from repro.core.llm import LLMProposer, make_llm
from repro.core.mcts import MCTS, SearchCurve
from repro.core.search import _one_shot_search, compare_efficiency
from repro.core.workloads import get_workload


def _mcts(wname="deepseek_r1_moe", guided=False, **kw):
    plat = get_platform("core-i9")
    oracle = HardwareOracle(plat)
    prop = LLMProposer(make_llm("gpt-4o-mini"), plat) if guided else None
    return MCTS(get_workload(wname), oracle, proposer=prop, seed=0, **kw)


def test_visit_count_accounting():
    m = _mcts()
    n_iters = 0
    for _ in range(60):
        if m.step() is not None:
            n_iters += 1
    assert m.root.N == n_iters  # every backprop touches the root
    # W bounded by N (rewards in (0,1))
    def walk(node):
        assert 0.0 <= node.W <= node.N + 1e-9
        assert len(node.children) <= m.branching
        for c in node.children:
            assert c.parent is node
            walk(c)
    walk(m.root)


def test_acyclicity_no_duplicate_programs():
    m = _mcts()
    for _ in range(80):
        m.step()
    keys = []
    def walk(node):
        keys.append(node.schedule.key())
        for c in node.children:
            walk(c)
    walk(m.root)
    assert len(keys) == len(set(keys))


def test_sample_accounting():
    m = _mcts()
    for _ in range(50):
        m.step()
    n_nodes = 0
    def walk(node):
        nonlocal n_nodes
        n_nodes += 1
        for c in node.children:
            walk(c)
    walk(m.root)
    assert m.samples == n_nodes - 1  # root is not a sample
    assert m.curve[-1][0] == m.samples


def test_curve_monotone():
    m = _mcts(guided=True)
    curve = m.search(80)
    best = 0.0
    for s, v in curve.points:
        assert v >= best
        best = v


def test_branching_respected():
    m = _mcts(branching=4)
    for _ in range(60):
        m.step()
    def walk(node):
        assert len(node.children) <= 4
        for c in node.children:
            walk(c)
    walk(m.root)


def test_curve_helpers():
    c = SearchCurve([(10, 2.0), (20, 5.0), (30, 5.0)])
    assert c.at(5) == 1.0 and c.at(15) == 2.0 and c.at(100) == 5.0
    assert c.samples_to_reach(4.9) == 20
    assert c.samples_to_reach(9.0) is None


def test_method_ordering_low_budget():
    """The paper's central claim at 36 samples, seed-averaged."""
    for wname in ("llama4_scout_mlp", "flux_attention"):
        def mean_at(method, **kw):
            vals = []
            for seed in range(3):
                r = _one_shot_search(wname, "core-i9", method, budget=40,
                               seed=seed, **kw)
                vals.append(r.curve.at(36))
            return sum(vals) / len(vals)
        guided = mean_at("llm-mcts")
        plain = mean_at("mcts")
        evo = mean_at("evolutionary")
        assert guided > plain, (wname, guided, plain)
        assert guided > evo, (wname, guided, evo)


def test_evolutionary_budget_respected():
    oracle = HardwareOracle(get_platform("core-i9"))
    es = EvolutionarySearch(get_workload("deepseek_r1_moe"), oracle, seed=0)
    es.search(55)
    assert es.samples == 55


def test_compare_efficiency_metrics():
    base = SearchCurve([(100, 2.0), (500, 4.0)])
    ours = SearchCurve([(20, 4.5)])
    c = compare_efficiency(base, ours, 600)
    assert c.ours_samples == 20
    assert c.sample_reduction == pytest.approx(500 / 20)
    assert c.efficiency_gain > 1


def test_transposition_and_prior_options_run():
    m = _mcts(guided=True, transposition_table=True, prior_weight=0.5)
    m.search(40)
    assert m.best.speedup >= 1.0
