"""Per-architecture smoke + serving-consistency tests (all 10 assigned
archs, reduced configs, CPU)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import model as M

KEY = jax.random.PRNGKey(0)
ARCHS = list_archs()


def _batch(cfg, b=2, s=16, with_labels=True):
    k1, k2, k3 = jax.random.split(KEY, 3)
    if cfg.frontend == "audio":
        out = {"frames": jax.random.normal(
            k1, (b, s, cfg.frontend_dim), jnp.float32)}
    else:
        out = {"tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab)}
        if cfg.frontend == "vision":
            out["patches"] = jax.random.normal(
                k3, (b, cfg.vision_patches, cfg.d_model), jnp.float32
            ) * 0.02
    if with_labels:
        out["labels"] = jax.random.randint(k2, (b, s), 0, cfg.vocab)
    return out


def test_all_archs_registered():
    assert len(ARCHS) == 10


_HEAVY_SMOKE = {"hymba-1.5b", "llava-next-34b"}
_SMOKE_PARAMS = [
    (pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_SMOKE else a)
    for a in ARCHS
]


@pytest.mark.parametrize("arch", _SMOKE_PARAMS)
def test_smoke_forward_and_loss(arch):
    """The assigned per-arch smoke test: reduced config, one forward +
    train step on CPU, output shapes + no NaNs."""
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg)
    logits, aux = M.forward(cfg, params, batch)
    b, s = 2, 16
    expect_s = s + (cfg.vision_patches if cfg.frontend == "vision" else 0)
    assert logits.shape == (b, expect_s, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    loss, metrics = M.loss_fn(cfg, params, batch)
    assert jnp.isfinite(loss)
    # one SGD-flavored step moves the loss
    g = jax.grad(lambda p: M.loss_fn(cfg, p, batch)[0])(params)
    p2 = jax.tree.map(lambda p, gg: p - 0.3 * gg.astype(p.dtype), params, g)
    loss2, _ = M.loss_fn(cfg, p2, batch)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_full_config_constructs_abstractly(arch):
    """FULL configs are only exercised abstractly (no allocation)."""
    cfg = get_config(arch)
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    tree = jax.eval_shape(lambda k: M.init_params(cfg, k, 16), key_spec)
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))
    assert n > 0.8 * cfg.param_count()  # within padding slack


FAST_DECODE = {"tinyllama-1.1b", "xlstm-125m"}
_DECODE_PARAMS = [
    (a if a in FAST_DECODE else pytest.param(a, marks=pytest.mark.slow))
    for a in ARCHS if get_config(a).has_decode
]


@pytest.mark.parametrize("arch", _DECODE_PARAMS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.block == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)  # no drops
    params = M.init_params(cfg, KEY)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)
    full, _ = M.forward(cfg, params, {"tokens": toks})
    lg, cache = M.prefill(cfg, params, {"tokens": toks[:, :S]}, max_len=S + 4)
    got, _ = M.decode_step(cfg, params, toks[:, S:S + 1], cache, jnp.int32(S))
    np.testing.assert_allclose(
        np.asarray(got[:, 0], np.float32), np.asarray(full[:, S], np.float32),
        atol=5e-4, rtol=5e-3,
    )
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32),
        np.asarray(full[:, S - 1], np.float32), atol=5e-4, rtol=5e-3,
    )


def test_encoder_has_no_decode():
    cfg = get_config("hubert-xlarge")
    assert not cfg.has_decode
    ok, why = cfg.supports("decode_32k")
    assert not ok and "encoder" in why


def test_long_context_gating():
    for arch in ARCHS:
        cfg = get_config(arch)
        ok, why = cfg.supports("long_500k")
        assert ok == cfg.sub_quadratic, (arch, why)
    assert get_config("xlstm-125m").supports("long_500k")[0]
    assert get_config("hymba-1.5b").supports("long_500k")[0]


def test_moe_capacity_drops_are_the_only_divergence():
    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
    cfg_nodrop = dataclasses.replace(cfg, capacity_factor=16.0)
    params = M.init_params(cfg_nodrop, KEY)
    batch = _batch(cfg_nodrop)
    l1, _ = M.forward(cfg_nodrop, params, batch)
    l2, _ = M.forward(cfg_nodrop, params, batch)
    np.testing.assert_array_equal(l1, l2)  # routing deterministic


def test_head_padding_function_preserving():
    """Padded (TP) layout must compute the same function (DESIGN.md §6)."""
    cfg = get_config("phi4-mini-3.8b", smoke=True)  # 3 heads / 1 kv
    tp = 2
    d_pad = M.attn_dims(cfg, tp)
    assert d_pad.heads == 4  # 3 -> 4 per-group padding
    p_ref = M.init_params(cfg, KEY, tp=1)
    p_pad = M.init_params(cfg, KEY, tp=tp)
    # graft real weights into the padded layout
    rg, pg = cfg.head_group_sizes(tp)
    L = cfg.layers
    attn_r, attn_p = p_ref["layers"]["attn"], p_pad["layers"]["attn"]
    wq = jnp.zeros_like(attn_p["wq"]).reshape(
        L, cfg.d_model, cfg.kv_heads, pg, cfg.hd)
    wq = wq.at[:, :, :, :rg].set(
        attn_r["wq"].reshape(L, cfg.d_model, cfg.kv_heads, rg, cfg.hd))
    wo = jnp.zeros_like(attn_p["wo"]).reshape(
        L, cfg.kv_heads, pg, cfg.hd, cfg.d_model)
    wo = wo.at[:, :, :rg].set(
        attn_r["wo"].reshape(L, cfg.kv_heads, rg, cfg.hd, cfg.d_model))
    p_pad["layers"]["attn"] = dict(
        attn_r, wq=wq.reshape(L, cfg.d_model, -1),
        wo=wo.reshape(L, -1, cfg.d_model))
    for k in p_pad:
        if k != "layers":
            p_pad[k] = p_ref[k]
    for k in p_pad["layers"]:
        if k != "attn":
            p_pad["layers"][k] = p_ref["layers"][k]
    batch = _batch(cfg)
    l_ref, _ = M.forward(cfg, p_ref, batch)
    l_pad, _ = M.forward(cfg, p_pad, batch)
    np.testing.assert_allclose(l_ref, l_pad, atol=1e-5, rtol=1e-5)


def test_hymba_window_vs_global_layers():
    cfg = get_config("hymba-1.5b", smoke=True)
    params = M.init_params(cfg, KEY)
    assert params["is_global"].shape == (cfg.layers,)
    assert float(params["is_global"][0]) == 1.0  # layer 0 global


def test_xlstm_layer_structure():
    cfg = get_config("xlstm-125m", smoke=True)
    params = M.init_params(cfg, KEY)
    flags = np.asarray(params["is_slstm"])
    assert flags.shape == (cfg.layers,)
    full = get_config("xlstm-125m")
    kf = jax.eval_shape(
        lambda k: M.init_params(full, k), jax.ShapeDtypeStruct((2,),
                                                               jnp.uint32))
    assert kf["is_slstm"].shape == (12,)
