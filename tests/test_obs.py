"""repro.obs: tracer semantics, histogram percentiles, export formats,
metrics-layer regressions, and the traced-engine / traced-session
integration (the PR-7 observability acceptance checks)."""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.obs import (
    MAIN_TRACK,
    NULL_TRACER,
    Histogram,
    Tracer,
    percentile,
    prometheus_text,
    snapshot,
)
from repro.serve import PagedServeEngine, Request, ServeEngine
from repro.serve.metrics import EngineMetrics

CFG = get_config("tinyllama-1.1b", smoke=True)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def _fake_clock():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    return t, clock


# ---------------------------------------------------------------------------
# Tracer primitives
# ---------------------------------------------------------------------------


def test_span_records_x_event_with_late_args():
    t, clock = _fake_clock()
    tr = Tracer(clock=clock)
    with tr.span("work", cat="c", rows=3) as sp:
        sp.set(latency_s=0.5)
    (ev,) = tr.events()
    assert (ev.name, ev.ph, ev.cat, ev.track) == ("work", "X", "c",
                                                  MAIN_TRACK)
    assert ev.ts == 1.0 and ev.dur == 1.0   # enter at t=1, exit at t=2
    assert ev.args == {"rows": 3, "latency_s": 0.5}


def test_nested_spans_inherit_track():
    _, clock = _fake_clock()
    tr = Tracer(clock=clock)
    with tr.span("outer", track="slot3"):
        with tr.span("inner"):          # no explicit track: inherits
            tr.instant("tick")          # instants inherit too
    inner, outer = tr.spans("inner")[0], tr.spans("outer")[0]
    tick = [e for e in tr.events() if e.name == "tick"][0]
    assert inner.track == outer.track == tick.track == "slot3"
    # nesting by time containment (what chrome://tracing renders)
    assert outer.ts < inner.ts
    assert inner.ts + inner.dur < outer.ts + outer.dur


def test_begin_end_cross_frame_pair():
    _, clock = _fake_clock()
    tr = Tracer(clock=clock)
    tr.begin("req7", track="slot0", uid=7)
    tr.instant("first-token", track="slot0")
    tr.end("req7", track="slot0", new_tokens=5)
    phs = [e.ph for e in tr.events()]
    assert phs == ["B", "i", "E"]
    b, e = tr.events()[0], tr.events()[2]
    assert b.track == e.track == "slot0"
    assert b.ts < e.ts


def test_ring_buffer_bounds_memory_and_counts_drops():
    _, clock = _fake_clock()
    tr = Tracer(clock=clock, capacity=4)
    for i in range(10):
        tr.instant(f"e{i}")
    evs = tr.events()
    assert len(evs) == 4
    assert [e.name for e in evs] == ["e6", "e7", "e8", "e9"]  # oldest out
    assert tr.dropped == 6
    assert tr.chrome_trace()["otherData"]["dropped_events"] == 6
    tr.clear()
    assert tr.events() == [] and tr.dropped == 0


def test_disabled_tracer_records_nothing():
    calls = [0]

    def clock():
        calls[0] += 1
        return 0.0

    tr = Tracer(clock=clock, enabled=False)
    with tr.span("x") as sp:
        sp.set(a=1)
    tr.instant("y")
    tr.begin("z")
    tr.end("z")
    assert tr.events() == []
    assert calls[0] == 0        # the disabled path never reads the clock
    assert NULL_TRACER.events() == []


def test_chrome_trace_format(tmp_path):
    _, clock = _fake_clock()
    tr = Tracer(clock=clock)
    with tr.span("a", cat="serve", track="slot1", rows=2):
        pass
    tr.instant("i1", track="slot1")
    path = tr.write(str(tmp_path / "t.trace.json"))
    d = json.load(open(path))
    evs = d["traceEvents"]
    meta = {e["args"]["name"]: e["tid"] for e in evs if e["ph"] == "M"}
    assert meta[MAIN_TRACK] == 0 and "slot1" in meta
    x = [e for e in evs if e["ph"] == "X"][0]
    assert x["ts"] == 1.0 * 1e6 and x["dur"] == 1.0 * 1e6  # microseconds
    assert x["tid"] == meta["slot1"]
    assert x["args"] == {"rows": 2}
    i = [e for e in evs if e["ph"] == "i"][0]
    assert i["s"] == "t"


def test_jsonl_export(tmp_path):
    _, clock = _fake_clock()
    tr = Tracer(clock=clock)
    with tr.span("a"):
        pass
    tr.instant("b", track="slot0", pages=[1, 2])
    path = tr.write(str(tmp_path / "t.jsonl"))
    lines = [json.loads(l) for l in open(path)]
    assert [l["name"] for l in lines] == ["a", "b"]
    assert lines[1]["args"] == {"pages": [1, 2]}
    assert lines[0]["ph"] == "X" and lines[0]["dur"] == 1.0  # seconds


# ---------------------------------------------------------------------------
# histograms / percentiles
# ---------------------------------------------------------------------------


def test_percentile_exact_interpolation():
    assert percentile([], 99) == 0.0
    assert percentile([3.0], 50) == 3.0
    xs = [0.5, 2.0, 0.9, 1.5]
    assert percentile(xs, 0) == 0.5
    assert percentile(xs, 100) == 2.0
    assert percentile(xs, 50) == pytest.approx(1.2)    # true median
    assert percentile(xs, 99) == pytest.approx(1.985)
    # the old nearest-rank helper returned 1.5 for p50 on n=4 (biased
    # high); interpolation must return the midpoint of 0.9 and 1.5
    assert percentile([1, 2, 3, 4], 50) == pytest.approx(2.5)


def test_histogram_exact_small_n_matches_percentile():
    h = Histogram()
    xs = [0.001, 0.01, 0.005, 0.1, 0.0001]
    h.observe_many(xs)
    for q in (50, 90, 99):
        assert h.percentile(q) == pytest.approx(percentile(xs, q))
    s = h.summary()
    assert s.count == 5 and s.min == 0.0001 and s.max == 0.1
    assert s.mean == pytest.approx(sum(xs) / 5)


def test_histogram_bucket_fallback_bounded_error():
    h = Histogram(exact_n=10)
    rng = np.random.RandomState(0)
    xs = list(rng.lognormal(-6, 1.0, size=500))
    h.observe_many(xs)
    assert h._exact is None             # cap crossed: buckets took over
    for q in (50, 90, 99):
        exact = percentile(xs, q)
        approx = h.percentile(q)
        # log buckets with growth=1.25 bound relative error to ~1 bucket
        assert abs(approx - exact) / exact < 0.25, (q, exact, approx)
    assert h.percentile(100) <= h.max
    assert sum(c for _, c in h.nonzero_buckets()) == 500


def test_histogram_zero_and_below_lowest():
    h = Histogram()
    h.observe(0.0)
    h.observe(-1.0)
    assert h.count == 2
    assert h.counts[0] == 2             # clamp to the first bucket
    assert Histogram().percentile(50) == 0.0


# ---------------------------------------------------------------------------
# export: Prometheus text + JSON snapshot
# ---------------------------------------------------------------------------


def test_prometheus_text_exposition():
    h = Histogram.from_values([0.001, 0.01, 0.01])
    text = prometheus_text({"finished": 3, "occ": 0.5}, {"lat_s": h},
                           prefix="t_")
    assert "# TYPE t_finished counter" in text
    assert "t_finished 3" in text
    assert "# TYPE t_occ gauge" in text            # float -> gauge
    assert "# TYPE t_lat_s histogram" in text
    assert 't_lat_s_bucket{le="+Inf"} 3' in text
    assert "t_lat_s_count 3" in text
    # cumulative buckets: counts never decrease along le
    cums = [int(l.rsplit(" ", 1)[1]) for l in text.splitlines()
            if l.startswith("t_lat_s_bucket")]
    assert cums == sorted(cums)


def test_snapshot_schema():
    h = Histogram.from_values([0.5, 2.0, 0.9, 1.5])
    snap = snapshot({"n": 4}, {"ttft_s": h}, meta={"run": "x"})
    assert snap["schema"] == "repro.obs/v1"
    assert snap["counters"] == {"n": 4}
    hs = snap["histograms"]["ttft_s"]
    assert hs["count"] == 4
    assert hs["p50"] == pytest.approx(1.2)
    assert hs["p99"] == pytest.approx(1.985)
    assert snap["meta"] == {"run": "x"}
    json.dumps(snap)                    # must be JSON-serializable


# ---------------------------------------------------------------------------
# metrics-layer regressions (the satellite fixes)
# ---------------------------------------------------------------------------


def test_first_token_does_not_bump_admitted():
    """Regression: on_first_token used to increment ``admitted``
    unconditionally — even for unknown uids, and once per request
    per call."""
    m = EngineMetrics(clock=lambda: 0.0)
    m.on_first_token(999)               # unknown uid
    assert m.admitted == 0
    m.on_submit(1, prompt_len=4)
    m.on_first_token(1)
    m.on_first_token(1)                 # idempotent
    assert m.admitted == 0              # first token is NOT admission
    m.on_admit(1)
    assert m.admitted == 1
    assert m.requests[1].admit_t is not None


def test_admit_timestamp_ordering():
    t = [0.0]
    m = EngineMetrics(clock=lambda: t[0])
    m.on_submit(1, prompt_len=4)
    t[0] = 1.0
    m.on_admit(1)
    t[0] = 2.0
    m.on_first_token(1)
    r = m.requests[1]
    assert r.submit_t < r.admit_t < r.first_token_t


def test_summary_zero_finished_requests():
    s = EngineMetrics(clock=lambda: 0.0).summary()
    assert s["requests"] == 0
    assert s["generated_tokens"] == 0
    assert s["throughput_tok_s"] == 0.0
    assert s["ttft_mean_s"] == 0.0 and s["ttft_p99_s"] == 0.0
    assert s["tpot_mean_s"] == 0.0


def test_summary_wall_floor_guard():
    """All requests finishing at one instant must not divide by zero."""
    m = EngineMetrics(clock=lambda: 5.0)
    m.on_submit(1, prompt_len=4)
    m.on_finish(1, new_tokens=3)
    s = m.summary()
    assert s["wall_s"] == pytest.approx(1e-9)
    assert np.isfinite(s["throughput_tok_s"])


def test_summary_spec_lane_unused():
    m = EngineMetrics(clock=lambda: 0.0)
    assert m.summary()["tokens_per_target_call"] == 0.0


def test_summary_slo_with_no_ttfts():
    m = EngineMetrics(clock=lambda: 0.0)
    m.ttft_slo_s = 1.0
    m.on_submit(1, prompt_len=4)
    m.on_finish(1, new_tokens=0)        # finished but never got a token
    assert m.summary()["ttft_under_slo"] == 1.0


def test_metrics_prometheus_surface():
    t = [0.0]
    m = EngineMetrics(clock=lambda: t[0])
    m.on_submit(1, prompt_len=4)
    m.on_admit(1)
    t[0] = 0.5
    m.on_first_token(1)
    m.on_prefill_time(0.1, 32)
    m.on_decode_time(0.02)
    t[0] = 1.0
    m.on_finish(1, new_tokens=3)
    text = m.prometheus()
    assert "repro_serve_admitted 1" in text
    assert "repro_serve_finished 1" in text
    assert "# TYPE repro_serve_ttft_s histogram" in text
    assert "# TYPE repro_serve_prefill_dispatch_s histogram" in text
    assert m.histograms()["decode_dispatch_s"].count == 1


# ---------------------------------------------------------------------------
# engine + session integration: the trace reconstructs the timeline
# ---------------------------------------------------------------------------


def _shared_prompts(n=4, shared_len=37, page=16):
    rng = np.random.RandomState(0)
    shared = rng.randint(0, CFG.vocab, size=shared_len).astype(np.int32)
    out = {}
    for uid in range(n):
        tail_len = page if uid == 0 else int(rng.randint(4, 10))
        tail = rng.randint(0, CFG.vocab, size=tail_len).astype(np.int32)
        out[uid] = np.concatenate([shared, tail])
    return out


def test_paged_engine_trace_reconstructs_timeline(params):
    """--trace-out acceptance: a prefix+speculative run must leave
    admit / prefill-bucket / draft / verify / COW / request-lifetime
    events, correctly nested and on per-slot tracks."""
    tr = Tracer()
    eng = PagedServeEngine(
        CFG, params, slots=2, max_len=96, page_size=16,
        prefix_cache=True, speculative=True, draft_len=3, tracer=tr,
    )
    for uid, p in _shared_prompts().items():
        eng.submit(Request(uid, p, max_new_tokens=6))
    done = eng.run()
    assert len(done) == 4

    names = {e.name for e in tr.events()}
    for required in ("admit", "prefill-bucket", "draft", "verify",
                     "spec-commit", "spec-round", "page-alloc",
                     "page-free", "cow-copy", "first-token"):
        assert required in names, f"missing {required} events"

    # request lifetimes: every uid opens (B) and closes (E) on a slot track
    for uid in range(4):
        pair = [e for e in tr.events() if e.name == f"req{uid}"]
        assert [e.ph for e in pair] == ["B", "E"], pair
        assert pair[0].track == pair[1].track
        assert pair[0].track.startswith("slot")
        assert pair[0].ts < pair[1].ts
        assert pair[1].args["new_tokens"] == 6

    # nesting: draft/verify/spec-commit fall inside their spec-round
    rounds = tr.spans("spec-round")
    assert rounds
    for name in ("draft", "verify", "spec-commit"):
        for inner in tr.spans(name):
            assert any(r.ts <= inner.ts
                       and inner.ts + inner.dur <= r.ts + r.dur + 1e-9
                       for r in rounds), f"{name} not inside a spec-round"

    # admit span carries queue depth and the admitted count
    adm = tr.spans("admit")[0]
    assert adm.args["queued"] == 4 and adm.args["admitted"] == 2

    # chrome export round-trips
    d = tr.chrome_trace()
    tracks = {e["args"]["name"] for e in d["traceEvents"]
              if e["ph"] == "M"}
    assert {"slot0", "slot1"} <= tracks


def test_dense_engine_trace(params):
    tr = Tracer()
    eng = ServeEngine(CFG, params, slots=2, max_len=64, tracer=tr)
    eng.submit(Request(0, np.arange(8, dtype=np.int32) % CFG.vocab,
                       max_new_tokens=4))
    eng.run()
    names = {e.name for e in tr.events()}
    assert {"prefill", "decode", "req0", "first-token"} <= names
    assert eng.metrics.admitted == 1


def test_untraced_engine_summary_unchanged(params):
    """Tracing must be a pure observer: counters identical on/off."""
    prompts = _shared_prompts(n=3)

    def run(tracer):
        eng = PagedServeEngine(CFG, params, slots=2, max_len=96,
                               page_size=16, prefix_cache=True,
                               tracer=tracer)
        for uid, p in prompts.items():
            eng.submit(Request(uid, p, max_new_tokens=4))
        outs = {r.uid: r.output for r in eng.run()}
        return outs, eng.metrics.summary()

    o_off, s_off = run(None)
    o_on, s_on = run(Tracer())
    assert o_off == o_on
    for k in ("requests", "prefill_calls", "prefill_tokens",
              "decode_steps", "prefix_cached_tokens", "admitted"
              if "admitted" in s_off else "requests"):
        assert s_off[k] == s_on[k], k


def test_session_trace_one_span_per_proposal_and_measurement():
    """launch.tune acceptance: the search trace carries one llm-proposal
    span per expansion and one oracle-measure span per consumed sample,
    plus a provenance-carrying compile-task span."""
    from repro.compiler import CompilerSession
    from repro.compiler.tasks import gemm_task

    tr = Tracer()
    sess = CompilerSession(
        "tpu-v5e", oracle="analytical", proposer="random",
        method="llm-mcts", budget_policy=6, tracer=tr,
    )
    (art,) = sess.compile([gemm_task(64, 64, 64)])

    tasks = tr.spans("compile-task")
    assert len(tasks) == 1
    args = tasks[0].args
    assert args["workload"].startswith("gemm")
    assert args["platform"] == "tpu-v5e"
    assert args["method"] == "llm-mcts"
    assert args["samples"] == art.record.samples
    assert args["speedup"] == pytest.approx(art.record.speedup, rel=1e-3)

    measures = tr.spans("oracle-measure")
    assert len(measures) >= art.record.samples
    assert all("latency_s" in m.args for m in measures)
    assert len(tr.spans("llm-proposal")) >= 1
    # every proposal/measure/backprop nests inside the compile-task span
    t0, t1 = tasks[0].ts, tasks[0].ts + tasks[0].dur
    for name in ("llm-proposal", "oracle-measure", "backprop"):
        for sp in tr.spans(name):
            assert t0 <= sp.ts and sp.ts + sp.dur <= t1 + 1e-9


def test_measured_oracle_time_kernel_spans():
    from repro.compiler.tasks import gemm_tuning_workload
    from repro.core.oracle import MeasuredOracle
    from repro.core.schedule import initial_schedule

    tr = Tracer()
    mo = MeasuredOracle("tpu-v5e", repeats=1, warmup=0,
                        check_numerics=False, tracer=tr)
    wl = gemm_tuning_workload(64, 64, 64)
    mo.measure(initial_schedule(wl))
    spans = tr.spans("time-kernel")
    assert len(spans) == 1
    assert spans[0].args["latency_s"] > 0
    # cache hit: no second timing span
    mo.measure(initial_schedule(wl))
    assert len(tr.spans("time-kernel")) == 1


# ---------------------------------------------------------------------------
# launcher CLI round-trips
# ---------------------------------------------------------------------------


def test_serve_launcher_trace_out(tmp_path, capsys):
    from repro.launch import serve as serve_cli

    out = tmp_path / "serve.trace.json"
    serve_cli.main([
        "--arch", "tinyllama-1.1b", "--smoke", "--requests", "3",
        "--max-new", "4", "--max-len", "64", "--slots", "2",
        "--trace-out", str(out),
    ])
    assert "trace:" in capsys.readouterr().out
    d = json.load(open(out))
    names = {e["name"] for e in d["traceEvents"] if e["ph"] != "M"}
    assert {"admit", "prefill-bucket", "decode", "page-alloc"} <= names


def test_tune_launcher_trace_out(tmp_path, capsys):
    from repro.launch import tune as tune_cli

    out = tmp_path / "tune.trace.jsonl"
    rc = tune_cli.main([
        "--arch", "tinyllama-1.1b", "--budget", "4", "--llm", "random",
        "--method", "mcts", "--oracle", "analytical", "--no-measure",
        "--records", str(tmp_path / "records.jsonl"),
        "--trace-out", str(out),
    ])
    assert rc == 0
    assert "trace:" in capsys.readouterr().out
    lines = [json.loads(l) for l in open(out)]
    assert any(l["name"] == "compile-task" for l in lines)
    assert any(l["name"] == "oracle-measure" for l in lines)
