"""Routed multi-LLM proposer pools (``repro.compiler.proposers``):
spec parsing, deterministic routing, the review tier's action matrix,
RNG-identity of a pool of one, and provenance through records."""
import json
import random

import pytest

from repro.compiler import (
    BudgetPolicy,
    CompilerSession,
    ProposerPool,
    ReviewTier,
    TuningRecords,
    attention_task,
    build_pool,
    gemm_task,
    is_pool_spec,
    parse_pool_spec,
)
from repro.compiler.proposers.pool import PooledProposer, tier_cost
from repro.compiler.proposers.review import _trace_avoid
from repro.compiler.proposers.routing import make_router
from repro.core import schedule as S
from repro.core.llm import (
    MODEL_TIERS,
    LLMBase,
    TraceEntry,
    make_llm,
)
from repro.core.workloads import get_workload
from repro.obs import Tracer

WORKLOAD = "llama3_8b_attention"


# ---------------------------------------------------------------------------
# spec parsing
# ---------------------------------------------------------------------------


def test_is_pool_spec():
    assert is_pool_spec("pool:gpt-4o-mini")
    assert not is_pool_spec("gpt-4o-mini")
    assert not is_pool_spec(None)


def test_parse_members_reviewer_route():
    ps = parse_pool_spec(
        "pool:gpt-4o-mini+llama3.1-8b:reviewer=o1-mini:route=bandit")
    assert ps.members == ("gpt-4o-mini", "llama3.1-8b")
    assert ps.reviewer == "o1-mini"
    assert ps.route == "bandit"


def test_parse_defaults():
    ps = parse_pool_spec("pool:llama3.1-8b")
    assert ps.members == ("llama3.1-8b",)
    assert ps.reviewer is None
    assert ps.route == "round-robin"


def test_parse_api_members_with_colons():
    ps = parse_pool_spec("pool:api:gpt-4o+llama3.1-8b:reviewer=api:o1")
    assert ps.members == ("api:gpt-4o", "llama3.1-8b")
    assert ps.reviewer == "api:o1"


def test_parse_rejects_bad_specs():
    with pytest.raises(ValueError):
        parse_pool_spec("gpt-4o-mini")
    with pytest.raises(ValueError):
        parse_pool_spec("pool:")
    with pytest.raises(ValueError):
        parse_pool_spec("pool:a+a")
    with pytest.raises(ValueError):
        parse_pool_spec("pool:gpt-4o-mini:route=nonsense")
    with pytest.raises(ValueError):
        parse_pool_spec("pool:gpt-4o-mini:route=bandit:route=bandit")


def test_build_pool_and_name_round_trip():
    spec = "pool:gpt-4o-mini+llama3.1-8b:reviewer=o1-mini:route=bandit"
    pool = build_pool(spec)
    assert [m.name for m in pool.members] == ["gpt-4o-mini", "llama3.1-8b"]
    assert pool.reviewer.name == "o1-mini"
    assert pool.name == spec
    # round-robin (the default) is omitted from the canonical name
    assert build_pool("pool:llama3.1-8b").name == "pool:llama3.1-8b"


def test_pool_rejects_duplicates_and_empty():
    with pytest.raises(ValueError):
        ProposerPool([], make_router("round-robin"))
    m = PooledProposer(make_llm("gpt-4o-mini"))
    m2 = PooledProposer(make_llm("gpt-4o-mini"))
    with pytest.raises(ValueError):
        ProposerPool([m, m2], make_router("round-robin"))


# ---------------------------------------------------------------------------
# cost model + routers (all deterministic: no rng anywhere)
# ---------------------------------------------------------------------------


def test_tier_cost_ordering_matches_capability():
    costs = {name: tier_cost(spec) for name, spec in MODEL_TIERS.items()}
    assert costs["gpt-4o-mini"] == 1.0  # strongest profile normalizes to 1
    assert costs["llama3.1-8b"] < costs["llama3.3-70b"]
    assert costs["deepseek-r1-distill-7b"] < costs["gpt-4o-mini"]
    assert tier_cost(None) == 1.0  # unknown models (api adapters)


def _members(*names):
    return [PooledProposer(make_llm(n)) for n in names]


def test_round_robin_cycles_in_order():
    r = make_router("round-robin")
    ms = _members("gpt-4o-mini", "llama3.1-8b", "o1-mini")
    assert [r.pick(ms) for _ in range(6)] == [0, 1, 2, 0, 1, 2]


def test_cost_weighted_prefers_cheap_members():
    r = make_router("cost-weighted")
    ms = _members("gpt-4o-mini", "deepseek-r1-distill-7b")
    picks = [r.pick(ms) for _ in range(100)]
    share_cheap = picks.count(1) / len(picks)
    want = (1 / ms[1].cost) / (1 / ms[0].cost + 1 / ms[1].cost)
    assert abs(share_cheap - want) < 0.05  # smooth WRR tracks 1/cost
    assert 0 in picks  # no starvation


def test_bandit_exploits_the_hitting_member():
    r = make_router("bandit")
    ms = _members("gpt-4o-mini", "llama3.1-8b")
    for i in range(40):
        j = r.pick(ms)
        ms[j].drafted += 1
        # member 1 always hits, member 0 never does
        ms[j].window.append(1 if j == 1 else 0)
    late = [r.pick(ms) for _ in range(10)]  # stateless reads
    assert late.count(1) == 10


def test_routers_are_deterministic():
    for policy in ("round-robin", "cost-weighted", "bandit"):
        a, b = make_router(policy), make_router(policy)
        ms = _members("gpt-4o-mini", "llama3.1-8b")
        assert [a.pick(ms) for _ in range(20)] == \
            [b.pick(ms) for _ in range(20)]


def test_make_router_rejects_unknown():
    with pytest.raises(KeyError):
        make_router("thompson")


# ---------------------------------------------------------------------------
# RNG-identity: pool of 1 == plain single proposer
# ---------------------------------------------------------------------------


def test_pool_of_one_is_rng_identical_to_single():
    single = CompilerSession(target="core-i9", proposer="gpt-4o-mini",
                             shared_context=False)
    pooled = CompilerSession(target="core-i9", proposer="pool:gpt-4o-mini",
                             shared_context=False)
    r1 = single.search(WORKLOAD, budget=30, seed=7)
    r2 = pooled.search(WORKLOAD, budget=30, seed=7)
    assert r1.curve.points == r2.curve.points
    assert r1.best_speedup == r2.best_speedup
    assert r1.best_schedule.key() == r2.best_schedule.key()
    # provenance still flows in the pooled arm
    assert r2.proposer == "gpt-4o-mini"


def test_pool_of_two_changes_nothing_structural():
    pooled = CompilerSession(
        target="core-i9", proposer="pool:gpt-4o-mini+llama3.1-8b",
        shared_context=False)
    res = pooled.search(WORKLOAD, budget=30, seed=7)
    assert res.best_speedup > 1.0
    assert res.llm == "pool:gpt-4o-mini+llama3.1-8b"
    # round-robin: both members drafted
    drafted = {m.name: m.drafted for m in pooled.pool.members}
    assert all(v > 0 for v in drafted.values())


# ---------------------------------------------------------------------------
# review tier: the accept / refine / replace / veto action matrix
# ---------------------------------------------------------------------------


class ScriptedLLM(LLMBase):
    """Replays a fixed completion (review-matrix control)."""

    def __init__(self, name, text):
        self.name = name
        self.text = text

    def complete(self, prompt, rng):
        return self.text


GOOD = "Reasoning: r.\nTransformations to apply: TileSize."
GARBAGE = "no plan here"


def _review_fixture(draft_text, review_text, history_delta=0.0):
    """A two-node trace + a drafted proposal + a reviewer around
    ``review_text``.  ``history_delta`` > 0 makes the drafted family a
    regression in the visible trace (feeds the veto path)."""
    from repro.core.llm import parse_response

    w = get_workload(WORKLOAD)
    s0 = S.initial_schedule(w)
    rng = random.Random(0)
    t = S.parse_transform("Parallel", s0, rng)
    s1 = t.apply(s0)
    lat1 = 1.0 + history_delta  # child slower than parent => regression
    trace = [TraceEntry(s1, lat1, 1.0 / lat1), TraceEntry(s0, 1.0, 1.0)]
    draft = parse_response(draft_text, s1, random.Random(1))
    draft.proposer = "drafter"
    tier = ReviewTier(ScriptedLLM("reviewer", review_text))
    return tier, trace, draft


def test_trace_avoid_flags_regressing_family():
    _, trace, _ = _review_fixture(GOOD, GOOD, history_delta=0.5)
    assert "Parallel" in _trace_avoid(trace)
    _, trace, _ = _review_fixture(GOOD, GOOD, history_delta=-0.5)
    assert "Parallel" not in _trace_avoid(trace)


def test_review_accept_when_reviewer_has_no_opinion():
    tier, trace, draft = _review_fixture(GOOD, GARBAGE)
    from repro.core.llm import build_prompt

    from repro.core.cost_model import get_platform

    prompt = build_prompt(trace, get_platform("core-i9"), 2)
    out = tier.review(prompt, trace, draft, random.Random(2))
    assert out.review_action == "accept"
    assert out.reviewer == "reviewer"
    assert out.proposer == "drafter"
    assert [t.describe() for t in out.transforms] == \
        [t.describe() for t in draft.transforms]
    assert tier.accepted == 1


def test_review_replace_invalid_draft():
    tier, trace, draft = _review_fixture(GARBAGE, GOOD)
    from repro.core.cost_model import get_platform
    from repro.core.llm import build_prompt

    prompt = build_prompt(trace, get_platform("core-i9"), 2)
    assert draft.fallback
    out = tier.review(prompt, trace, draft, random.Random(2))
    assert out.review_action == "replace"
    assert not out.fallback
    assert out.proposer == "drafter"  # drafting credit stays
    assert tier.replaced == 1


def test_review_refine_overlapping_families():
    tier, trace, draft = _review_fixture(
        GOOD,
        "Reasoning: tighter.\nTransformations to apply: TileSize, Unroll.",
    )
    from repro.core.cost_model import get_platform
    from repro.core.llm import build_prompt

    prompt = build_prompt(trace, get_platform("core-i9"), 2)
    out = tier.review(prompt, trace, draft, random.Random(2))
    assert out.review_action == "refine"
    assert {t.name for t in out.transforms} >= {"TileSize"}
    assert tier.refined == 1


def test_review_replace_disjoint_families():
    tier, trace, draft = _review_fixture(
        GOOD, "Reasoning: other axis.\nTransformations to apply: Unroll.")
    from repro.core.cost_model import get_platform
    from repro.core.llm import build_prompt

    prompt = build_prompt(trace, get_platform("core-i9"), 2)
    out = tier.review(prompt, trace, draft, random.Random(2))
    assert out.review_action == "replace"
    assert {t.name for t in out.transforms} == {"Unroll"}


def test_review_veto_kills_regressing_draft():
    # the draft proposes ONLY the family the visible trace says regressed,
    # and the reviewer has nothing better: the draft dies pre-oracle
    tier, trace, draft = _review_fixture(
        "Reasoning: d.\nTransformations to apply: Parallel.",
        GARBAGE, history_delta=0.5)
    from repro.core.cost_model import get_platform
    from repro.core.llm import build_prompt

    prompt = build_prompt(trace, get_platform("core-i9"), 2)
    out = tier.review(prompt, trace, draft, random.Random(2))
    assert out.review_action == "veto"
    assert out.fallback  # empty transforms -> default expansion policy
    assert out.proposer == "drafter"
    assert tier.vetoed == 1 and tier.veto_rate == 1.0


def test_promising_quantile_window():
    tier = ReviewTier(ScriptedLLM("r", GARBAGE), quantile=0.7, min_obs=8)
    assert not tier.promising(99.0)  # under min_obs: review nothing
    for v in range(10):
        tier.observe(float(v))
    assert tier.promising(9.0)
    assert not tier.promising(1.0)


# ---------------------------------------------------------------------------
# provenance: SearchResult, records, schema compat
# ---------------------------------------------------------------------------


def test_fallback_by_proposer_in_search_result():
    session = CompilerSession(
        target="core-i9", proposer="pool:gpt-4o-mini+llama3.1-8b",
        shared_context=False)
    res = session.search(WORKLOAD, budget=24, seed=0)
    assert set(res.fallback_by_proposer) == {"gpt-4o-mini", "llama3.1-8b"}
    for name, stats in res.fallback_by_proposer.items():
        assert stats.name == name
        assert stats.expansions > 0
    assert res.pool_stats is not None
    # single-proposer searches report one attributed entry
    single = CompilerSession(target="core-i9", proposer="gpt-4o-mini",
                             shared_context=False)
    r1 = single.search(WORKLOAD, budget=24, seed=0)
    assert set(r1.fallback_by_proposer) == {"gpt-4o-mini"}
    assert r1.pool_stats is None


def test_records_carry_pool_provenance(tmp_path):
    path = str(tmp_path / "records.jsonl")
    session = CompilerSession(
        target="core-i9", proposer="pool:gpt-4o-mini+llama3.1-8b",
        records=path, budget_policy=BudgetPolicy(per_task=48,
                                                 early_stop=False))
    session.compile([
        attention_task(8, 512, 512, 128, kv_heads=2, priority=10),
        attention_task(8, 256, 256, 128, kv_heads=2, priority=5),
        gemm_task(512, 1024, 1024, epilogue="swiglu", priority=1),
    ], force=True)
    recs = session.records.all()
    assert len(recs) == 3
    names = {r.proposer for r in recs if r.proposer}
    assert len(names) >= 2  # both members drafted winning nodes
    assert all(r.schema >= 2 for r in recs)
    assert all(r.llm == "pool:gpt-4o-mini+llama3.1-8b" for r in recs)
    # the JSONL on disk round-trips the new fields
    reloaded = TuningRecords(path)
    assert {r.proposer for r in reloaded.all() if r.proposer} == names


def test_legacy_schema1_rows_still_load(tmp_path):
    path = str(tmp_path / "records.jsonl")
    legacy = {
        "key": "core-i9:attn[i=128]", "kind": "attention",
        "params": {"block_q": 64, "block_k": 64}, "speedup": 2.0,
        "samples": 8, "method": "llm-mcts", "platform": "core-i9",
        "workload": "attn", "schema": 1, "created_at": 1.0,
    }
    with open(path, "w") as f:
        f.write(json.dumps(legacy) + "\n")
    store = TuningRecords(path)
    rec = store.get("core-i9:attn[i=128]")
    assert rec is not None and rec.schema == 1
    assert rec.proposer is None and rec.reviewer is None
    assert rec.review_action is None
    assert store.quarantined == 0


# ---------------------------------------------------------------------------
# session integration: shared pool state, summaries, obs spans
# ---------------------------------------------------------------------------


def test_pool_state_survives_across_tasks():
    session = CompilerSession(
        target="core-i9", proposer="pool:gpt-4o-mini+llama3.1-8b",
        budget_policy=BudgetPolicy(per_task=16, early_stop=False))
    pool = session.pool
    assert pool is not None
    session.compile([attention_task(8, 256, 256, 128, kv_heads=2)],
                    force=True)
    after_one = sum(m.drafted for m in pool.members)
    session.compile([attention_task(8, 512, 512, 128, kv_heads=2)],
                    force=True)
    after_two = sum(m.drafted for m in pool.members)
    assert session.pool is pool  # same object all session
    assert after_one > 0 and after_two > after_one


def test_proposer_summary_shapes():
    session = CompilerSession(
        target="core-i9",
        proposer="pool:gpt-4o-mini+llama3.1-8b:reviewer=o1-mini",
        shared_context=False)
    session.search(WORKLOAD, budget=24, seed=0)
    rows = session.proposer_summary()
    assert [r.get("proposer") for r in rows[:2]] == \
        ["gpt-4o-mini", "llama3.1-8b"]
    assert rows[-1]["reviewer"] == "o1-mini"
    assert {"reviews", "vetoed", "veto_rate"} <= set(rows[-1])
    # single-proposer summary accumulates across searches
    single = CompilerSession(target="core-i9", proposer="gpt-4o-mini",
                             shared_context=False)
    single.search(WORKLOAD, budget=12, seed=0)
    single.search(WORKLOAD, budget=12, seed=1)
    (row,) = single.proposer_summary()
    assert row["proposer"] == "gpt-4o-mini"
    assert row["expansions"] > 12


def test_pool_emits_obs_spans():
    tracer = Tracer()
    session = CompilerSession(
        target="core-i9",
        proposer="pool:gpt-4o-mini+llama3.1-8b:reviewer=o1-mini",
        shared_context=False, tracer=tracer)
    session.search(WORKLOAD, budget=24, seed=0)
    events = tracer.events()
    drafts = [e for e in events if e.name == "draft" and e.cat == "pool"]
    routes = [e for e in events if e.name == "route" and e.cat == "pool"]
    assert drafts and routes
    assert {e.args["proposer"] for e in routes} == \
        {"gpt-4o-mini", "llama3.1-8b"}
    reviews = [e for e in events if e.name == "review" and e.cat == "pool"]
    assert all(e.args.get("reviewer") == "o1-mini" for e in reviews)
