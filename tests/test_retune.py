"""Serve→compile loop: ShapeStats, ArtifactRegistry epochs, hot swaps,
and the BackgroundRetuner (tier-1: outputs bit-identical across a swap)."""
import threading

import jax
import numpy as np
import pytest

from repro.compiler import ArtifactRegistry, ArtifactSet, tasks_for_shapes
from repro.compiler.records import TuningRecords
from repro.configs import get_config
from repro.models import model as M
from repro.serve import BackgroundRetuner, Request, ShapeStats
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import PagedServeEngine


# ---------------------------------------------------------------------------
# ShapeStats
# ---------------------------------------------------------------------------


def test_shape_stats_bucket_weighting():
    st = ShapeStats()
    st.observe("attention", (128, 128))
    st.observe("attention", (128, 128), weight=3.0)
    st.observe("attention", (64, 64), weight=2.0)
    assert st.weight("attention", (128, 128)) == 4.0
    assert st.weight("attention", (64, 64)) == 2.0
    assert st.total("attention") == 6.0
    # shapes are int-coerced so numpy dims land on the same key
    st.observe("decode_batch", (np.int64(2),))
    assert st.weight("decode_batch", (2,)) == 1.0
    with pytest.raises(KeyError):
        st.observe("nope", (1,))


def test_shape_stats_decay_drops_below_floor():
    st = ShapeStats()
    st.observe("prefill_bucket", (32, 2), weight=8.0)
    st.observe("prefill_bucket", (64, 1), weight=0.01)
    st.decay(0.5, floor=1e-2)
    assert st.weight("prefill_bucket", (32, 2)) == 4.0
    assert st.weight("prefill_bucket", (64, 1)) == 0.0   # dropped
    assert st.counts()["prefill_bucket"] == 1
    # full decay empties the histogram (bounded memory)
    for _ in range(20):
        st.decay(0.1)
    assert st.total("prefill_bucket") == 0.0


def test_shape_stats_top_k_stability():
    st = ShapeStats()
    st.observe("attention", (256, 256), weight=5.0)
    st.observe("attention", (128, 128), weight=2.0)
    st.observe("attention", (64, 64), weight=2.0)      # tie with 128
    top = st.top_k("attention", 2)
    assert top == [((256, 256), 5.0), ((64, 64), 2.0)]  # ties: shape asc
    assert st.top_k("attention", 0) == []
    assert len(st.top_k("attention", 99)) == 3
    # deterministic across observation order
    st2 = ShapeStats()
    st2.observe("attention", (64, 64), weight=2.0)
    st2.observe("attention", (128, 128), weight=2.0)
    st2.observe("attention", (256, 256), weight=5.0)
    assert st2.top_k("attention", 3) == st.top_k("attention", 3)


def test_tasks_for_shapes_ranked_by_weight():
    cfg = get_config("tinyllama-1.1b")
    tasks = tasks_for_shapes(
        cfg,
        attention=[((128, 128), 2.0), ((256, 256), 7.0)],
        gemm_m=[(128, 4.0)],
        tp=1,
    )
    assert [t.kind for t in tasks] == ["attention", "gemm", "attention"]
    assert tasks[0].priority > tasks[1].priority > tasks[2].priority
    assert tasks[0].workload.loop_map["i"].extent == 256
    assert tasks[1].workload.loop_map["i"].extent == 128


# ---------------------------------------------------------------------------
# ArtifactRegistry epochs
# ---------------------------------------------------------------------------


def test_registry_publish_and_current():
    reg = ArtifactRegistry(TuningRecords(None), platform="core-i9")
    a0 = reg.current()
    assert reg.epoch == 0 and a0.epoch == 0
    assert reg.publish() == 1
    a1 = reg.current(tp=2)
    assert a1.epoch == 1 and a1.tp == 2
    # per-(epoch, tp) sets are cached
    assert reg.current(tp=2) is a1


def test_artifact_set_is_immutable():
    art = ArtifactRegistry(TuningRecords(None)).current()
    with pytest.raises(AttributeError):
        art.records = {}
    with pytest.raises(AttributeError):
        art.epoch = 99


def test_registry_pin_unpin_refcounts():
    reg = ArtifactRegistry(TuningRecords(None))
    art = reg.acquire()                      # resolve + pin epoch 0
    assert reg.pins(0) == 1
    reg.pin(0)
    assert reg.pins(0) == 2
    reg.publish()                            # epoch 1; 0 pinned -> kept
    assert reg.get(0).epoch == art.epoch == 0
    assert reg.unpin(0) == 1
    assert reg.unpin(0) == 0                 # superseded + unpinned -> GC
    with pytest.raises(KeyError):
        reg.get(0)
    with pytest.raises(ValueError):
        reg.unpin(0)                         # never pinned / already gone
    # the current epoch never GCs, pinned or not
    assert reg.current().epoch == 1


def test_registry_bind_respects_prebound_cfg():
    reg = ArtifactRegistry(TuningRecords(None))
    cfg = get_config("tinyllama-1.1b")
    bound, tp = reg.bind(cfg, tp=2)
    assert tp == 2 and bound.artifacts.tp == 2 and bound.artifacts.epoch == 0
    # an already-bound cfg passes through untouched (no double-pin)
    again, _ = reg.bind(bound, tp=2)
    assert again.artifacts is bound.artifacts
    assert reg.pins(0) == 1


# ---------------------------------------------------------------------------
# engines: hot swap at step boundaries, bit-identical outputs
# ---------------------------------------------------------------------------


def _prompts(n, vocab, plen=6, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(4, vocab, size=plen).astype(np.int32)
            for _ in range(n)]


def _run_batch(engine, prompts, uid0=0, max_new=4):
    for i, p in enumerate(prompts):
        engine.submit(Request(uid0 + i, p, max_new_tokens=max_new))
    return {r.uid: r.output for r in engine.run()}


@pytest.mark.parametrize("engine_cls", [ServeEngine, PagedServeEngine])
def test_swap_is_bit_identical(engine_cls):
    """Tier-1 acceptance: greedy outputs across an artifact-epoch swap
    match a control engine that never swaps, token for token."""
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reg = ArtifactRegistry(TuningRecords(None), platform="core-i9")
    eng = engine_cls(cfg, params, slots=2, max_len=64, backend="jax",
                     registry=reg)
    ctl = engine_cls(cfg, params, slots=2, max_len=64, backend="jax")
    prompts = _prompts(2, cfg.vocab)
    out1 = _run_batch(eng, prompts, uid0=0)
    # retune between batches: new epoch, swap adopted at the next step
    ret = BackgroundRetuner(eng, top_k=2, budget=6)
    summary = ret.run_once()
    assert summary["fresh"] > 0 and summary["epoch"] == 1
    out2 = _run_batch(eng, prompts, uid0=10)
    assert eng.metrics.artifact_swaps == 1
    assert eng._artifact_epoch == 1
    ctl_out1 = _run_batch(ctl, prompts, uid0=0)
    ctl_out2 = _run_batch(ctl, prompts, uid0=10)
    assert out1 == ctl_out1
    assert {u - 10: o for u, o in out2.items()} == \
        {u: o for u, o in ctl_out1.items()}
    assert out2 == ctl_out2


def test_no_mid_step_epoch_mixing_under_concurrent_publish():
    """Property: with a thread publishing epochs as fast as it can, every
    engine step still resolves against exactly ONE epoch (swaps happen
    only at step boundaries), and the engine's pinned epoch stays
    resolvable until it unpins at the boundary."""
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reg = ArtifactRegistry(TuningRecords(None), platform="core-i9")

    probes = []

    class Probed(PagedServeEngine):
        def _admit(self):
            probes.append(("admit", self._artifact_epoch,
                           self.cfg.artifacts.epoch))
            return super()._admit()

        def _decode_iteration(self):
            # mid-step: the engine's epoch must still be resolvable
            # (pinned) no matter how far the registry has advanced
            assert reg.get(self._artifact_epoch) is not None
            probes.append(("decode", self._artifact_epoch,
                           self.cfg.artifacts.epoch))
            return super()._decode_iteration()

    eng = Probed(cfg, params, slots=2, max_len=64, backend="jax",
                 registry=reg)
    ctl = PagedServeEngine(cfg, params, slots=2, max_len=64, backend="jax")
    stop = threading.Event()
    published = []

    def publisher():
        while not stop.is_set():
            published.append(reg.publish())

    t = threading.Thread(target=publisher, daemon=True)
    t.start()
    try:
        prompts = _prompts(3, cfg.vocab, seed=7)
        out = _run_batch(eng, prompts)
    finally:
        stop.set()
        t.join(timeout=10)
    assert _run_batch(ctl, prompts) == out        # bit-identical anyway
    assert len(published) > 2 and eng.metrics.artifact_swaps >= 1
    # within any step, admit and decode saw the same single epoch
    steps, cur = [], []
    for kind, held, bound in probes:
        assert held == bound                       # cfg matches the pin
        if kind == "admit":
            if cur:
                steps.append(cur)
            cur = [held]
        else:
            cur.append(held)
    steps.append(cur)
    for epochs in steps:
        assert len(set(epochs)) == 1, steps
    # epochs only ever move forward across steps
    firsts = [e[0] for e in steps]
    assert firsts == sorted(firsts)


def test_speculative_lane_rebinds_on_swap():
    """A spec-decoding paged engine swaps its verify lane too — and stays
    bit-identical to the no-spec engine across the swap."""
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reg = ArtifactRegistry(TuningRecords(None), platform="core-i9")
    eng = PagedServeEngine(cfg, params, slots=2, max_len=64, backend="jax",
                           registry=reg, speculative=True, draft_len=2)
    plain = PagedServeEngine(cfg, params, slots=2, max_len=64,
                             backend="jax")
    prompts = _prompts(2, cfg.vocab, seed=3)
    out1 = _run_batch(eng, prompts, uid0=0)
    old_verify = eng.spec._verify_j
    reg.publish()
    out2 = _run_batch(eng, prompts, uid0=10)
    assert eng.metrics.artifact_swaps == 1
    assert eng.spec._verify_j is not old_verify   # lane was rebuilt
    assert eng.spec.cfg.artifacts.epoch == 1
    ctl = _run_batch(plain, prompts, uid0=0)
    assert out1 == ctl
    assert {u - 10: o for u, o in out2.items()} == ctl


# ---------------------------------------------------------------------------
# BackgroundRetuner
# ---------------------------------------------------------------------------


def test_retuner_compiles_hot_shapes_then_cache_hits():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reg = ArtifactRegistry(TuningRecords(None), platform="core-i9")
    eng = PagedServeEngine(cfg, params, slots=2, max_len=64, backend="jax",
                           registry=reg)
    _run_batch(eng, _prompts(2, cfg.vocab))
    ret = BackgroundRetuner(eng, top_k=2, budget=6)
    s1 = ret.run_once()
    assert s1["fresh"] > 0 and s1["epoch"] == 1
    assert len(reg.records) == s1["fresh"]
    # same shape distribution again: everything cache-hits, NO new epoch
    s2 = ret.run_once()
    assert s2["fresh"] == 0 and s2["epoch"] is None
    assert s2["cache_hits"] >= 1
    assert reg.epoch == 1
    assert ret.cycles == 2 and ret.published_epochs == [1]


def test_retuner_decays_stats_each_cycle():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reg = ArtifactRegistry(TuningRecords(None), platform="core-i9")
    eng = PagedServeEngine(cfg, params, slots=2, max_len=64, backend="jax",
                           registry=reg)
    eng.metrics.shapes.observe("attention", (32, 32), weight=8.0)
    ret = BackgroundRetuner(eng, top_k=1, budget=4, decay=0.5)
    ret.run_once()
    assert eng.metrics.shapes.weight("attention", (32, 32)) == 4.0


def test_retuner_requires_shared_records():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reg = ArtifactRegistry(TuningRecords(None), platform="core-i9")
    eng = PagedServeEngine(cfg, params, slots=2, max_len=64, backend="jax",
                           registry=reg)
    from repro.compiler import CompilerSession

    foreign = CompilerSession(target="core-i9", method="mcts",
                              records=TuningRecords(None),
                              shared_context=False)
    with pytest.raises(AssertionError, match="registry"):
        BackgroundRetuner(eng, session=foreign)


def test_retuner_thread_start_stop():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reg = ArtifactRegistry(TuningRecords(None), platform="core-i9")
    eng = PagedServeEngine(cfg, params, slots=2, max_len=64, backend="jax",
                           registry=reg)
    _run_batch(eng, _prompts(1, cfg.vocab))
    ret = BackgroundRetuner(eng, top_k=1, budget=4)
    ret.start(interval_s=0.02)
    with pytest.raises(RuntimeError):
        ret.start(interval_s=0.02)               # no double-start
    deadline = threading.Event()
    for _ in range(200):
        if ret.cycles >= 2:
            break
        deadline.wait(0.05)
    ret.stop()
    assert ret.cycles >= 2
    assert ret.published_epochs and ret.published_epochs[0] == 1
    cycles_after = ret.cycles
    deadline.wait(0.1)
    assert ret.cycles == cycles_after            # really stopped
