"""Property tests for the schedule IR + transformation space (paper §2).

Invariants: every legal transformation preserves (a) tile products ==
loop extents, (b) annotation legality (vector width divides the inner tile,
unroll <= inner tile), (c) history append-only; illegal applications raise
ScheduleError and never corrupt state (schedules are immutable).
"""
import math
import random

import pytest

pytest.importorskip("hypothesis", reason="dev dependency (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import schedule as S
from repro.core.workloads import (
    PAPER_WORKLOADS,
    get_workload,
    matmul_workload,
)

WORKLOADS = sorted(PAPER_WORKLOADS)


@st.composite
def schedules(draw):
    wname = draw(st.sampled_from(WORKLOADS))
    seed = draw(st.integers(0, 2**16))
    steps = draw(st.integers(0, 10))
    w = get_workload(wname)
    rng = random.Random(seed)
    s = S.initial_schedule(w)
    for _ in range(steps):
        try:
            s = S.random_transform(rng, s).apply(s)
        except S.ScheduleError:
            break
    return s


@settings(max_examples=60, deadline=None)
@given(schedules(), st.integers(0, 2**16))
def test_transform_preserves_invariants(s, seed):
    rng = random.Random(seed)
    try:
        t = S.random_transform(rng, s)
    except S.ScheduleError:
        return
    out = t.apply(s)
    w = out.workload
    for loop in w.loops:
        dec = out.tile_map[loop.name]
        assert math.prod(dec) == loop.extent
        assert all(f >= 1 for f in dec)
        levels = (S.SPATIAL_LEVELS if loop.kind == "S"
                  else S.REDUCTION_LEVELS)
        assert len(dec) == levels
    vec_axis = w.output.axes[-1]
    assert out.inner_tile(vec_axis) % out.vector_width == 0
    for axis, f in out.unroll:
        assert f <= out.inner_tile(axis)
    assert len(out.history) == len(s.history) + 1
    assert out.history[:len(s.history)] == s.history
    # original untouched (immutability)
    assert s.key() == s.key()


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 65536), st.integers(1, 6), st.integers(0, 2**16))
def test_sample_perfect_tile_product(extent, parts, seed):
    rng = random.Random(seed)
    dec = S.sample_perfect_tile(rng, extent, parts)
    assert len(dec) == parts
    assert math.prod(dec) == extent


def test_initial_schedule_trivial():
    w = get_workload("deepseek_r1_moe")
    s = S.initial_schedule(w)
    for loop in w.loops:
        assert s.tile_map[loop.name][0] == loop.extent
    assert s.vector_width == 1 and s.parallel_levels == 0
    assert s.history == ()


def test_illegal_transforms_raise():
    w = get_workload("deepseek_r1_moe")
    s = S.initial_schedule(w)
    with pytest.raises(S.ScheduleError):
        S.TileSize("nope", (1, 1)).apply(s)
    with pytest.raises(S.ScheduleError):
        S.TileSize("k", (2, 2)).apply(s)  # product != extent
    with pytest.raises(S.ScheduleError):
        S.Vectorize(8).apply(s)  # inner tile 1 not divisible
    with pytest.raises(S.ScheduleError):
        S.Unroll("i", 8).apply(s)
    with pytest.raises(S.ScheduleError):
        S.Layout("A", "diag").apply(s)
    with pytest.raises(S.ScheduleError):
        S.ComputeLocation(2).apply(s)  # matmul w/o epilogue


def test_tilesize_revalidates_annotations():
    w = matmul_workload("m", m=64, n=64, k=64)
    s = S.initial_schedule(w)
    s = S.TileSize("j", (4, 1, 1, 16)).apply(s)
    s = S.Vectorize(8).apply(s)
    s = S.Unroll("j", 16).apply(s)
    # shrinking the inner tile must clamp both annotations
    s = S.TileSize("j", (16, 1, 2, 2)).apply(s)
    assert s.vector_width in (1, 2)
    assert s.unroll_map["j"] <= 2


def test_key_identity_for_reordered_paths():
    w = matmul_workload("m", m=64, n=64, k=64)
    s0 = S.initial_schedule(w)
    a = S.Parallel(1).apply(S.CacheWrite(True).apply(s0))
    b = S.CacheWrite(True).apply(S.Parallel(1).apply(s0))
    assert a.key() == b.key()          # same program
    assert a.history != b.history      # different derivation
