"""Serving engine: continuous batching with ragged prompts must exactly
match sequential single-request decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import Request, ServeEngine

CFG = get_config("tinyllama-1.1b", smoke=True)


def _sequential_greedy(params, prompt, n):
    lg, cache = M.prefill(
        CFG, params, {"tokens": jnp.asarray(prompt)[None]}, 64
    )
    toks = [int(jnp.argmax(lg[0, -1]))]
    pos = len(prompt)
    for _ in range(n - 1):
        lg, cache = M.decode_step(
            CFG, params, jnp.asarray([[toks[-1]]], jnp.int32), cache,
            jnp.int32(pos),
        )
        toks.append(int(jnp.argmax(lg[0, -1])))
        pos += 1
    return toks


@pytest.mark.slow
def test_engine_matches_sequential_decode():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = {u: rng.randint(0, CFG.vocab, size=n).astype(np.int32)
               for u, n in enumerate([7, 12, 5, 9])}
    eng = ServeEngine(CFG, params, slots=2, max_len=64)
    for u, p in prompts.items():
        eng.submit(Request(u, p, max_new_tokens=5))
    done = {r.uid: r for r in eng.run()}
    assert len(done) == 4
    for u, p in prompts.items():
        want = _sequential_greedy(params, p, 5)
        assert done[u].output == want, (u, done[u].output, want)


def test_engine_rejects_encoder():
    cfg = get_config("hubert-xlarge", smoke=True)
    with pytest.raises(AssertionError):
        ServeEngine(cfg, {}, slots=1, max_len=32)


def test_engine_slot_reuse():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    eng = ServeEngine(CFG, params, slots=1, max_len=64)
    rng = np.random.RandomState(1)
    for u in range(3):
        eng.submit(Request(
            u, rng.randint(0, CFG.vocab, size=6).astype(np.int32),
            max_new_tokens=3,
        ))
    done = eng.run()
    assert len(done) == 3  # one slot served all three sequentially
