"""Serving engines: continuous batching with ragged prompts must exactly
match sequential single-request decoding, and the paged scheduler must
decode token-for-token identically to the dense baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serve import PagedServeEngine, Request, ServeEngine

CFG = get_config("tinyllama-1.1b", smoke=True)


def _run_engine(eng, prompts, max_new=5, **req_kw):
    for u, p in prompts.items():
        eng.submit(Request(u, p, max_new_tokens=max_new, **req_kw))
    return {r.uid: r.output for r in eng.run()}


def _sequential_greedy(params, prompt, n):
    lg, cache = M.prefill(
        CFG, params, {"tokens": jnp.asarray(prompt)[None]}, 64
    )
    toks = [int(jnp.argmax(lg[0, -1]))]
    pos = len(prompt)
    for _ in range(n - 1):
        lg, cache = M.decode_step(
            CFG, params, jnp.asarray([[toks[-1]]], jnp.int32), cache,
            jnp.int32(pos),
        )
        toks.append(int(jnp.argmax(lg[0, -1])))
        pos += 1
    return toks


@pytest.mark.slow
def test_engine_matches_sequential_decode():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = {u: rng.randint(0, CFG.vocab, size=n).astype(np.int32)
               for u, n in enumerate([7, 12, 5, 9])}
    eng = ServeEngine(CFG, params, slots=2, max_len=64)
    for u, p in prompts.items():
        eng.submit(Request(u, p, max_new_tokens=5))
    done = {r.uid: r for r in eng.run()}
    assert len(done) == 4
    for u, p in prompts.items():
        want = _sequential_greedy(params, p, 5)
        assert done[u].output == want, (u, done[u].output, want)


def test_engine_rejects_encoder():
    cfg = get_config("hubert-xlarge", smoke=True)
    with pytest.raises(AssertionError):
        ServeEngine(cfg, {}, slots=1, max_len=32)


def test_engine_slot_reuse():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    eng = ServeEngine(CFG, params, slots=1, max_len=64)
    rng = np.random.RandomState(1)
    for u in range(3):
        eng.submit(Request(
            u, rng.randint(0, CFG.vocab, size=6).astype(np.int32),
            max_new_tokens=3,
        ))
    done = eng.run()
    assert len(done) == 3  # one slot served all three sequentially
    assert eng.metrics.summary()["requests"] == 3


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.RandomState(2)
    return {u: rng.randint(0, CFG.vocab, size=n).astype(np.int32)
            for u, n in enumerate([7, 12, 5, 9, 21, 3])}


def test_engine_eos_early_stop(params):
    prompt = np.arange(8, dtype=np.int32) % CFG.vocab
    eng = ServeEngine(CFG, params, slots=1, max_len=64)
    eng.submit(Request(0, prompt, max_new_tokens=20))
    free_run = eng.run()[0].output
    assert len(free_run) == 20
    eng2 = ServeEngine(CFG, params, slots=1, max_len=64)
    eng2.submit(Request(1, prompt, max_new_tokens=20, eos_id=free_run[3]))
    stopped = eng2.run()[0].output
    assert stopped == free_run[:4]  # stops AT the eos token


def test_engine_max_len_truncation(params):
    prompt = np.arange(10, dtype=np.int32) % CFG.vocab
    eng = ServeEngine(CFG, params, slots=1, max_len=16)
    eng.submit(Request(0, prompt, max_new_tokens=100))
    out = eng.run(max_iters=200)[0].output
    # positions stop at max_len - 1: prompt + generated never exceed the
    # cache (first token comes from prefill, the rest from decode)
    assert len(prompt) + len(out) - 1 <= 16 - 1
    assert len(out) < 100


@pytest.mark.slow
def test_paged_matches_dense_ragged(params, prompts):
    """The acceptance bar: paged scheduler decodes token-for-token
    identically to the dense engine across ragged prompts."""
    dense = _run_engine(ServeEngine(CFG, params, slots=2, max_len=64),
                        prompts)
    paged = _run_engine(
        PagedServeEngine(CFG, params, slots=2, max_len=64, page_size=16),
        prompts,
    )
    assert dense.keys() == paged.keys()
    for u in dense:
        assert dense[u] == paged[u], (u, dense[u], paged[u])


@pytest.mark.slow
def test_paged_chunked_prefill_matches(params):
    rng = np.random.RandomState(3)
    prompts = {u: rng.randint(0, CFG.vocab, size=n).astype(np.int32)
               for u, n in enumerate([40, 7, 33])}
    dense = _run_engine(ServeEngine(CFG, params, slots=2, max_len=64),
                        prompts, max_new=4)
    chunked = PagedServeEngine(CFG, params, slots=2, max_len=64,
                               prefill_chunk=16)
    got = _run_engine(chunked, prompts, max_new=4)
    for u in dense:
        assert dense[u] == got[u], (u, dense[u], got[u])
    assert chunked.metrics.prefill_chunk_calls >= 4  # 40- and 33-token


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "hymba-1.5b",
                                  "xlstm-125m"])
def test_paged_matches_dense_stateful_archs(arch):
    """MoE (capacity dropping) and recurrent-state archs must admit via
    exact-length groups — padding would change the computed function.
    Repeated lengths force multi-row groups: MoE rows must each keep
    their own b=1 capacity pool inside the batched admission call."""
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.RandomState(6)
    prompts = {u: rng.randint(0, cfg.vocab, size=n).astype(np.int32)
               for u, n in enumerate([6, 6, 6, 13, 6])}
    dense = _run_engine(ServeEngine(cfg, params, slots=3, max_len=32),
                        prompts, max_new=3)
    paged = _run_engine(
        PagedServeEngine(cfg, params, slots=3, max_len=32, page_size=8),
        prompts, max_new=3,
    )
    for u in dense:
        assert dense[u] == paged[u], (arch, u, dense[u], paged[u])


def test_paged_eos_and_max_len(params):
    prompt = np.arange(9, dtype=np.int32) % CFG.vocab
    eng = PagedServeEngine(CFG, params, slots=1, max_len=32, page_size=8)
    eng.submit(Request(0, prompt, max_new_tokens=10))
    out = eng.run()[0].output
    eng2 = PagedServeEngine(CFG, params, slots=1, max_len=32, page_size=8)
    eng2.submit(Request(1, prompt, max_new_tokens=10, eos_id=out[2]))
    assert eng2.run()[0].output == out[:3]
    eng3 = PagedServeEngine(CFG, params, slots=1, max_len=16, page_size=8)
    eng3.submit(Request(2, prompt, max_new_tokens=100))
    r3 = eng3.run(max_iters=200)[0]
    assert len(prompt) + len(r3.output) - 1 <= 16 - 1
    # finished requests release their pages
    assert eng3.kv.used_pages == 0


def test_paged_overcommitted_pool(params):
    """Fewer pages than slots×pages_per_slot: admission gates on page
    reservations and every request still completes."""
    rng = np.random.RandomState(4)
    eng = PagedServeEngine(CFG, params, slots=4, max_len=64, page_size=16,
                           capacity=8)
    prompts = {u: rng.randint(0, CFG.vocab, size=20).astype(np.int32)
               for u in range(5)}
    done = _run_engine(eng, prompts, max_new=8)
    assert len(done) == 5
    assert eng.metrics.summary()["kv_occupancy_max"] <= 1.0


def test_paged_rejects_oversized_request(params):
    eng = PagedServeEngine(CFG, params, slots=1, max_len=16)
    with pytest.raises(AssertionError):
        eng.submit(Request(0, np.zeros((16,), np.int32)))
    # a request that can never fit the page pool is rejected AT SUBMIT so
    # it cannot deadlock admission (or discard finished work) later
    small = PagedServeEngine(CFG, params, slots=2, max_len=64,
                             page_size=16, capacity=2)
    with pytest.raises(ValueError):
        small.submit(Request(1, np.zeros((40,), np.int32),
                             max_new_tokens=20))


def test_admit_preserves_cache_sharding(params):
    """The _admit slot write must keep the mesh-committed layout instead
    of silently replacing it (regression test for the eager tree-map)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = ServeEngine(CFG, params, slots=2, max_len=32, mesh=mesh)
    committed = {k: leaf.sharding for k, leaf in eng.cache.items()}
    rng = np.random.RandomState(5)
    prompts = {u: rng.randint(0, CFG.vocab, size=6).astype(np.int32)
               for u in range(3)}
    done = _run_engine(eng, prompts, max_new=3)
    assert len(done) == 3
    for k, leaf in eng.cache.items():
        assert leaf.sharding.is_equivalent_to(committed[k], leaf.ndim), k
