"""Serving engines: continuous batching with ragged prompts must exactly
match sequential single-request decoding, and the paged scheduler must
decode token-for-token identically to the dense baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serve import PagedServeEngine, Request, ServeEngine

CFG = get_config("tinyllama-1.1b", smoke=True)


def _run_engine(eng, prompts, max_new=5, **req_kw):
    for u, p in prompts.items():
        eng.submit(Request(u, p, max_new_tokens=max_new, **req_kw))
    return {r.uid: r.output for r in eng.run()}


def _sequential_greedy(params, prompt, n):
    lg, cache = M.prefill(
        CFG, params, {"tokens": jnp.asarray(prompt)[None]}, 64
    )
    toks = [int(jnp.argmax(lg[0, -1]))]
    pos = len(prompt)
    for _ in range(n - 1):
        lg, cache = M.decode_step(
            CFG, params, jnp.asarray([[toks[-1]]], jnp.int32), cache,
            jnp.int32(pos),
        )
        toks.append(int(jnp.argmax(lg[0, -1])))
        pos += 1
    return toks


@pytest.mark.slow
def test_engine_matches_sequential_decode():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = {u: rng.randint(0, CFG.vocab, size=n).astype(np.int32)
               for u, n in enumerate([7, 12, 5, 9])}
    eng = ServeEngine(CFG, params, slots=2, max_len=64)
    for u, p in prompts.items():
        eng.submit(Request(u, p, max_new_tokens=5))
    done = {r.uid: r for r in eng.run()}
    assert len(done) == 4
    for u, p in prompts.items():
        want = _sequential_greedy(params, p, 5)
        assert done[u].output == want, (u, done[u].output, want)


def test_engine_rejects_encoder():
    cfg = get_config("hubert-xlarge", smoke=True)
    with pytest.raises(AssertionError):
        ServeEngine(cfg, {}, slots=1, max_len=32)


def test_engine_slot_reuse():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    eng = ServeEngine(CFG, params, slots=1, max_len=64)
    rng = np.random.RandomState(1)
    for u in range(3):
        eng.submit(Request(
            u, rng.randint(0, CFG.vocab, size=6).astype(np.int32),
            max_new_tokens=3,
        ))
    done = eng.run()
    assert len(done) == 3  # one slot served all three sequentially
    assert eng.metrics.summary()["requests"] == 3


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.RandomState(2)
    return {u: rng.randint(0, CFG.vocab, size=n).astype(np.int32)
            for u, n in enumerate([7, 12, 5, 9, 21, 3])}


def test_engine_eos_early_stop(params):
    prompt = np.arange(8, dtype=np.int32) % CFG.vocab
    eng = ServeEngine(CFG, params, slots=1, max_len=64)
    eng.submit(Request(0, prompt, max_new_tokens=20))
    free_run = eng.run()[0].output
    assert len(free_run) == 20
    eng2 = ServeEngine(CFG, params, slots=1, max_len=64)
    eng2.submit(Request(1, prompt, max_new_tokens=20, eos_id=free_run[3]))
    stopped = eng2.run()[0].output
    assert stopped == free_run[:4]  # stops AT the eos token


def test_engine_max_len_truncation(params):
    prompt = np.arange(10, dtype=np.int32) % CFG.vocab
    eng = ServeEngine(CFG, params, slots=1, max_len=16)
    eng.submit(Request(0, prompt, max_new_tokens=100))
    out = eng.run(max_iters=200)[0].output
    # positions stop at max_len - 1: prompt + generated never exceed the
    # cache (first token comes from prefill, the rest from decode)
    assert len(prompt) + len(out) - 1 <= 16 - 1
    assert len(out) < 100


@pytest.mark.slow
def test_paged_matches_dense_ragged(params, prompts):
    """The acceptance bar: paged scheduler decodes token-for-token
    identically to the dense engine across ragged prompts."""
    dense = _run_engine(ServeEngine(CFG, params, slots=2, max_len=64),
                        prompts)
    paged = _run_engine(
        PagedServeEngine(CFG, params, slots=2, max_len=64, page_size=16),
        prompts,
    )
    assert dense.keys() == paged.keys()
    for u in dense:
        assert dense[u] == paged[u], (u, dense[u], paged[u])


@pytest.mark.slow
def test_paged_chunked_prefill_matches(params):
    rng = np.random.RandomState(3)
    prompts = {u: rng.randint(0, CFG.vocab, size=n).astype(np.int32)
               for u, n in enumerate([40, 7, 33])}
    dense = _run_engine(ServeEngine(CFG, params, slots=2, max_len=64),
                        prompts, max_new=4)
    chunked = PagedServeEngine(CFG, params, slots=2, max_len=64,
                               prefill_chunk=16)
    got = _run_engine(chunked, prompts, max_new=4)
    for u in dense:
        assert dense[u] == got[u], (u, dense[u], got[u])
    assert chunked.metrics.prefill_chunk_calls >= 4  # 40- and 33-token


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "hymba-1.5b",
                                  "xlstm-125m"])
def test_paged_matches_dense_stateful_archs(arch):
    """MoE (capacity dropping) and recurrent-state archs must admit via
    exact-length groups — padding would change the computed function.
    Repeated lengths force multi-row groups: MoE rows must each keep
    their own b=1 capacity pool inside the batched admission call."""
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.RandomState(6)
    prompts = {u: rng.randint(0, cfg.vocab, size=n).astype(np.int32)
               for u, n in enumerate([6, 6, 6, 13, 6])}
    dense = _run_engine(ServeEngine(cfg, params, slots=3, max_len=32),
                        prompts, max_new=3)
    paged = _run_engine(
        PagedServeEngine(cfg, params, slots=3, max_len=32, page_size=8),
        prompts, max_new=3,
    )
    for u in dense:
        assert dense[u] == paged[u], (arch, u, dense[u], paged[u])


def test_paged_eos_and_max_len(params):
    prompt = np.arange(9, dtype=np.int32) % CFG.vocab
    eng = PagedServeEngine(CFG, params, slots=1, max_len=32, page_size=8)
    eng.submit(Request(0, prompt, max_new_tokens=10))
    out = eng.run()[0].output
    eng2 = PagedServeEngine(CFG, params, slots=1, max_len=32, page_size=8)
    eng2.submit(Request(1, prompt, max_new_tokens=10, eos_id=out[2]))
    assert eng2.run()[0].output == out[:3]
    eng3 = PagedServeEngine(CFG, params, slots=1, max_len=16, page_size=8)
    eng3.submit(Request(2, prompt, max_new_tokens=100))
    r3 = eng3.run(max_iters=200)[0]
    assert len(prompt) + len(r3.output) - 1 <= 16 - 1
    # finished requests release their pages
    assert eng3.kv.used_pages == 0


def test_paged_overcommitted_pool(params):
    """Fewer pages than slots×pages_per_slot: admission gates on page
    reservations and every request still completes."""
    rng = np.random.RandomState(4)
    eng = PagedServeEngine(CFG, params, slots=4, max_len=64, page_size=16,
                           capacity=8)
    prompts = {u: rng.randint(0, CFG.vocab, size=20).astype(np.int32)
               for u in range(5)}
    done = _run_engine(eng, prompts, max_new=8)
    assert len(done) == 5
    assert eng.metrics.summary()["kv_occupancy_max"] <= 1.0


def test_paged_rejects_oversized_request(params):
    eng = PagedServeEngine(CFG, params, slots=1, max_len=16)
    with pytest.raises(AssertionError):
        eng.submit(Request(0, np.zeros((16,), np.int32)))
    # a request that can never fit the page pool is rejected AT SUBMIT so
    # it cannot deadlock admission (or discard finished work) later
    small = PagedServeEngine(CFG, params, slots=2, max_len=64,
                             page_size=16, capacity=2)
    with pytest.raises(ValueError):
        small.submit(Request(1, np.zeros((40,), np.int32),
                             max_new_tokens=20))


@pytest.mark.slow
def test_prefix_cache_matches_dense_shared_prompts(params):
    """The prefix-caching acceptance bar: on a shared-prefix stream the
    prefix engine decodes token-for-token identically to dense AND to the
    prefix-off paged engine, while computing strictly fewer prefill
    tokens and reporting a nonzero hit rate."""
    rng = np.random.RandomState(7)
    shared = rng.randint(0, CFG.vocab, size=37).astype(np.int32)
    prompts = {}
    for u in range(6):
        # the first prompt runs past the page-16 boundary at 48 tokens so
        # its third page is full and indexable: followers matching only 37
        # shared tokens then hit it PARTIALLY, forcing boundary COW
        n_tail = 13 if u == 0 else int(rng.randint(3, 12))
        tail = rng.randint(0, CFG.vocab, size=n_tail).astype(np.int32)
        prompts[u] = np.concatenate([shared, tail]) if u != 2 else tail
    dense = _run_engine(ServeEngine(CFG, params, slots=2, max_len=64),
                        prompts, max_new=4)
    off = PagedServeEngine(CFG, params, slots=2, max_len=64, page_size=16)
    got_off = _run_engine(off, prompts, max_new=4)
    on = PagedServeEngine(CFG, params, slots=2, max_len=64, page_size=16,
                          prefix_cache=True)
    got_on = _run_engine(on, prompts, max_new=4)
    for u in dense:
        assert dense[u] == got_off[u] == got_on[u], u
    s_on, s_off = on.metrics.summary(), off.metrics.summary()
    assert s_on["prefill_tokens"] < s_off["prefill_tokens"]
    assert s_on["prefix_hit_rate"] > 0 and s_on["prefix_cached_tokens"] > 0
    # the 37-token prefix is not page-aligned: boundary pages went
    # through copy-on-write without perturbing any donor
    assert on.kv.cow_copies > 0


def test_prefix_cow_end_to_end(params):
    """Boundary-page COW in the full engine: a follower sharing 37 of a
    50-token donor prompt copies the donor's third page, and both decode
    exactly as without any sharing."""
    rng = np.random.RandomState(8)
    shared = rng.randint(0, CFG.vocab, size=37).astype(np.int32)
    prompts = {
        0: np.concatenate([shared, rng.randint(0, CFG.vocab, size=13)
                           .astype(np.int32)]),
        1: np.concatenate([shared, rng.randint(0, CFG.vocab, size=9)
                           .astype(np.int32)]),
    }
    plain = _run_engine(
        PagedServeEngine(CFG, params, slots=1, max_len=64, page_size=16),
        prompts, max_new=4,
    )
    # capacity=8 gives the pool headroom for the boundary copy; a fully
    # provisioned slots=1 pool instead trims the match to full pages
    # (exercised below) rather than paying the copy
    pref = PagedServeEngine(CFG, params, slots=1, max_len=64, page_size=16,
                            capacity=8, prefix_cache=True)
    got = _run_engine(pref, prompts, max_new=4)
    assert got == plain
    assert pref.kv.cow_copies == 1
    assert pref.metrics.prefix_cached_tokens == 37
    # tight pool: same stream, fully provisioned — the reservation cannot
    # afford the copy, the boundary trims away, and the follower still
    # reuses the donor's two full pages (and still decodes identically)
    tight = PagedServeEngine(CFG, params, slots=1, max_len=64, page_size=16,
                             prefix_cache=True)
    got2 = _run_engine(tight, prompts, max_new=4)
    assert got2 == plain
    assert tight.kv.cow_copies == 0
    assert tight.metrics.prefix_cached_tokens == 32


@pytest.mark.slow
def test_chunk_lanes_batch_concurrent_prefills(params):
    """Two equally long prompts admitted together advance their chunked
    prefill in ONE jitted call per chunk — half the calls of the per-slot
    path — and still match the unchunked engine exactly."""
    rng = np.random.RandomState(9)
    prompts = {u: rng.randint(0, CFG.vocab, size=40).astype(np.int32)
               for u in range(2)}
    ref = _run_engine(
        PagedServeEngine(CFG, params, slots=2, max_len=64, page_size=16),
        prompts, max_new=4,
    )
    eng = PagedServeEngine(CFG, params, slots=2, max_len=64, page_size=16,
                           prefill_chunk=16)
    got = _run_engine(eng, prompts, max_new=4)
    assert got == ref
    # 40 tokens = chunks of 16/16/8 per slot; lanes batch both slots
    assert eng.metrics.prefill_chunk_calls == 3


def test_admission_policy_ordering():
    """Policy unit semantics on synthetic candidates (no engines)."""
    from repro.serve import (
        AdmissionPolicy, Candidate, ShortestPrefillFirst, SLOAware,
        make_policy,
    )
    from repro.serve.metrics import EngineMetrics

    m = EngineMetrics(clock=lambda: 0.0)
    cands = [
        Candidate(req=None, submit_t=0.0, prefill_tokens=100, order=0),
        Candidate(req=None, submit_t=1.0, prefill_tokens=5, order=1),
        Candidate(req=None, submit_t=2.0, prefill_tokens=40, order=2),
    ]
    assert [c.order for c in AdmissionPolicy().order(cands, 3.0, m)] \
        == [0, 1, 2]
    assert [c.order for c in ShortestPrefillFirst().order(cands, 3.0, m)] \
        == [1, 2, 0]
    # SLO: with an observed prefill rate of 10ms/token and a 2s SLO the
    # long first arrival has the least laxity (deadline 2.0, needs 1.0s);
    # among the rest the earlier deadline wins
    m.prefill_tokens = 1000
    m.prefill_time_s = 10.0
    assert m.prefill_rate() == 0.01
    slo = make_policy("slo", ttft_slo_s=2.0)
    assert isinstance(slo, SLOAware)
    assert [c.order for c in slo.order(cands, 3.0, m)] == [0, 1, 2]
    # flip: make the newest request's prefill enormous — least laxity now
    cands[2].prefill_tokens = 1000
    assert [c.order for c in slo.order(cands, 3.0, m)] == [2, 0, 1]
    import pytest as _pytest
    with _pytest.raises(ValueError):
        make_policy("nope")


def test_slo_attainment_summary():
    from repro.serve.metrics import EngineMetrics

    t = [0.0]
    m = EngineMetrics(clock=lambda: t[0])
    m.ttft_slo_s = 1.0
    for uid, ttft in enumerate([0.5, 2.0, 0.9, 1.5]):
        t[0] = 0.0
        m.on_submit(uid, prompt_len=4)
        t[0] = ttft
        m.on_first_token(uid)
        t[0] = ttft + 1.0
        m.on_finish(uid, new_tokens=3)
    s = m.summary()
    assert s["ttft_under_slo"] == 0.5
    # interpolated percentile (obs.hist): rank 0.99*(4-1)=2.97 between
    # the 1.5 and 2.0 order statistics
    assert s["ttft_p99_s"] == pytest.approx(1.985)


# ---------------------------------------------------------------------------
# speculative decoding: the spec lane must be bit-identical to plain
# paged decoding (greedy verify accepts exactly the tokens sequential
# decode would have produced) across self-spec, cross-arch drafts, the
# prefix-cache path, and eos/max_len edge cases
# ---------------------------------------------------------------------------


def test_speculative_matches_paged_dense(params, prompts):
    """The speculation acceptance bar: self-speculative greedy decode
    (draft == target) emits token-for-token what the plain paged engine
    does, while spending strictly fewer target calls per token."""
    plain = _run_engine(
        PagedServeEngine(CFG, params, slots=2, max_len=64, page_size=16),
        prompts, max_new=6,
    )
    spec_eng = PagedServeEngine(CFG, params, slots=2, max_len=64,
                                page_size=16, speculative=True,
                                draft_len=4)
    spec = _run_engine(spec_eng, prompts, max_new=6)
    assert spec.keys() == plain.keys()
    for u in plain:
        assert spec[u] == plain[u], (u, spec[u], plain[u])
    s = spec_eng.metrics.summary()
    # draft == target ⇒ every greedy proposal is reproduced by verify
    assert s["spec_accepted"] > 0
    assert s["spec_acceptance_rate"] >= 0.9
    # the speculation win: > 1 emitted token per per-slot target call
    # (sequential decode is exactly 1.0 by construction)
    assert s["tokens_per_target_call"] > 1.5
    assert s["spec_emitted"] == s["decode_tokens"]
    assert spec_eng.kv.used_pages == 0


@pytest.mark.slow
def test_speculative_cross_arch_draft(params):
    """A different (random-weight) draft architecture proposes mostly
    wrong tokens — acceptance collapses but the verify/correct path must
    still reproduce plain decoding exactly."""
    dcfg = get_config("stablelm-1.6b", smoke=True)
    assert dcfg.vocab == CFG.vocab
    dparams = M.init_params(dcfg, jax.random.PRNGKey(7))
    rng = np.random.RandomState(9)
    prompts = {u: rng.randint(0, CFG.vocab, size=n).astype(np.int32)
               for u, n in enumerate([7, 12, 5])}
    plain = _run_engine(
        PagedServeEngine(CFG, params, slots=2, max_len=64, page_size=16),
        prompts, max_new=6,
    )
    spec_eng = PagedServeEngine(CFG, params, slots=2, max_len=64,
                                page_size=16, speculative=True,
                                draft_cfg=dcfg, draft_params=dparams,
                                draft_len=3)
    spec = _run_engine(spec_eng, prompts, max_new=6)
    for u in plain:
        assert spec[u] == plain[u], (u, spec[u], plain[u])
    s = spec_eng.metrics.summary()
    assert s["spec_steps"] > 0 and s["draft_calls"] > 0
    # rejected drafts cost extra verify positions but never correctness,
    # and the bonus/correction token keeps tokens-per-call at >= 1.0
    assert s["tokens_per_target_call"] >= 1.0


@pytest.mark.slow
def test_speculative_with_prefix_cache(params):
    """Spec decode over COW-shared prompt pages: followers fork the
    donor's pages, draft/verify on top, and match plain decoding."""
    rng = np.random.RandomState(8)
    shared = rng.randint(0, CFG.vocab, size=37).astype(np.int32)
    prompts = {
        0: np.concatenate([shared, rng.randint(0, CFG.vocab, size=13)
                           .astype(np.int32)]),
        1: np.concatenate([shared, rng.randint(0, CFG.vocab, size=9)
                           .astype(np.int32)]),
    }
    plain = _run_engine(
        PagedServeEngine(CFG, params, slots=1, max_len=64, page_size=16),
        prompts, max_new=5,
    )
    eng = PagedServeEngine(CFG, params, slots=1, max_len=64, page_size=16,
                           capacity=8, prefix_cache=True,
                           speculative=True, draft_len=4)
    got = _run_engine(eng, prompts, max_new=5)
    assert got == plain
    s = eng.metrics.summary()
    assert s["prefix_cached_tokens"] == 37
    assert s["spec_accepted"] > 0 and s["tokens_per_target_call"] > 1.0
    # slot pages all freed; only the radix index still holds the donor's
    # three full prompt pages for future reuse
    assert eng.kv.used_pages == eng.kv.pages_needed(48)


def test_speculative_eos_and_max_len(params):
    """eos landing mid-draft truncates the emitted run at eos; max_len
    clamps speculative growth; finished slots leak no pages."""
    prompt = np.arange(9, dtype=np.int32) % CFG.vocab
    eng = PagedServeEngine(CFG, params, slots=1, max_len=32, page_size=8,
                           speculative=True, draft_len=3)
    eng.submit(Request(0, prompt, max_new_tokens=10))
    out = eng.run()[0].output
    # same engine config, eos at the 3rd generated token: a verify round
    # emitting past it must discard the overshoot
    eng2 = PagedServeEngine(CFG, params, slots=1, max_len=32, page_size=8,
                            speculative=True, draft_len=3)
    eng2.submit(Request(1, prompt, max_new_tokens=10, eos_id=out[2]))
    assert eng2.run()[0].output == out[:3]
    eng3 = PagedServeEngine(CFG, params, slots=1, max_len=16, page_size=8,
                            speculative=True, draft_len=3)
    eng3.submit(Request(2, prompt, max_new_tokens=100))
    r3 = eng3.run(max_iters=200)[0]
    assert len(prompt) + len(r3.output) - 1 <= 16 - 1
    for e in (eng, eng2, eng3):
        assert e.kv.used_pages == 0


def test_request_timing_monotonic(params):
    """Timing assertions stay structural — lifecycle ordering, percentile
    ordering, counter consistency — never absolute durations, which flake
    on loaded CI runners."""
    rng = np.random.RandomState(11)
    prompts = {u: rng.randint(0, CFG.vocab, size=n).astype(np.int32)
               for u, n in enumerate([5, 9, 6])}
    eng = PagedServeEngine(CFG, params, slots=2, max_len=64, page_size=8)
    done = _run_engine(eng, prompts, max_new=4)
    m = eng.metrics
    for r in m.requests.values():
        assert r.submit_t <= r.first_token_t <= r.finish_t, r.uid
        assert r.ttft >= 0 and (r.tpot is None or r.tpot >= 0)
    s = m.summary()
    assert 0 <= s["ttft_p50_s"] <= s["ttft_p99_s"]
    assert s["ttft_p50_s"] <= s["ttft_mean_s"] or True  # mean can be < p50
    assert s["wall_s"] > 0 and s["throughput_tok_s"] > 0
    assert m.prefill_rate() >= 0.0
    # counters tie out against the actual outputs (first token comes from
    # prefill; every later token from a decode step)
    assert s["generated_tokens"] == sum(len(o) for o in done.values())
    assert s["decode_tokens"] == sum(len(o) - 1 for o in done.values())
    # same structural guarantees through the speculative lane, plus the
    # spec counters' internal consistency
    es = PagedServeEngine(CFG, params, slots=2, max_len=64, page_size=8,
                          speculative=True, draft_len=3)
    dspec = _run_engine(es, prompts, max_new=4)
    ss = es.metrics.summary()
    for r in es.metrics.requests.values():
        assert r.submit_t <= r.first_token_t <= r.finish_t, r.uid
    assert ss["spec_emitted"] == ss["decode_tokens"] \
        == sum(len(o) - 1 for o in dspec.values())
    assert ss["spec_accepted"] <= ss["spec_proposed"]
    assert ss["spec_steps"] == ss["decode_steps"]
    assert es.metrics.spec_slot_steps >= ss["spec_steps"]


def test_admit_preserves_cache_sharding(params):
    """The _admit slot write must keep the mesh-committed layout instead
    of silently replacing it (regression test for the eager tree-map)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = ServeEngine(CFG, params, slots=2, max_len=32, mesh=mesh)
    committed = {k: leaf.sharding for k, leaf in eng.cache.items()}
    rng = np.random.RandomState(5)
    prompts = {u: rng.randint(0, CFG.vocab, size=6).astype(np.int32)
               for u in range(3)}
    done = _run_engine(eng, prompts, max_new=3)
    assert len(done) == 3
    for k, leaf in eng.cache.items():
        assert leaf.sharding.is_equivalent_to(committed[k], leaf.ndim), k
